"""Online incremental serving correctness (ISSUE 6).

  * the service's served pair/match sets stay BIT-IDENTICAL to a
    from-scratch ``resolve`` over the live entities after any interleaving
    of inserts and deletes — all three variants x {scan, pallas}, including
    micro-batches smaller than the window and deletes inside previously
    delta-matched neighborhoods
  * every result's edits are a consistent delta: prev_served − retired +
    new == served
  * micro-batcher: adjacent same-kind requests coalesce up to
    max_batch/max_wait; a kind change closes the batch (order preserved)
  * steady state: identically-shaped micro-batches are served entirely
    from the executable cache (zero retraces after warm-up)
  * compaction: tombstoned rows + spool files reclaimed, served sets
    unchanged, mutations after compaction stay exact; deleted eids may be
    re-inserted
  * service/index guardrails: duplicate or unknown eids, unsupported
    configs, empty ops
"""
import glob

import numpy as np
import pytest

from repro import api
from repro.core import entities as E
from repro.serve import SortedIndex

N, R, W = 520, 4, 6
VARIANTS = ["srp", "repsn", "jobsn"]
ENGINES = ["scan", "pallas"]


def _cfg(**kw):
    kw.setdefault("window", W)
    kw.setdefault("num_shards", R)
    kw.setdefault("variant", "repsn")
    kw.setdefault("hops", R - 1)
    kw.setdefault("runner", "vmap")
    if kw.get("band_engine") == "pallas":
        kw.setdefault("band_interpret", True)
        kw.setdefault("band_block", 64)
    return api.ERConfig(**kw)


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(11)
    return E.to_host(E.synth_entities(rng, N, n_keys=70, dup_frac=0.25))


def _take(h, sel):
    return E.host_take(h, sel)


def _resolve_live(h_live, cfg):
    dev = E.make_entities(h_live["key"], h_live["eid"],
                          payload=h_live["payload"], valid=h_live["valid"])
    return api.resolve(dev, cfg)


def _assert_parity(svc, corpus, live_mask, cfg):
    ref = _resolve_live(_take(corpus, np.flatnonzero(live_mask)), cfg)
    assert svc.pairs == ref.blocking.pairs
    assert svc.matches == ref.matches


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_interleaved_parity(corpus, variant, engine):
    """The tentpole contract: serve == from-scratch resolve at every point
    of an insert/delete interleaving (small batches, neighborhood-internal
    deletes, skewed order)."""
    cfg = _cfg(variant=variant, band_engine=engine)
    svc = api.serve(cfg, initial=_take(corpus, slice(0, 300)), start=False)
    live = np.zeros(N, bool)
    live[:300] = True
    _assert_parity(svc, corpus, live, cfg)

    prev = svc.pairs
    eid = corpus["eid"]
    ops = [
        ("insert", slice(300, 303)),          # batch far below the window
        ("delete", eid[150:154]),             # inside the initial corpus
        ("insert", slice(303, 380)),
        ("delete", np.concatenate([eid[301:302], eid[320:350]])),
        ("insert", slice(380, 420)),
    ]
    for kind, arg in ops:
        if kind == "insert":
            res = svc.resolve_incremental(_take(corpus, arg))
            live[arg] = True
        else:
            res = svc.delete(arg)
            live[np.isin(eid, arg)] = False
        # the reported edits must BE the delta between served snapshots
        assert (prev - res.retired_pairs) | res.new_pairs == svc.pairs
        assert res.new_pairs.isdisjoint(prev)
        assert res.retired_pairs <= prev
        prev = svc.pairs
        _assert_parity(svc, corpus, live, cfg)


def test_delete_creates_and_insert_retires(corpus):
    """Maintained-set (not monotone-union) semantics: a delete can CREATE
    pairs (survivors pulled together) and an insert can RETIRE pairs
    (old neighbors pushed beyond w-1)."""
    cfg = _cfg()
    svc = api.serve(cfg, initial=_take(corpus, slice(0, 300)), start=False)
    # delete a contiguous run of mid-corpus ranks: entities on both sides
    # of the hole move within w-1 of each other
    mid = svc.index.eids_at_ranks(140, 160)
    res = svc.delete(mid)
    assert res.new_pairs, "delete should pull survivors into the window"
    # re-inserting the same entities must push those pairs back out
    rows = np.flatnonzero(np.isin(corpus["eid"][:300], mid))
    res2 = svc.resolve_incremental(_take(corpus, rows))
    assert res2.retired_pairs >= res.new_pairs


def test_microbatcher_coalesces_and_preserves_order(corpus):
    cfg = _cfg()
    svc = api.serve(cfg, initial=_take(corpus, slice(0, 200)),
                    max_batch=400, max_wait_ms=250.0)
    try:
        futs = [svc.submit_insert(_take(corpus, slice(200 + 5 * i,
                                                      205 + 5 * i)))
                for i in range(6)]
        res = [f.result() for f in futs]
        # all six tiny inserts ride ONE delta call
        assert all(r.batched == 6 for r in res)
        assert res[0] is res[5]
        # a kind change closes the batch: delete of a just-inserted eid
        # must see it live
        fi = svc.submit_insert(_take(corpus, slice(230, 240)))
        fd = svc.submit_delete(corpus["eid"][232:234])
        fi.result(), fd.result()
        live = np.zeros(N, bool)
        live[:240] = True
        live[232:234] = False
        _assert_parity(svc, corpus, live, cfg)
        st = svc.stats()
        # 1 bootstrap + 6 coalesced + insert + delete
        assert st.requests == 9 and st.batches <= 4
        assert st.p95_ms >= st.p50_ms > 0.0
    finally:
        svc.close()
    with pytest.raises(RuntimeError):
        svc.resolve_incremental(_take(corpus, slice(240, 241)))


def test_steady_state_is_zero_retrace(corpus):
    """Shape bucketing: after warm-up, identically-sized micro-batches are
    pure executable-cache hits — traces must not grow with requests."""
    cfg = _cfg()
    svc = api.serve(cfg, initial=_take(corpus, slice(0, 300)), start=False)
    for i in range(3):                                   # warm the buckets
        svc.resolve_incremental(_take(corpus, slice(300 + 10 * i,
                                                    310 + 10 * i)))
    warm = svc.stats()
    for i in range(3, 8):
        res = svc.resolve_incremental(_take(corpus, slice(300 + 10 * i,
                                                          310 + 10 * i)))
    st = res.stats
    assert st.traces == warm.traces
    assert st.cache_misses == warm.cache_misses
    assert st.cache_hits > warm.cache_hits
    assert st.steady_batches - warm.steady_batches == 5
    assert len(st.shapes) == len(warm.shapes)


def test_compaction_reclaims_and_preserves(corpus, tmp_path):
    cfg = _cfg(num_shards=2, hops=1)
    spool = str(tmp_path / "serve")
    svc = api.serve(cfg, initial=_take(corpus, slice(0, 200)), start=False,
                    spool_dir=spool, segment_rows=64, max_runs=3,
                    max_tombstone_frac=0.1)
    live = np.zeros(N, bool)
    live[:200] = True
    for i in range(5):
        svc.resolve_incremental(_take(corpus, slice(200 + 20 * i,
                                                    220 + 20 * i)))
    live[200:300] = True
    gone = corpus["eid"][10:40]
    svc.delete(gone)
    live[10:40] = False
    st = svc.stats()
    assert st.compactions >= 1
    assert st.tombstones == 0 and st.index_rows == st.live_entities
    # old-generation spool files are actually deleted
    assert all("g000" not in p for p in glob.glob(spool + "/*.npz"))
    _assert_parity(svc, corpus, live, cfg)
    # a deleted eid is re-insertable, and mutations after compaction are
    # still exact
    svc.resolve_incremental(_take(corpus, slice(10, 25)))
    live[10:25] = True
    _assert_parity(svc, corpus, live, cfg)


def test_service_guardrails(corpus):
    with pytest.raises(ValueError):
        api.serve(_cfg(passes=("key",)))
    with pytest.raises(ValueError):
        api.serve(_cfg(linkage=True))
    with pytest.raises(ValueError):
        api.serve(_cfg(return_scores=True))
    svc = api.serve(_cfg(), initial=_take(corpus, slice(0, 100)),
                    start=False)
    with pytest.raises(ValueError):            # live-eid collision
        svc.resolve_incremental(_take(corpus, slice(50, 60)))
    with pytest.raises(ValueError):            # unknown delete
        svc.delete(np.asarray([999999], np.int64))
    before = svc.pairs
    empty = _take(corpus, np.zeros((0,), np.int64))
    assert svc.resolve_incremental(empty).new_pairs == frozenset()
    assert svc.pairs == before
    # failed requests leave the state untouched
    _assert_parity(svc, corpus,
                   np.arange(N) < 100, _cfg())


def test_delete_all_then_rebuild(corpus):
    cfg = _cfg(num_shards=2, hops=1)
    svc = api.serve(cfg, initial=_take(corpus, slice(0, 60)), start=False)
    svc.delete(corpus["eid"][:60])
    assert svc.pairs == frozenset() and svc.stats().live_entities == 0
    svc.resolve_incremental(_take(corpus, slice(30, 90)))
    live = np.zeros(N, bool)
    live[30:90] = True
    _assert_parity(svc, corpus, live, cfg)


def test_pair_ids_are_stable(corpus):
    svc = api.serve(_cfg(), initial=_take(corpus, slice(0, 300)),
                    start=False)
    mid = svc.index.eids_at_ranks(140, 160)
    res = svc.delete(mid)
    created = next(iter(res.new_pairs))
    pid = res.pair_ids[created]
    rows = np.flatnonzero(np.isin(corpus["eid"][:300], mid))
    svc.resolve_incremental(_take(corpus, rows))     # retires it again
    res3 = svc.delete(mid)                           # ...and re-creates it
    assert res3.pair_ids[created] == pid
    assert svc.pair_id(created) == pid


def test_sorted_index_units(tmp_path):
    rng = np.random.default_rng(3)
    idx = SortedIndex(W, spool_dir=str(tmp_path / "idx"))
    h = E.to_host(E.synth_entities(rng, 100, n_keys=20))
    dev = E.make_entities(h["key"], h["eid"], payload=h["payload"],
                          valid=h["valid"])
    run = E.sort_chunk(dev)
    idx.insert(run)
    assert idx.n_live == 100
    # the flat rank index is the (key, eid) sort order
    assert np.array_equal(idx.live_comps, np.sort(idx.live_comps))
    comps = idx.comps_of(h["eid"][:5])
    ranks = np.searchsorted(idx.live_comps, comps)
    assert np.array_equal(idx.eids_at_ranks(int(ranks[0]),
                                            int(ranks[0]) + 1),
                          np.asarray(h["eid"][:1], np.int64))
    # a comp-range materialization returns exactly the ranks' entities
    region = idx.take_comp_range(int(idx.live_comps[10]),
                                 int(idx.live_comps[19]))
    assert np.array_equal(np.asarray(region["eid"], np.int64),
                          idx.eids_at_ranks(10, 20))
    with pytest.raises(ValueError):
        idx.insert(run)                            # duplicate eids
    idx.delete(h["eid"][:10])
    with pytest.raises(ValueError):
        idx.comps_of(h["eid"][:1])                 # tombstoned
    assert idx.n_live == 90 and idx.tombstones == 10
    # profile decrement is exact: equals profiling the survivors
    from repro import balance as B
    surv = np.asarray(run["key"])[~np.isin(np.asarray(run["eid"], np.int64),
                                           np.asarray(h["eid"][:10],
                                                      np.int64))]
    q = B.profile_keys(surv, window=W)
    assert np.array_equal(idx.profile.uniq, q.uniq)
    assert np.array_equal(idx.profile.counts, q.counts)
    idx.compact()
    assert idx.tombstones == 0 and idx.n_rows == idx.n_live == 90
    assert np.array_equal(idx.profile.uniq, q.uniq)
