"""Property tests for the paper's core claims (deterministic parametrized
cases — no hypothesis dependency, so tier-1 always runs them).

  INV1 (pair completeness): RepSN and JobSN produce EXACTLY the sequential
        SN pair set — the paper's correctness claim for both variants.
  INV2 (SRP miss formula): SRP alone misses exactly (r-1)*w*(w-1)/2 boundary
        pairs when every partition holds >= w-1 entities (paper §4.1).
  INV3 (replication bound): RepSN replicates at most (r-1)*(w-1) entities
        (paper §4.3 bounds m*(r-1)*(w-1) across mappers; post-SRP our halo is
        exactly <= (r-1)*(w-1) replicas).
  INV4 (multi-hop halo): with hops=r-1, RepSN is complete even when
        partitions are smaller than the window (beyond-paper robustness) —
        folded into INV1's random keys.
  INV5 (monotone partitioning): shard loads are permutation-invariant wrt
        mapper assignment, and no entity is lost when capacity suffices.

All parallel runs go through the ``repro.api`` facade (vmap runner); raw
shard output (halos, band masks) comes from ``VmapRunner.run_raw``.
"""
import numpy as np
import pytest

from repro import api
from repro.core import entities as E
from repro.core import partition as P
from repro.core import sn

SEED_GRID = [(40, 2, 2, 16, 0), (97, 4, 3, 64, 1), (200, 8, 8, 256, 2),
             (150, 4, 5, 16, 3), (64, 8, 4, 64, 4), (123, 2, 7, 256, 5)]


def _ents(rng, n, n_keys, skew=0.0):
    return E.synth_entities(rng, n, n_keys=n_keys, dup_frac=0.2, skew=skew)


@pytest.mark.parametrize("n,r,w,n_keys,seed", SEED_GRID)
def test_inv1_pair_completeness(n, r, w, n_keys, seed):
    rng = np.random.default_rng(seed)
    ents = _ents(rng, n, n_keys)
    keys, eids = np.asarray(ents["key"]), np.asarray(ents["eid"])
    oracle = sn.sequential_sn_pairs(keys, eids, w)
    bounds = P.range_partition(n_keys, r)
    # hops=r-1 guarantees completeness even for partitions < w (INV4 folded
    # in: random keys can make partitions arbitrarily small).
    for variant, hops in [("repsn", r - 1), ("jobsn", 1)]:
        res = api.resolve(ents, api.ERConfig(
            window=w, variant=variant, hops=hops, runner="vmap",
            num_shards=r), bounds=bounds)
        got = set(res.blocking.pairs)
        if variant == "jobsn":
            # JobSN is paper-faithful single-boundary: only assert equality
            # when every partition holds >= w-1 entities (paper assumption).
            if all(l >= w - 1 for l in res.blocking.load):
                assert got == oracle
            else:
                assert got <= oracle
        else:
            assert got == oracle, (len(got), len(oracle))
        assert res.blocking.overflow == 0


@pytest.mark.parametrize("seed,r,w", [(0, 2, 2), (1, 4, 3), (2, 4, 6),
                                      (3, 2, 5), (4, 4, 4)])
def test_inv2_srp_miss_formula(seed, r, w):
    rng = np.random.default_rng(seed)
    n_keys = 64
    # dense key coverage => every partition has plenty of entities
    n = 40 * r + w * r
    ents = _ents(rng, n, n_keys)
    keys, eids = np.asarray(ents["key"]), np.asarray(ents["eid"])
    bounds = P.range_partition(n_keys, r)
    sizes = np.asarray(P.partition_sizes(bounds, ents["key"], r=r))
    if not (sizes >= w).all():
        pytest.skip("formula precondition: partitions >= w")
    oracle = sn.sequential_sn_pairs(keys, eids, w)
    res = api.resolve(ents, api.ERConfig(window=w, variant="srp",
                                         runner="vmap", num_shards=r),
                      bounds=bounds)
    got = set(res.blocking.pairs)
    assert len(oracle - got) == sn.srp_missed_boundary_pairs(r, w)
    assert not (got - oracle)


@pytest.mark.parametrize("seed", range(5))
def test_inv3_replication_bound(seed):
    rng = np.random.default_rng(seed)
    n, r, w, n_keys = 120, 4, 5, 64
    ents = _ents(rng, n, n_keys)
    cfg = api.ERConfig(window=w, variant="repsn", runner="vmap",
                       num_shards=r)
    out = api.VmapRunner(r).run_raw(ents, P.range_partition(n_keys, r), cfg)
    halo_valid = np.asarray(out["main"]["ents"]["valid"])[:, :w - 1]
    assert halo_valid.sum() <= (r - 1) * (w - 1)


@pytest.mark.parametrize("n,seed,skew", [(30, 0, 0.0), (77, 1, 0.5),
                                         (120, 2, 0.85), (64, 3, 0.5)])
def test_inv5_no_entity_lost(n, seed, skew):
    rng = np.random.default_rng(seed)
    n_keys, r = 32, 4
    ents = _ents(rng, n, n_keys, skew=skew)
    cfg = api.ERConfig(window=4, variant="srp", runner="vmap", num_shards=r)
    out = api.VmapRunner(r).run_raw(ents, P.range_partition(n_keys, r), cfg)
    assert int(out["overflow"][0]) == 0
    # every input eid appears exactly once across shards
    sh_ents = out["main"]["ents"]
    valid = np.asarray(sh_ents["valid"])
    eids = np.asarray(sh_ents["eid"])[valid]
    assert sorted(eids.tolist()) == list(range(n))
    # per-shard keys sorted and shard ranges ordered (SRP property)
    keys = np.asarray(sh_ents["key"])
    prev_max = -1
    for s in range(r):
        ks = keys[s][valid[s]]
        assert (np.diff(ks) >= 0).all()
        if len(ks):
            assert ks[0] >= prev_max or prev_max == -1
            prev_max = max(prev_max, ks[-1])


def test_overflow_counted_exactly():
    rng = np.random.default_rng(0)
    n, r, w, n_keys = 128, 4, 3, 16
    ents = E.synth_entities(rng, n, n_keys=n_keys, skew=0.9)
    res = api.resolve(ents, api.ERConfig(
        window=w, variant="srp", cap_factor=1.0, runner="vmap",
        num_shards=r), bounds=P.range_partition(n_keys, r))
    assert res.blocking.overflow > 0          # skewed keys must overflow
    assert res.blocking.total_load + res.blocking.overflow == n


def test_gini_matches_paper_values_shape():
    """Ordering sanity for the paper's Table 1: more skew => larger g."""
    rng = np.random.default_rng(0)
    n, n_keys, r = 20_000, 512, 8
    gs = []
    for hot in [0.0, 0.4, 0.55, 0.7, 0.85]:
        ents = E.synth_entities(rng, n, n_keys=n_keys, skew=hot)
        sizes = P.partition_sizes(P.range_partition(n_keys, r),
                                  ents["key"], r=r)
        gs.append(P.gini(np.asarray(sizes)))
    assert all(b > a - 1e-9 for a, b in zip(gs, gs[1:])), gs
    assert gs[-1] > 0.5


def test_sample_partition_balances_moderate_skew():
    """Beyond-paper equi-depth splitters (device-side quantiles): beats the
    even key-space split when the distribution is skewed but no single key
    dominates."""
    rng = np.random.default_rng(0)
    n, n_keys, r = 20_000, 512, 8
    keys = (rng.zipf(1.5, size=n) % n_keys).astype(np.int32)
    ents = E.make_entities(keys, np.arange(n, dtype=np.int32))
    even = P.partition_sizes(P.range_partition(n_keys, r), ents["key"], r=r)
    smart = P.partition_sizes(
        P.sample_partition(ents["key"], r), ents["key"], r=r)
    assert P.gini(np.asarray(smart)) < P.gini(np.asarray(even))


def test_balanced_partition_hot_key():
    """Greedy histogram splitter handles a dominant key: every other shard
    stays near the even share (the hot key's own shard is irreducible —
    MapReduce-inherent, paper §5.3)."""
    rng = np.random.default_rng(0)
    n, n_keys, r = 20_000, 512, 8
    ents = E.synth_entities(rng, n, n_keys=n_keys, skew=0.85)
    keys = np.asarray(ents["key"])
    bounds = P.balanced_partition(keys, r)
    sizes = np.asarray(P.partition_sizes(bounds, ents["key"], r=r))
    non_hot = np.sort(sizes)[:-1]
    assert non_hot.max() <= 2 * (n * 0.15) / (r - 1) + 5
    g_even = P.gini(np.asarray(P.partition_sizes(
        P.range_partition(n_keys, r), ents["key"], r=r)))
    assert P.gini(sizes) <= g_even + 1e-9
