"""Planner invariants for the repro.balance subsystem (ISSUE 3).

  * the profile (analysis job) reproduces the closed-form SN pair counts
  * every entity is assigned to exactly one shard by every planner
  * plans are deterministic functions of the profile
  * halo/boundary pairs are never lost vs the sequential oracle — every
    planner, scan AND pallas band engines
  * rank-granular (dest) plans keep SRP's parallel/sequential semantics
    aligned, and blocksplit actually splits an oversized key block
  * overflow stays an ACCOUNTED outcome when an explicit cap_factor beats
    the planned capacity; configurations that would silently truncate a
    halo are rejected with actionable errors
"""
import numpy as np
import pytest

from repro import api
from repro import balance as B
from repro.core import sn
from repro.data.corpus import zipf_entities

N, R, W = 1400, 8, 8
PLANNERS = ["uniform", "blocksplit", "pairrange"]


@pytest.fixture(scope="module")
def ents():
    return zipf_entities(7, N, n_clusters=64, exponent=1.1, dup_frac=0.25)


@pytest.fixture(scope="module")
def oracle(ents):
    keys = np.asarray(ents["key"])
    eids = np.asarray(ents["eid"])
    return sn.sequential_sn_pairs(keys, eids, W)


def _cfg(**kw):
    kw.setdefault("window", W)
    kw.setdefault("num_shards", R)
    kw.setdefault("variant", "repsn")
    kw.setdefault("hops", R - 1)
    kw.setdefault("runner", "vmap")
    return api.ERConfig(**kw)


def test_profile_matches_closed_form(ents):
    keys = np.asarray(ents["key"])
    prof = B.profile_keys(keys, window=W)
    assert prof.n == N
    assert int(prof.counts.sum()) == N
    assert prof.total_comparisons == sn.expected_pair_count(N, W)
    assert (np.diff(prof.uniq) > 0).all()
    assert (np.diff(prof.cum_entities) > 0).all()
    np.testing.assert_array_equal(prof.halo_cost,
                                  np.minimum(prof.cum_entities, W - 1))
    # per-block comparisons sum back to the total and are all non-negative
    assert int(prof.block_comparisons.sum()) == prof.total_comparisons
    assert (prof.block_comparisons >= 0).all()


@pytest.mark.parametrize("planner", PLANNERS + ["balanced"])
def test_every_entity_assigned_exactly_once(ents, planner):
    plan = B.plan_shards(ents, _cfg(partitioner=planner), R)
    assign = plan.assignment(np.asarray(ents["key"]),
                             np.asarray(ents["valid"]))
    assert assign.shape == (N,)
    assert assign.min() >= 0 and assign.max() < R
    counts = np.bincount(assign, minlength=R)
    assert counts.sum() == N
    if plan.planned_load is not None:
        np.testing.assert_array_equal(counts, plan.planned_load)
        assert int(plan.planned_comparisons.sum()) == \
            sn.expected_pair_count(N, W)
    # monotone in the global (key, eid) sort: shard ids never decrease
    keys = np.asarray(ents["key"])
    eids = np.asarray(ents["eid"])
    order = np.lexsort((eids, keys))
    assert (np.diff(assign[order]) >= 0).all()


@pytest.mark.parametrize("planner", PLANNERS)
def test_plans_deterministic(ents, planner):
    a = B.plan_shards(ents, _cfg(partitioner=planner), R)
    b = B.plan_shards(ents, _cfg(partitioner=planner), R)
    np.testing.assert_array_equal(a.bounds, b.bounds)
    np.testing.assert_array_equal(a.rank_bounds, b.rank_bounds)
    np.testing.assert_array_equal(a.planned_load, b.planned_load)
    assert a.cap_link == b.cap_link
    if a.dest is None:
        assert b.dest is None
    else:
        np.testing.assert_array_equal(a.dest, b.dest)


def test_balance_planners_beat_uniform(ents):
    imb = {p: B.plan_shards(ents, _cfg(partitioner=p), R).imbalance
           for p in PLANNERS}
    # Zipfian hot-head corpus: uniform key ranges pile work on shard 0
    assert imb["uniform"] > 3.0 * imb["blocksplit"]
    assert imb["uniform"] > 3.0 * imb["pairrange"]
    assert imb["pairrange"] < 1.1


@pytest.mark.parametrize("engine", ["scan", "pallas"])
@pytest.mark.parametrize("planner", PLANNERS)
def test_no_pairs_lost_vs_oracle(ents, oracle, planner, engine):
    """Halo/boundary pairs are never lost: every planner x band engine
    reproduces the sequential SN oracle exactly (repsn, hops=r-1)."""
    cfg = _cfg(partitioner=planner, band_engine=engine,
               band_interpret=True if engine == "pallas" else None)
    res = api.resolve(ents, cfg)
    assert set(res.blocking.pairs) == oracle, (planner, engine)
    assert res.blocking.overflow == 0
    assert res.balance is not None
    assert res.balance.realized_load == res.balance.planned_load


def test_engines_agree_on_matches(ents):
    cfg = _cfg(partitioner="blocksplit")
    scan = api.resolve(ents, cfg)
    pal = api.resolve(ents, cfg.with_(band_engine="pallas",
                                      band_interpret=True))
    assert pal.blocking.pairs == scan.blocking.pairs
    assert pal.matches == scan.matches


def test_srp_plan_parity_vmap_vs_sequential(ents):
    """Rank-granular plans change WHICH pairs SRP misses; parallel and
    sequential runs must still agree when given the same plan."""
    cfg = _cfg(variant="srp", partitioner="pairrange")
    plan = B.plan_shards(ents, cfg, R)
    assert plan.dest is not None
    vm = api.resolve(ents, cfg, bounds=plan)
    seq = api.resolve(ents, cfg.with_(runner="sequential"), bounds=plan)
    assert vm.blocking.pairs == seq.blocking.pairs
    assert vm.matches == seq.matches


def test_blocksplit_splits_oversized_block():
    """One key holding ~45% of the corpus: unsplittable by any key-bounds
    partitioner, so blocksplit must go rank-granular — and still lose
    nothing vs the oracle."""
    ents = zipf_entities(3, N, n_clusters=40, exponent=2.2, dup_frac=0.0)
    keys = np.asarray(ents["key"])
    hot_count = int(np.bincount(keys).max())
    assert hot_count > N // R                   # genuinely oversized
    cfg = _cfg(partitioner="blocksplit")
    plan = B.plan_shards(ents, cfg, R)
    assert plan.dest is not None                # a block was split
    assert plan.planned_comparisons.max() < \
        B.profile_keys(keys, window=W).block_comparisons.max()
    res = api.resolve(ents, cfg)
    want = sn.sequential_sn_pairs(keys, np.asarray(ents["eid"]), W)
    assert set(res.blocking.pairs) == want
    # vs the best any key-bounds plan can do, the hot shard is now level
    bal = B.plan_shards(ents, _cfg(partitioner="balanced"), R)
    assert plan.imbalance < bal.imbalance


def test_overflow_accounted_when_cap_factor_overrides(ents):
    """Explicit cap_factor beats the planned capacity (historical
    semantics): a too-tight cap overflows, counted — never silent."""
    cfg = _cfg(partitioner="blocksplit", variant="srp", cap_factor=0.6)
    res = api.resolve(ents, cfg)
    assert res.blocking.overflow > 0
    assert res.blocking.total_load + res.blocking.overflow == N
    roomy = api.resolve(ents, cfg.with_(cap_factor=0.0))
    assert roomy.blocking.overflow == 0
    assert roomy.blocking.total_load == N


def test_planned_capacity_never_overflows(ents):
    """The plan's cap_link is exact: cap_factor=0 runs under it with zero
    overflow and a much smaller padded band than the legacy full capacity."""
    cfg = _cfg(partitioner="pairrange")
    plan = B.plan_shards(ents, cfg, R)
    assert plan.cap_link is not None
    assert R * plan.cap_link >= W - 1           # halo slice stays legal
    assert R * plan.cap_link < N                # genuinely smaller band
    res = api.resolve(ents, cfg)
    assert res.blocking.overflow == 0


def test_num_shards_exceeding_entities_rejected():
    tiny = zipf_entities(0, 5, n_clusters=4, exponent=1.0, dup_frac=0.0)
    with pytest.raises(ValueError, match="exceeds the entity count"):
        api.resolve(tiny, _cfg(num_shards=8, hops=7))
    # the sequential runner takes its partition count from the bounds, not
    # cfg.num_shards: explicit 2-partition bounds stay valid at any r
    res = api.resolve(tiny, _cfg(window=3, num_shards=8, hops=1,
                                 runner="sequential"),
                      bounds=np.asarray([1 << 18], np.int32))
    assert sum(res.blocking.load) == 5
    with pytest.raises(ValueError, match="partitions"):
        api.resolve(tiny, _cfg(window=3, num_shards=8, hops=1,
                               runner="sequential"),
                    bounds=np.arange(1, 8, dtype=np.int32))


def test_runner_rejects_mismatched_raw_bounds(ents):
    """Direct runner calls (bypassing the facade) still catch a partition/
    shard mismatch — entities routed past the last shard would vanish
    without even an overflow count."""
    cfg = _cfg(variant="srp", num_shards=4)
    with pytest.raises(ValueError, match="partitions"):
        api.VmapRunner(4).resolve(ents, np.arange(1, 12, dtype=np.int32),
                                  cfg)


def test_halo_truncation_rejected():
    """A plan whose shards are smaller than the window needs more halo hops
    than configured — silently losing boundary pairs is rejected."""
    ents = zipf_entities(1, 40, n_clusters=16, exponent=0.5, dup_frac=0.0)
    with pytest.raises(ValueError, match="hops"):
        api.resolve(ents, _cfg(window=12, num_shards=8, hops=1,
                               partitioner="pairrange"))
    # the suggested fix works and loses nothing
    res = api.resolve(ents, _cfg(window=12, num_shards=8, hops=7,
                                 partitioner="pairrange"))
    want = sn.sequential_sn_pairs(np.asarray(ents["key"]),
                                  np.asarray(ents["eid"]), 12)
    assert set(res.blocking.pairs) == want
    # jobsn has no hops lever: tiny interior shards are rejected outright
    with pytest.raises(ValueError, match="jobsn|JobSN"):
        api.resolve(ents, _cfg(window=12, num_shards=8, variant="jobsn",
                               partitioner="pairrange"))
    # legacy partitioners are profile-backed too: the same silent
    # truncation is rejected the same way
    with pytest.raises(ValueError, match="hops"):
        api.resolve(ents, _cfg(window=12, num_shards=8, hops=1,
                               partitioner="balanced"))


def test_registered_partitioner_usable_through_config(ents):
    """The planner registry is a first-class config surface: a custom
    planner selects through ERConfig like the built-ins."""
    from repro.balance.planners import _PLANNERS, PairRangePartitioner

    @B.register_partitioner("pairrange_test_alias")
    class AliasPlanner(PairRangePartitioner):
        pass

    try:
        res = api.resolve(ents, _cfg(partitioner="pairrange_test_alias"))
        ref = api.resolve(ents, _cfg(partitioner="pairrange"))
        assert res.blocking.pairs == ref.blocking.pairs
        assert res.balance.planned_load == ref.balance.planned_load
    finally:
        _PLANNERS.pop("pairrange_test_alias", None)
    with pytest.raises(ValueError, match="unknown partitioner"):
        api.ERConfig(partitioner="pairrange_test_alias")


def test_balance_metrics_surface(ents):
    cfg = _cfg(partitioner="blocksplit", compute_metrics=True)
    res = api.resolve(ents, cfg)
    bal = res.balance
    assert bal is not None and res.metrics.balance is bal
    assert sum(bal.realized_load) == N
    assert len(bal.planned_comparisons) == R
    assert bal.imbalance_realized >= 1.0
    assert 0 <= bal.straggler_shard < R
    assert bal.partitioner == "blocksplit"
    assert res.metrics.pairs_completeness == 1.0
    # explicit raw bounds carry no plan: no balance telemetry
    raw = api.resolve(ents, cfg.with_(compute_metrics=False),
                      bounds=api.default_bounds(ents, cfg, R))
    assert raw.balance is None


def test_explicit_plan_equals_derived(ents):
    cfg = _cfg(partitioner="blocksplit")
    plan = B.plan_shards(ents, cfg, R)
    a = api.resolve(ents, cfg)
    b = api.resolve(ents, cfg, bounds=plan)
    assert a.blocking.pairs == b.blocking.pairs
    assert a.matches == b.matches
    assert a.balance == b.balance


def _profiles_equal(a, b):
    return (a.n == b.n and a.window == b.window
            and np.array_equal(a.uniq, b.uniq)
            and np.array_equal(a.counts, b.counts)
            and np.array_equal(a.cum_entities, b.cum_entities)
            and np.array_equal(a.block_comparisons, b.block_comparisons)
            and np.array_equal(a.cum_comparisons, b.cum_comparisons))


def test_profile_merge_remove_roundtrip(ents):
    """The serve-layer delete path: a.merge(b).merge(b, remove=True) is
    bit-for-bit ``a`` — every derived column, not just the counts."""
    keys = np.asarray(ents["key"])
    a = B.profile_keys(keys[:900], window=W)
    b = B.profile_keys(keys[900:], window=W)
    merged = a.merge(b)
    assert _profiles_equal(merged, B.profile_keys(keys, window=W))
    assert _profiles_equal(merged.merge(b, remove=True), a)
    # removing a whole profile reaches the exact empty identity
    gone = merged.merge(a, remove=True).merge(b, remove=True)
    assert gone.n == 0 and gone.n_blocks == 0
    # removed key blocks are reclaimed, not kept at count zero
    only_a = merged.merge(b, remove=True)
    assert only_a.n_blocks == a.n_blocks


def test_profile_remove_rejects_overdraw(ents):
    keys = np.asarray(ents["key"])
    a = B.profile_keys(keys[:100], window=W)
    with pytest.raises(ValueError, match="over-removed"):
        a.merge(B.profile_keys(keys[:200], window=W), remove=True)
    # a key the profile never held is an overdraw too
    alien = B.profile_keys(np.asarray([2 ** 29], np.int32), window=W)
    with pytest.raises(ValueError, match="over-removed"):
        a.merge(alien, remove=True)


def test_suggest_caps_never_overflow(ents):
    """Profile-derived capacities replace the manual probe loop: under the
    suggested caps an emit='pairs' resolve of skewed data must not
    overflow and must keep the exact pair set."""
    cfg = _cfg(partitioner="blocksplit", emit="pairs")
    prof = B.profile_keys(np.asarray(ents["key"]), window=W)
    caps = B.suggest_caps(prof, cfg)
    assert caps.pair_cap == (W - 1) * caps.max_load + 16
    capped = api.resolve(ents, cfg.with_(cand_cap=caps.cand_cap,
                                         pair_cap=caps.pair_cap))
    free = api.resolve(ents, cfg)
    assert capped.blocking.pair_overflow == 0
    assert capped.blocking.pairs == free.blocking.pairs
    assert capped.matches == free.matches
    # observed survivor counts tighten cand_cap below the band bound
    probe = api.resolve(ents, cfg.with_(band_engine="pallas",
                                        band_interpret=True))
    tight = B.suggest_caps(prof, cfg, observed_cand=probe.blocking.cand_count)
    assert tight.cand_cap <= caps.cand_cap
    assert tight.pair_cap == caps.pair_cap
    with pytest.raises(ValueError, match="empty profile"):
        B.suggest_caps(B.KeyProfile.empty(W), cfg)
    explicit = B.suggest_caps(B.KeyProfile.empty(W), cfg, max_load=128)
    assert explicit.pair_cap == (W - 1) * 128 + 16
