"""Data pipeline: dedup stage behaviour + deterministic batching."""
import numpy as np

from repro.data.corpus import (TokenBatcher, dedup_corpus, doc_entities,
                               synth_corpus)


def test_dedup_finds_planted_duplicates():
    docs = synth_corpus(0, 1024, doc_len=32, vocab=500, dup_frac=0.3)
    res = dedup_corpus(docs, r=4, window=10, threshold=0.95)
    # exact duplicates share identical signatures+features -> must be found
    assert res.n_dropped > 0
    assert res.overflow == 0
    # survivors should contain at most one copy of each exact-dup group
    kept = docs[res.keep]
    uniq = np.unique(kept, axis=0)
    dup_left = len(kept) - len(uniq)
    total_dups = len(docs) - len(np.unique(docs, axis=0))
    assert dup_left < total_dups * 0.35, (dup_left, total_dups)


def test_dedup_never_drops_all():
    docs = synth_corpus(1, 256, doc_len=16, vocab=100, dup_frac=0.9)
    res = dedup_corpus(docs, r=2, window=6)
    assert res.keep.sum() >= len(np.unique(docs, axis=0)) * 0.5


def test_batcher_deterministic_and_resumable():
    docs = synth_corpus(2, 128, doc_len=64, vocab=1000)
    b1 = TokenBatcher(docs, seq_len=64, global_batch=4, seed=3)
    b2 = TokenBatcher(docs, seq_len=64, global_batch=4, seed=3)
    for step in [0, 5, 17]:
        np.testing.assert_array_equal(b1.batch(step)["tokens"],
                                      b2.batch(step)["tokens"])
    # labels are next-token shifted with -1 tail mask
    b = b1.batch(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["labels"][:, -1] == -1).all()


def test_doc_entities_shapes():
    docs = synth_corpus(0, 64, doc_len=32, vocab=500)
    ents = doc_entities(docs)
    assert ents["key"].shape == (64,)
    assert (np.asarray(ents["key"]) >= 0).all()
    assert ents["payload"]["sig"].dtype.name == "uint32"
    f = np.asarray(ents["payload"]["feat"])
    np.testing.assert_allclose(np.linalg.norm(f, axis=1), 1.0, atol=1e-3)
