"""Fault tolerance (DESIGN.md §11, invariant 11): checkpointed resumable
streaming, overflow-recovery retries, and serve durability.

  * kill-at-every-chunk-boundary property: for EVERY chunk index k (clean
    kill after commit AND torn kill between spool and commit), all three
    variants x {scan, pallas}, the resumed pair union is bit-identical to
    an uninterrupted monolithic resolve
  * mid-ingest kills resume by re-supplying the iterator; config /
    chunk-size drift across a resume is rejected loudly
  * overflow recovery: ``on_overflow="retry"`` re-executes with doubled
    caps and drops ZERO pairs; "count" keeps the legacy counters; "raise"
    and an exhausted ladder raise ``CapacityOverflowError``
  * auto caps: unset (None) caps size from the key profile on
    profile-backed plans; explicit caps always win; legacy partitioners
    keep the historical unbounded semantics
  * ChunkStore atomic appends + attach/dispose crash hygiene
  * serve durability: index/service snapshot-restore parity, worker
    failure surfacing, graceful close(drain=True)
"""
import os

import numpy as np
import pytest

from repro import api, stream
from repro.core import entities as E
from repro.resilience import (CapacityOverflowError, FaultPlan,
                              InjectedFault, flaky_chunks, micro_caps,
                              resume_stream)
from repro.stream.store import ChunkStore, atomic_savez

N, R, W = 360, 4, 6
CHUNK = 60
VARIANTS = ["srp", "repsn", "jobsn"]
ENGINES = ["scan", "pallas"]


def _cfg(**kw):
    kw.setdefault("window", W)
    kw.setdefault("num_shards", R)
    kw.setdefault("variant", "repsn")
    kw.setdefault("hops", R - 1)
    kw.setdefault("runner", "vmap")
    return api.ERConfig(**kw)


@pytest.fixture(scope="module")
def ents():
    rng = np.random.default_rng(11)
    return E.synth_entities(rng, N, n_keys=60, dup_frac=0.25, text_len=8)


def _chunks(ents, sz=CHUNK):
    h = E.to_host(ents)
    n = int(h["key"].shape[0])
    return [E.host_take(h, slice(s, min(s + sz, n)))
            for s in range(0, n, sz)]


# -- kill/resume parity -------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_kill_at_every_chunk_boundary(tmp_path, ents, variant, engine):
    """Property: killing the stream at ANY chunk k — cleanly after the
    commit or torn between spool and commit — and resuming yields the
    bit-identical pair union of an uninterrupted monolithic resolve."""
    cfg = _cfg(variant=variant, band_engine=engine)
    ref = api.resolve(ents, cfg)
    n_chunks = (N + CHUNK - 1) // CHUNK
    for k in range(n_chunks):
        # alternate the crash kind so both commit seams get every index
        fault = FaultPlan(crash_after_chunk=k) if k % 2 == 0 \
            else FaultPlan(crash_before_commit=k)
        d = str(tmp_path / f"{variant}-{engine}-{k}")
        with pytest.raises(InjectedFault):
            stream.resolve_stream(_chunks(ents), cfg, chunk_size=CHUNK,
                                  checkpoint_dir=d, fault_plan=fault)
        res = api.resume(d)
        assert res.pairs == ref.pairs, (variant, engine, k)
        assert res.matches == ref.matches, (variant, engine, k)
        assert res.stream.chunks == n_chunks


def test_mid_ingest_kill_resumes_with_fresh_iterator(tmp_path, ents):
    cfg = _cfg()
    ref = stream.resolve_stream(_chunks(ents), cfg, chunk_size=CHUNK)
    d = str(tmp_path / "ingest")
    with pytest.raises(InjectedFault):
        stream.resolve_stream(flaky_chunks(_chunks(ents), 3), cfg,
                              chunk_size=CHUNK, checkpoint_dir=d)
    # mid-ingest checkpoints cannot resume without the iterator...
    with pytest.raises(ValueError, match="re-supplied"):
        api.resume(d)
    # ...and resume with it — already-committed chunks are skipped
    res = api.resume(d, chunks=_chunks(ents))
    assert res.pairs == ref.pairs
    assert res.matches == ref.matches


def test_rerunning_same_command_is_a_resume(tmp_path, ents):
    """A killed ``resolve_stream(checkpoint_dir=...)`` resumes simply by
    re-running the same call — the manifest is matched, not recreated."""
    cfg = _cfg()
    ref = api.resolve(ents, cfg)
    d = str(tmp_path / "rerun")
    with pytest.raises(InjectedFault):
        stream.resolve_stream(_chunks(ents), cfg, chunk_size=CHUNK,
                              checkpoint_dir=d,
                              fault_plan=FaultPlan(crash_after_chunk=2))
    res = stream.resolve_stream(_chunks(ents), cfg, chunk_size=CHUNK,
                                checkpoint_dir=d)
    assert res.pairs == ref.pairs


def test_checkpointed_run_and_resume_of_done(tmp_path, ents):
    cfg = _cfg()
    plain = stream.resolve_stream(_chunks(ents), cfg, chunk_size=CHUNK)
    d = str(tmp_path / "full")
    ck = stream.resolve_stream(_chunks(ents), cfg, chunk_size=CHUNK,
                               checkpoint_dir=d)
    assert ck.pairs == plain.pairs and ck.matches == plain.matches
    again = api.resume(d)          # a completed checkpoint replays entirely
    assert again.pairs == plain.pairs


def test_multipass_checkpoint_resume(tmp_path, ents):
    passes = (api.SortKeySpec(name="fwd", source="key"),
              api.SortKeySpec(name="sig", source="text", kind="prefix",
                              width=3))
    cfg = _cfg(passes=passes)
    ref = stream.resolve_stream(_chunks(ents), cfg, chunk_size=CHUNK)
    d = str(tmp_path / "mp")
    with pytest.raises(InjectedFault):
        stream.resolve_stream(
            _chunks(ents), cfg, chunk_size=CHUNK, checkpoint_dir=d,
            fault_plan=FaultPlan(crash_after_chunk=1, label="sig"))
    res = api.resume(d)
    assert res.pairs == ref.pairs
    assert res.pass_names == ref.pass_names
    for a, b in zip(res.passes, ref.passes):
        assert a.pairs == b.pairs


def test_resume_guards(tmp_path, ents):
    cfg = _cfg()
    with pytest.raises(FileNotFoundError):
        api.resume(str(tmp_path / "nowhere"))
    d = str(tmp_path / "guards")
    stream.resolve_stream(_chunks(ents), cfg, chunk_size=CHUNK,
                          checkpoint_dir=d)
    # config drift across a resume is rejected by fingerprint
    with pytest.raises(ValueError, match="fingerprint"):
        resume_stream(d, cfg=cfg.with_(window=W + 2))
    # so is a changed chunk grid (it defines the commit points)
    with pytest.raises(ValueError, match="chunk_size"):
        stream.resolve_stream(_chunks(ents), cfg, chunk_size=CHUNK + 1,
                              checkpoint_dir=d)
    # and a changed shard layout (it shapes the pair sets)
    with pytest.raises(ValueError, match="fingerprint|setup"):
        resume_stream(d, cfg=cfg.with_(num_shards=R * 2))


def test_checkpoint_rejects_compute_metrics(tmp_path, ents):
    with pytest.raises(ValueError, match="compute_metrics"):
        stream.resolve_stream(_chunks(ents), _cfg(compute_metrics=True),
                              chunk_size=CHUNK,
                              checkpoint_dir=str(tmp_path / "m"))


def test_fault_plan_requires_checkpoint(ents):
    with pytest.raises(ValueError, match="checkpoint_dir"):
        stream.resolve_stream(_chunks(ents), _cfg(), chunk_size=CHUNK,
                              fault_plan=FaultPlan(crash_after_chunk=0))


# -- overflow recovery --------------------------------------------------------

def _pairs_cfg(**kw):
    kw.setdefault("variant", "srp")
    kw.setdefault("emit", "pairs")
    kw.setdefault("partitioner", "uniform")
    return _cfg(**kw)


def test_retry_drops_zero_pairs_stream(ents):
    base = stream.resolve_stream(_chunks(ents), _pairs_cfg(pair_cap=0),
                                 chunk_size=CHUNK)
    tiny = micro_caps(_pairs_cfg(), pair_cap=32).with_(
        cand_cap=None, on_overflow="retry", retry_limit=8)
    res = stream.resolve_stream(_chunks(ents), tiny, chunk_size=CHUNK)
    assert res.pairs == base.pairs and res.matches == base.matches
    assert res.blocking.pair_overflow == 0          # recovered, not counted
    assert res.resilience.retries > 0
    assert res.resilience.escalations >= res.resilience.retries
    # the escalated cap is sticky: later chunks reuse it instead of
    # re-climbing the ladder, so retries stay far below chunks * ladder
    assert res.resilience.pair_cap > 32


def test_retry_drops_zero_pairs_resolve(ents):
    base = api.resolve(ents, _pairs_cfg(pair_cap=0))
    tiny = micro_caps(_pairs_cfg(), pair_cap=32).with_(
        cand_cap=None, on_overflow="retry", retry_limit=8)
    res = api.resolve(ents, tiny)
    assert res.pairs == base.pairs and res.matches == base.matches
    assert res.blocking.pair_overflow == 0
    assert res.resilience.retries > 0


def test_count_policy_keeps_legacy_counters(ents):
    tiny = micro_caps(_pairs_cfg(), pair_cap=8).with_(cand_cap=None)
    res = api.resolve(ents, tiny)
    assert res.blocking.pair_overflow > 0           # counted, not recovered
    assert res.resilience.retries == 0


def test_raise_policy_raises(ents):
    tiny = micro_caps(_pairs_cfg(), pair_cap=8).with_(
        cand_cap=None, on_overflow="raise")
    with pytest.raises(CapacityOverflowError) as ei:
        api.resolve(ents, tiny)
    assert ei.value.pair_overflow > 0


def test_exhausted_ladder_raises(ents):
    tiny = micro_caps(_pairs_cfg(), pair_cap=2).with_(
        cand_cap=None, on_overflow="retry", retry_limit=1)
    with pytest.raises(CapacityOverflowError) as ei:
        api.resolve(ents, tiny)
    assert ei.value.retries == 1


# -- capacity auto-sizing -----------------------------------------------------

def test_auto_caps_from_profile_backed_plan(ents):
    base = api.resolve(ents, _pairs_cfg(pair_cap=0))
    res = api.resolve(ents, _pairs_cfg())           # pair_cap unset -> auto
    assert res.resilience.auto_caps
    assert res.resilience.pair_cap > 0
    assert res.blocking.pair_overflow == 0          # band bound never clips
    assert res.pairs == base.pairs


def test_explicit_caps_override_auto(ents):
    res = api.resolve(ents, _pairs_cfg(pair_cap=7))
    assert not res.resilience.auto_caps
    assert res.resilience.pair_cap == 7
    assert res.blocking.pair_overflow > 0           # tiny cap honored


def test_default_config_consumes_no_caps(ents):
    # the default emit/engine consume no capacity knobs, so unset caps stay
    # at the historical 0 (= unbounded) and nothing is auto-sized — default
    # runs keep their legacy shapes and executable-cache keys
    res = api.resolve(ents, _cfg())
    assert not res.resilience.auto_caps
    assert res.resilience.pair_cap == 0
    assert res.resilience.cand_cap == 0
    assert res.blocking.pair_overflow == 0


def test_stream_auto_caps(ents):
    base = stream.resolve_stream(_chunks(ents), _pairs_cfg(pair_cap=0),
                                 chunk_size=CHUNK)
    res = stream.resolve_stream(_chunks(ents), _pairs_cfg(),
                                chunk_size=CHUNK)
    assert res.resilience.auto_caps
    assert res.blocking.pair_overflow == 0
    assert res.pairs == base.pairs


def test_config_overflow_validation():
    with pytest.raises(ValueError, match="on_overflow"):
        _cfg(on_overflow="explode")
    with pytest.raises(ValueError, match="retry_limit"):
        _cfg(retry_limit=-1)
    with pytest.raises(ValueError, match="cand_cap"):
        _cfg(cand_cap=-2)
    with pytest.raises(ValueError, match="pair_cap"):
        _cfg(pair_cap=-2)
    assert _cfg().cand_cap is None                  # None = auto is legal


# -- ChunkStore crash hygiene -------------------------------------------------

def test_atomic_append_leaves_no_tmp(tmp_path, ents):
    store = ChunkStore(str(tmp_path), prefix="c")
    for h in _chunks(ents, 90):
        store.append(h)
    names = sorted(os.listdir(tmp_path))
    assert names == [f"c{i:06d}.npz" for i in range(len(store))]


def test_attach_sweeps_uncommitted_debris(tmp_path, ents):
    hs = _chunks(ents, 90)
    store = ChunkStore(str(tmp_path), prefix="c")
    for h in hs:
        store.append(h)
    # simulate a crash mid-append: a torn tmp + a chunk the manifest never
    # committed (count=2 adopts only the first two)
    open(tmp_path / "c000099.npz.tmp", "wb").write(b"torn")
    att = ChunkStore.attach(str(tmp_path), "c", count=2)
    assert len(att) == 2
    left = sorted(os.listdir(tmp_path))
    assert left == ["c000000.npz", "c000001.npz"]
    got = att.load(1)
    np.testing.assert_array_equal(got["key"], hs[1]["key"])
    # a manifest promising more chunks than exist is corruption, not silence
    with pytest.raises(FileNotFoundError, match="committed"):
        ChunkStore.attach(str(tmp_path), "c", count=5)


def test_dispose_tolerates_missing_files(tmp_path, ents):
    store = ChunkStore(str(tmp_path), prefix="c")
    for h in _chunks(ents, 120):
        store.append(h)
    os.remove(tmp_path / "c000000.npz")     # crashed cleanup raced us
    store.dispose()                          # must not raise
    assert [n for n in os.listdir(tmp_path) if n.startswith("c")] == []
    assert store.spooled_bytes == 0


def test_atomic_savez_replaces_whole_file(tmp_path):
    p = str(tmp_path / "x.npz")
    atomic_savez(p, a=np.arange(4))
    atomic_savez(p, a=np.arange(9))         # overwrite is atomic too
    with np.load(p) as z:
        assert z["a"].shape == (9,)
    assert not os.path.exists(p + ".tmp")


# -- serve durability ---------------------------------------------------------

def _serve_cfg():
    return _cfg(variant="repsn", partitioner="uniform")


def test_index_snapshot_restore_parity(tmp_path, ents):
    from repro.serve import SortedIndex
    idx = SortedIndex(W)
    for h in _chunks(ents, 90):
        idx.insert(E.sort_chunk(E.make_entities(
            h["key"], h["eid"], payload=h["payload"], valid=h["valid"])))
    idx.delete(np.asarray(E.to_host(ents)["eid"])[5:25])
    idx.snapshot(str(tmp_path))
    back = SortedIndex.restore(str(tmp_path))
    assert back.n_live == idx.n_live
    np.testing.assert_array_equal(back.live_comps, idx.live_comps)
    # the restored profile is EXACTLY the live one (merge is exact), so
    # every downstream plan is identical
    np.testing.assert_array_equal(back.profile.uniq, idx.profile.uniq)
    np.testing.assert_array_equal(back.profile.counts, idx.profile.counts)


def test_service_snapshot_restore_serves_identical_pairs(tmp_path, ents):
    h = E.to_host(ents)
    svc = api.serve(_serve_cfg(), start=False)
    for i in range(0, 240, 60):
        svc.resolve_incremental(E.host_take(h, slice(i, i + 60)))
    svc.delete(np.asarray(h["eid"])[10:20])
    svc.snapshot(str(tmp_path))
    from repro.serve import ResolutionService
    back = ResolutionService.restore(str(tmp_path), _serve_cfg(),
                                     start=False)
    assert back.pairs == svc.pairs and back.matches == svc.matches
    # further mutations stay in lock-step, and pair ids survive the restore
    r1 = svc.resolve_incremental(E.host_take(h, slice(240, 300)))
    r2 = back.resolve_incremental(E.host_take(h, slice(240, 300)))
    assert r1.new_pairs == r2.new_pairs
    assert svc.pairs == back.pairs
    for p in list(r1.new_pairs)[:5]:
        assert svc.pair_id(p) == back.pair_id(p)


def test_service_restore_rejects_config_drift(tmp_path, ents):
    svc = api.serve(_serve_cfg(), start=False,
                    initial=E.host_take(E.to_host(ents), slice(0, 60)))
    svc.snapshot(str(tmp_path))
    from repro.serve import ResolutionService
    with pytest.raises(ValueError, match="snapshot"):
        ResolutionService.restore(str(tmp_path),
                                  _serve_cfg().with_(window=W + 2),
                                  start=False)


def test_service_worker_failure_surfaces(ents):
    h = E.to_host(ents)
    svc = api.serve(_serve_cfg())
    svc.resolve_incremental(E.host_take(h, slice(0, 60)))

    class Boom(RuntimeError):
        pass

    def broken(*a, **k):
        raise Boom("injected delta failure")

    svc._delta.insert = broken
    fut = svc.submit_insert(E.host_take(h, slice(60, 90)))
    with pytest.raises(Boom):
        fut.result(timeout=30)
    # the failure is recorded, surfaced in stats, and the service refuses
    # new work instead of dying silently
    deadline = 50
    while svc.stats().failure is None and deadline:
        import time
        time.sleep(0.05)
        deadline -= 1
    assert svc.stats().failure is not None
    with pytest.raises(RuntimeError, match="failed"):
        svc.submit_insert(E.host_take(h, slice(90, 120)))


def test_service_value_error_keeps_serving(ents):
    h = E.to_host(ents)
    svc = api.serve(_serve_cfg())
    svc.resolve_incremental(E.host_take(h, slice(0, 60)))
    with pytest.raises(ValueError):
        svc.resolve_incremental(E.host_take(h, slice(0, 5)))  # live eids
    res = svc.resolve_incremental(E.host_take(h, slice(60, 120)))
    assert res.batched >= 1
    assert svc.stats().failure is None
    svc.close()


def test_service_close_drain(ents):
    h = E.to_host(ents)
    svc = api.serve(_serve_cfg())
    futs = [svc.submit_insert(E.host_take(h, slice(i, i + 30)))
            for i in range(0, 180, 30)]
    svc.close(drain=True)
    for f in futs:
        assert f.exception(timeout=30) is None     # all served before stop
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit_insert(E.host_take(h, slice(180, 210)))
