"""Checkpoint/restore, fault injection, elastic remesh, loop determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, smoke_variant
from repro.configs.base import RunConfig, ShapeConfig
from repro.data.corpus import TokenBatcher, synth_corpus
from repro.models import lm
from repro.train import optim, steps
from repro.train.checkpoint import Checkpointer
from repro.train.loop import LoopConfig, train_loop


@pytest.fixture()
def setup(tmp_path):
    cfg = smoke_variant(ARCHS["phi4-mini-3.8b"])
    run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
                    fsdp=False, remat="none")
    oc = optim.OptConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    train_step = jax.jit(steps.make_train_step(cfg, run, None, oc))
    state = steps.train_state_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    docs = synth_corpus(0, 256, doc_len=32, vocab=cfg.vocab_size)
    batcher = TokenBatcher(docs, seq_len=32, global_batch=4)
    return cfg, train_step, state, batcher, tmp_path


def test_checkpoint_roundtrip(setup):
    cfg, train_step, state, batcher, tmp = setup
    ck = Checkpointer(tmp / "ck")
    state2, _ = train_step(state, batcher.batch(0))
    ck.save(1, state2)
    assert ck.latest_step() == 1
    restored = ck.restore(1, jax.eval_shape(lambda: state2))
    for a, b in zip(jax.tree.leaves(state2), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_partial_checkpoints(setup):
    cfg, train_step, state, batcher, tmp = setup
    ck = Checkpointer(tmp / "ck")
    ck.save(5, state)
    # a stale tmp file (simulated crash mid-write) must not be visible
    (tmp / "ck" / "step_9.npz.tmp").write_bytes(b"garbage")
    assert ck.latest_step() == 5


def test_fault_injection_recovers(setup):
    """A mid-run device failure restores from the last checkpoint and the
    run completes with the same step count."""
    cfg, train_step, state, batcher, tmp = setup
    ck = Checkpointer(tmp / "ckf")
    lc = LoopConfig(total_steps=12, ckpt_every=4, log_every=100)
    final, stats = train_loop(train_step, state, batcher, ck, lc,
                              inject_fault_at=6)
    assert stats.restores == 1
    assert ck.latest_step() == 12
    assert len(stats.losses) >= 12


def test_resume_determinism(setup):
    """Run 10 steps straight vs 5 + crash + resume: identical final params
    (deterministic data order + checkpointed optimizer state)."""
    cfg, train_step, state, batcher, tmp = setup
    ck_a = Checkpointer(tmp / "a")
    la = LoopConfig(total_steps=10, ckpt_every=5, log_every=100)
    final_a, _ = train_loop(train_step, state, batcher, ck_a, la)

    ck_b = Checkpointer(tmp / "b")
    lb = LoopConfig(total_steps=5, ckpt_every=5, log_every=100)
    mid, _ = train_loop(train_step, state, batcher, ck_b, lb)
    lb2 = LoopConfig(total_steps=10, ckpt_every=5, log_every=100)
    final_b, _ = train_loop(train_step, state, batcher, ck_b, lb2)

    for a, b in zip(jax.tree.leaves(final_a["params"]),
                    jax.tree.leaves(final_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_elastic_remesh_restore(setup, tmp_path):
    """Checkpoints restore onto a DIFFERENT mesh shape (elastic rescale)."""
    cfg, train_step, state, batcher, tmp = setup
    from repro.sharding.rules import Rules
    ck = Checkpointer(tmp_path / "ck")
    ck.save(3, state)
    n = len(jax.devices())
    if n == 1:
        mesh = jax.make_mesh((1,), ("data",))
    else:
        mesh = jax.make_mesh((n // 2, 2), ("data", "model"))
    rules = Rules(mesh, fsdp=False)
    sh = steps.resolve_shardings(
        rules, steps.train_state_specs(cfg), state)
    step, restored = ck.restore_latest(state, shardings=sh)
    assert step == 3
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding.mesh.shape == mesh.shape


def test_loss_decreases_over_training(setup):
    cfg, train_step, state, batcher, tmp = setup
    ck = Checkpointer(tmp / "ld")
    lc = LoopConfig(total_steps=40, ckpt_every=50, log_every=100)
    _, stats = train_loop(train_step, state, batcher, ck, lc)
    first = np.mean(stats.losses[:5])
    last = np.mean(stats.losses[-5:])
    assert last < first, (first, last)
