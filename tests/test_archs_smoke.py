"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED same-family config and runs one
forward + one train step on CPU, asserting output shapes and no NaNs.  The
full-size configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, smoke_variant
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import lm
from repro.train import optim, steps

ARCH_NAMES = sorted(ARCHS)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_no_nan(name, rng):
    cfg = smoke_variant(ARCHS[name])
    params = lm.lm_init(rng, cfg, jnp.float32)
    b, s = 2, 32
    if cfg.frontend:
        embeds = jax.random.normal(rng, (b, s, cfg.d_model), jnp.float32)
        logits, _, aux = lm.forward(params, cfg, embeds=embeds, remat="none")
    else:
        toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
        logits, _, aux = lm.forward(params, cfg, tokens=toks, remat="none")
    assert logits.shape == (b, s, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_train_step(name, rng):
    cfg = smoke_variant(ARCHS[name])
    b, s = 2, 16
    run = RunConfig(model=cfg, shape=ShapeConfig("smoke", s, b, "train"),
                    fsdp=False, remat="block")
    oc = optim.OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    train_step = steps.make_train_step(cfg, run, rules=None, oc=oc)
    state = steps.train_state_init(rng, cfg, jnp.float32)
    if cfg.frontend:
        batch = {"embeds": jax.random.normal(rng, (b, s, cfg.d_model)),
                 "labels": jax.random.randint(rng, (b, s), 0, cfg.vocab_size)}
    else:
        toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
    state2, metrics = jax.jit(train_step)(state, batch)
    assert not bool(jnp.isnan(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert int(state2["opt"]["step"]) == 1
    # params actually changed
    diffs = jax.tree.map(
        lambda a, b_: float(jnp.abs(a.astype(jnp.float32)
                                    - b_.astype(jnp.float32)).max()),
        state["params"], state2["params"])
    assert max(jax.tree.leaves(diffs)) > 0


@pytest.mark.parametrize("name", ["gemma2-9b", "mixtral-8x22b", "xlstm-350m",
                                  "recurrentgemma-9b", "qwen3-moe-235b-a22b"])
def test_prefill_decode_matches_full(name, rng):
    """Cache semantics: prefill + decode == full forward (per family)."""
    cfg = smoke_variant(ARCHS[name])
    params = lm.lm_init(rng, cfg, jnp.float32)
    b, s, p = 2, 32, 16
    toks = jax.random.randint(rng, (b, s), 0, cfg.vocab_size)
    full, _, _ = lm.forward(params, cfg, tokens=toks, remat="none")
    cache = lm.cache_init(cfg, b, s, jnp.float32)
    pre, cache, _ = lm.forward(params, cfg, tokens=toks[:, :p], cache=cache,
                               remat="none")
    assert jnp.allclose(pre, full[:, :p], atol=2e-4), \
        float(jnp.abs(pre - full[:, :p]).max())
    for t in range(p, s):
        step_l, cache, _ = lm.forward(
            params, cfg, tokens=toks[:, t:t + 1], cache=cache,
            cache_pos=jnp.int32(t + 1), remat="none")
        assert jnp.allclose(step_l[:, 0], full[:, t], atol=2e-4), \
            (t, float(jnp.abs(step_l[:, 0] - full[:, t]).max()))
