"""Matcher correctness: edit distance vs host DP oracle (hypothesis),
Jaccard, cascade skip semantics (paper §5.1 optimization)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")          # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core import match as M


@given(la=st.integers(0, 16), lb=st.integers(0, 16),
       seed=st.integers(0, 100000), alpha=st.integers(2, 6))
@settings(max_examples=60, deadline=None)
def test_edit_distance_matches_oracle(la, lb, seed, alpha):
    rng = np.random.default_rng(seed)
    L = 16
    a = np.zeros(L, np.uint8)
    b = np.zeros(L, np.uint8)
    a[:la] = rng.integers(97, 97 + alpha, la)
    b[:lb] = rng.integers(97, 97 + alpha, lb)
    want = M.edit_distance_ref(a, b)
    got = int(M.edit_distance_impl(jnp.asarray(a)[None],
                                   jnp.asarray(b)[None])[0])
    assert got == want


def test_edit_distance_batch():
    rng = np.random.default_rng(1)
    A = rng.integers(97, 103, (128, 24)).astype(np.uint8)
    B = rng.integers(97, 103, (128, 24)).astype(np.uint8)
    got = np.asarray(M.edit_distance_impl(jnp.asarray(A), jnp.asarray(B)))
    want = np.array([M.edit_distance_ref(A[i], B[i]) for i in range(128)])
    np.testing.assert_array_equal(got, want)


def test_jaccard_known_values():
    a = jnp.asarray([[0b1111, 0]], jnp.uint32)
    b = jnp.asarray([[0b0011, 0]], jnp.uint32)
    assert float(M.jaccard_sig(a, b)[0]) == pytest.approx(0.5)
    assert float(M.jaccard_sig(a, a)[0]) == pytest.approx(1.0)
    z = jnp.zeros((1, 2), jnp.uint32)
    assert float(M.jaccard_sig(z, z)[0]) == pytest.approx(1.0)  # empty sets


def test_cosine_range_and_identity():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(32, 16)).astype(np.float32)
    x /= np.linalg.norm(x, axis=1, keepdims=True)
    xj = jnp.asarray(x)
    s_ii = M.cosine_sim(xj, xj)
    np.testing.assert_allclose(np.asarray(s_ii), 1.0, atol=1e-5)
    s = M.cosine_sim(xj, jnp.roll(xj, 1, axis=0))
    assert ((np.asarray(s) >= 0) & (np.asarray(s) <= 1)).all()


def test_cascade_skip_semantics():
    """The skip optimization must never change which pairs match: a skipped
    matcher only occurs when the threshold is already unreachable."""
    rng = np.random.default_rng(2)
    n = 256
    feat = rng.normal(size=(n, 8)).astype(np.float32)
    feat /= np.linalg.norm(feat, axis=1, keepdims=True)
    sig = rng.integers(0, 2**32, size=(n, 4), dtype=np.uint64) \
        .astype(np.uint32)
    pa = {"feat": jnp.asarray(feat), "sig": jnp.asarray(sig)}
    pb = {"feat": jnp.asarray(np.roll(feat, 1, 0)),
          "sig": jnp.asarray(np.roll(sig, 1, 0))}
    mm = M.default_matcher()
    with_skip = np.asarray(mm.matches(pa, pb, skip=True))
    without = np.asarray(mm.matches(pa, pb, skip=False))
    np.testing.assert_array_equal(with_skip, without)
    # and the skip actually skips work for sub-threshold cheap scores
    _, evaluated = mm.combined(pa, pb, skip=True)
    assert float(np.asarray(evaluated).mean()) < 2.0


def test_cascade_order_by_cost():
    mm = M.CascadeMatcher(matchers=(
        M.Matcher(field="a", kind="cosine", cost=5.0),
        M.Matcher(field="b", kind="cosine", cost=1.0)), threshold=0.5)
    assert [m.field for m in mm.ordered()] == ["b", "a"]
