"""Overload-hardened serving (ISSUE 9): admission, degradation, chaos.

  * queue policies: ``reject`` raises ``OverloadError`` at submit on a
    full queue; ``shed_oldest`` evicts + fails the oldest queued future
    and admits the newcomer; ``block`` (legacy) backpressures but fails
    fast when the worker dies or the service closes mid-wait
  * deadlines: an expired request fails with ``DeadlineExceededError`` at
    batch-formation time, before any work is spent on it
  * watchdog: a stuck batch fails with ``BatchTimeoutError`` instead of
    hanging the worker; the service marks itself failed (never silently)
  * brownout + repair (invariant 13): degraded batches keep the BLOCKED
    set exact and under-approximate matches; ``repair()`` restores served
    sets bit-identical to a from-scratch resolve; snapshots drain repair
    debt first
  * ``close(timeout=...)`` cannot hang behind a stuck batch — queued
    futures fail typed
  * chaos property sweep: under any ``ChaosPlan`` schedule x queue
    policy, every submitted future completes (result or typed error),
    none is silently dropped, and post-repair served sets match a batch
    resolve of exactly the applied mutations
"""
import threading
import time

import numpy as np
import pytest

from repro import api
from repro.core import entities as E
from repro.resilience import ChaosEvent, ChaosPlan, InjectedFault
from repro.serve import (AdmissionConfig, BatchTimeoutError,
                         DeadlineExceededError, OverloadError,
                         WatermarkController)
from repro.serve.admission import derive_health

N, R, W = 520, 4, 6

#: the permanently-engaged brownout (high trips at depth 0, low can never
#: release) — the deterministic fixture for the degraded path
ALWAYS_DEGRADED = AdmissionConfig(brownout_high=0.0, brownout_low=-1.0)


def _cfg(**kw):
    kw.setdefault("window", W)
    kw.setdefault("num_shards", R)
    kw.setdefault("variant", "repsn")
    kw.setdefault("hops", R - 1)
    kw.setdefault("runner", "vmap")
    return api.ERConfig(**kw)


@pytest.fixture(scope="module")
def corpus():
    rng = np.random.default_rng(11)
    return E.to_host(E.synth_entities(rng, N, n_keys=70, dup_frac=0.25))


def _resolve_live(h_live, cfg):
    dev = E.make_entities(h_live["key"], h_live["eid"],
                          payload=h_live["payload"], valid=h_live["valid"])
    return api.resolve(dev, cfg)


class _Gate:
    """Deterministically stall the delta inside the worker: ``insert``
    blocks on an event the test releases — no sleeps, no timing races."""

    def __init__(self, svc):
        self.entered = threading.Event()
        self.release = threading.Event()
        self._orig = svc._delta.insert

    def insert(self, *a, **k):
        self.entered.set()
        self.release.wait(30)
        return self._orig(*a, **k)


def _gated_service(corpus, *, admission, queue_cap, **kw):
    svc = api.serve(_cfg(), queue_cap=queue_cap, admission=admission,
                    max_wait_ms=0.0, **kw)
    # seed with an explicit generous deadline so admission configs with a
    # tiny default_deadline_ms cannot expire the seeding insert itself
    svc.submit_insert(E.host_take(corpus, slice(0, 60)),
                      deadline_ms=600_000.0).result()
    gate = _Gate(svc)
    svc._delta.insert = gate.insert
    return svc, gate


# -- config validation -------------------------------------------------------

def test_admission_config_validates():
    with pytest.raises(ValueError, match="queue_policy"):
        AdmissionConfig(queue_policy="drop_newest")
    with pytest.raises(ValueError, match="brownout_low"):
        AdmissionConfig(brownout_low=0.9, brownout_high=0.5)
    with pytest.raises(ValueError, match="deadline"):
        AdmissionConfig(default_deadline_ms=-1)
    with pytest.raises(ValueError, match="batch_timeout_s"):
        AdmissionConfig(batch_timeout_s=0)
    with pytest.raises(ValueError):
        ChaosEvent(batch=0, kind="explode")


def test_watermark_hysteresis():
    wm = WatermarkController(
        AdmissionConfig(brownout_high=0.75, brownout_low=0.25,
                        brownout_p95_ms=100.0), queue_cap=100)
    assert wm.update(50, 0.0) is False          # between watermarks: off
    assert wm.update(80, 0.0) is True           # depth crosses high
    assert wm.update(50, 0.0) is True           # hysteresis: stays on
    assert wm.update(26, 0.0) is True
    assert wm.update(25, 0.0) is False          # releases at low
    assert wm.update(50, 250.0) is True         # latency engages too
    assert wm.update(50, 0.0) is True           # ...and holds until low
    assert wm.update(0, 0.0) is False
    assert wm.transitions == 4


def test_derive_health_precedence():
    assert derive_health(failure=True, brownout=True, dirty_ranges=3,
                         depth_frac=1.0, high=0.75) == "failed"
    assert derive_health(failure=False, brownout=True, dirty_ranges=0,
                         depth_frac=0.9, high=0.75) == "overloaded"
    assert derive_health(failure=False, brownout=True, dirty_ranges=0,
                         depth_frac=0.1, high=0.75) == "degraded"
    assert derive_health(failure=False, brownout=False, dirty_ranges=2,
                         depth_frac=0.1, high=0.75) == "degraded"
    assert derive_health(failure=False, brownout=False, dirty_ranges=0,
                         depth_frac=0.0, high=0.75) == "ok"


# -- queue policies ----------------------------------------------------------

def test_reject_policy_fails_fast(corpus):
    svc, gate = _gated_service(
        corpus, admission=AdmissionConfig(queue_policy="reject"),
        queue_cap=2)
    futs = [svc.submit_insert(E.host_take(corpus, slice(60, 70)))]
    gate.entered.wait(30)                  # worker busy inside the gate
    futs.append(svc.submit_insert(E.host_take(corpus, slice(70, 80))))
    futs.append(svc.submit_insert(E.host_take(corpus, slice(80, 90))))
    with pytest.raises(OverloadError):     # queue_cap=2 is now full
        svc.submit_insert(E.host_take(corpus, slice(90, 100)))
    gate.release.set()
    for f in futs:                         # admitted requests all serve
        assert f.result(timeout=30).batched >= 1
    assert svc.stats().rejected == 1
    assert svc.stats().failure is None
    svc.close()


def test_shed_oldest_policy_evicts_oldest(corpus):
    svc, gate = _gated_service(
        corpus, admission=AdmissionConfig(queue_policy="shed_oldest"),
        queue_cap=2)
    f0 = svc.submit_insert(E.host_take(corpus, slice(60, 70)))
    gate.entered.wait(30)
    f1 = svc.submit_insert(E.host_take(corpus, slice(70, 80)))
    f2 = svc.submit_insert(E.host_take(corpus, slice(80, 90)))
    f3 = svc.submit_insert(E.host_take(corpus, slice(90, 100)))  # sheds f1
    with pytest.raises(OverloadError, match="shed"):
        f1.result(timeout=30)
    gate.release.set()
    for f in (f0, f2, f3):                 # survivors serve normally
        assert f.result(timeout=30).batched >= 1
    st = svc.stats()
    assert st.shed == 1 and st.failure is None
    # the shed insert was never applied: its entities are re-insertable
    svc.resolve_incremental(E.host_take(corpus, slice(70, 80)))
    svc.close()


def test_block_policy_fails_fast_when_worker_dies(corpus):
    svc, gate = _gated_service(corpus, admission=None, queue_cap=1)

    class Boom(RuntimeError):
        pass

    def broken(*a, **k):
        gate.entered.set()
        gate.release.wait(30)
        raise Boom("delta blew up")

    svc._delta.insert = broken
    f0 = svc.submit_insert(E.host_take(corpus, slice(60, 70)))
    gate.entered.wait(30)
    svc.submit_insert(E.host_take(corpus, slice(70, 80)))  # fills the queue
    blocked_err = []

    def blocked_submit():
        try:
            svc.submit_insert(E.host_take(corpus, slice(80, 90)))
        except RuntimeError as exc:
            blocked_err.append(exc)

    t = threading.Thread(target=blocked_submit, daemon=True)
    t.start()
    time.sleep(0.2)                        # let it enter the put loop
    assert t.is_alive()                    # genuinely blocked on backpressure
    gate.release.set()                     # worker dies with Boom
    t.join(30)
    assert not t.is_alive()                # the FIX: no infinite block
    assert blocked_err and "failed" in str(blocked_err[0])
    assert isinstance(blocked_err[0].__cause__, Boom)
    with pytest.raises(Boom):
        f0.result(timeout=30)


# -- deadlines ---------------------------------------------------------------

def test_deadline_expires_in_queue(corpus):
    svc, gate = _gated_service(
        corpus, admission=AdmissionConfig(queue_policy="block"),
        queue_cap=8)
    f0 = svc.submit_insert(E.host_take(corpus, slice(60, 70)))
    gate.entered.wait(30)
    doomed = svc.submit_insert(E.host_take(corpus, slice(70, 80)),
                               deadline_ms=0.0)
    ok = svc.submit_insert(E.host_take(corpus, slice(80, 90)))
    gate.release.set()
    with pytest.raises(DeadlineExceededError):
        doomed.result(timeout=30)
    assert f0.result(timeout=30).batched >= 1
    assert ok.result(timeout=30).batched >= 1
    st = svc.stats()
    assert st.expired == 1 and st.failure is None
    # the expired insert was never applied — its entities re-insert cleanly
    svc.resolve_incremental(E.host_take(corpus, slice(70, 80)))
    svc.close()


def test_default_deadline_from_admission_config(corpus):
    svc, gate = _gated_service(
        corpus,
        admission=AdmissionConfig(default_deadline_ms=0.0), queue_cap=8)
    f0 = svc.submit_insert(E.host_take(corpus, slice(60, 70)),
                           deadline_ms=60_000.0)   # explicit wins
    gate.entered.wait(30)
    doomed = svc.submit_insert(E.host_take(corpus, slice(70, 80)))
    gate.release.set()
    with pytest.raises(DeadlineExceededError):
        doomed.result(timeout=30)
    assert f0.result(timeout=30).batched >= 1
    svc.close()


# -- watchdog ----------------------------------------------------------------

def test_watchdog_fails_stuck_batch(corpus):
    svc, gate = _gated_service(
        corpus, admission=AdmissionConfig(batch_timeout_s=0.2),
        queue_cap=8)
    stuck = svc.submit_insert(E.host_take(corpus, slice(60, 70)))
    gate.entered.wait(30)                  # never released: batch is stuck
    with pytest.raises(BatchTimeoutError):
        stuck.result(timeout=30)
    st = svc.stats()
    assert st.failure is not None and st.health == "failed"
    with pytest.raises(RuntimeError, match="failed"):
        svc.submit_insert(E.host_take(corpus, slice(70, 80)))
    gate.release.set()                     # zombie finishing is a no-op


def test_chaos_stall_trips_watchdog(corpus):
    svc = api.serve(
        _cfg(), admission=AdmissionConfig(batch_timeout_s=0.15),
        chaos=ChaosPlan((ChaosEvent(batch=1, kind="stall", seconds=10.0),)))
    svc.resolve_incremental(E.host_take(corpus, slice(0, 60)))  # batch 0
    stuck = svc.submit_insert(E.host_take(corpus, slice(60, 90)))
    with pytest.raises(BatchTimeoutError):
        stuck.result(timeout=30)
    assert svc.stats().health == "failed"


# -- brownout + repair (invariant 13) ----------------------------------------

def test_degraded_blocked_exact_matches_deferred(corpus):
    svc = api.serve(_cfg(), start=False, admission=ALWAYS_DEGRADED)
    live = np.zeros(N, bool)
    res = svc.resolve_incremental(E.host_take(corpus, slice(0, 200)))
    live[:200] = True
    assert res.degraded and res.stats.degraded_batches == 1
    svc.delete(corpus["eid"][50:80])
    live[50:80] = False
    res = svc.resolve_incremental(E.host_take(corpus, slice(200, 400)))
    live[200:400] = True
    assert res.degraded
    ref = _resolve_live(E.host_take(corpus, np.flatnonzero(live)), _cfg())
    # blocked NEVER degrades; matches under-approximate (never invent)
    assert svc.pairs == ref.blocking.pairs
    assert svc.matches <= ref.matches
    st = svc.stats()
    assert st.dirty_ranges > 0 and st.health in ("degraded", "overloaded")
    assert svc.repair() > 0
    assert svc.pairs == ref.blocking.pairs
    assert svc.matches == ref.matches      # eventually-exact
    st = svc.stats()
    assert st.dirty_ranges == 0 and st.repairs == 1
    assert svc.repair() == 0               # idempotent: nothing dirty


def test_degraded_interleaving_repair_parity(corpus):
    """Property-style: a random degraded insert/delete interleaving stays
    blocked-exact throughout and fully exact after each repair."""
    rng = np.random.default_rng(5)
    svc = api.serve(_cfg(), start=False, admission=ALWAYS_DEGRADED)
    live = np.zeros(N, bool)
    nxt = 0
    for step in range(6):
        if nxt < N and (step % 2 == 0 or not live.any()):
            take = min(int(rng.integers(40, 90)), N - nxt)
            svc.resolve_incremental(
                E.host_take(corpus, slice(nxt, nxt + take)))
            live[nxt:nxt + take] = True
            nxt += take
        else:
            gone = rng.choice(np.flatnonzero(live),
                              min(17, int(live.sum())), replace=False)
            svc.delete(corpus["eid"][gone])
            live[gone] = False
        ref = _resolve_live(E.host_take(corpus, np.flatnonzero(live)),
                            _cfg())
        assert svc.pairs == ref.blocking.pairs      # exact at every step
        if step == 3:
            svc.repair()
            assert svc.matches == ref.matches       # exact after repair
    svc.repair()
    ref = _resolve_live(E.host_take(corpus, np.flatnonzero(live)), _cfg())
    assert svc.pairs == ref.blocking.pairs
    assert svc.matches == ref.matches


def test_snapshot_drains_repair_debt(corpus, tmp_path):
    svc = api.serve(_cfg(), start=False, admission=ALWAYS_DEGRADED)
    svc.resolve_incremental(E.host_take(corpus, slice(0, 300)))
    assert svc.stats().dirty_ranges > 0
    svc.snapshot(str(tmp_path))
    assert svc.stats().dirty_ranges == 0   # snapshot repaired first
    from repro.serve import ResolutionService
    back = ResolutionService.restore(str(tmp_path), _cfg(), start=False)
    ref = _resolve_live(E.host_take(corpus, slice(0, 300)), _cfg())
    assert back.pairs == ref.blocking.pairs
    assert back.matches == ref.matches


def test_worker_repairs_when_queue_drains(corpus):
    """The background repair pass: brownout engages under a realistic
    watermark, then releases and repairs once the queue drains."""
    svc = api.serve(
        _cfg(),
        admission=AdmissionConfig(brownout_high=0.3, brownout_low=0.1),
        queue_cap=10, max_batch=60)
    svc.resolve_incremental(E.host_take(corpus, slice(0, 60)))
    # flood: enough queued inserts to cross the 30% watermark
    futs = [svc.submit_insert(E.host_take(corpus, slice(i, i + 20)))
            for i in range(60, 300, 20)]
    for f in futs:                         # every future completes
        f.result(timeout=60)
    deadline = time.monotonic() + 30
    while svc.stats().dirty_ranges and time.monotonic() < deadline:
        time.sleep(0.05)                   # idle worker repairs in background
    st = svc.stats()
    assert st.dirty_ranges == 0
    ref = _resolve_live(E.host_take(corpus, slice(0, 300)), _cfg())
    assert svc.pairs == ref.blocking.pairs
    assert svc.matches == ref.matches
    assert st.health in ("ok", "degraded")
    svc.close()


# -- close timeout -----------------------------------------------------------

def test_close_timeout_fails_queued_typed(corpus):
    svc, gate = _gated_service(corpus, admission=None, queue_cap=8)
    stuck = svc.submit_insert(E.host_take(corpus, slice(60, 70)))
    gate.entered.wait(30)
    queued = svc.submit_insert(E.host_take(corpus, slice(70, 80)))
    t0 = time.monotonic()
    svc.close(drain=True, timeout=0.2)     # must NOT hang behind the gate
    assert time.monotonic() - t0 < 10
    with pytest.raises(BatchTimeoutError):
        queued.result(timeout=30)
    with pytest.raises(RuntimeError):
        svc.submit_insert(E.host_take(corpus, slice(80, 90)))
    gate.release.set()                     # the stuck batch may now finish
    assert stuck.exception(timeout=30) is None or \
        isinstance(stuck.exception(timeout=30), BatchTimeoutError)


# -- chaos property sweep ----------------------------------------------------

CHAOS_SCHEDULES = [
    ChaosPlan(()),
    ChaosPlan((ChaosEvent(batch=2, kind="error"),)),
    ChaosPlan((ChaosEvent(batch=1, kind="latency", seconds=0.05),
               ChaosEvent(batch=3, kind="error"),
               ChaosEvent(batch=4, kind="error"))),
]


@pytest.mark.parametrize("policy", ["block", "reject", "shed_oldest"])
@pytest.mark.parametrize("plan", CHAOS_SCHEDULES,
                         ids=["calm", "one_error", "spike_two_errors"])
def test_chaos_no_future_hangs_no_silent_drops(corpus, policy, plan):
    """Under any injection schedule x queue policy: every submitted
    future completes (result or typed error), nothing is silently
    dropped, the service survives request-level chaos, and post-repair
    served sets match a batch resolve of exactly the applied ops."""
    adm = AdmissionConfig(queue_policy=policy, default_deadline_ms=30_000,
                          brownout_high=0.8, brownout_low=0.2)
    svc = api.serve(_cfg(), admission=adm, chaos=plan, queue_cap=4,
                    max_batch=30)
    svc.resolve_incremental(E.host_take(corpus, slice(0, 60)))  # batch 0
    ops = []                               # (future, kind, lo, hi)
    for i, lo in enumerate(range(60, 300, 30)):
        try:
            if i == 4:
                f = svc.submit_delete(corpus["eid"][10:20])
                ops.append((f, "delete", 10, 20))
            else:
                f = svc.submit_insert(E.host_take(corpus,
                                                  slice(lo, lo + 30)))
                ops.append((f, "insert", lo, lo + 30))
        except OverloadError:
            ops.append((None, "rejected", lo, lo + 30))
    live = np.zeros(N, bool)
    live[:60] = True
    outcomes = []
    for f, kind, lo, hi in ops:
        if f is None:
            outcomes.append("rejected")
            continue
        exc = f.exception(timeout=60)      # NO future may hang
        if exc is None:
            outcomes.append("ok")
            if kind == "insert":
                live[lo:hi] = True
            else:
                live[lo:hi] = False
        else:
            # typed failures only — nothing vague, nothing silent
            assert isinstance(exc, (OverloadError, DeadlineExceededError,
                                    InjectedFault)), repr(exc)
            outcomes.append(type(exc).__name__)
    assert len(outcomes) == len(ops)       # accounting is total
    st = svc.stats()
    assert st.failure is None              # chaos never kills the service
    svc.repair()
    ref = _resolve_live(E.host_take(corpus, np.flatnonzero(live)), _cfg())
    assert svc.pairs == ref.blocking.pairs
    assert svc.matches == ref.matches
    svc.close()
