"""Per-kernel allclose tests vs the ref.py oracles: shape & dtype sweeps
(deliverable c).  Kernels run in interpret mode on CPU — same code path
compiles natively on TPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("m,f,w,bi", [
    (256, 128, 16, 256),
    (512, 64, 64, 256),
    (300, 32, 10, 128),      # non-multiple M (padding path)
    (128, 256, 128, 128),    # window == block
    (1024, 128, 200, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_banded_sim(m, f, w, bi, dtype):
    feat = jnp.asarray(RNG.normal(size=(m, f)).astype(np.float32), dtype)
    got = ops.banded_dot_band(feat, window=w, block_i=bi, interpret=True)
    want = ref.banded_sim_ref(feat, window=w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("m,words,w,bi", [
    (256, 8, 16, 256),
    (512, 4, 64, 256),
    (192, 16, 32, 64),
    (130, 2, 8, 128),        # padding path
])
def test_jaccard_band(m, words, w, bi):
    sig = jnp.asarray(
        RNG.integers(0, 2**32, size=(m, words), dtype=np.uint64)
        .astype(np.uint32))
    got = ops.jaccard_band(sig, window=w, block_i=bi, interpret=True)
    want = ref.jaccard_band_ref(sig, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("bh,s,d,w,blk", [
    (4, 512, 64, 128, 128),
    (2, 1024, 128, 256, 256),
    (2, 512, 64, 100, 128),   # window not a multiple of block
    (1, 256, 128, 256, 128),  # window == seq (== dense causal)
    (3, 768, 64, 384, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_local_attention(bh, s, d, w, blk, dtype):
    q = jnp.asarray(RNG.normal(size=(bh, s, d)).astype(np.float32), dtype)
    k = jnp.asarray(RNG.normal(size=(bh, s, d)).astype(np.float32), dtype)
    v = jnp.asarray(RNG.normal(size=(bh, s, d)).astype(np.float32), dtype)
    got = ops.local_attn(q, k, v, window=w, block_q=blk, block_k=blk,
                         interpret=True)
    want = ref.local_attention_ref(q, k, v, window=w)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_local_attention_softcap():
    q = jnp.asarray(RNG.normal(size=(2, 256, 64)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(2, 256, 64)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(2, 256, 64)).astype(np.float32))
    got = ops.local_attn(q, k, v, window=128, block_q=128, block_k=128,
                         softcap=20.0, interpret=True)
    want = ref.local_attention_ref(q, k, v, window=128, softcap=20.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_band_kernel_matches_window_module():
    """The Pallas band path and the core window module agree on scores."""
    from repro.core import entities as E
    from repro.core import window as W
    from repro.core.match import CascadeMatcher, Matcher
    rng = np.random.default_rng(3)
    n, w = 256, 9
    ents = E.synth_entities(rng, n, n_keys=32)
    ents = E.sort_entities(ents)
    matcher = CascadeMatcher(
        matchers=(Matcher(field="feat", kind="cosine", weight=1.0),),
        threshold=0.75)
    scores, mask = W.band_scores(ents, w, matcher)      # (w-1, M)
    dot = ops.banded_dot_band(ents["payload"]["feat"], window=w - 1,
                              interpret=True)           # (M, w-1)
    cos = np.clip(0.5 * (np.asarray(dot) + 1.0), 0.0, 1.0)
    want = np.where(np.asarray(mask), cos.T, 0.0)
    np.testing.assert_allclose(
        np.where(np.asarray(mask), np.asarray(scores), 0.0), want,
        rtol=1e-5, atol=1e-5)
