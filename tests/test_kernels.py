"""Per-kernel allclose tests vs the ref.py oracles: shape & dtype sweeps
(deliverable c).  Kernels run in interpret mode on CPU — same code path
compiles natively on TPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("m,f,w,bi", [
    (256, 128, 16, 256),
    (512, 64, 64, 256),
    (300, 32, 10, 128),      # non-multiple M (padding path)
    (128, 256, 128, 128),    # window == block
    (1024, 128, 200, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_banded_sim(m, f, w, bi, dtype):
    feat = jnp.asarray(RNG.normal(size=(m, f)).astype(np.float32), dtype)
    got = ops.banded_dot_band(feat, window=w, block_i=bi, interpret=True)
    want = ref.banded_sim_ref(feat, window=w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * 10)


@pytest.mark.parametrize("m,words,w,bi", [
    (256, 8, 16, 256),
    (512, 4, 64, 256),
    (192, 16, 32, 64),
    (130, 2, 8, 128),        # padding path
])
def test_jaccard_band(m, words, w, bi):
    sig = jnp.asarray(
        RNG.integers(0, 2**32, size=(m, words), dtype=np.uint64)
        .astype(np.uint32))
    got = ops.jaccard_band(sig, window=w, block_i=bi, interpret=True)
    want = ref.jaccard_band_ref(sig, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("bh,s,d,w,blk", [
    (4, 512, 64, 128, 128),
    (2, 1024, 128, 256, 256),
    (2, 512, 64, 100, 128),   # window not a multiple of block
    (1, 256, 128, 256, 128),  # window == seq (== dense causal)
    (3, 768, 64, 384, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_local_attention(bh, s, d, w, blk, dtype):
    q = jnp.asarray(RNG.normal(size=(bh, s, d)).astype(np.float32), dtype)
    k = jnp.asarray(RNG.normal(size=(bh, s, d)).astype(np.float32), dtype)
    v = jnp.asarray(RNG.normal(size=(bh, s, d)).astype(np.float32), dtype)
    got = ops.local_attn(q, k, v, window=w, block_q=blk, block_k=blk,
                         interpret=True)
    want = ref.local_attention_ref(q, k, v, window=w)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


def test_local_attention_softcap():
    q = jnp.asarray(RNG.normal(size=(2, 256, 64)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(2, 256, 64)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(2, 256, 64)).astype(np.float32))
    got = ops.local_attn(q, k, v, window=128, block_q=128, block_k=128,
                         softcap=20.0, interpret=True)
    want = ref.local_attention_ref(q, k, v, window=128, softcap=20.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("m,f,words,w,bi", [
    (256, 64, 8, 16, 256),
    (300, 32, 4, 10, 128),   # non-multiple M (padding path)
    (64, 32, 8, 48, 64),     # window fills most of the block
])
@pytest.mark.parametrize("w_cos,w_jac", [(0.5, 0.5), (1.0, 0.0), (0.0, 2.0)])
def test_fused_cheap_band(m, f, words, w, bi, w_cos, w_jac):
    """Fused kernel == w_cos*cosine + w_jac*jaccard of the jnp oracles,
    including the jaccard empty-vs-empty == 1.0 convention."""
    feat = jnp.asarray(RNG.normal(size=(m, f)).astype(np.float32))
    sig = jnp.asarray(RNG.integers(0, 2**32, size=(m, words),
                                   dtype=np.uint64).astype(np.uint32))
    got = ops.fused_cheap_band(feat, sig, window=w, w_cos=w_cos, w_jac=w_jac,
                               block_i=bi, interpret=True)
    cos = np.clip(0.5 * (np.asarray(ref.banded_sim_ref(feat, window=w))
                         + 1.0), 0.0, 1.0)
    jac = np.asarray(ref.jaccard_band_ref(sig, window=w))
    ok = (np.arange(m)[:, None] + 1 + np.arange(w)[None, :]) < m
    want = np.where(ok, w_cos * cos + w_jac * jac, 0.0)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_fused_band_empty_sig_convention():
    """All-zero signatures: jaccard_sig says empty-vs-empty similarity is
    1.0 — the kernel must agree or the cascade gate would under-select."""
    m, w = 64, 4
    feat = jnp.zeros((m, 8), jnp.float32)
    sig = jnp.zeros((m, 4), jnp.uint32)
    got = np.asarray(ops.fused_cheap_band(feat, sig, window=w, w_cos=0.0,
                                          w_jac=1.0, block_i=64,
                                          interpret=True))
    ok = (np.arange(m)[:, None] + 1 + np.arange(w)[None, :]) < m
    np.testing.assert_allclose(got, np.where(ok, 1.0, 0.0))


def test_small_m_auto_grows_block():
    """M smaller than the window used to trip the kernels'
    ``window <= block_i`` assert via ``bi = min(block_i, m)``; the resolved
    block now grows to the window and M is padded."""
    m, f, w = 8, 16, 16
    feat = jnp.asarray(RNG.normal(size=(m, f)).astype(np.float32))
    got = ops.banded_dot_band(feat, window=w, block_i=256, interpret=True)
    want = ref.banded_sim_ref(feat, window=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)
    sig = jnp.asarray(RNG.integers(0, 2**32, size=(m, 4), dtype=np.uint64)
                      .astype(np.uint32))
    got_j = ops.jaccard_band(sig, window=w, block_i=256, interpret=True)
    np.testing.assert_allclose(np.asarray(got_j),
                               np.asarray(ref.jaccard_band_ref(sig, window=w)),
                               rtol=1e-6, atol=1e-6)


def test_window_exceeding_block_is_actionable():
    feat = jnp.zeros((512, 8), jnp.float32)
    with pytest.raises(ValueError, match="window=300 exceeds block_i=256"):
        ops.banded_dot_band(feat, window=300, block_i=256, interpret=True)


def test_band_kernel_matches_window_module():
    """The Pallas band path and the core window module agree on scores."""
    from repro.core import entities as E
    from repro.core import window as W
    from repro.core.match import CascadeMatcher, Matcher
    rng = np.random.default_rng(3)
    n, w = 256, 9
    ents = E.synth_entities(rng, n, n_keys=32)
    ents = E.sort_entities(ents)
    matcher = CascadeMatcher(
        matchers=(Matcher(field="feat", kind="cosine", weight=1.0),),
        threshold=0.75)
    scores, mask = W.band_scores(ents, w, matcher)      # (w-1, M)
    dot = ops.banded_dot_band(ents["payload"]["feat"], window=w - 1,
                              interpret=True)           # (M, w-1)
    cos = np.clip(0.5 * (np.asarray(dot) + 1.0), 0.0, 1.0)
    want = np.where(np.asarray(mask), cos.T, 0.0)
    np.testing.assert_allclose(
        np.where(np.asarray(mask), np.asarray(scores), 0.0), want,
        rtol=1e-5, atol=1e-5)
