"""Committed BENCH_*.json artifacts must stay loadable and schema-current.

Every blob ``benchmarks.run.write_bench`` emits carries the shared
``schema_version`` (``repro.obs.SCHEMA_VERSION``) and a ``machine_proxy_s``
host-speed proxy; perf_smoke refuses blobs whose version drifted.  This
tier-1 test applies the same refusal to the COMMITTED copies: bumping the
schema without regenerating the committed baselines fails here, not in a
silently-wrong perf comparison.  ``BENCH_obs_trace.json`` is a Chrome
trace export (a different artifact class) and is exempt."""
import json
import os
import sys

import pytest

from repro.obs import SCHEMA_VERSION

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BENCHES = sorted(
    f for f in os.listdir(ROOT)
    if f.startswith("BENCH_") and f.endswith(".json"))


def _load(name):
    with open(os.path.join(ROOT, name)) as f:
        return json.load(f)


def _is_chrome_trace(blob):
    return isinstance(blob, dict) and "traceEvents" in blob


def test_expected_baselines_are_committed():
    """The perf-smoke + quality gates need their committed baselines."""
    assert "BENCH_band_engine.json" in BENCHES
    assert "BENCH_recall.json" in BENCHES
    assert len(BENCHES) >= 4


@pytest.mark.parametrize("name", BENCHES)
def test_committed_bench_schema(name):
    blob = _load(name)
    if _is_chrome_trace(blob):
        pytest.skip("Chrome trace export — not a write_bench blob")
    assert blob.get("schema_version") == SCHEMA_VERSION, (
        f"{name}: schema_version={blob.get('schema_version')!r} != "
        f"{SCHEMA_VERSION} — regenerate with `python -m benchmarks.run` "
        f"(write_bench stamps the shared version)")
    proxy = blob.get("machine_proxy_s")
    assert isinstance(proxy, float) and 0.0 < proxy < 60.0, (
        f"{name}: machine_proxy_s={proxy!r} — the host-speed proxy used "
        f"for cross-machine normalization is missing or implausible")


@pytest.mark.parametrize("name", BENCHES)
def test_committed_bench_passes_perf_smoke_schema(name):
    """The exact checker CI runs agrees (no drift between this test and
    benchmarks.perf_smoke.check_schema)."""
    blob = _load(name)
    if _is_chrome_trace(blob):
        pytest.skip("Chrome trace export — not a write_bench blob")
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.perf_smoke import check_schema
    finally:
        sys.path.pop(0)
    assert check_schema(blob, name) == []


def test_committed_recall_passes_quality_gates():
    """The committed BENCH_recall.json must satisfy the same structural
    gates perf_smoke --recall enforces on fresh runs: Pareto points
    present, adaptive dominating the mid fixed window, the clean-corpus
    full-window PC=1.0 gate, pruning engaged without dropping gold pairs,
    and streamed/traced parity bits all true."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.perf_smoke import check_recall
    finally:
        sys.path.pop(0)
    assert check_recall(_load("BENCH_recall.json")) == []
