"""Seam correctness for out-of-core streaming + multi-pass blocking (ISSUE 5).

  * ``resolve_stream`` over fixed AND random chunkings (including
    chunk_size < w) is bit-identical to monolithic ``resolve`` — all three
    variants x {scan, pallas} band engines
  * SRP streaming reproduces the monolithic plan exactly (key-bounds and
    rank-granular planners) from the incrementally merged KeyProfile
  * multi-pass blocking: the union equals the per-pass union oracle, both
    monolithic and streamed; linkage streams untag correctly
  * streaming machinery units: external merge ordering, rechunking, the
    disk spool roundtrip, steady-state chunk accounting
  * quality levers (ISSUE 10): adaptive-window (+ evidence-pruned)
    streams run the same random-chunking bit-parity matrix and the
    checkpoint kill/resume path without breaking invariants 9/11 — the
    merged KeyProfile reproduces the monolithic per-entity weff exactly
"""
import numpy as np
import pytest

from repro import api, stream
from repro import balance as B
from repro.core import entities as E
from repro.core import keys as K
from repro.stream.external_sort import merged_blocks, rechunk
from repro.stream.store import ChunkStore

N, R, W = 700, 4, 6
VARIANTS = ["srp", "repsn", "jobsn"]
ENGINES = ["scan", "pallas"]


def _cfg(**kw):
    kw.setdefault("window", W)
    kw.setdefault("num_shards", R)
    kw.setdefault("variant", "repsn")
    kw.setdefault("hops", R - 1)
    kw.setdefault("runner", "vmap")
    return api.ERConfig(**kw)


@pytest.fixture(scope="module")
def ents():
    rng = np.random.default_rng(5)
    return E.synth_entities(rng, N, n_keys=90, dup_frac=0.25, text_len=8)


def _chunks_of(ents, sizes):
    """Split an entity set into host chunks of the given sizes."""
    h = E.to_host(ents)
    out, s = [], 0
    for sz in sizes:
        out.append(E.host_take(h, slice(s, s + sz)))
        s += sz
    assert s == h["key"].shape[0]
    return out


def _even_chunks(ents, sz):
    h = E.to_host(ents)
    n = int(h["key"].shape[0])
    return [E.host_take(h, slice(s, min(s + sz, n)))
            for s in range(0, n, sz)]


# -- streaming == monolithic, all variants x engines --------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_stream_bit_identical_to_monolithic(ents, variant, engine):
    cfg = _cfg(variant=variant, band_engine=engine)
    mono = api.resolve(ents, cfg)
    res = stream.resolve_stream(_even_chunks(ents, 175), cfg,
                                chunk_size=175)
    assert res.pairs == mono.pairs
    assert res.matches == mono.matches
    # the corpus is 4x the chunk: the stream really ran chunked
    assert res.stream.chunks == 4
    assert res.stream.entities == N


def test_random_chunkings_property(ents):
    """Random input chunk sizes AND random device chunk_size (including
    chunk_size < w) all reproduce the monolithic pair sets."""
    cfg = _cfg()
    mono = api.resolve(ents, cfg)
    for seed in range(4):
        rng = np.random.default_rng(seed)
        sizes, left = [], N
        while left:
            s = int(rng.integers(1, min(left, 130) + 1))
            sizes.append(s)
            left -= s
        chunk_size = int(rng.integers(2, 140))   # seeds cover < W and >= W
        res = stream.resolve_stream(_chunks_of(ents, sizes), cfg,
                                    chunk_size=chunk_size)
        assert res.pairs == mono.pairs, (seed, sizes, chunk_size)
        assert res.matches == mono.matches, (seed, sizes, chunk_size)


def test_tiny_chunk_size_smaller_than_window(ents):
    cfg = _cfg(variant="jobsn", band_engine="pallas")
    mono = api.resolve(ents, cfg)
    res = stream.resolve_stream(_even_chunks(ents, 100), cfg, chunk_size=4)
    assert res.pairs == mono.pairs
    # chunks of 4 < r*w cannot plan r shards: they collapse (and say so)
    assert res.stream.degenerate_chunks == res.stream.chunks


@pytest.mark.parametrize("partitioner",
                         ["balanced", "uniform", "blocksplit", "pairrange"])
def test_srp_stream_reproduces_monolithic_plan(ents, partitioner):
    """SRP's pair set DEPENDS on the partitioning: streaming must rebuild
    the exact monolithic plan from the merged profile and route chunks by
    global rank (rank-granular planners included)."""
    cfg = _cfg(variant="srp", partitioner=partitioner)
    mono = api.resolve(ents, cfg)
    res = stream.resolve_stream(_even_chunks(ents, 200), cfg,
                                chunk_size=160)
    assert res.pairs == mono.pairs
    assert res.matches == mono.matches


def test_srp_stream_metrics_expose_boundary_loss(ents):
    """The streaming oracle is the FULL sequential-SN set (like the
    facade's): SRP streams must report the same sub-1.0 completeness the
    monolithic resolve does, not absolve the missed boundary pairs."""
    cfg = _cfg(variant="srp", compute_metrics=True)
    mono = api.resolve(ents, cfg)
    res = stream.resolve_stream(_even_chunks(ents, 175), cfg,
                                chunk_size=175)
    assert mono.metrics.pairs_completeness < 1.0
    assert res.metrics.pairs_completeness == \
        pytest.approx(mono.metrics.pairs_completeness, abs=1e-12)
    assert res.metrics.reduction_ratio == \
        pytest.approx(mono.metrics.reduction_ratio, abs=1e-12)


def test_sequential_runner_stream(ents):
    cfg = _cfg(variant="srp", runner="sequential")
    mono = api.resolve(ents, cfg)
    res = stream.resolve_stream(_even_chunks(ents, 180), cfg,
                                chunk_size=180)
    assert res.pairs == mono.pairs
    assert res.matches == mono.matches


# -- multi-pass blocking ------------------------------------------------------------

def _passes():
    return (api.SortKeySpec(name="key"),
            api.SortKeySpec(name="text1", source="text", kind="prefix",
                            offset=1, width=2))


def test_multipass_union_equals_per_pass_oracle(ents):
    """resolve() under cfg.passes returns the union of the single-pass
    runs, and that union scores pairs_completeness == 1 against the union
    of the per-pass sequential oracles."""
    cfg = _cfg(compute_metrics=True, passes=_passes())
    res = api.resolve(ents, cfg)
    assert isinstance(res, api.MultiPassResult)
    singles = [api.resolve(
        {"key": K.derive_sort_key(ents, spec), "eid": ents["eid"],
         "valid": ents["valid"], "payload": ents["payload"]},
        cfg.with_(passes=())) for spec in cfg.passes]
    assert res.pairs == frozenset().union(*(s.pairs for s in singles))
    assert res.matches == frozenset().union(*(s.matches for s in singles))
    assert res.metrics.pairs_completeness == 1.0
    # the second key really adds recall (otherwise the test is vacuous)
    assert len(res.pairs) > len(res.passes[0].pairs)
    assert res.pass_result("key").pairs == res.passes[0].pairs


def test_multipass_stream_equals_monolithic(ents):
    cfg = _cfg(passes=_passes())
    mono = api.resolve(ents, cfg)
    res = stream.resolve_stream(_even_chunks(ents, 175), cfg,
                                chunk_size=175)
    assert res.pairs == mono.pairs
    assert res.matches == mono.matches
    assert res.pass_names == mono.pass_names
    for sp, mp in zip(res.passes, mono.passes):
        assert sp.pairs == mp.pairs


def test_multipass_rejects_explicit_bounds(ents):
    cfg = _cfg(passes=_passes())
    with pytest.raises(ValueError, match="bounds"):
        api.resolve(ents, cfg, bounds=np.asarray([10, 20, 30], np.int32))


def test_sort_key_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        api.SortKeySpec(kind="hash")
    with pytest.raises(ValueError, match="width"):
        api.SortKeySpec(kind="prefix", width=9)
    with pytest.raises(ValueError, match="unique"):
        api.ERConfig(passes=(api.SortKeySpec(), api.SortKeySpec()))


def test_multipass_link(ents):
    """link() under passes: union + per-pass pairs untagged to source id
    spaces, cross-source only."""
    rng = np.random.default_rng(9)
    lhs = E.synth_entities(rng, 250, n_keys=60, text_len=8)
    rhs = E.synth_entities(rng, 200, n_keys=60, text_len=8)
    cfg = _cfg(passes=_passes())
    res = api.link(lhs, rhs, cfg)
    assert isinstance(res, api.MultiPassResult)
    singles = [api.link(lhs, rhs, cfg.with_(passes=(spec,)))
               for spec in cfg.passes]
    assert res.pairs == frozenset().union(
        *(s.pairs for s in singles))
    n_l, n_r = 250, 200
    assert all(0 <= a < n_l and 0 <= b < n_r for a, b in res.pairs)


def test_link_stream_matches_link():
    rng = np.random.default_rng(12)
    lhs = E.synth_entities(rng, 260, n_keys=50)
    rhs = E.synth_entities(rng, 220, n_keys=50)
    cfg = _cfg()
    mono = api.link(lhs, rhs, cfg)
    res = stream.link_stream(_even_chunks(lhs, 100), _even_chunks(rhs, 90),
                             cfg, chunk_size=150)
    assert res.pairs == mono.pairs
    assert res.matches == mono.matches


# -- streaming machinery units ------------------------------------------------------

def test_merged_blocks_global_order(ents):
    """The k-way merge emits the exact global (key, eid) sort."""
    runs = ChunkStore()
    h = E.to_host(ents)
    for c in _chunks_of(ents, [200, 300, 150, 50]):
        dev = E.make_entities(c["key"], c["eid"], payload=c["payload"],
                              valid=c["valid"])
        runs.append(E.sort_chunk(dev))
    merged = E.host_concat(list(merged_blocks(runs, 128)))
    order = np.lexsort((h["eid"], h["key"]))
    np.testing.assert_array_equal(merged["key"], h["key"][order])
    np.testing.assert_array_equal(merged["eid"], h["eid"][order])


def test_rechunk_exact_sizes(ents):
    blocks = _chunks_of(ents, [37, 211, 3, 149, 300])
    out = list(rechunk(iter(blocks), 128))
    sizes = [int(c["key"].shape[0]) for c in out]
    assert sizes == [128] * (N // 128) + ([N % 128] if N % 128 else [])
    np.testing.assert_array_equal(
        E.host_concat(out)["eid"], E.to_host(ents)["eid"])


def test_chunk_store_spool_roundtrip(tmp_path, ents):
    mem = ChunkStore()
    disk = ChunkStore(str(tmp_path))
    for c in _chunks_of(ents, [300, 400]):
        mem.append(c)
        disk.append(c)
    assert disk.spooled_bytes > 0
    assert len(list(tmp_path.glob("raw*.npz"))) == 0   # prefix is "chunk"
    assert len(list(tmp_path.glob("chunk*.npz"))) == 2
    for i in range(2):
        a, b = mem.load(i), disk.load(i)
        np.testing.assert_array_equal(a["key"], b["key"])
        np.testing.assert_array_equal(a["eid"], b["eid"])
        for k in a["payload"]:
            np.testing.assert_array_equal(a["payload"][k], b["payload"][k])
        idx = disk.load_index(i)
        np.testing.assert_array_equal(idx["key"], a["key"])
    assert mem.n_entities == disk.n_entities == 700


def test_spooled_stream_matches_memory(tmp_path, ents):
    cfg = _cfg()
    res_mem = stream.resolve_stream(_even_chunks(ents, 175), cfg,
                                    chunk_size=175)
    res_disk = stream.resolve_stream(_even_chunks(ents, 175), cfg,
                                     chunk_size=175,
                                     spool_dir=str(tmp_path))
    assert res_disk.pairs == res_mem.pairs
    assert res_disk.stream.spooled_bytes > 0
    assert res_mem.stream.spooled_bytes == 0


def test_steady_state_and_residency_accounting(ents):
    """After the first chunk every chunk hits the executable cache, and the
    per-chunk device footprint is a fraction of the corpus footprint."""
    from repro.perf.cache import executable_cache
    executable_cache().clear()
    cfg = _cfg()
    res = stream.resolve_stream(_even_chunks(ents, 175), cfg,
                                chunk_size=175)
    assert res.stream.chunks == 4
    assert res.stream.steady_chunks == 3       # all but the first
    assert res.stream.cache_misses <= 2        # shard program + collectors
    # a second identical stream is steady from chunk 0
    res2 = stream.resolve_stream(_even_chunks(ents, 175), cfg,
                                 chunk_size=175)
    assert res2.stream.steady_chunks == res2.stream.chunks
    assert res2.stream.traces == 0
    # peak device input is bounded by the chunk, not the corpus
    assert res.stream.chunk_device_bytes < res.stream.corpus_bytes / 2
    assert res.stream.carry_entities == (W - 1) * 3


def test_stream_rejects_what_monolithic_rejects():
    """A halo-truncating config fails the stream with the monolithic
    facade's actionable error (validated once against the GLOBAL plan) —
    never as a silent cascade of collapsed chunks."""
    small = E.make_entities(np.arange(12, dtype=np.int32) % 4 * 3,
                            np.arange(12, dtype=np.int32),
                            payload={"feat": np.ones((12, 4), np.float32)})
    cfg = api.ERConfig(window=8, variant="repsn", hops=1, runner="vmap",
                       num_shards=4, partitioner="uniform")
    with pytest.raises(ValueError, match="hops"):
        api.resolve(small, cfg)
    with pytest.raises(ValueError, match="hops"):
        stream.resolve_stream([E.to_host(small)], cfg, chunk_size=6)


def test_multipass_spool_counts_raw_once(tmp_path, ents):
    """Per-pass stats spool only their own sorted runs; the shared raw
    store is stamped once at the top level."""
    cfg = _cfg(passes=_passes())
    res = stream.resolve_stream(_even_chunks(ents, 350), cfg,
                                chunk_size=350, spool_dir=str(tmp_path))
    raw_bytes = sum(f.stat().st_size for f in tmp_path.glob("raw*.npz"))
    assert raw_bytes > 0
    assert res.stream.spooled_bytes == raw_bytes + sum(
        p.stream.spooled_bytes for p in res.passes)


def test_profile_merge_is_exact(ents):
    keys = np.asarray(ents["key"])
    parts = np.array_split(keys, 5)
    merged = B.KeyProfile.empty(W)
    for p in parts:
        merged = merged.merge(B.profile_keys(p, window=W))
    full = B.profile_keys(keys, window=W)
    np.testing.assert_array_equal(merged.uniq, full.uniq)
    np.testing.assert_array_equal(merged.counts, full.counts)
    np.testing.assert_array_equal(merged.cum_comparisons,
                                  full.cum_comparisons)
    assert merged.n == full.n
    with pytest.raises(ValueError, match="window"):
        merged.merge(B.profile_keys(keys, window=W + 1))


def test_plan_from_profile_matches_plan_shards(ents):
    for part in ["balanced", "uniform", "blocksplit", "pairrange"]:
        cfg = _cfg(partitioner=part)
        full = B.plan_shards(ents, cfg, R)
        prof = B.plan_from_profile(
            B.profile_keys(np.asarray(ents["key"]), window=W), part, R)
        np.testing.assert_array_equal(np.asarray(full.bounds),
                                      np.asarray(prof.bounds))
        np.testing.assert_array_equal(np.asarray(full.rank_bounds),
                                      np.asarray(prof.rank_bounds))
        assert prof.rank_granular == (full.dest is not None)


# -- quality levers: adaptive windows + pruning stream bit-identically --------------

def _adaptive_cfg(**kw):
    kw.setdefault("window", 3)
    kw.setdefault("window_policy", "adaptive")
    kw.setdefault("window_max", 10)
    return _cfg(**kw)


@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_adaptive_stream_bit_identical(ents, variant, engine):
    """Invariant 9 under window_policy='adaptive': the streamed run's
    merged profile yields the monolithic per-entity weff, so chunked
    resolution over the window_max carry is bit-identical."""
    cfg = _adaptive_cfg(variant=variant, band_engine=engine)
    mono = api.resolve(ents, cfg)
    res = stream.resolve_stream(_even_chunks(ents, 175), cfg,
                                chunk_size=175)
    assert res.pairs == mono.pairs
    assert res.matches == mono.matches
    assert res.stream.chunks == 4
    # the carry keeps window_max-1 rows per seam: wider than fixed-w
    assert res.stream.carry_entities == (cfg.window_max - 1) * 3


def test_adaptive_prune_random_chunkings_property(ents):
    """The full quality path (adaptive windows + evidence pruning) over
    random input chunk sizes AND random device chunk_size reproduces the
    monolithic pair sets; the streamed pruned counter can only over-count
    (carry overlap re-prunes), never under-count."""
    cfg = _adaptive_cfg(prune_policy="evidence", prune_threshold=0.55)
    mono = api.resolve(ents, cfg)
    assert mono.blocking.pruned > 0            # the lever really engaged
    for seed in range(3):
        rng = np.random.default_rng(seed)
        sizes, left = [], N
        while left:
            s = int(rng.integers(1, min(left, 130) + 1))
            sizes.append(s)
            left -= s
        chunk_size = int(rng.integers(2, 140))
        res = stream.resolve_stream(_chunks_of(ents, sizes), cfg,
                                    chunk_size=chunk_size)
        assert res.pairs == mono.pairs, (seed, sizes, chunk_size)
        assert res.matches == mono.matches, (seed, sizes, chunk_size)
        assert res.blocking.pruned >= mono.blocking.pruned


def test_adaptive_multipass_stream_equals_monolithic(ents):
    cfg = _adaptive_cfg(passes=_passes())
    mono = api.resolve(ents, cfg)
    res = stream.resolve_stream(_even_chunks(ents, 175), cfg,
                                chunk_size=175)
    assert res.pairs == mono.pairs
    assert res.matches == mono.matches


def test_adaptive_prune_checkpoint_kill_resume(tmp_path, ents):
    """Invariant 11 holds for the quality path: a checkpointed adaptive +
    pruned stream killed mid-run resumes to the bit-identical union, and
    the resumed pruned counter matches an uninterrupted stream's."""
    from repro.resilience import FaultPlan, InjectedFault
    cfg = _adaptive_cfg(prune_policy="evidence", prune_threshold=0.55)
    mono = api.resolve(ents, cfg)
    plain = stream.resolve_stream(_even_chunks(ents, 140), cfg,
                                  chunk_size=140)
    for k in (1, 3):
        d = str(tmp_path / f"kill{k}")
        with pytest.raises(InjectedFault):
            stream.resolve_stream(_even_chunks(ents, 140), cfg,
                                  chunk_size=140, checkpoint_dir=d,
                                  fault_plan=FaultPlan(crash_after_chunk=k))
        res = api.resume(d)
        assert res.pairs == mono.pairs, k
        assert res.matches == mono.matches, k
        assert res.blocking.pruned == plain.blocking.pruned, k


def test_stream_weff_matches_monolithic_profile(ents):
    """The incrementally merged KeyProfile reproduces the full-corpus
    per-entity effective windows exactly (the reason invariant 9 extends
    to adaptive runs)."""
    from repro.quality import weff_for_keys
    keys = np.asarray(ents["key"])
    full = B.profile_keys(keys, window=3)
    merged = B.KeyProfile.empty(3)
    for part in np.array_split(keys, 6):
        merged = merged.merge(B.profile_keys(part, window=3))
    np.testing.assert_array_equal(
        weff_for_keys(keys, full, 3, 10),
        weff_for_keys(keys, merged, 3, 10))
    assert weff_for_keys(keys, full, 3, 10).max() > 3   # density engaged
