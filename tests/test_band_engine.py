"""BandEngine seam: pallas-vs-scan parity + cascade accounting.

The pallas engine (fused cheap-band kernel -> cumsum compaction -> exact
matcher on survivors, core/window.py) must reproduce the scan oracle's
blocked AND matched pair sets exactly — across all three variants, both
device runners, awkward M/block geometry, and the linkage cross-source mask.
Kernels run under the Pallas interpreter on CPU (same code path compiles
natively on TPU).

Also covered: the cand_cap capacity model (overflow counted, matches-only
losses), the cumsum compaction primitive, and the §5.1 FLOP claim
(matcher_evals(pallas) == compacted candidates <= band slots == scan).
"""
import numpy as np
import pytest

from repro import api
from repro.core import entities as E
from repro.core import partition as P
from repro.core import window as W

N, R, WIN, NK = 260, 4, 6, 64
BB = 32          # small band_block so shards (M=260) span many blocks


@pytest.fixture(scope="module")
def ents():
    return E.synth_entities(np.random.default_rng(11), N, n_keys=NK,
                            dup_frac=0.25)


@pytest.fixture(scope="module")
def bounds(ents):
    return P.balanced_partition(np.asarray(ents["key"]), R)


def _cfg(**kw):
    kw.setdefault("window", WIN)
    kw.setdefault("num_shards", R)
    kw.setdefault("hops", R - 1)
    kw.setdefault("band_block", BB)
    kw.setdefault("band_interpret", True)
    return api.ERConfig(**kw)


@pytest.mark.parametrize("variant", ["srp", "repsn", "jobsn"])
def test_vmap_parity_all_variants(ents, bounds, variant):
    """Acceptance: identical blocked/matched sets, and — with a finite
    cand_cap sized above the survivor count — the pallas engine's
    expensive-matcher evaluations (its cand_cap buffer) stay well under the
    scan engine's one-per-band-slot cost."""
    cfg = _cfg(variant=variant, runner="vmap")
    scan = api.resolve(ents, cfg, bounds=bounds)
    pal = api.resolve(ents, cfg.with_(band_engine="pallas", cand_cap=256),
                      bounds=bounds)
    assert pal.blocking.pairs == scan.blocking.pairs
    assert pal.matches == scan.matches
    assert pal.blocking.cand_overflow == 0
    # the FLOP lever: the cap-sized buffer, vs every (w-1, M) band slot
    assert 0 < pal.blocking.matcher_evals < scan.blocking.matcher_evals
    # every match is a gate survivor, every kept survivor was scored;
    # cand_count is per-shard (the public probe for the cand_cap sizing rule)
    assert len(pal.blocking.cand_count) == R
    assert len(pal.matches) <= sum(pal.blocking.cand_count) \
        <= pal.blocking.matcher_evals


@pytest.mark.parametrize("variant", ["srp", "repsn", "jobsn"])
def test_shard_map_parity(ents, variant):
    """Same contract under the real-device runner (in-process mesh)."""
    r = api.ShardMapRunner().shards
    cfg = _cfg(variant=variant, runner="shard_map",
               hops=max(r - 1, 1))
    b = api.default_bounds(ents, cfg, r)
    scan = api.resolve(ents, cfg, bounds=b)
    pal = api.resolve(ents, cfg.with_(band_engine="pallas"), bounds=b)
    assert pal.blocking.pairs == scan.blocking.pairs
    assert pal.matches == scan.matches


@pytest.mark.parametrize("n,band_block,window", [
    (300, 128, 6),     # M not a multiple of block_i (padding path)
    (130, 8, 9),       # window-1 == band_block (band fills the block)
    (40, 64, 5),       # M < block_i (block clamped, then padded)
])
def test_parity_block_geometry(n, band_block, window):
    ents = E.synth_entities(np.random.default_rng(3), n, n_keys=32,
                            dup_frac=0.3)
    bounds = P.balanced_partition(np.asarray(ents["key"]), 2)
    cfg = _cfg(window=window, variant="repsn", runner="vmap", num_shards=2,
               hops=1, band_block=band_block)
    scan = api.resolve(ents, cfg, bounds=bounds)
    pal = api.resolve(ents, cfg.with_(band_engine="pallas"), bounds=bounds)
    assert pal.blocking.pairs == scan.blocking.pairs
    assert pal.matches == scan.matches


@pytest.mark.parametrize("variant", ["srp", "repsn", "jobsn"])
def test_linkage_parity(variant):
    """Cross-source band mask feeds the cascade gate BEFORE compaction, so
    linkage runs must agree engine-to-engine too."""
    rng = np.random.default_rng(5)
    lhs = E.synth_entities(rng, 200, n_keys=48, dup_frac=0.0)
    take = rng.permutation(200)[:80]
    rhs = E.make_entities(
        np.asarray(lhs["key"])[take], np.arange(80, dtype=np.int32),
        payload={k: np.asarray(v)[take] for k, v in lhs["payload"].items()})
    cfg = _cfg(window=5, variant=variant, runner="vmap")
    scan = api.link(lhs, rhs, cfg)
    pal = api.link(lhs, rhs, cfg.with_(band_engine="pallas"))
    assert pal.blocking.pairs == scan.blocking.pairs
    assert pal.matches == scan.matches
    assert scan.matches        # planted duplicates must be found


@pytest.mark.parametrize("variant", ["srp", "repsn", "jobsn"])
def test_auto_mode_jnp_cheap_band_parity(ents, bounds, variant):
    """band_interpret=None off-TPU routes the cheap stage through
    window.cheap_band_jnp (band-shaped jnp, no tile kernel) — the path
    every real CPU user of band_engine='pallas' takes; it must reproduce
    the scan oracle exactly, like the forced-interpreter kernel path the
    other parity tests pin."""
    cfg = _cfg(variant=variant, runner="vmap", band_interpret=None)
    scan = api.resolve(ents, cfg, bounds=bounds)
    pal = api.resolve(ents, cfg.with_(band_engine="pallas", cand_cap=256),
                      bounds=bounds)
    assert pal.blocking.pairs == scan.blocking.pairs
    assert pal.matches == scan.matches
    assert pal.blocking.cand_overflow == 0


def test_cheap_band_jnp_matches_kernel_math(ents):
    """The jnp cheap band computes the same weighted partial scores as the
    matchers it mirrors, row d-1 holding distance-d pairs."""
    import jax.numpy as jnp
    from repro.core.match import cosine_sim, jaccard_sig, default_matcher
    payload = {k: np.asarray(v)[:64] for k, v in ents["payload"].items()}
    payload = {k: jnp.asarray(v) for k, v in payload.items()}
    matcher = default_matcher()
    split = W.split_cascade(matcher, payload)
    w = 5
    rows = np.asarray(W.cheap_band_jnp(payload, split, w))
    for d in range(1, w):
        want = split.w_cos * cosine_sim(
            payload["feat"], jnp.roll(payload["feat"], -d, axis=0)) + \
            split.w_jac * jaccard_sig(
                payload["sig"], jnp.roll(payload["sig"], -d, axis=0))
        np.testing.assert_allclose(rows[d - 1], np.asarray(want), rtol=1e-6)


def test_cand_cap_overflow_counted(ents, bounds):
    """cand_cap exceeded: counted in cand_overflow, never silent — blocked
    pairs are untouched (pre-compaction mask) and at most cand_overflow
    matches can be lost (the SRP capacity model applied to matching)."""
    cfg = _cfg(variant="srp", runner="vmap")
    full = api.resolve(ents, cfg.with_(band_engine="pallas"), bounds=bounds)
    tight = api.resolve(ents, cfg.with_(band_engine="pallas", cand_cap=4),
                        bounds=bounds)
    assert tight.blocking.cand_overflow > 0
    assert tight.blocking.pairs == full.blocking.pairs
    assert tight.matches <= full.matches
    assert len(full.matches - tight.matches) <= tight.blocking.cand_overflow
    # roomy cap -> identical outcome, zero overflow
    roomy = api.resolve(ents, cfg.with_(band_engine="pallas", cand_cap=4096),
                        bounds=bounds)
    assert roomy.blocking.cand_overflow == 0
    assert roomy.matches == full.matches


def test_compact_candidates_cumsum():
    """The cumsum compaction packs gate survivors in band order and accounts
    for capacity exactly."""
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    gate = jnp.asarray(rng.random((5, 37)) < 0.2)
    want = np.flatnonzero(np.asarray(gate).reshape(-1))
    for cap in [3, len(want), 4 * len(want) + 1]:
        ci, cd, cv, n_cand, ovf = W.compact_candidates(gate, cap)
        ci, cd, cv = np.asarray(ci), np.asarray(cd), np.asarray(cv)
        assert int(n_cand) == len(want)
        assert int(ovf) == max(len(want) - cap, 0)
        kept = min(cap, len(want))
        assert cv.sum() == kept
        got_flat = (cd[:kept] - 1) * 37 + ci[:kept]
        np.testing.assert_array_equal(got_flat, want[:kept])


def test_unsupported_cascade_falls_back_to_scan(ents, bounds):
    """A cascade whose first matcher has no kernel (edit distance) cannot be
    gated by the fused kernel — the pallas engine must fall back to the scan
    oracle rather than mis-gate."""
    from repro.core.match import CascadeMatcher, Matcher
    payload = dict(ents["payload"])
    payload["text"] = np.zeros((N, 8), np.uint8)
    tents = E.make_entities(ents["key"], ents["eid"], payload=payload)
    matcher = CascadeMatcher(
        matchers=(Matcher(field="text", kind="edit", weight=1.0),),
        threshold=0.9)
    cfg = _cfg(variant="srp", runner="vmap", matcher=matcher)
    scan = api.resolve(tents, cfg, bounds=bounds)
    pal = api.resolve(tents, cfg.with_(band_engine="pallas"), bounds=bounds)
    assert pal.blocking.pairs == scan.blocking.pairs
    assert pal.matches == scan.matches


def test_band_engine_config_validation():
    with pytest.raises(ValueError, match="unknown band engine"):
        api.ERConfig(band_engine="pallass")
    with pytest.raises(ValueError, match="band_block"):
        api.ERConfig(band_engine="pallas", window=300, band_block=256)
    with pytest.raises(ValueError, match="cand_cap"):
        api.ERConfig(cand_cap=-1)
    # scan engine has no block constraint
    api.ERConfig(band_engine="scan", window=300, band_block=256)


# -- packed pair plumbing -----------------------------------------------------------


def test_packed_pair_roundtrip():
    rng = np.random.default_rng(1)
    a = rng.integers(0, 2**31 - 1, size=1000)
    b = rng.integers(0, 2**31 - 1, size=1000)
    packed = api.pack_pairs(a, b)
    lo, hi = api.unpack_pairs(packed)
    np.testing.assert_array_equal(lo, np.minimum(a, b))
    np.testing.assert_array_equal(hi, np.maximum(a, b))
    assert api.packed_to_frozenset(packed) == \
        {(int(min(x, y)), int(max(x, y))) for x, y in zip(a, b)}


def test_packed_collection_matches_set_baseline(ents, bounds):
    """packed_pairs_from_band (hot path) == pairs_from_band (reference)."""
    cfg = _cfg(variant="jobsn", runner="vmap")
    out = api.VmapRunner(R).run_raw(ents, bounds, cfg)
    for part in ["main", "boundary"]:
        for field in ["mask", "match"]:
            packed = api.packed_pairs_from_band(out[part], field)
            assert api.packed_to_frozenset(packed) == \
                api.pairs_from_band(out[part], field)
