"""The ``repro.api`` facade contract.

  * resolve() returns identical blocked AND matched pair sets for every
    (variant in {srp, repsn, jobsn}) x (runner in {sequential, vmap}) combo
    (shard_map is covered on real devices in test_distributed_cpu.py, and
    in-process on a 1-device mesh here)
  * JobSN boundary dedup: main and boundary passes never double-count a pair
  * cap_factor overflow accounting (srp_shard's counts survive the facade)
  * dual-source linkage emits only cross-source pairs == the linkage oracle
  * the variant registry is open (custom variants) and validating
  * old core.pipeline entry points still work via deprecation shims
"""
import warnings

import numpy as np
import pytest

from repro import api
from repro.core import entities as E
from repro.core import partition as P
from repro.core import sn

N, R, W, NK = 260, 4, 6, 64


@pytest.fixture(scope="module")
def ents():
    return E.synth_entities(np.random.default_rng(11), N, n_keys=NK,
                            dup_frac=0.25)


@pytest.fixture(scope="module")
def bounds(ents):
    return P.balanced_partition(np.asarray(ents["key"]), R)


@pytest.mark.parametrize("variant", ["srp", "repsn", "jobsn"])
def test_runners_agree_with_sequential_oracle(ents, bounds, variant):
    """Acceptance: every runner reproduces the sequential oracle's pair sets
    under the variant's semantics (srp: per-partition; others: complete)."""
    cfg = api.ERConfig(window=W, variant=variant, runner="sequential",
                       num_shards=R, hops=R - 1)
    seq = api.resolve(ents, cfg, bounds=bounds)
    res = api.resolve(ents, cfg.with_(runner="vmap"), bounds=bounds)
    assert res.blocking.pairs == seq.blocking.pairs, variant
    assert res.matches == seq.matches, variant
    assert res.blocking.overflow == 0
    assert sum(res.blocking.load) == N
    # shard_map in-process: mesh over however many local devices exist (the
    # 8-device run lives in test_distributed_cpu) — bounds must match r
    r_sm = api.ShardMapRunner().shards
    b_sm = api.default_bounds(ents, cfg, r_sm)
    sm = api.resolve(ents, cfg.with_(runner="shard_map",
                                     hops=max(r_sm - 1, 1)), bounds=b_sm)
    seq_sm = api.resolve(ents, cfg.with_(num_shards=r_sm,
                                         hops=max(r_sm - 1, 1)), bounds=b_sm)
    assert sm.blocking.pairs == seq_sm.blocking.pairs, variant
    assert sm.matches == seq_sm.matches, variant
    # boundary-complete variants == the full sequential SN pair set
    keys, eids = np.asarray(ents["key"]), np.asarray(ents["eid"])
    full = sn.sequential_sn_pairs(keys, eids, W)
    if variant == "srp":
        assert set(seq.blocking.pairs) <= full
    else:
        assert set(seq.blocking.pairs) == full


def test_metrics_vs_oracle(ents, bounds):
    res = api.resolve(ents, api.ERConfig(
        window=W, variant="repsn", hops=R - 1, runner="vmap", num_shards=R,
        compute_metrics=True), bounds=bounds)
    m = res.metrics
    assert m is not None
    assert m.pairs_completeness == 1.0          # RepSN is complete
    assert 0.0 < m.reduction_ratio < 1.0        # blocking prunes comparisons
    assert m.total_comparisons == N * (N - 1) // 2
    srp = api.resolve(ents, api.ERConfig(
        window=W, variant="srp", runner="vmap", num_shards=R,
        compute_metrics=True), bounds=bounds)
    assert srp.metrics.pairs_completeness < 1.0  # boundary pairs missed


def test_jobsn_boundary_dedup(ents, bounds):
    """Main and boundary passes partition the pair set: no pair is counted
    by both (mode='cross' is the paper's lineage-prefix duplicate filter),
    and their union is exactly the sequential SN pair set."""
    cfg = api.ERConfig(window=W, variant="jobsn", runner="vmap",
                       num_shards=R)
    out = api.VmapRunner(R).run_raw(ents, bounds, cfg)
    main = api.pairs_from_band(out["main"], "mask")
    boundary = api.pairs_from_band(out["boundary"], "mask")
    assert main and boundary                      # both passes contribute
    assert not (main & boundary)                  # counted once
    loads = np.asarray(out["load"])[0]
    if (loads >= W - 1).all():                    # paper's size assumption
        keys, eids = np.asarray(ents["key"]), np.asarray(ents["eid"])
        assert main | boundary == sn.sequential_sn_pairs(keys, eids, W)
    # collect() must agree with the manual union (dedup via packed np.unique)
    col = api.get_variant("jobsn").collect(out)
    col_blocked = api.packed_to_frozenset(col.blocked)
    assert col_blocked == main | boundary
    assert len(col_blocked) == len(main) + len(boundary)


def test_cap_factor_overflow_reported():
    """srp_shard's capacity-overflow count survives to BlockingResult and
    balances the books: survivors + dropped == n (nothing silently lost)."""
    rng = np.random.default_rng(0)
    n, r = 128, 4
    ents = E.synth_entities(rng, n, n_keys=16, skew=0.9)
    bounds = P.range_partition(16, r)
    tight = api.resolve(ents, api.ERConfig(
        window=3, variant="srp", cap_factor=1.0, runner="vmap",
        num_shards=r), bounds=bounds)
    assert tight.blocking.overflow > 0
    assert tight.blocking.total_load + tight.blocking.overflow == n
    roomy = api.resolve(ents, api.ERConfig(
        window=3, variant="srp", cap_factor=0.0, runner="vmap",
        num_shards=r), bounds=bounds)
    assert roomy.blocking.overflow == 0
    assert roomy.blocking.total_load == n


# -- dual-source (R x S) linkage --------------------------------------------------


@pytest.fixture(scope="module")
def sources():
    """Two sources with planted cross-source duplicates: rhs is a perturbed
    sample of lhs (same keys/payloads), so matches must be found."""
    rng = np.random.default_rng(5)
    lhs = E.synth_entities(rng, 200, n_keys=48, dup_frac=0.0)
    take = rng.permutation(200)[:80]
    rhs = {
        "key": np.asarray(lhs["key"])[take],
        "eid": np.arange(80, dtype=np.int32),
        "valid": np.ones(80, bool),
        "payload": {k: np.asarray(v)[take]
                    for k, v in lhs["payload"].items()},
    }
    return lhs, E.make_entities(rhs["key"], rhs["eid"],
                                payload=rhs["payload"])


@pytest.mark.parametrize("runner", ["sequential", "vmap"])
@pytest.mark.parametrize("variant", ["srp", "repsn", "jobsn"])
def test_linkage_cross_source_only(sources, runner, variant):
    lhs, rhs = sources
    w = 5
    res = api.link(lhs, rhs, api.ERConfig(
        window=w, variant=variant, runner=runner, num_shards=R, hops=R - 1))
    merged, offset = api.tag_sources(lhs, rhs)
    keys = np.asarray(merged["key"])
    eids = np.asarray(merged["eid"])
    src = np.asarray(merged["payload"]["src"])
    oracle = api.linkage.untag_pairs(
        api.sequential_link_pairs(keys, eids, src, w), offset)
    got = set(res.blocking.pairs)
    # every pair is (lhs_eid, rhs_eid) — cross-source by construction
    n_l, n_r = 200, 80
    assert all(0 <= a < n_l and 0 <= b < n_r for a, b in got)
    if variant == "srp":
        assert got <= oracle
    else:
        assert got == oracle
    # planted duplicates are found, and matches are blocked pairs
    assert res.matches and res.matches <= res.blocking.pairs
    assert any(np.asarray(lhs["key"])[a] == np.asarray(rhs["key"])[b]
               for a, b in res.matches)


def test_linkage_parallel_equals_sequential(sources):
    lhs, rhs = sources
    cfg = api.ERConfig(window=5, variant="repsn", hops=R - 1, num_shards=R)
    seq = api.link(lhs, rhs, cfg.with_(runner="sequential"))
    vm = api.link(lhs, rhs, cfg.with_(runner="vmap"))
    assert seq.blocking.pairs == vm.blocking.pairs
    assert seq.matches == vm.matches


# -- registry ----------------------------------------------------------------------


def test_config_and_facade_validation(ents):
    with pytest.raises(ValueError, match="unknown runner"):
        api.ERConfig(runner="vmapp")
    with pytest.raises(ValueError, match="unknown partitioner"):
        api.ERConfig(partitioner="balance")
    with pytest.raises(ValueError, match="window"):
        api.ERConfig(window=1)
    # bounds/shards mismatch would silently drop entities — rejected
    with pytest.raises(ValueError, match="partitions"):
        api.resolve(ents, api.ERConfig(runner="vmap", num_shards=4),
                    bounds=P.range_partition(NK, 8))
    # halo variants need w-1 slots per shard: clear error, not a deep crash
    tiny = E.synth_entities(np.random.default_rng(1), 3, n_keys=4)
    with pytest.raises(ValueError, match="per-shard buffer"):
        api.resolve(tiny, api.ERConfig(window=10, variant="repsn",
                                       runner="vmap", num_shards=2))


def test_registry_is_open_and_validating(ents, bounds):
    assert set(api.available_variants()) >= {"srp", "repsn", "jobsn"}
    with pytest.raises(ValueError, match="unknown SN variant"):
        api.get_variant("nope")

    from repro.api.variants import SrpVariant

    @api.register_variant("srp_test_alias")
    class AliasVariant(SrpVariant):
        pass

    try:
        res = api.resolve(ents, api.ERConfig(
            window=W, variant="srp_test_alias", runner="vmap",
            num_shards=R), bounds=bounds)
        srp = api.resolve(ents, api.ERConfig(
            window=W, variant="srp", runner="vmap", num_shards=R),
            bounds=bounds)
        assert res.blocking.pairs == srp.blocking.pairs
    finally:
        from repro.api import variants as V
        V._REGISTRY.pop("srp_test_alias", None)


# -- deprecation shims -------------------------------------------------------------


def test_old_pipeline_entry_points_still_work(ents, bounds):
    from repro.core import pipeline as PL
    cfg = PL.SNConfig(window=W, variant="jobsn")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        out = PL.run_vmap(ents, R, bounds, cfg)
        blocked = PL.blocked_pairs(out)
        matched = PL.result_pairs(out)
    res = api.resolve(ents, api.ERConfig(window=W, variant="jobsn",
                                         runner="vmap", num_shards=R),
                      bounds=bounds)
    assert blocked == set(res.blocking.pairs)
    assert matched == set(res.matches)
    with pytest.raises(ValueError, match="unknown SN variant"):
        PL.sn_shard(ents, bounds, R, "sn", PL.SNConfig(variant="bogus"))


def test_old_entry_points_warn(ents, bounds):
    from repro.core import pipeline as PL
    with pytest.warns(DeprecationWarning):
        out = PL.run_vmap(ents, R, bounds, PL.SNConfig(window=3))
    with pytest.warns(DeprecationWarning):
        PL.blocked_pairs(out)
