"""Integration tests on REAL multiple devices (8 CPU host devices via
subprocess — jax locks the device count at first init, so these re-exec)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_with_devices(n, body: str, timeout=900) -> dict:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
            " --xla_force_host_platform_device_count={n}")
        import json
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("@@R@@" + json.dumps(out))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO}/src:{REPO}:" + env.get("PYTHONPATH", "")
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    for line in p.stdout.splitlines():
        if line.startswith("@@R@@"):
            return json.loads(line[5:])
    raise AssertionError(f"subprocess failed:\n{p.stdout[-3000:]}\n"
                         f"{p.stderr[-3000:]}")


def test_sn_pipeline_shard_map_matches_oracle():
    """The REAL-collective path (repro.api resolve with the shard_map runner
    over 8 devices) produces exactly the sequential SN pair set — same
    oracle as the vmap property tests."""
    out = run_with_devices(8, """
        import numpy as np, jax
        from repro import api
        from repro.core import entities as E, partition as P, sn
        rng = np.random.default_rng(5)
        n, w, nk = 400, 6, 128
        ents = E.synth_entities(rng, n, n_keys=nk, dup_frac=0.3)
        keys, eids = np.asarray(ents["key"]), np.asarray(ents["eid"])
        oracle = sn.sequential_sn_pairs(keys, eids, w)
        mesh = jax.make_mesh((8,), ("data",))
        res = {}
        for variant in ["repsn", "jobsn"]:
            o = api.resolve(ents,
                            api.ERConfig(window=w, variant=variant, hops=7,
                                         runner="shard_map"),
                            bounds=P.balanced_partition(keys, 8), mesh=mesh)
            got = set(o.blocking.pairs)
            res[variant] = [len(oracle - got), len(got - oracle),
                            o.blocking.overflow]
        out = res
    """)
    assert out["repsn"] == [0, 0, 0]
    assert out["jobsn"] == [0, 0, 0]


def test_dual_source_linkage_shard_map():
    """Dual-source R x S linkage on real devices: only cross-source pairs,
    equal to the host linkage oracle."""
    out = run_with_devices(8, """
        import numpy as np, jax
        from repro import api
        from repro.core import entities as E
        rng = np.random.default_rng(9)
        w = 5
        lhs = E.synth_entities(rng, 300, n_keys=96, dup_frac=0.0)
        take = rng.permutation(300)[:120]
        rhs = E.make_entities(np.asarray(lhs["key"])[take],
                              np.arange(120, dtype=np.int32),
                              payload={k: np.asarray(v)[take]
                                       for k, v in lhs["payload"].items()})
        mesh = jax.make_mesh((8,), ("data",))
        merged, offset = api.tag_sources(lhs, rhs)
        oracle = api.linkage.untag_pairs(api.sequential_link_pairs(
            np.asarray(merged["key"]), np.asarray(merged["eid"]),
            np.asarray(merged["payload"]["src"]), w), offset)
        res = api.link(lhs, rhs,
                       api.ERConfig(window=w, variant="repsn", hops=7,
                                    runner="shard_map"), mesh=mesh)
        got = set(res.blocking.pairs)
        out = {"diff": [len(oracle - got), len(got - oracle)],
               "n_matches": len(res.matches),
               "cross_only": all(0 <= a < 300 and 0 <= b < 120
                                 for a, b in got)}
    """)
    assert out["diff"] == [0, 0]
    assert out["cross_only"]
    assert out["n_matches"] > 0


def test_moe_distributed_matches_single_device():
    """shard_map MoE (EP over model axis) == single-device oracle."""
    out = run_with_devices(8, """
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import ARCHS, smoke_variant
        from repro.models import moe as MO
        from repro.sharding.rules import Rules
        cfg = smoke_variant(ARCHS["qwen3-moe-235b-a22b"])
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rules = Rules(mesh, fsdp=False)
        p = MO.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
        with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
            y_dist, aux_d, _ = jax.jit(
                lambda p, x: MO.moe_apply(p, x, cfg, rules=rules))(p, x)
        y_ref, aux_r, _ = MO.moe_apply(p, x, cfg, rules=None)
        out = {
            "max_err": float(jnp.abs(y_dist - y_ref).max()),
            "ref_scale": float(jnp.abs(y_ref).max()),
            "aux_err": abs(float(aux_d) - float(aux_r)),
        }
    """)
    assert out["max_err"] <= 2e-4 * max(out["ref_scale"], 1.0), out
    assert out["aux_err"] < 1e-5


def test_train_step_distributed_runs():
    """One real distributed train step (fsdp x tp on 8 devices): finite loss
    and sharded params."""
    out = run_with_devices(8, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import ARCHS, smoke_variant
        from repro.configs.base import RunConfig, ShapeConfig
        from repro.models import lm
        from repro.sharding.rules import Rules
        from repro.train import steps, optim
        cfg = smoke_variant(ARCHS["gemma2-9b"])
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = Rules(mesh, fsdp=True)
        run = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 8, "train"),
                        remat="block", microbatch=2)
        ts = steps.make_train_step(cfg, run, rules)
        state = steps.train_state_init(jax.random.PRNGKey(0), cfg,
                                       jnp.float32)
        sh = steps.resolve_shardings(rules, steps.train_state_specs(cfg),
                                     state)
        state = jax.tree.map(jax.device_put, state, sh)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                  cfg.vocab_size)
        batch = {"tokens": toks, "labels": toks}
        with mesh:
            state2, m = jax.jit(ts, donate_argnums=(0,))(state, batch)
        out = {"loss": float(m["loss"]),
               "gnorm": float(m["grad_norm"])}
    """)
    assert np.isfinite(out["loss"]) if (np := __import__("numpy")) else True
    assert out["gnorm"] > 0
