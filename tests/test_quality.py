"""Ground-truth match quality (ISSUE 10): the repro.quality harness.

  * labeled-corpus construction: deterministic gold pair sets, one key
    block per duplicate cluster, typo corruption bounded and recoverable
  * metric math: PC / PQ / RR / F computed exactly from packed pair sets
  * the clean-corpus full-window gate: boundary-complete SN at
    w >= max block with pruning off is exhaustive (PC = 1.0)
  * multi-pass PC >= single-pass, and adaptive-window PC >= fixed-w at
    equal-or-better reduction ratio — all 3 variants x {scan, pallas}
  * adaptive runs keep sequential == device parity and an oracle-complete
    metrics shortcut
  * evidence pruning (meta-blocking): scan == pallas == sequential pair
    sets AND pruned counters, and invariant 14 — no pair whose cheap
    evidence clears the threshold is ever pruned (checked literally
    against host-recomputed evidence, gold and non-gold alike)
  * config-surface validation for the four new quality levers
"""
import numpy as np
import pytest

from repro import api, quality, stream
from repro.core import entities as E
from repro.core import window as W
from repro.core.match import cosine_sim, jaccard_sig, default_matcher
from repro.data.truth import labeled_corpus

R = 4
VARIANTS = ["srp", "repsn", "jobsn"]
ENGINES = ["scan", "pallas"]
WBASE, WMID, WMAX = 4, 8, 12
THR = 0.55


def _cfg(**kw):
    kw.setdefault("window", WBASE)
    kw.setdefault("num_shards", R)
    kw.setdefault("variant", "repsn")
    kw.setdefault("hops", R - 1)
    kw.setdefault("runner", "vmap")
    return api.ERConfig(**kw)


def _adaptive(**kw):
    kw.setdefault("window_policy", "adaptive")
    kw.setdefault("window_max", WMAX)
    return _cfg(**kw)


@pytest.fixture(scope="module")
def clean():
    return labeled_corpus(0, 600, max_cluster=WMAX, typo_rate=0.0)


@pytest.fixture(scope="module")
def dirty():
    return labeled_corpus(1, 600, max_cluster=WMAX, typo_rate=0.12)


# -- labeled corpus -----------------------------------------------------------

def test_labeled_corpus_is_deterministic_and_consistent():
    a = labeled_corpus(3, 500, max_cluster=10, typo_rate=0.2)
    b = labeled_corpus(3, 500, max_cluster=10, typo_rate=0.2)
    np.testing.assert_array_equal(np.asarray(a.ents["key"]),
                                  np.asarray(b.ents["key"]))
    assert a.gold == b.gold
    np.testing.assert_array_equal(a.gold_packed, b.gold_packed)
    assert a.n == 500 and a.max_block == a.max_cluster == 10
    assert len(a.gold) == a.gold_packed.size       # packing is lossless
    assert all(lo < hi for lo, hi in a.gold)
    # the forced max cluster exists: some unit contributes C(10,2) pairs
    assert len(a.gold) >= 45
    assert 0 < a.n_typos < 500


def test_labeled_corpus_gold_pairs_share_unit():
    tc = labeled_corpus(4, 300, max_cluster=6)
    alt = np.asarray(tc.ents["payload"]["alt"])
    eids = np.asarray(tc.ents["eid"])
    by_eid = np.empty(tc.n, np.int32)
    by_eid[eids] = alt
    assert all(by_eid[lo] == by_eid[hi] for lo, hi in tc.gold)
    # and completeness: every unit of size c contributes C(c,2) pairs
    _, counts = np.unique(alt, return_counts=True)
    assert len(tc.gold) == int((counts * (counts - 1) // 2).sum())


def test_labeled_corpus_validation():
    with pytest.raises(ValueError, match="max_cluster"):
        labeled_corpus(0, 100, max_cluster=1)
    with pytest.raises(ValueError, match="typo_rate"):
        labeled_corpus(0, 100, typo_rate=1.0)


# -- metric math --------------------------------------------------------------

def test_metric_math_exact():
    """PC/PQ/RR/F from hand-countable sets: 6 gold, blocked catches 4 of
    them in 10 candidates out of 45 possible comparisons."""
    gold = [(0, 1), (0, 2), (1, 2), (3, 4), (3, 5), (4, 5)]
    blocked = [(0, 1), (0, 2), (1, 2), (3, 4),
               (6, 7), (6, 8), (7, 8), (0, 9), (1, 9), (2, 9)]

    class Truth:
        n = 10
        gold_packed = np.unique(
            np.asarray([(a << 32) | b for a, b in gold], np.uint64))

    q = quality.evaluate(
        np.asarray([(a << 32) | b for a, b in blocked], np.uint64), Truth())
    assert q.gold_pairs == 6 and q.blocked_pairs == 10
    assert q.true_positives == 4
    assert q.pairs_completeness == pytest.approx(4 / 6)
    assert q.pairs_quality == pytest.approx(4 / 10)
    assert q.total_comparisons == 45
    assert q.reduction_ratio == pytest.approx(1 - 10 / 45)
    pc, pq = 4 / 6, 4 / 10
    assert q.f_measure == pytest.approx(2 * pc * pq / (pc + pq))


def test_attach_surfaces_quality_on_metrics(clean):
    res = api.resolve(clean.ents, _cfg(window=WMAX, compute_metrics=True))
    out = quality.attach(res, clean)
    assert out.metrics.quality is not None
    assert out.metrics.quality.pairs_completeness == 1.0
    assert out.metrics.quality.gold_pairs == len(clean.gold)
    # the oracle-relative completeness the repo always reported is intact
    assert out.metrics.pairs_completeness == 1.0


# -- the full-window gate -----------------------------------------------------

@pytest.mark.parametrize("variant", ["repsn", "jobsn"])
def test_full_window_clean_corpus_is_exhaustive(clean, variant):
    """Pruning off + w >= the largest key block: boundary-complete SN
    must find every gold pair (the PC=1.0 gate BENCH_recall.json keeps)."""
    res = api.resolve(clean.ents, _cfg(window=clean.max_block,
                                       variant=variant))
    q = quality.evaluate(res, clean)
    assert q.pairs_completeness == 1.0
    assert q.true_positives == q.gold_pairs == len(clean.gold)


# -- multi-pass PC >= single-pass ---------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_multipass_pc_geq_single_pass(dirty, variant, engine):
    """The alt-key pass recovers typo-corrupted cluster members: union PC
    is strictly above the key-only pass for boundary-complete variants
    (srp keeps >=: partition cuts apply to both runs)."""
    cfg = _cfg(window=WMAX, variant=variant, band_engine=engine)
    single = quality.evaluate(api.resolve(dirty.ents, cfg), dirty)
    multi_cfg = cfg.with_(passes=(
        api.SortKeySpec(name="key"),
        api.SortKeySpec(name="alt", source="alt", kind="identity")))
    multi = quality.evaluate(api.resolve(dirty.ents, multi_cfg), dirty)
    if variant == "srp":
        assert multi.pairs_completeness >= single.pairs_completeness
    else:
        assert single.pairs_completeness < 1.0          # typos really bite
        assert multi.pairs_completeness > single.pairs_completeness


# -- adaptive windows dominate fixed-w ----------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
@pytest.mark.parametrize("variant", VARIANTS)
def test_adaptive_pc_geq_fixed_at_better_rr(clean, variant, engine):
    """window_policy='adaptive' (base WBASE grown to block density, cap
    WMAX) reaches PC >= a fixed mid window at equal-or-better reduction
    ratio — strictly higher PC for boundary-complete variants (the fixed
    window misses far pairs inside blocks wider than WMID)."""
    fixed = quality.evaluate(
        api.resolve(clean.ents, _cfg(window=WMID, variant=variant,
                                     band_engine=engine)), clean)
    adapt = quality.evaluate(
        api.resolve(clean.ents, _adaptive(variant=variant,
                                          band_engine=engine)), clean)
    assert adapt.blocked_pairs <= fixed.blocked_pairs   # equal-or-better RR
    assert adapt.reduction_ratio >= fixed.reduction_ratio
    if variant == "srp":
        assert adapt.pairs_completeness >= fixed.pairs_completeness
    else:
        assert adapt.pairs_completeness == 1.0          # weff covers blocks
        assert fixed.pairs_completeness < 1.0


def test_adaptive_sequential_matches_device(clean):
    """The sequential reference runner computes the same adaptive pair set
    as the vmapped band engines, and the adaptive oracle scores the run
    complete (compute_metrics uses the per-entity weff oracle)."""
    dev = api.resolve(clean.ents, _adaptive(compute_metrics=True))
    seq = api.resolve(clean.ents, _adaptive(runner="sequential"))
    assert dev.pairs == seq.pairs
    assert dev.matches == seq.matches
    assert dev.metrics.pairs_completeness == 1.0


def test_adaptive_config_is_cache_distinct():
    """window_policy/window_max/prune_* enter the executable fingerprint:
    two configs differing only in quality levers never share executables."""
    a = _cfg().static_fingerprint()
    b = _adaptive().static_fingerprint()
    c = _cfg(prune_policy="evidence", prune_threshold=THR)\
        .static_fingerprint()
    assert len({a, b, c}) == 3


# -- evidence pruning (meta-blocking) -----------------------------------------

def _host_evidence(ents, pairs):
    """Recompute each pair's cheap evidence exactly as the engines do:
    the cheap cascade prefix (cosine on feat + jaccard on sig)."""
    split = W.split_cascade(default_matcher(), ents["payload"])
    eids = np.asarray(ents["eid"])
    row = np.empty(eids.max() + 1, np.int64)
    row[eids] = np.arange(eids.size)
    arr = np.asarray(sorted(pairs), np.int64)
    ra, rb = row[arr[:, 0]], row[arr[:, 1]]
    feat = np.asarray(ents["payload"]["feat"])
    sig = np.asarray(ents["payload"]["sig"])
    ev = split.w_cos * np.asarray(cosine_sim(feat[ra], feat[rb])) \
        + split.w_jac * np.asarray(jaccard_sig(sig[ra], sig[rb]))
    return {tuple(p): float(e) for p, e in zip(map(tuple, arr), ev)}, split


@pytest.mark.parametrize("engine", ENGINES)
def test_prune_engine_parity_and_counter(dirty, engine):
    """scan, pallas and the sequential reference agree bit-identically on
    the pruned pair set AND on the pruned counter."""
    cfg = _adaptive(band_engine=engine, prune_policy="evidence",
                    prune_threshold=THR)
    dev = api.resolve(dirty.ents, cfg)
    seq = api.resolve(dirty.ents, cfg.with_(runner="sequential"))
    assert dev.pairs == seq.pairs
    assert dev.matches == seq.matches
    assert dev.blocking.pruned == seq.blocking.pruned > 0


def test_prune_never_drops_evidence_above_threshold(dirty):
    """Invariant 14, literally: every candidate the unpruned run blocks
    whose host-recomputed cheap evidence clears the threshold survives
    pruning — gold pairs and impostors alike — and everything pruned
    scored below the bar."""
    base = api.resolve(dirty.ents, _adaptive())
    pruned = api.resolve(dirty.ents,
                         _adaptive(prune_policy="evidence",
                                   prune_threshold=THR))
    assert pruned.pairs < base.pairs                   # it really pruned
    assert pruned.blocking.pruned == len(base.pairs) - len(pruned.pairs)
    ev, split = _host_evidence(dirty.ents, base.pairs)
    bar = THR * (split.w_cos + split.w_jac)
    for pair, e in ev.items():
        if e >= bar + 1e-4:
            assert pair in pruned.pairs, (pair, e)
        elif e < bar - 1e-4:
            assert pair not in pruned.pairs, (pair, e)
    # on this corpus the gold separation is wide: no gold pair was lost
    assert quality.evaluate(pruned, dirty).true_positives == \
        quality.evaluate(base, dirty).true_positives


def test_prune_requires_cascade_matcher(dirty):
    """Evidence pruning needs a splittable cascade (a cheap prefix to
    score); a matcher without one fails loudly, not silently unpruned."""
    from repro.core.match import CascadeMatcher, Matcher
    opaque = CascadeMatcher(matchers=(
        Matcher(field="feat", kind="edit", weight=1.0, cost=1.0),),
        threshold=0.5)
    cfg = _cfg(matcher=opaque, prune_policy="evidence",
               prune_threshold=THR)
    with pytest.raises(ValueError, match="cheap"):
        api.resolve(dirty.ents, cfg)


# -- config surface -----------------------------------------------------------

def test_quality_config_validation():
    with pytest.raises(ValueError, match="window_policy"):
        _cfg(window_policy="magic")
    with pytest.raises(ValueError, match="window_max"):
        _cfg(window_policy="adaptive", window_max=2)    # < window
    with pytest.raises(ValueError, match="window_max"):
        _cfg(window_max=WMAX)                           # without adaptive
    with pytest.raises(ValueError, match="band_block"):
        _adaptive(band_engine="pallas", band_block=8, window_max=64)
    with pytest.raises(ValueError, match="linkage"):
        _adaptive(linkage=True)
    with pytest.raises(ValueError, match="prune_policy"):
        _cfg(prune_policy="magic")
    with pytest.raises(ValueError, match="prune_threshold"):
        _cfg(prune_policy="evidence", prune_threshold=1.0)
    with pytest.raises(ValueError, match="prune_threshold"):
        _cfg(prune_threshold=0.5)                       # without evidence


def test_adaptive_is_not_servable():
    with pytest.raises(ValueError, match="adaptive"):
        api.serve(_adaptive())

