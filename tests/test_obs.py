"""Unified tracing + metrics layer (ISSUE 8).

  * span mechanics: nesting/parenting, attrs, thread safety, the no-op
    singleton fast path when no tracer is active
  * the disabled-overhead contract (invariant 12): trace=True is excluded
    from the executable fingerprint (zero extra traces on a warm cache),
    traced and untraced runs produce identical pair sets, and the no-op
    span path stays cheap
  * metrics: histogram ring buffer matches the historical serve-deque
    percentile semantics exactly and stays bounded
  * the unified stats schema round-trips all five legacy stats types
    through JSON
  * end-to-end: TraceReport on resolve / resolve_stream (per-chunk spans,
    coverage >= 0.9, kill/resume), ResolutionService.trace_report, Chrome
    export validity
"""
import json
import threading

import numpy as np
import pytest

from repro import api, obs, stream
from repro.core import entities as E

N, R, W = 600, 4, 6


def _cfg(**kw):
    kw.setdefault("window", W)
    kw.setdefault("num_shards", R)
    kw.setdefault("variant", "repsn")
    kw.setdefault("hops", R - 1)
    kw.setdefault("runner", "vmap")
    return api.ERConfig(**kw)


@pytest.fixture(scope="module")
def ents():
    rng = np.random.default_rng(8)
    return E.synth_entities(rng, N, n_keys=90, dup_frac=0.25, text_len=8)


def _chunks(ents, sz=150):
    h = E.to_host(ents)
    n = int(h["key"].shape[0])
    return [E.host_take(h, slice(s, min(s + sz, n)))
            for s in range(0, n, sz)]


# -- span mechanics ----------------------------------------------------------

def test_span_nesting_and_attrs():
    t = obs.Tracer()
    with obs.activate(t):
        with obs.span("root", a=1):
            with obs.span("child") as c:
                c.set(b=2)
            with obs.span("child"):
                pass
    spans = t.spans()
    assert [s.name for s in spans] == ["root", "child", "child"]
    root, c1, c2 = spans
    assert root.parent == -1 and root.depth == 0
    assert c1.parent == root.index and c1.depth == 1
    assert c2.parent == root.index
    assert root.attrs == {"a": 1} and c1.attrs == {"b": 2}
    assert all(s.dur is not None and s.dur >= 0 for s in spans)
    # children are contained in the root's interval
    assert c1.t0 >= root.t0 and c1.t0 + c1.dur <= root.t0 + root.dur + 1e-6


def test_noop_singleton_when_inactive():
    assert obs.current_tracer() is None
    sp = obs.span("anything", big=list(range(10)))
    assert sp is obs.NOOP_SPAN
    assert not sp.enabled
    with sp:
        sp.set(ignored=True)       # must be a silent no-op


def test_activate_restores_previous_tracer():
    t1, t2 = obs.Tracer(), obs.Tracer()
    with obs.activate(t1):
        assert obs.current_tracer() is t1
        with obs.activate(t2):
            assert obs.current_tracer() is t2
        assert obs.current_tracer() is t1
    assert obs.current_tracer() is None


def test_spans_are_thread_safe():
    t = obs.Tracer()

    def work(i):
        with obs.activate(t):
            with obs.span("outer", i=i):
                with obs.span("inner", i=i):
                    pass

    threads = [threading.Thread(target=work, args=(i,)) for i in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    spans = t.spans()
    assert len(spans) == 16
    by_index = {s.index: s for s in spans}
    for s in spans:
        if s.name == "inner":
            parent = by_index[s.parent]
            assert parent.name == "outer"
            # each inner span nests under ITS thread's outer span
            assert parent.tid == s.tid
            assert parent.attrs["i"] == s.attrs["i"]


# -- metrics -----------------------------------------------------------------

def test_histogram_matches_deque_semantics_and_stays_bounded():
    from collections import deque
    rng = np.random.default_rng(0)
    cap = 64
    h = obs.Histogram("lat", capacity=cap)
    d = deque(maxlen=cap)
    for v in rng.normal(size=500):
        h.observe(float(v))
        d.append(float(v))
        lat = sorted(d)
        for p in (0.5, 0.95):
            want = lat[min(len(lat) - 1, int(p * (len(lat) - 1)))]
            assert h.percentile(p) == pytest.approx(want)
    assert len(h) == cap          # window bounded
    assert h.count == 500         # lifetime count preserved


def test_registry_type_conflict_raises():
    m = obs.MetricsRegistry()
    m.counter("x").inc(3)
    assert m.counter("x").value == 3
    with pytest.raises(TypeError):
        m.gauge("x")
    m.gauge("g").set(1.5)
    m.histogram("h").observe(2.0)
    d = m.to_dict()
    assert d["x"] == {"type": "counter", "value": 3}
    assert d["g"] == {"type": "gauge", "value": 1.5}
    assert d["h"]["type"] == "histogram" and d["h"]["count"] == 1


# -- unified stats schema ----------------------------------------------------

def test_schema_round_trips_all_five_stats_types():
    from repro.api.results import BalanceMetrics, PerfStats
    from repro.resilience.retry import ResilienceStats
    from repro.serve.service import ServeStats
    from repro.stream.resolver import StreamStats
    samples = [
        BalanceMetrics(partitioner="pairrange", planned_load=(3, 4),
                       realized_load=(3, 5), planned_comparisons=(9, 16),
                       realized_comparisons=(9, 25), imbalance_planned=1.2,
                       imbalance_realized=1.4, straggler_shard=1,
                       halo_entities=6, cap_link=7),
        PerfStats(cache_hits=5, cache_misses=1, traces=1, cache_entries=6),
        StreamStats(chunks=4, chunk_size=128, entities=600, runs=5,
                    carry_entities=15, degenerate_chunks=0, steady_chunks=3,
                    cache_hits=8, cache_misses=2, traces=1,
                    spooled_bytes=1024, chunk_device_bytes=4096,
                    corpus_bytes=65536),
        ServeStats(requests=10, batches=4, steady_batches=3, queue_depth=0,
                   batch_fill=0.5, cache_hits=6, cache_misses=2, traces=1,
                   device_calls=4, p50_ms=1.5, p95_ms=3.0, live_entities=9,
                   index_runs=2, index_rows=16, tombstones=1, compactions=0,
                   pairs=12, matches=3, shapes=((2, 64), (4, 128)),
                   failure=None),
        ResilienceStats(policy="retry", retries=2, escalations=3,
                        cand_cap=128, pair_cap=256, auto_caps=True),
    ]
    for original in samples:
        packed = obs.pack_stats(original)
        assert packed["kind"] == type(original).__name__
        # the packed form must survive real JSON serialization
        restored = obs.unpack_stats(json.loads(json.dumps(packed)))
        assert restored == original
        assert type(restored) is type(original)


# -- invariant 12: tracing changes nothing -----------------------------------

def test_trace_excluded_from_fingerprint_and_pairs(ents):
    cfg = _cfg()
    assert cfg.static_fingerprint() == \
        cfg.with_(trace=True).static_fingerprint()
    plain = api.resolve(ents, cfg)
    assert plain.trace is None
    traced = api.resolve(ents, cfg.with_(trace=True))
    assert traced.trace is not None
    assert traced.pairs == plain.pairs
    assert traced.matches == plain.matches


def test_traced_run_adds_zero_retraces(ents):
    from repro.perf.cache import executable_cache
    cfg = _cfg()
    cache = executable_cache()
    api.resolve(ents, cfg)                    # warm the cache untraced
    before = cache.stats.snapshot()
    api.resolve(ents, cfg.with_(trace=True))  # must HIT those executables
    hits, misses, traces = cache.stats.delta(before)
    assert traces == 0 and misses == 0
    assert hits > 0


def test_disabled_path_is_cheap():
    import time
    assert obs.current_tracer() is None
    loops = 50_000
    t0 = time.perf_counter()
    for _ in range(loops):
        with obs.span("x", attr=1):
            pass
    per_call = (time.perf_counter() - t0) / loops
    # generous smoke bound: the no-op path is a thread-local lookup plus a
    # constant-folding with-block — single-digit microseconds even on a
    # busy CI box (the tight <= 1% budget is gated by perf_smoke --obs)
    assert per_call < 20e-6


# -- end-to-end reports ------------------------------------------------------

def test_resolve_trace_report(ents):
    res = api.resolve(ents, _cfg(trace=True))
    tr = res.trace
    names = {s.name for s in tr.spans}
    assert {"resolve", "plan", "execute", "shard_program",
            "collect"} <= names
    m = tr.metrics()
    assert m["schema_version"] == obs.SCHEMA_VERSION
    assert m["metrics"]["pairs"]["value"] == len(res.pairs)
    assert m["metrics"]["transfer_bytes"]["value"] > 0
    assert {"BalanceMetrics", "PerfStats",
            "ResilienceStats"} <= set(m["stats"])
    # typed reconstruction goes through the same accessor
    assert tr.stat("PerfStats") == res.perf
    assert tr.stat("BalanceMetrics") == res.balance
    assert tr.coverage() >= 0.9
    assert dict(tr.self_times())["shard_program"] > 0


def test_stream_trace_per_chunk_spans_cover_wall(ents):
    cfg = _cfg(trace=True)
    res = stream.resolve_stream(iter(_chunks(ents)), cfg, chunk_size=150)
    tr = res.trace
    chunk_spans = [s for s in tr.spans if s.name == "chunk"]
    assert len(chunk_spans) == res.stream.chunks
    assert [s.attrs["index"] for s in chunk_spans] == \
        list(range(res.stream.chunks))
    assert sum(s.attrs["carry"] for s in chunk_spans) == \
        res.stream.carry_entities
    assert tr.coverage() >= 0.9
    assert tr.stat("StreamStats") == res.stream
    # per-pass results share the owner's tracer: no nested reports
    assert all(p.trace is None for p in res.passes)


def test_stream_trace_across_kill_resume(ents, tmp_path):
    from repro.resilience import FaultPlan, InjectedFault
    cfg = _cfg(trace=True)
    ck = str(tmp_path / "ck")
    with pytest.raises(InjectedFault):
        stream.resolve_stream(iter(_chunks(ents)), cfg, chunk_size=150,
                              checkpoint_dir=ck,
                              fault_plan=FaultPlan(crash_after_chunk=1))
    res = stream.resolve_stream(iter(_chunks(ents)), cfg, chunk_size=150,
                                checkpoint_dir=ck)
    tr = res.trace
    # the resumed run only re-resolves the uncommitted chunks, and its
    # spans say so: chunk indices start past the committed prefix and
    # nest under the pass span
    chunk_spans = [s for s in tr.spans if s.name == "chunk"]
    assert [s.attrs["index"] for s in chunk_spans] == \
        list(range(2, res.stream.chunks))
    by_index = {s.index: s for s in tr.spans}
    for s in chunk_spans:
        assert by_index[s.parent].name == "pass"
    assert tr.coverage() >= 0.9
    assert tr.registry["checkpoint_commit_ms"]["count"] == len(chunk_spans)
    # parity with an untraced, uninterrupted run (invariant 12 end-to-end)
    plain = stream.resolve_stream(iter(_chunks(ents)), _cfg(),
                                  chunk_size=150)
    assert res.pairs == plain.pairs and res.matches == plain.matches


def test_serve_trace_report(ents):
    svc = api.serve(_cfg(num_shards=2, trace=True), start=False)
    h = E.to_host(ents)
    svc.resolve_incremental(E.host_take(h, slice(0, 300)))
    svc.resolve_incremental(E.host_take(h, slice(300, 600)))
    svc.delete([int(h["eid"][0])])
    rep = svc.trace_report()
    batch_spans = [s for s in rep.spans if s.name == "batch"]
    assert len(batch_spans) == svc.stats().batches
    assert batch_spans[0].attrs["kind"] == "insert"
    assert batch_spans[-1].attrs["kind"] == "delete"
    assert rep.registry["batch_ms"]["count"] == len(batch_spans)
    assert rep.stat("ServeStats") == svc.stats()
    # p50/p95 still come from the bounded window with deque semantics
    assert svc.stats().p95_ms >= svc.stats().p50_ms > 0
    # untraced service: no tracer, no report
    svc2 = api.serve(_cfg(num_shards=2), start=False)
    svc2.resolve_incremental(E.host_take(h, slice(0, 100)))
    assert svc2.trace_report() is None


def test_chrome_export_is_loadable(ents, tmp_path):
    res = api.resolve(ents, _cfg(trace=True))
    path = str(tmp_path / "trace.json")
    res.trace.export_chrome(path)
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    assert len(events) == len(res.trace.spans)
    for ev in events:
        assert ev["ph"] == "X" and ev["dur"] >= 0 and ev["ts"] >= 0
        assert "index" in ev["args"] and "parent" in ev["args"]
    assert doc["repro"]["schema_version"] == obs.SCHEMA_VERSION
    # the CLI digests the file standalone
    from tools.trace_report import digest, load_trace
    d = digest(load_trace(path), top=5)
    assert d["spans"] == len(events)
    assert d["top_self_time"] and d["pairs"] == len(res.pairs)
