"""Executable cache + device-side pair emission (ISSUE 4).

Steady-state contract: after one warm call, repeated same-shaped
``resolve()`` calls perform ZERO new jit traces (asserted through the
trace-counting wrapper the cache installs around every shard program) and
report pure cache hits on ``ERResult.perf``; any change to input shape,
window, or another static config field is a miss that retraces.  Device-
emitted packed pairs (emit="pairs") must be bit-identical to the host
band-extraction path across all 3 variants x {vmap, shard_map} x
{scan, pallas}, and pair_cap overflow is counted, never silent.
"""
import numpy as np
import pytest

from repro import api
from repro.core import entities as E
from repro.core import partition as P
from repro.perf.cache import executable_cache

N, R, WIN, NK = 240, 4, 6, 64


@pytest.fixture(scope="module")
def ents():
    return E.synth_entities(np.random.default_rng(7), N, n_keys=NK,
                            dup_frac=0.25, text_len=12)


@pytest.fixture(scope="module")
def bounds(ents):
    return P.balanced_partition(np.asarray(ents["key"]), R)


def _cfg(**kw):
    kw.setdefault("window", WIN)
    kw.setdefault("num_shards", R)
    kw.setdefault("hops", R - 1)
    kw.setdefault("band_interpret", True)
    return api.ERConfig(**kw)


# -- executable cache ---------------------------------------------------------------


def test_second_call_zero_new_traces(ents, bounds):
    """The tentpole contract: a same-shape second call dispatches a cached
    executable — no build, no trace."""
    cache = executable_cache()
    cache.clear()
    cfg = _cfg(variant="repsn", runner="vmap")
    first = api.resolve(ents, cfg, bounds=bounds)
    assert first.perf is not None
    assert first.perf.cache_misses >= 1
    assert first.perf.traces == first.perf.cache_misses  # one trace per build
    second = api.resolve(ents, cfg, bounds=bounds)
    assert second.perf.traces == 0
    assert second.perf.cache_misses == 0
    assert second.perf.cache_hits >= 1
    assert second.perf.steady_state
    assert second.blocking.pairs == first.blocking.pairs
    assert second.matches == first.matches


def test_bounds_values_are_traced_not_keyed(ents):
    """Replanning boundaries must NOT retrace: bounds ride as a traced
    argument, so two different same-shaped boundary arrays share one
    executable (the replanning-per-request serving pattern)."""
    cfg = _cfg(variant="srp", runner="vmap")
    b1 = P.balanced_partition(np.asarray(ents["key"]), R)
    b2 = np.asarray(b1) + 1
    api.resolve(ents, cfg, bounds=b1)
    moved = api.resolve(ents, cfg, bounds=np.asarray(b2, np.int32))
    assert moved.perf.traces == 0 and moved.perf.steady_state


@pytest.mark.parametrize("change", [
    {"window": WIN + 1},                      # static cfg field
    {"band_engine": "pallas"},                # engine swap
    {"cand_cap": 64, "band_engine": "pallas"},  # cascade capacity
    {"emit": "pairs"},                        # emission mode
])
def test_static_cfg_change_misses(ents, bounds, change):
    cfg = _cfg(variant="repsn", runner="vmap")
    api.resolve(ents, cfg, bounds=bounds)           # warm base entry
    base = api.resolve(ents, cfg, bounds=bounds)
    assert base.perf.steady_state
    changed = api.resolve(ents, cfg.with_(**change), bounds=bounds)
    assert changed.perf.cache_misses >= 1
    assert changed.perf.traces == changed.perf.cache_misses


def test_shape_change_misses(ents, bounds):
    cfg = _cfg(variant="repsn", runner="vmap")
    api.resolve(ents, cfg, bounds=bounds)
    smaller = E.synth_entities(np.random.default_rng(8), N - 40, n_keys=NK,
                               dup_frac=0.25, text_len=12)
    res = api.resolve(smaller, cfg, bounds=bounds)
    assert res.perf.cache_misses >= 1


def test_jit_cache_off_bypasses(ents, bounds):
    cfg = _cfg(variant="repsn", runner="vmap", jit_cache=False)
    on = api.resolve(ents, cfg.with_(jit_cache=True), bounds=bounds)
    off = api.resolve(ents, cfg, bounds=bounds)
    assert off.perf.cache_hits == 0 and off.perf.cache_misses == 0
    assert off.blocking.pairs == on.blocking.pairs
    assert off.matches == on.matches


def test_shard_map_second_call_steady(ents):
    runner = api.ShardMapRunner()
    r = runner.shards
    cfg = _cfg(variant="jobsn", runner="shard_map", num_shards=r,
               hops=max(r - 1, 1))
    b = api.default_bounds(ents, cfg, r)
    api.resolve(ents, cfg, bounds=b)
    res = api.resolve(ents, cfg, bounds=b)
    assert res.perf.steady_state and res.perf.cache_hits >= 1


def test_lru_eviction_bounds_cache():
    """The cache never holds more than max_entries executables; evicted
    keys rebuild on next use (counted, never an error)."""
    from repro.perf.cache import ExecutableCache
    cache = ExecutableCache(max_entries=2)
    calls = []
    for k in ["a", "b", "c"]:
        cache.get_or_build(k, lambda k=k: lambda: calls.append(k))
    assert len(cache) == 2
    assert cache.stats.evictions == 1
    cache.get_or_build("c", lambda: (lambda: None))      # hit, no rebuild
    assert cache.stats.hits == 1
    cache.get_or_build("a", lambda: (lambda: None))      # evicted: rebuilds
    assert cache.stats.misses == 4


# -- device-side pair emission ------------------------------------------------------


@pytest.mark.parametrize("variant", ["srp", "repsn", "jobsn"])
@pytest.mark.parametrize("runner_name", ["vmap", "shard_map"])
@pytest.mark.parametrize("engine", ["scan", "pallas"])
def test_emitted_pairs_bit_identical(ents, bounds, variant, runner_name,
                                     engine):
    """Device-emitted packed pairs == host packed_pairs_from_band output,
    bit for bit, across variants x runners x engines."""
    if runner_name == "vmap":
        runner, b = api.VmapRunner(R), bounds
        cfg = _cfg(variant=variant, runner="vmap", band_engine=engine,
                   cand_cap=256 if engine == "pallas" else 0)
    else:
        runner = api.ShardMapRunner()
        cfg = _cfg(variant=variant, runner="shard_map",
                   num_shards=runner.shards, hops=max(runner.shards - 1, 1),
                   band_engine=engine,
                   cand_cap=256 if engine == "pallas" else 0)
        b = api.default_bounds(ents, cfg, runner.shards)
    variant_obj = api.get_variant(variant)
    col_band = variant_obj.collect(runner.run_raw(ents, b, cfg))
    col_idx = variant_obj.collect(
        runner.run_raw(ents, b, cfg.with_(emit="pairs")))
    np.testing.assert_array_equal(col_band.blocked, col_idx.blocked)
    np.testing.assert_array_equal(col_band.matched, col_idx.matched)
    assert col_band.blocked.size > 0


def test_emitted_part_transfers_no_bands(ents, bounds):
    """emit='pairs' parts carry index buffers + eids only — the O(w*M)
    bands and the full payload tree stay on device."""
    cfg = _cfg(variant="repsn", runner="vmap", emit="pairs")
    out = api.VmapRunner(R).run_raw(ents, bounds, cfg)
    part = out["main"]
    for absent in ("mask", "match", "ents"):
        assert absent not in part
    for present in ("mask_idx", "mask_n", "mask_overflow", "match_idx",
                    "match_n", "match_overflow", "eid"):
        assert present in part


def test_pair_cap_overflow_counted(ents, bounds):
    """pair_cap exceeded: dropped slots counted in pair_overflow (blocked
    pairs CAN be lost here — the capacity contract is count, never
    silence); a roomy cap loses nothing."""
    cfg = _cfg(variant="srp", runner="vmap", emit="pairs")
    full = api.resolve(ents, cfg, bounds=bounds)
    assert full.blocking.pair_overflow == 0
    tight = api.resolve(ents, cfg.with_(pair_cap=8), bounds=bounds)
    assert tight.blocking.pair_overflow > 0
    assert tight.blocking.pairs <= full.blocking.pairs
    assert len(full.blocking.pairs) - len(tight.blocking.pairs) \
        <= tight.blocking.pair_overflow
    assert tight.matches <= full.matches


def test_pair_emission_config_validation():
    with pytest.raises(ValueError, match="emit"):
        api.ERConfig(emit="bands")
    with pytest.raises(ValueError, match="pair_cap"):
        api.ERConfig(pair_cap=-1)
    with pytest.raises(ValueError, match="emit='pairs'"):
        api.ERConfig(emit="pairs", return_scores=True)


def test_linkage_emission_parity(ents):
    """Cross-source masking happens before compaction, so linkage runs
    agree between emission modes too."""
    rng = np.random.default_rng(3)
    lhs = E.synth_entities(rng, 160, n_keys=48, dup_frac=0.0, text_len=12)
    take = rng.permutation(160)[:60]
    rhs = E.make_entities(
        np.asarray(lhs["key"])[take], np.arange(60, dtype=np.int32),
        payload={k: np.asarray(v)[take] for k, v in lhs["payload"].items()})
    cfg = _cfg(window=5, variant="repsn", runner="vmap")
    band = api.link(lhs, rhs, cfg)
    idx = api.link(lhs, rhs, cfg.with_(emit="pairs"))
    assert band.blocking.pairs == idx.blocking.pairs
    assert band.matches == idx.matches
    assert band.matches                     # planted duplicates found


# -- sequential chunk scorer --------------------------------------------------------


def test_seq_match_tail_padding_parity(ents, bounds):
    """A chunk size that doesn't divide the pair count pads the tail chunk
    instead of compiling a second shape: identical matches, one scorer
    executable."""
    cache = executable_cache()
    cfg = _cfg(variant="repsn", runner="sequential")
    big = api.SequentialRunner(num_shards=R).resolve(ents, bounds, cfg)
    cache.clear()
    h0, m0, t0 = cache.stats.snapshot()
    small = api.SequentialRunner(num_shards=R, match_chunk=128).resolve(
        ents, bounds, cfg)
    h1, m1, t1 = cache.stats.snapshot()
    assert small.matched == big.matched
    assert small.blocked == big.blocked
    assert m1 - m0 == 1 and t1 - t0 == 1    # ONE executable, tail included
    # warm second run: pure hits
    api.SequentialRunner(num_shards=R, match_chunk=128).resolve(
        ents, bounds, cfg)
    h2, m2, t2 = cache.stats.snapshot()
    assert m2 - m1 == 0 and t2 - t1 == 0 and h2 > h1
