"""Documentation gates (ISSUE 5).

  * **API-reference docstring lint** (ast-based, no imports needed): every
    public module / class / function / method on the public surface —
    ``repro.api.*``, ``repro.balance.*``, ``repro.perf.cache``,
    ``repro.stream.*``, ``repro.serve.*``, ``repro.resilience.*`` —
    carries a real docstring (functions that take arguments get a
    substantive one, not a stub).
  * **Local link check**: every relative markdown link in README.md,
    DESIGN.md, ROADMAP.md and docs/ resolves to a file in the repo (the
    executable-code-block check runs in CI via tools/check_docs.py).
  * **Paper-map coverage**: docs/paper-map.md addresses every paper
    section §3–§5 (the ISSUE 5 acceptance bar).
"""
import ast
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))
import check_docs  # noqa: E402  (the ONE link-check implementation)

PUBLIC_MODULES = sorted(
    [*(REPO / "src/repro/api").glob("*.py"),
     *(REPO / "src/repro/balance").glob("*.py"),
     *(REPO / "src/repro/stream").glob("*.py"),
     *(REPO / "src/repro/serve").glob("*.py"),
     *(REPO / "src/repro/resilience").glob("*.py"),
     *(REPO / "src/repro/obs").glob("*.py"),
     REPO / "src/repro/perf/cache.py"])

DOC_FILES = check_docs.default_doc_files()

MIN_DOC = 20          # chars: anything shorter is a stub, not documentation
MIN_DOC_WITH_ARGS = 30


def _public_defs(tree):
    """Yield (node, kind, qualname) for every public def/class, including
    methods of public classes (dunders and _-prefixed names are private)."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if not node.name.startswith("_"):
                yield node, "function", node.name
        elif isinstance(node, ast.ClassDef) and \
                not node.name.startswith("_"):
            yield node, "class", node.name
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef,
                                    ast.AsyncFunctionDef)) and \
                        not sub.name.startswith("_"):
                    yield sub, "method", f"{node.name}.{sub.name}"


def _n_args(fn) -> int:
    args = [a.arg for a in fn.args.args + fn.args.kwonlyargs
            if a.arg not in ("self", "cls")]
    return len(args)


@pytest.mark.parametrize("path", PUBLIC_MODULES,
                         ids=[str(p.relative_to(REPO))
                              for p in PUBLIC_MODULES])
def test_public_surface_is_documented(path):
    tree = ast.parse(path.read_text())
    problems = []
    if not (ast.get_docstring(tree) or "").strip():
        problems.append("module docstring missing")
    for node, kind, name in _public_defs(tree):
        doc = (ast.get_docstring(node) or "").strip()
        floor = MIN_DOC
        if kind in ("function", "method") and _n_args(node) > 0:
            floor = MIN_DOC_WITH_ARGS
        if len(doc) < floor:
            problems.append(
                f"{kind} {name}: docstring "
                f"{'missing' if not doc else f'too thin ({len(doc)} chars)'}"
                f" (need >= {floor} chars covering args/returns/invariants)")
    assert not problems, \
        f"{path.relative_to(REPO)}:\n  " + "\n  ".join(problems)


@pytest.mark.parametrize("path", DOC_FILES,
                         ids=[str(p.relative_to(REPO)) for p in DOC_FILES])
def test_markdown_links_resolve(path):
    broken = check_docs.check_links(path)
    assert not broken, f"{path.relative_to(REPO)}: broken links {broken}"


def test_paper_map_covers_sections_3_to_5():
    text = (REPO / "docs" / "paper-map.md").read_text()
    for section in ["§3", "§4.1", "§4.2", "§4.3", "§5.1", "§5.2", "§5.3"]:
        assert section in text, f"paper-map.md misses paper section {section}"
    # the named mechanisms of the mapping must appear
    for term in ["SRP", "JobSN", "RepSN", "halo", "boundary"]:
        assert term in text, f"paper-map.md misses {term!r}"
