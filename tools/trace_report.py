"""Human-readable summary of a ``repro.obs`` Chrome trace.json.

Usage:  python tools/trace_report.py trace.json [--top N] [--json]

Reads a trace written by ``TraceReport.export_chrome`` /
``Tracer.export_chrome`` and prints the run's observability digest:

  * top spans by SELF time (inclusive duration minus direct children) —
    where the wall clock actually went, not double-counted through the
    nesting;
  * throughput: blocked pairs per second (the ``pairs`` gauge over the
    root span's wall);
  * executable-cache hit rate, shard imbalance, and overflow/retry event
    counts, pulled from whichever legacy stats blocks
    (``PerfStats``/``BalanceMetrics``/``StreamStats``/``ServeStats``/
    ``ResilienceStats``) the run embedded under the ``"repro"`` key.

``--json`` emits the digest as JSON instead (CI archives that form).
The span tree is rebuilt from the ``index``/``parent`` entries each
event's ``args`` carries, so the tool needs only the trace file — not
the repro package or the original run.
"""
from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict


def load_trace(path: str) -> dict:
    """Parse one trace.json; raises SystemExit with a clear message on a
    file that is not a repro obs trace."""
    with open(path) as f:
        doc = json.load(f)
    if "traceEvents" not in doc:
        raise SystemExit(f"{path}: no traceEvents — not a Chrome trace")
    return doc


def self_times(events: list) -> list:
    """[(name, self_seconds, count)] sorted by descending self time,
    reconstructed from the ``index``/``parent`` args (falls back to
    inclusive durations when a trace lacks them)."""
    spans = [e for e in events if e.get("ph") == "X"]
    child_sum: dict = defaultdict(float)
    indexed = all("index" in (e.get("args") or {}) for e in spans)
    if indexed:
        for e in spans:
            a = e["args"]
            if a.get("parent", -1) >= 0:
                child_sum[a["parent"]] += e["dur"]
    agg: dict = defaultdict(lambda: [0.0, 0])
    for e in spans:
        own = e["dur"] - child_sum.get(e["args"]["index"], 0.0) \
            if indexed else e["dur"]
        entry = agg[e["name"]]
        entry[0] += max(0.0, own) * 1e-6      # µs -> s
        entry[1] += 1
    return sorted(((k, v[0], v[1]) for k, v in agg.items()),
                  key=lambda t: -t[1])


def _stat(repro: dict, kind: str) -> dict:
    return (repro.get("stats") or {}).get(kind) or {}


def digest(doc: dict, top: int) -> dict:
    """The summary dict the CLI renders: top self-time spans, pairs/s,
    cache hit rate, imbalance, and overflow/retry events."""
    events = doc["traceEvents"]
    repro = doc.get("repro") or {}
    metrics = repro.get("metrics") or {}
    wall = float(repro.get("wall_s") or 0.0)
    out: dict = {
        "schema_version": repro.get("schema_version"),
        "wall_s": wall,
        "spans": len([e for e in events if e.get("ph") == "X"]),
        "top_self_time": [
            {"name": n, "self_s": round(s, 6), "count": c}
            for n, s, c in self_times(events)[:top]],
    }
    pairs = (metrics.get("pairs") or {}).get("value")
    if pairs is not None and wall > 0:
        out["pairs"] = int(pairs)
        out["pairs_per_s"] = pairs / wall
    perf = _stat(repro, "PerfStats")
    stream = _stat(repro, "StreamStats")
    serve = _stat(repro, "ServeStats")
    hits = sum(int(d.get("cache_hits", 0)) for d in (perf, stream, serve))
    misses = sum(int(d.get("cache_misses", 0))
                 for d in (perf, stream, serve))
    if hits + misses:
        out["cache_hit_rate"] = hits / (hits + misses)
        out["traces"] = sum(int(d.get("traces", 0))
                            for d in (perf, stream, serve))
    bal = _stat(repro, "BalanceMetrics")
    if bal.get("imbalance") is not None:
        out["imbalance"] = bal["imbalance"]
    rz = _stat(repro, "ResilienceStats")
    if rz:
        out["retries"] = rz.get("retries", 0)
        out["escalations"] = rz.get("escalations", 0)
    # admission-control digest (serving traces, DESIGN.md §13): the
    # ServeStats overload block plus the admission_ms queue-wait
    # histogram and the shed/expired/degraded counters the service
    # records per batch
    adm = {k: int(serve[k]) for k in
           ("shed", "rejected", "expired", "degraded_batches",
            "repairs", "dirty_ranges") if k in serve}
    for key in ("shed", "rejected", "expired", "degraded_batches",
                "repairs"):
        m = metrics.get(key)
        if m and m.get("type") == "counter":
            adm.setdefault(key, int(m["value"]))
    if any(adm.values()):
        out["admission"] = adm
        if "health" in serve:
            out["admission"]["health"] = serve["health"]
    wait = metrics.get("admission_ms")
    if wait and wait.get("type") == "histogram" and wait.get("count"):
        out["admission_wait_ms"] = {
            "count": wait["count"], "p50": wait.get("p50"),
            "p95": wait.get("p95")}
    for key in ("overflow_events", "retries", "carry_entities"):
        if key in metrics and metrics[key].get("type") == "counter":
            out.setdefault(key, metrics[key]["value"])
    return out


def render(d: dict) -> str:
    """Fixed-width text rendering of one digest."""
    lines = [f"trace: {d['spans']} spans over {d['wall_s']:.3f}s "
             f"(schema v{d['schema_version']})"]
    lines.append("top spans by self time:")
    for row in d["top_self_time"]:
        lines.append(f"  {row['name']:<20} {row['self_s']:>10.4f}s  "
                     f"x{row['count']}")
    if "pairs_per_s" in d:
        lines.append(f"pairs: {d['pairs']} ({d['pairs_per_s']:.0f}/s)")
    if "cache_hit_rate" in d:
        lines.append(f"executable cache: {100 * d['cache_hit_rate']:.1f}% "
                     f"hit rate, {d['traces']} trace(s)")
    if "imbalance" in d:
        lines.append(f"shard imbalance: {d['imbalance']:.3f}")
    if "retries" in d or "overflow_events" in d:
        lines.append(f"recovery: {d.get('retries', 0)} retries, "
                     f"{d.get('escalations', 0)} escalations, "
                     f"{d.get('overflow_events', 0)} overflow event(s)")
    if "admission" in d:
        a = d["admission"]
        lines.append(
            f"admission: {a.get('shed', 0)} shed, "
            f"{a.get('rejected', 0)} rejected, "
            f"{a.get('expired', 0)} expired, "
            f"{a.get('degraded_batches', 0)} degraded batch(es), "
            f"{a.get('repairs', 0)} repair(s), "
            f"{a.get('dirty_ranges', 0)} dirty"
            + (f", health={a['health']}" if "health" in a else ""))
    if "admission_wait_ms" in d:
        w = d["admission_wait_ms"]
        lines.append(f"queue wait: p50={w['p50']:.1f}ms "
                     f"p95={w['p95']:.1f}ms over {w['count']} request(s)")
    return "\n".join(lines)


def main(argv=None) -> int:
    """CLI entry point (returns the process exit status)."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("trace", help="trace.json written by export_chrome")
    ap.add_argument("--top", type=int, default=10,
                    help="span names to list by self time (default 10)")
    ap.add_argument("--json", action="store_true",
                    help="emit the digest as JSON instead of text")
    args = ap.parse_args(argv)
    d = digest(load_trace(args.trace), args.top)
    try:
        print(json.dumps(d, indent=2) if args.json else render(d))
    except BrokenPipeError:      # downstream (head, a closed pager) left —
        return 0                 # the digest succeeded; don't fail the job
    return 0


if __name__ == "__main__":
    sys.exit(main())
