"""Docs build/lint gate: link check + EXECUTE every ```python doc block.

Usage:  PYTHONPATH=src python tools/check_docs.py [file.md ...]

Defaults to README.md, DESIGN.md and docs/*.md.  Two checks:

  1. every relative markdown link resolves to a file in the repo
     (external http(s)/mailto links and pure #anchors are skipped);
  2. every fenced ```python block is executed, top to bottom, in ONE
     namespace per file — quickstarts in the docs are real programs run
     against the current API, not decorative snippets.  Blocks that are
     intentionally illustrative must use a different info string
     (```text, ```bash, ...).

Exit status is non-zero on any broken link or failing block, with the
file/block identified — the CI docs job runs exactly this.
"""
from __future__ import annotations

import re
import sys
import traceback
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
BLOCK_RE = re.compile(r"```python\n(.*?)```", re.DOTALL)


def default_doc_files() -> list:
    """The repo's checked markdown set — ONE list shared with the pytest
    gate (tests/test_docs.py imports this module), so CI's docs job and
    the test suite can never disagree about what is covered."""
    return [REPO / "README.md", REPO / "DESIGN.md", REPO / "ROADMAP.md",
            *sorted((REPO / "docs").glob("*.md"))]


def check_links(path: Path) -> list:
    """Broken relative link targets in one markdown file."""
    broken = []
    for target in LINK_RE.findall(path.read_text()):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#")[0]
        if rel and not (path.parent / rel).exists():
            broken.append(target)
    return broken


def run_blocks(path: Path) -> list:
    """Execute every ```python block of one file in a shared namespace;
    returns [(block_index, traceback_str)] for failures."""
    failures = []
    ns: dict = {"__name__": f"docblock:{path.name}"}
    for i, code in enumerate(BLOCK_RE.findall(path.read_text())):
        try:
            exec(compile(code, f"{path.name}[block {i}]", "exec"), ns)
        except Exception:
            failures.append((i, traceback.format_exc()))
    return failures


def main(argv) -> int:
    """Check the given markdown files (or the repo defaults); returns the
    process exit code (0 = all links resolve and all blocks ran)."""
    files = [Path(a) for a in argv[1:]] or default_doc_files()
    rc = 0
    for f in files:
        broken = check_links(f)
        for t in broken:
            print(f"BROKEN LINK {f}: {t}")
            rc = 1
        fails = run_blocks(f)
        for i, tb in fails:
            print(f"DOC BLOCK FAILED {f} [block {i}]:\n{tb}")
            rc = 1
        n_blocks = len(BLOCK_RE.findall(f.read_text()))
        print(f"{f}: {n_blocks} python block(s) ran, "
              f"{len(broken)} broken link(s)")
    return rc


if __name__ == "__main__":
    sys.exit(main(sys.argv))
