"""The paper's §5.3 data-skew study in miniature + the beyond-paper fix.

Builds increasingly skewed key distributions (Even8_40..85 analogues), runs
the full pipeline through ``repro.api.resolve`` with (a) even key-range
splits — the paper's setup — and (b) the histogram-balanced splitter (the
load-balancing 'future work' of paper §7, implemented here), and reports
Gini + max-shard load (the critical-path proxy for reducer wall time)
straight off the typed ``BlockingResult``.

  PYTHONPATH=src python examples/skew_study.py
"""
import numpy as np

from repro import api
from repro.core import entities as E
from repro.core import partition as P


def main():
    rng = np.random.default_rng(0)
    n, n_keys, r, w = 40_000, 512, 8, 6
    cfg = api.ERConfig(window=w, variant="repsn", hops=r - 1,
                       runner="vmap", num_shards=r)
    print(f"{'skew':>6} | {'even-split gini':>15} {'max_load':>9} | "
          f"{'balanced gini':>13} {'max_load':>9}")
    for hot in [0.0, 0.4, 0.55, 0.7, 0.85]:
        ents = E.synth_entities(rng, n, n_keys=n_keys, skew=hot)
        loads = {}
        for part in ["range", "balanced"]:
            res = api.resolve(ents, cfg.with_(partitioner=part))
            loads[part] = np.asarray(res.blocking.load)
        print(f"{hot:6.2f} | {P.gini(loads['range']):15.3f} "
              f"{loads['range'].max():9d} | "
              f"{P.gini(loads['balanced']):13.3f} "
              f"{loads['balanced'].max():9d}")
    print("\nEven splits degrade with skew (paper Fig. 9); the balanced "
          "splitter holds the non-hot shards level — the hot key itself is "
          "irreducible under MapReduce semantics (paper §5.3).")


if __name__ == "__main__":
    main()
