"""The paper's §5.3 data-skew study in miniature + the beyond-paper fixes.

Builds increasingly skewed key distributions (Even8_40..85 analogues), runs
the full pipeline through ``repro.api.resolve`` with (a) even key-range
splits — the paper's setup — (b) the histogram-balanced splitter, and
(c) the ``repro.balance`` comparison-count planners (blocksplit), reporting
Gini + the planned comparison imbalance (max/mean — the critical-path proxy
for reducer wall time) straight off the typed results.

  PYTHONPATH=src python examples/skew_study.py
"""
import numpy as np

from repro import api
from repro.core import entities as E
from repro.core import partition as P


def main():
    rng = np.random.default_rng(0)
    n, n_keys, r, w = 40_000, 512, 8, 6
    cfg = api.ERConfig(window=w, variant="repsn", hops=r - 1,
                       runner="vmap", num_shards=r)
    parts = ["range", "balanced", "blocksplit"]
    hdr = " | ".join(f"{p + ' gini':>15} {'imb':>6}" for p in parts)
    print(f"{'skew':>6} | {hdr}")
    for hot in [0.0, 0.4, 0.55, 0.7, 0.85]:
        ents = E.synth_entities(rng, n, n_keys=n_keys, skew=hot)
        cells = []
        for part in parts:
            res = api.resolve(ents, cfg.with_(partitioner=part))
            imb = res.balance.imbalance_realized
            g = P.gini(np.asarray(res.blocking.load))
            cells.append(f"{g:15.3f} {imb:6.2f}")
        print(f"{hot:6.2f} | " + " | ".join(cells))
    print("\nEven splits degrade with skew (paper Fig. 9); the balanced "
          "splitter levels the non-hot shards but cannot split a hot key "
          "across shards — the blocksplit planner (repro.balance) can, "
          "holding the comparison imbalance near 1.0 at any skew.")


if __name__ == "__main__":
    main()
