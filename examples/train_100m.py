"""End-to-end driver (deliverable b): dedup a corpus with the paper's
technique, then train a ~100M-parameter LM for a few hundred steps with
checkpointing and fault tolerance.

  PYTHONPATH=src python examples/train_100m.py [--steps 300] [--arch gemma2-9b]

(~100M-parameter member of the chosen arch family; runs on CPU in ~minutes
with the default reduced sequence length.)
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    stats = train_main([
        "--arch", args.arch, "--preset", "100m",
        "--steps", str(args.steps), "--seq-len", str(args.seq_len),
        "--batch", str(args.batch), "--dedup",
        "--ckpt-dir", "/tmp/repro_100m_ckpt", "--ckpt-every", "100",
    ])
    assert stats.losses[-1] < stats.losses[0], "loss should decrease"
    print("OK: loss decreased "
          f"{stats.losses[0]:.3f} -> {stats.losses[-1]:.3f}")


if __name__ == "__main__":
    main()
