"""Batched serving example: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python examples/serve_lm.py [--arch mixtral-8x22b]

Uses the reduced (smoke) config of the chosen arch so it runs on CPU; the
identical engine lowers for the production mesh in the decode dry-run cells.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_variant
from repro.models import lm
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke_variant(ARCHS[args.arch])
    params = lm.lm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    eng = ServeEngine(cfg=cfg, params=params,
                      max_len=args.prompt_len + args.new_tokens,
                      batch=args.batch)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len)).astype(np.int32)

    t0 = time.perf_counter()
    toks = eng.generate(prompts, n_new=args.new_tokens)
    dt = time.perf_counter() - t0
    print(f"arch={args.arch} generated {toks.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    print("first sequence:", toks[0].tolist())


if __name__ == "__main__":
    main()
