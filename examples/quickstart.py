"""Quickstart: parallel Sorted Neighborhood entity resolution in 60 seconds.

One facade — ``repro.api.resolve`` — runs the paper's three MapReduce-style
SN variants (SRP / RepSN / JobSN) on any registered runner and returns typed
results with blocking-quality metrics computed against the sequential
oracle.  A second call, ``repro.api.link``, does dual-source (R x S) record
linkage with the same machinery.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import api
from repro.core import entities as E
from repro.core import sn


def main():
    rng = np.random.default_rng(7)
    n, r, w, n_keys = 2000, 8, 8, 512
    print(f"n={n} entities, r={r} shards, window w={w}")

    ents = E.synth_entities(rng, n, n_keys=n_keys, dup_frac=0.25)
    keys, eids = np.asarray(ents["key"]), np.asarray(ents["eid"])
    oracle = sn.sequential_sn_pairs(keys, eids, w)
    print(f"sequential SN pairs: {len(oracle)} "
          f"(closed form: {sn.expected_pair_count(n, w)})")

    # -- one config, three variants, typed results --------------------------------
    base = api.ERConfig(window=w, runner="vmap", num_shards=r,
                        partitioner="balanced", compute_metrics=True)
    print(f"\nvariants ({', '.join(api.available_variants())} are "
          f"registered; runner={base.runner}):")
    for variant in ["srp", "repsn", "jobsn"]:
        res = api.resolve(ents, base.with_(variant=variant))
        b = res.blocking
        note = ""
        if variant == "srp":
            note = (f"  <- misses exactly (r-1)*w*(w-1)/2 = "
                    f"{sn.srp_missed_boundary_pairs(r, w)} boundary pairs")
        print(f"  {variant:6s}: blocked={len(b.pairs)} "
              f"matched={len(res.matches)} "
              f"completeness={res.metrics.pairs_completeness:.4f} "
              f"reduction={res.metrics.reduction_ratio:.4f} "
              f"max_load={b.max_load}{note}")

    # -- same config, sequential oracle runner: must agree exactly ----------------
    seq = api.resolve(ents, base.with_(variant="repsn", runner="sequential"))
    par = api.resolve(ents, base.with_(variant="repsn"))
    assert seq.blocking.pairs == par.blocking.pairs
    assert seq.matches == par.matches
    print("\nRepSN (vmap) == sequential oracle: the paper's §4 claim, "
          "verified through one facade.")

    # -- dual-source linkage: R x S, cross-source pairs only ----------------------
    take = rng.permutation(n)[: n // 4]
    rhs = E.make_entities(
        np.asarray(ents["key"])[take],
        np.arange(len(take), dtype=np.int32),
        payload={k: np.asarray(v)[take]
                 for k, v in ents["payload"].items()})
    linked = api.link(ents, rhs, base.with_(variant="repsn", hops=r - 1))
    print(f"\ndual-source linkage R({n}) x S({len(take)}): "
          f"blocked={len(linked.blocking.pairs)} "
          f"matched={len(linked.matches)} (all cross-source, "
          f"completeness={linked.metrics.pairs_completeness:.4f})")


if __name__ == "__main__":
    main()
