"""Quickstart: parallel Sorted Neighborhood blocking in 60 seconds.

Generates a synthetic publication-like corpus, runs the three MapReduce-style
SN variants (SRP / RepSN / JobSN) over 8 vmapped shards, and checks the
results against the sequential oracle — the paper's §4 in miniature.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import entities as E
from repro.core import partition as P
from repro.core import pipeline as PL
from repro.core import sn
from repro.core.pipeline import SNConfig


def main():
    rng = np.random.default_rng(7)
    n, r, w, n_keys = 2000, 8, 8, 512
    print(f"n={n} entities, r={r} shards, window w={w}")

    ents = E.synth_entities(rng, n, n_keys=n_keys, dup_frac=0.25)
    keys, eids = np.asarray(ents["key"]), np.asarray(ents["eid"])
    bounds = P.balanced_partition(keys, r)
    sizes = np.asarray(P.partition_sizes(bounds, ents["key"], r=r))
    print(f"partition sizes: {sizes.tolist()}  (gini={P.gini(sizes):.3f})")

    oracle = sn.sequential_sn_pairs(keys, eids, w)
    print(f"sequential SN pairs: {len(oracle)} "
          f"(closed form: {sn.expected_pair_count(n, w)})")

    for variant in ["srp", "repsn", "jobsn"]:
        out = PL.run_vmap(ents, r, bounds, SNConfig(window=w,
                                                    variant=variant))
        blocked = PL.blocked_pairs(out)
        matched = PL.result_pairs(out)
        missing = len(oracle - blocked)
        note = ""
        if variant == "srp":
            note = (f"  <- misses exactly (r-1)*w*(w-1)/2 = "
                    f"{sn.srp_missed_boundary_pairs(r, w)} boundary pairs")
        print(f"{variant:6s}: blocked={len(blocked)} matched={len(matched)} "
              f"missing={missing}{note}")

    print("\nRepSN/JobSN == sequential SN: the paper's §4 claims, verified.")


if __name__ == "__main__":
    main()
