"""Before/after comparison of dry-run artifact sets (the §Perf evidence).

  PYTHONPATH=src:. python -m benchmarks.perf_compare \
      experiments/dryrun_baseline experiments/dryrun

Prints per-cell collective/flops/memory deltas between the paper-faithful
baseline sweep and the optimized sweep.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path


def load(d: Path) -> dict:
    out = {}
    for p in d.glob("*.json"):
        r = json.loads(p.read_text())
        if r.get("status") == "ok" and not r.get("tag"):
            out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def main():
    base_dir = Path(sys.argv[1]) if len(sys.argv) > 1 else \
        Path("experiments/dryrun_baseline")
    new_dir = Path(sys.argv[2]) if len(sys.argv) > 2 else \
        Path("experiments/dryrun")
    base, new = load(base_dir), load(new_dir)
    keys = sorted(set(base) & set(new))
    print("| arch | shape | mesh | coll B before | after | Δ | temp GB before | after |")
    print("|" + "---|" * 8)
    tot_b = tot_n = 0.0
    for k in keys:
        b, n = base[k], new[k]
        cb = b["analysis"]["collective_bytes"]
        cn = n["analysis"]["collective_bytes"]
        tb = b.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 1e9
        tn = n.get("memory_analysis", {}).get("temp_size_in_bytes", 0) / 1e9
        tot_b += cb
        tot_n += cn
        print(f"| {k[0]} | {k[1]} | {k[2]} | {cb:.2e} | {cn:.2e} "
              f"| {(cn-cb)/max(cb,1):+.0%} | {tb:.1f} | {tn:.1f} |")
    print(f"\ntotal collective bytes: {tot_b:.3e} -> {tot_n:.3e} "
          f"({(tot_n-tot_b)/tot_b:+.1%})")


if __name__ == "__main__":
    main()
