"""Run a benchmark callable in a subprocess with N host CPU devices.

jax locks the device count at first init, so multi-shard wall-time
measurements (the paper's speedup curves) re-exec python with
``--xla_force_host_platform_device_count=N`` and return JSON via stdout.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]


def run_with_devices(n_devices: int, module: str, func: str,
                     kwargs: dict, timeout: int = 1200) -> dict:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
            " --xla_force_host_platform_device_count={n_devices}")
        import json, sys
        from {module} import {func}
        out = {func}(**{kwargs!r})
        print("@@RESULT@@" + json.dumps(out))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = f"{REPO}/src:{REPO}:" + env.get("PYTHONPATH", "")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)
    for line in proc.stdout.splitlines():
        if line.startswith("@@RESULT@@"):
            return json.loads(line[len("@@RESULT@@"):])
    raise RuntimeError(
        f"subprocess failed (rc={proc.returncode}):\n"
        f"stdout: {proc.stdout[-2000:]}\nstderr: {proc.stderr[-2000:]}")
