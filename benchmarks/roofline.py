"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

Reads the dry-run artifacts (experiments/dryrun/*.json), derives

  compute term    = dot_FLOPs_per_device / peak_FLOPs            [s]
  memory term     = dot_bytes_per_device / HBM_bw                [s]
  collective term = collective_bytes_per_device / link_bw        [s]

(all per-device quantities come from the trip-count-corrected HLO analysis —
``cost_analysis`` counts while bodies once; see repro/perf/hlo_analysis.py),
plus MODEL_FLOPS (6ND train / 2ND prefill / 2N·B decode) and the useful-
compute ratio.  Writes experiments/roofline.csv and a markdown table.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
ART = REPO / "experiments" / "dryrun"

PEAK_FLOPS = 197e12          # bf16 / chip (v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / ICI link (term uses 1 link, conservative)

SHAPE_TOKENS = {  # tokens processed per step (global)
    "train_4k": ("train", 256 * 4096),
    "prefill_32k": ("prefill", 32 * 32768),
    "decode_32k": ("decode", 128),        # one token per sequence
    "long_500k": ("decode", 1),
}


def model_flops(rec: dict) -> float:
    kind, tokens = SHAPE_TOKENS[rec["shape"]]
    n_active = rec.get("active_params") or rec.get("model_params", 0)
    if kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


def analyze_record(rec: dict) -> dict:
    an = rec["analysis"]
    chips = rec["devices"]
    t_comp = an["dot_flops"] / PEAK_FLOPS
    t_mem = an["dot_bytes"] / HBM_BW
    t_coll = an["collective_bytes"] / LINK_BW
    mf = model_flops(rec)
    hlo_total = an["dot_flops"] * chips
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = t_comp / bound if bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom,
        "roofline_fraction": frac,          # compute term / dominant term
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": mf / hlo_total if hlo_total else 0.0,
        "tag": rec.get("tag", ""),
    }


_SUGGEST = {
    "compute": "compute-bound: reduce redundant FLOPs (remat policy, causal "
               "chunk enumeration) or accept — near roofline.",
    "memory": "HBM-bound: fuse/shrink activations, bigger MXU tiles, lower "
              "precision traffic.",
    "collective": "collective-bound: shrink/bf16-cast psums, reduce-scatter "
                  "instead of all-reduce, overlap via async collectives.",
}


def load_all(tag: str = "") -> list[dict]:
    out = []
    for p in sorted(ART.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") != "ok" or rec.get("tag", "") != tag:
            continue
        out.append(analyze_record(rec))
    return out


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | roofline frac | useful ratio |\n|" + "---|" * 9)
    lines = [hdr]
    for r in sorted(rows, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | {r['dominant']} "
            f"| {r['roofline_fraction']:.2f} | {r['useful_ratio']:.2f} |")
    return "\n".join(lines)


def main():
    tag = sys.argv[1] if len(sys.argv) > 1 else ""
    rows = load_all(tag)
    if not rows:
        print("no dry-run artifacts found; run repro.launch.dryrun first")
        return
    out_csv = REPO / "experiments" / "roofline.csv"
    cols = list(rows[0].keys())
    with open(out_csv, "w") as f:
        f.write(",".join(cols) + "\n")
        for r in rows:
            f.write(",".join(str(r[c]) for c in cols) + "\n")
    print(markdown_table(rows))
    print(f"\nwrote {out_csv} ({len(rows)} cells)")
    # bottleneck histogram + suggestions
    from collections import Counter
    doms = Counter(r["dominant"] for r in rows)
    print("\nbottlenecks:", dict(doms))
    for k, v in doms.items():
        print(f"  {k}: {_SUGGEST[k]}")


if __name__ == "__main__":
    main()
