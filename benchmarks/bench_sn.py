"""Benchmark bodies for the paper's tables/figures.

These functions are invoked in subprocesses (benchmarks/_subproc.py) with a
controlled CPU device count, or inline for single-device measurements.

  * scalability_body     — Fig. 8: wall time of the full parallel SN pipeline
                           at r shards (real shard_map over r host devices)
  * skew_body            — Fig. 9 / Table 1: runtime + Gini per partitioner
  * jobsn_vs_repsn_body  — §5.2: variant comparison (time + collectives)
"""
from __future__ import annotations

import time
from dataclasses import replace
from typing import Optional

import numpy as np


def _setup(n, n_keys, seed=0, skew=0.0):
    import jax
    from repro.core import entities as E
    rng = np.random.default_rng(seed)
    return E.synth_entities(rng, n, n_keys=n_keys, dup_frac=0.2, skew=skew)


def _time_pipeline(ents, mesh, bounds, cfg, reps=3):
    import jax
    from repro.api import ShardMapRunner
    runner = ShardMapRunner(mesh=mesh, axis="data")
    run = lambda: runner.run_raw(ents, bounds, cfg)
    out = run()                              # compile + warm
    jax.block_until_ready(out["main"]["match"])
    t0 = time.perf_counter()
    for _ in range(reps):
        out = run()
        jax.block_until_ready(out["main"]["match"])
    dt = (time.perf_counter() - t0) / reps
    n_pairs = int(np.asarray(out["main"]["match"]).sum())
    if "boundary" in out:
        n_pairs += int(np.asarray(out["boundary"]["match"]).sum())
    return dt, n_pairs, out


def scalability_body(n: int = 100_000, w: int = 10, n_keys: int = 4096,
                     variant: str = "repsn", reps: int = 3) -> dict:
    """Wall time of blocking+matching at r = #devices shards (paper Fig. 8)."""
    import jax
    from repro.api import ERConfig
    from repro.core import partition as P
    r = len(jax.devices())
    mesh = jax.make_mesh((r,), ("data",))
    ents = _setup(n, n_keys)
    bounds = P.balanced_partition(np.asarray(ents["key"]), r)
    cfg = ERConfig(window=w, variant=variant, cap_factor=3.0,
                   runner="shard_map")
    dt, n_pairs, out = _time_pipeline(ents, mesh, bounds, cfg, reps)
    # critical-path model: parallel time ~ max per-shard window work.  This
    # container exposes ONE physical core, so the r "devices" timeshare it
    # and measured wall time stays ~flat; the derived speedup is
    # total work / max-shard work (exactly the quantity the paper's Fig. 8
    # tracks — see EXPERIMENTS.md methodology).
    loads = np.asarray(out["load"])[0]
    total_work = int(loads.sum()) * (w - 1)
    max_work = int(loads.max()) * (w - 1)
    return {"r": r, "n": n, "w": w, "variant": variant,
            "seconds": dt, "pairs": n_pairs,
            "work_speedup": total_work / max(max_work, 1),
            "max_load": int(loads.max())}


def skew_body(n: int = 60_000, w: int = 20, n_keys: int = 4096,
              strategy: str = "manual", reps: int = 3) -> dict:
    """Runtime under skewed partitioning (paper Fig. 9 / Table 1).

    strategy: manual | even10->even mapped onto r | even8_40/55/70/85
    (hot_frac of entities forced into the last partition)."""
    import jax
    from repro.api import ERConfig
    from repro.core import partition as P
    r = len(jax.devices())
    mesh = jax.make_mesh((r,), ("data",))
    hot = 0.0
    if strategy.startswith("even") and "_" in strategy:
        hot = int(strategy.split("_")[1]) / 100.0
    ents = _setup(n, n_keys, skew=0.0)
    keys_np = np.asarray(ents["key"])
    if strategy == "manual":
        bounds = P.balanced_partition(keys_np, r)
    elif hot > 0:
        bounds = P.skewed_partition(n_keys, r, hot, keys_np)
    else:
        bounds = P.range_partition(n_keys, r)
    sizes = np.asarray(P.partition_sizes(bounds, ents["key"], r=r))
    g = P.gini(sizes)
    cfg = ERConfig(window=w, variant="repsn", cap_factor=3.0,
                   runner="shard_map")
    dt, n_pairs, _ = _time_pipeline(ents, mesh, bounds, cfg, reps)
    return {"strategy": strategy, "r": r, "gini": round(g, 3),
            "seconds": dt, "max_load": int(sizes.max()),
            "pairs": n_pairs}


def jobsn_vs_repsn_body(n: int = 60_000, w: int = 50, n_keys: int = 4096,
                        reps: int = 3) -> dict:
    """Variant comparison (paper §5.2) + collective op counts from HLO."""
    import jax
    from repro.api import ERConfig, ShardMapRunner
    from repro.core import partition as P
    from repro.perf import hlo_analysis
    r = len(jax.devices())
    mesh = jax.make_mesh((r,), ("data",))
    ents = _setup(n, n_keys)
    bounds = P.balanced_partition(np.asarray(ents["key"]), r)
    out = {}
    for variant in ["srp", "repsn", "jobsn"]:
        cfg = ERConfig(window=w, variant=variant, cap_factor=3.0,
                       runner="shard_map")
        dt, n_pairs, _ = _time_pipeline(ents, mesh, bounds, cfg, reps)
        # collective profile of the compiled pipeline
        import jax as _jax
        runner = ShardMapRunner(mesh=mesh, axis="data")
        lowered = _jax.jit(
            lambda e: runner.run_raw(e, bounds, cfg)
        ).lower(ents)
        an = hlo_analysis.analyze(lowered.compile().as_text())
        out[variant] = {
            "seconds": dt, "pairs": n_pairs,
            "collective_bytes": an["collective_bytes"],
            "permute_count": an["collectives"]["collective-permute"]["count"],
            "all_to_all_bytes": an["collectives"]["all-to-all"]["bytes"],
        }
    return out
