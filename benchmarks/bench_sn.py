"""Benchmark bodies for the paper's tables/figures.

These functions are invoked in subprocesses (benchmarks/_subproc.py) with a
controlled CPU device count, or inline for single-device measurements.

Timing methodology (ISSUE 4): every body reports ``cold_seconds`` (the
FIRST call on an empty executable cache — includes trace + compile) and
``steady_seconds`` (median of >= 5 warm iterations, fully blocked)
SEPARATELY.  The old single-shot numbers conflated compile time with run
time, which made the pallas cascade look slower than the scan oracle
end-to-end; steady-state is what the serving workload pays.  ``seconds``
stays as an alias of ``steady_seconds`` for downstream readers.

  * scalability_body     — Fig. 8: wall time of the full parallel SN pipeline
                           at r shards (real shard_map over r host devices)
  * skew_body            — Fig. 9 / Table 1: runtime + Gini per partitioner
  * jobsn_vs_repsn_body  — §5.2: variant comparison (time + collectives)
  * band_engine_body     — §5.1: scan vs pallas band engine with the paper's
                           full cascade (cheap cosine+jaccard gating an
                           expensive edit-distance stage), cold/steady wall
                           time, device-side pair emission transfer bytes,
                           packed-vs-set host collection — the
                           BENCH_band_engine.json baseline + perf-smoke gate
  * balance_body         — skew-aware load balancing (ISSUE 3): uniform vs
                           blocksplit vs pairrange planners on a Zipfian
                           corpus (imbalance ratio, planned capacity, wall
                           time, oracle parity) — the BENCH_balance.json
                           baseline
  * stream_body          — out-of-core streaming (ISSUE 5): chunked
                           resolve_stream vs monolithic resolve on a corpus
                           4x the chunk size (steady-state pairs/s, peak
                           device bytes, pair-set parity for all variants x
                           engines) — the BENCH_stream.json baseline
  * serve_body           — online incremental serving (ISSUE 6): sustained
                           micro-batch inserts (+ interleaved deletes) into
                           a ResolutionService over an n-entity base corpus
                           (inserts/s, p50/p95 latency, zero-retrace steady
                           state, final parity vs from-scratch resolve) —
                           the BENCH_serve.json baseline
  * overload_body        — overload hardening (ISSUE 9): an open-loop load
                           generator driving a ResolutionService at 1x/2x/5x
                           its measured warm capacity under a ChaosPlan
                           (latency spikes + injected matcher errors) with
                           queue_policy=shed_oldest and per-request
                           deadlines; every future is accounted (ok / shed /
                           expired / chaos error — zero hung, zero silent
                           drops), goodput and p95/p99 latency per rate, and
                           post-pressure ``repair()`` restores bit-parity —
                           the BENCH_overload.json baseline
  * resilience_body      — fault tolerance (ISSUE 7): checkpointed stream
                           overhead vs plain streaming, kill/resume wall
                           time + parity, overflow-retry zero-dropped-pairs
                           — the BENCH_resilience.json baseline
  * obs_body             — observability (ISSUE 8): traced vs untraced
                           steady resolve (tracing overhead), the
                           deterministic disabled-path cost, zero extra
                           retraces under tracing, and per-variant streamed
                           trace coverage + the exported Chrome trace —
                           the BENCH_obs.json baseline
"""
from __future__ import annotations

import time
from dataclasses import replace
from typing import Optional

import numpy as np


def _setup(n, n_keys, seed=0, skew=0.0, text_len=0):
    import jax
    from repro.core import entities as E
    rng = np.random.default_rng(seed)
    return E.synth_entities(rng, n, n_keys=n_keys, dup_frac=0.2, skew=skew,
                            text_len=text_len)


def _cold_steady(run, steady_reps=5):
    """(cold_seconds, steady_seconds, last_result): first call on an empty
    executable cache vs the median of >= 5 fully-blocked warm calls."""
    import jax
    from repro.perf.cache import executable_cache
    executable_cache().clear()
    t0 = time.perf_counter()
    out = jax.block_until_ready(run())
    cold = time.perf_counter() - t0
    ts = []
    for _ in range(max(steady_reps, 5)):
        t0 = time.perf_counter()
        out = jax.block_until_ready(run())
        ts.append(time.perf_counter() - t0)
    return cold, float(np.median(ts)), out


def _time_pipeline(ents, mesh, bounds, cfg, reps=3):
    import jax
    from repro.api import ShardMapRunner
    runner = ShardMapRunner(mesh=mesh, axis="data")
    cold, steady, out = _cold_steady(
        lambda: runner.run_raw(ents, bounds, cfg), steady_reps=reps)
    n_pairs = int(np.asarray(out["main"]["match"]).sum())
    if "boundary" in out:
        n_pairs += int(np.asarray(out["boundary"]["match"]).sum())
    return cold, steady, n_pairs, out


def scalability_body(n: int = 100_000, w: int = 10, n_keys: int = 4096,
                     variant: str = "repsn", reps: int = 3) -> dict:
    """Wall time of blocking+matching at r = #devices shards (paper Fig. 8)."""
    import jax
    from repro.api import ERConfig
    from repro.core import partition as P
    r = len(jax.devices())
    mesh = jax.make_mesh((r,), ("data",))
    ents = _setup(n, n_keys)
    bounds = P.balanced_partition(np.asarray(ents["key"]), r)
    cfg = ERConfig(window=w, variant=variant, cap_factor=3.0,
                   runner="shard_map")
    cold, steady, n_pairs, out = _time_pipeline(ents, mesh, bounds, cfg, reps)
    # critical-path model: parallel time ~ max per-shard window work.  This
    # container exposes ONE physical core, so the r "devices" timeshare it
    # and measured wall time stays ~flat; the derived speedup is
    # total work / max-shard work (exactly the quantity the paper's Fig. 8
    # tracks — see EXPERIMENTS.md methodology).
    loads = np.asarray(out["load"])[0]
    total_work = int(loads.sum()) * (w - 1)
    max_work = int(loads.max()) * (w - 1)
    return {"r": r, "n": n, "w": w, "variant": variant,
            "cold_seconds": cold, "steady_seconds": steady,
            "seconds": steady, "pairs": n_pairs,
            "work_speedup": total_work / max(max_work, 1),
            "max_load": int(loads.max())}


def skew_body(n: int = 60_000, w: int = 20, n_keys: int = 4096,
              strategy: str = "manual", reps: int = 3) -> dict:
    """Runtime under skewed partitioning (paper Fig. 9 / Table 1).

    strategy: manual | even10->even mapped onto r | even8_40/55/70/85
    (hot_frac of entities forced into the last partition)."""
    import jax
    from repro.api import ERConfig
    from repro.core import partition as P
    r = len(jax.devices())
    mesh = jax.make_mesh((r,), ("data",))
    hot = 0.0
    if strategy.startswith("even") and "_" in strategy:
        hot = int(strategy.split("_")[1]) / 100.0
    ents = _setup(n, n_keys, skew=0.0)
    keys_np = np.asarray(ents["key"])
    if strategy == "manual":
        bounds = P.balanced_partition(keys_np, r)
    elif hot > 0:
        bounds = P.skewed_partition(n_keys, r, hot, keys_np)
    else:
        bounds = P.range_partition(n_keys, r)
    sizes = np.asarray(P.partition_sizes(bounds, ents["key"], r=r))
    g = P.gini(sizes)
    cfg = ERConfig(window=w, variant="repsn", cap_factor=3.0,
                   runner="shard_map")
    cold, steady, n_pairs, _ = _time_pipeline(ents, mesh, bounds, cfg, reps)
    return {"strategy": strategy, "r": r, "gini": round(g, 3),
            "cold_seconds": cold, "steady_seconds": steady,
            "seconds": steady, "max_load": int(sizes.max()),
            "pairs": n_pairs}


def _part_transfer_bytes(part: dict) -> int:
    """Host bytes a part's pair representation transfers: boolean bands (+
    the (r, M) eids backing extraction) or emitted index buffers + counts."""
    if "mask_idx" in part:
        fields = ["mask_idx", "mask_n", "match_idx", "match_n", "eid"]
    else:
        fields = ["mask", "match"]
    total = sum(np.asarray(part[f]).nbytes for f in fields)
    if "mask_idx" not in part:
        total += np.asarray(part["ents"]["eid"]).nbytes
    return total


def paper_cascade():
    """The paper's §3/§5.1 match strategy shape: cheap similarities (cosine
    on embeddings, Jaccard on trigram signatures) gating an EXPENSIVE
    edit-distance stage, weighted average, threshold 0.75.  This is the
    workload where the cascade has a real cost gap — the old bench used the
    cheap-only default matcher, where "skipping the expensive stage" had
    nothing to skip."""
    from repro.core.match import CascadeMatcher, Matcher
    return CascadeMatcher(matchers=(
        Matcher(field="feat", kind="cosine", weight=0.25, cost=1.0),
        Matcher(field="sig", kind="jaccard", weight=0.25, cost=2.0),
        Matcher(field="text", kind="edit", weight=0.5, cost=10.0),
    ), threshold=0.75)


def band_engine_body(n: int = 20_000, w: int = 10, n_keys: int = 2048,
                     r: int = 4, variant: str = "repsn", reps: int = 5,
                     collect_pairs: int = 100_000) -> dict:
    """Scan vs pallas band engine on the vmap runner (single device), with
    the paper's full cascade (cosine + jaccard gating edit distance) and
    device-side pair emission (emit="pairs").

    Reports per engine: cold (first call, trace + compile) and steady
    (median of >= 5 warm, blocked calls) wall time of the full resolve —
    device run + host collection; expensive-matcher evaluations ACTUALLY
    run (the §5.1 FLOP lever — scan pays one full cascade per band slot;
    pallas scores its cand_cap buffer).  Capacities come from
    ``balance.suggest_caps`` fed with one unbounded probe resolve: the
    probe's realized loads bound ``pair_cap`` hard (zero overflow) and its
    gate-survivor counts tighten ``cand_cap`` to the DESIGN.md §6 ~1.25x
    rule.  Also reported: transfer bytes of the band-mask vs
    packed-index representations.  ``pairs_per_s`` is STEADY-STATE blocked
    pairs per second — the acceptance metric the perf-smoke CI gate
    tracks.  Also times host pair collection: packed uint64 (+np.unique)
    vs the set-of-tuples baseline at ~``collect_pairs`` pairs."""
    import jax
    from repro import api
    from repro import balance as B
    from repro.core import partition as P

    ents = _setup(n, n_keys, text_len=16)
    bounds = P.balanced_partition(np.asarray(ents["key"]), r)
    matcher = paper_cascade()
    feat_dim = ents["payload"]["feat"].shape[1]
    sig_words = ents["payload"]["sig"].shape[1]
    text_len = ents["payload"]["text"].shape[1]
    # crude per-evaluation cascade cost: cosine 2F + jaccard ~6W + the
    # edit-distance DP's ~8*L^2 ops — the expensive stage dominates
    flops_per_eval = 2 * feat_dim + 6 * sig_words + 8 * text_len * text_len
    runner = api.VmapRunner(r)

    out = {"n": n, "w": w, "r": r, "variant": variant,
           "backend": jax.default_backend(), "engines": {}}
    results = {}
    for engine in ["scan", "pallas"]:
        cfg = api.ERConfig(window=w, variant=variant, hops=r - 1,
                           runner="vmap", num_shards=r, band_engine=engine,
                           matcher=matcher, emit="pairs")
        # one unbounded probe resolve feeds balance.suggest_caps: realized
        # loads set the hard pair_cap bound, gate-survivor counts tighten
        # cand_cap (pallas only — scan has no survivor buffer)
        probe = runner.resolve(ents, bounds, cfg)
        prof = B.profile_keys(np.asarray(ents["key"]), window=w)
        caps = B.suggest_caps(
            prof, cfg, max_load=int(max(probe.load)),
            observed_cand=probe.cand_count if engine == "pallas" else None)
        cand_cap = caps.cand_cap if engine == "pallas" else 0
        pair_cap = caps.pair_cap
        cfg = cfg.with_(cand_cap=cand_cap, pair_cap=pair_cap)

        cold, steady, res = _cold_steady(
            lambda: runner.resolve(ents, bounds, cfg), steady_reps=reps)
        results[engine] = res
        raw = runner.run_raw(ents, bounds, cfg)
        raw_band = runner.run_raw(ents, bounds, cfg.with_(emit="band"))
        transfer_packed = sum(_part_transfer_bytes(raw[p])
                              for p in ("main", "boundary") if p in raw)
        transfer_band = sum(_part_transfer_bytes(raw_band[p])
                            for p in ("main", "boundary") if p in raw_band)
        out["engines"][engine] = {
            "cold_seconds": cold,
            "steady_seconds": steady,
            "seconds": steady,
            "steady_speedup_vs_cold": cold / max(steady, 1e-9),
            "matcher_evals": res.matcher_evals,
            "matcher_flops_est": res.matcher_evals * flops_per_eval,
            "band_slots": (w - 1) * sum(res.load),
            "cand_cap": cand_cap,
            "cand_count": sum(res.cand_count),
            "cand_count_per_shard": list(res.cand_count),
            "cand_overflow": res.cand_overflow,
            "pair_cap": pair_cap,
            "pair_overflow": res.pair_overflow,
            "transfer_bytes_packed": transfer_packed,
            "transfer_bytes_band": transfer_band,
            "blocked": len(res.blocked),
            "matched": len(res.matched),
            "pairs_per_s": len(res.blocked) / max(steady, 1e-9),
        }
    seq = api.SequentialRunner(num_shards=r).resolve(
        ents, bounds, api.ERConfig(window=w, variant=variant,
                                   runner="sequential", num_shards=r,
                                   matcher=matcher))
    out["parity"] = {
        "blocked_equal": results["scan"].blocked == results["pallas"].blocked,
        "matched_equal": results["scan"].matched == results["pallas"].matched,
        "oracle_equal": results["scan"].blocked == seq.blocked
        and results["scan"].matched == seq.matched,
    }

    # host pair collection: one synthetic stacked part with ~collect_pairs
    # band hits, timed through both extraction paths
    m = max(collect_pairs // (w - 1) + w, 4 * w)
    rng = np.random.default_rng(0)
    band = rng.random((1, w - 1, m)) < \
        collect_pairs / ((w - 1) * m)
    for d in range(1, w):                                # keep i + d < m
        band[0, d - 1, m - d:] = False
    part = {"ents": {"eid": np.arange(m, dtype=np.int32)[None, :]},
            "match": band}

    def timeit(fn, reps_c=5):
        fn()
        t0 = time.perf_counter()
        for _ in range(reps_c):
            fn()
        return (time.perf_counter() - t0) / reps_c

    t_set = timeit(lambda: api.pairs_from_band(part, "match"))
    t_packed = timeit(lambda: api.packed_pairs_from_band(part, "match"))
    out["collection"] = {
        "pairs": int(band.sum()),
        "set_seconds": t_set,
        "packed_seconds": t_packed,
        "speedup": t_set / max(t_packed, 1e-9),
    }
    return out


def balance_body(n: int = 6_000, w: int = 10, r: int = 8,
                 exponent: float = 1.0, n_clusters: int = 256,
                 dup_frac: float = 0.15, reps: int = 5) -> dict:
    """Uniform vs blocksplit vs pairrange partition planners on a Zipfian
    hot-head corpus (the ISSUE 3 acceptance benchmark).

    Per planner: planned/realized comparison-count imbalance (max/mean — the
    direct parallel-efficiency loss, since wall-clock is the max of
    per-shard work), the planned per-shard padded capacity (static shapes:
    every shard PAYS the padded band, so capacity is also the single-device
    FLOP lever measured by the vmap wall time here), and exact pair-set
    parity against the uniform planner and the sequential SN oracle."""
    import jax
    from repro import api
    from repro import balance as B
    from repro.core import sn
    from repro.data.corpus import zipf_entities

    ents = zipf_entities(0, n, n_clusters=n_clusters, exponent=exponent,
                         dup_frac=dup_frac)
    keys = np.asarray(ents["key"])
    eids = np.asarray(ents["eid"])
    oracle = sn.sequential_sn_pairs(keys, eids, w)
    hot_key_count = int(np.bincount(keys).max())

    out = {"n": n, "w": w, "r": r, "exponent": exponent,
           "n_clusters": n_clusters, "hot_key_count": hot_key_count,
           "backend": jax.default_backend(), "oracle_pairs": len(oracle),
           "planners": {}}
    pairs_by = {}
    for planner in ["uniform", "blocksplit", "pairrange"]:
        cfg = api.ERConfig(window=w, variant="repsn", hops=r - 1,
                           runner="vmap", num_shards=r, partitioner=planner)
        plan = B.plan_shards(ents, cfg, r)
        runner = api.VmapRunner(r)
        cold, steady, res = _cold_steady(
            lambda: runner.resolve(ents, plan, cfg), steady_reps=reps)
        pairs_by[planner] = res.blocked
        out["planners"][planner] = {
            "cold_seconds": cold, "steady_seconds": steady,
            "seconds": steady,
            "imbalance_planned": plan.imbalance,
            "imbalance_realized": B.imbalance_ratio(
                B.realized_comparisons(res.load, w)),
            "planned_load": [int(x) for x in plan.planned_load],
            "realized_load": [int(x) for x in res.load],
            "max_comparisons": int(np.max(plan.planned_comparisons)),
            "straggler_shard": plan.straggler,
            "cap_link": plan.cap_link,
            "band_slots_per_shard": (w - 1) * r * plan.cap_link,
            "split_routing": plan.dest is not None,
            "halo_entities": int(np.asarray(plan.halo).sum()),
            "overflow": res.overflow,
            "blocked": len(res.blocked),
            "matched": len(res.matched),
            "oracle_equal": set(res.blocked) == oracle,
        }
    imb = {p: out["planners"][p]["imbalance_planned"]
           for p in out["planners"]}
    out["parity"] = {
        "blocksplit_equals_uniform":
            pairs_by["blocksplit"] == pairs_by["uniform"],
        "pairrange_equals_uniform":
            pairs_by["pairrange"] == pairs_by["uniform"],
        "all_equal_oracle": all(v["oracle_equal"]
                                for v in out["planners"].values()),
    }
    out["imbalance_reduction"] = {
        "blocksplit": imb["uniform"] / max(imb["blocksplit"], 1e-9),
        "pairrange": imb["uniform"] / max(imb["pairrange"], 1e-9),
    }
    return out


def stream_body(n: int = 24_000, chunk: int = 6_000, w: int = 10,
                n_keys: int = 2048, r: int = 4, reps: int = 3) -> dict:
    """Out-of-core streaming vs monolithic resolution (ISSUE 5 acceptance).

    The corpus is ``n = 4x chunk`` entities consumed as a chunk generator;
    ``resolve_stream`` externally sorts and resolves it chunk-by-chunk with
    a w-1 seam halo while monolithic ``resolve`` stages everything at once.
    Reports, per band engine (repsn, the timing workload): cold and steady
    wall time of both paths (steady = median of ``reps`` blocked warm
    calls; the stream's warm calls replay the whole sort+merge+resolve
    pipeline against a hot executable cache), steady-state blocked pairs/s,
    and the device-residency ratio — peak per-chunk device input bytes over
    the bytes a monolithic resolve stages (the out-of-core claim, measured
    from the staged arrays themselves: the process-wide jax allocator
    high-water mark is monotone and would only echo whichever path ran
    first).  The parity grid then checks blocked/matched bit-identity
    stream-vs-monolithic for ALL variants x engines at this scale."""
    import jax
    from repro import api, stream
    from repro.core import entities as E
    from repro.data.corpus import synth_entity_chunks

    def chunks():
        return synth_entity_chunks(0, n, chunk, n_keys=n_keys,
                                   dup_frac=0.2)

    full = E.host_concat([E.to_host(c) for c in chunks()])
    ents = E.make_entities(full["key"], full["eid"],
                           payload=full["payload"])

    out = {"n": n, "chunk": chunk, "w": w, "r": r,
           "backend": jax.default_backend(), "engines": {}, "parity": {}}
    timed = {}            # repsn results, reused by the parity grid below
    for engine in ["scan", "pallas"]:
        cfg = api.ERConfig(window=w, variant="repsn", hops=r - 1,
                           runner="vmap", num_shards=r, band_engine=engine)
        mono_cold, mono_steady, mono = _cold_steady(
            lambda: api.resolve(ents, cfg), steady_reps=reps)
        s_cold, s_steady, sres = _cold_steady(
            lambda: stream.resolve_stream(chunks(), cfg, chunk_size=chunk),
            steady_reps=reps)
        timed[engine] = (mono, sres)
        st = sres.stream
        out["engines"][engine] = {
            "mono_cold_seconds": mono_cold,
            "mono_steady_seconds": mono_steady,
            "stream_cold_seconds": s_cold,
            "stream_steady_seconds": s_steady,
            "seconds": s_steady,
            "pairs": len(sres.pairs),
            "mono_pairs_per_s": len(mono.pairs) / max(mono_steady, 1e-9),
            "stream_pairs_per_s": len(sres.pairs) / max(s_steady, 1e-9),
            "stream_overhead": s_steady / max(mono_steady, 1e-9),
            "chunks": st.chunks,
            "steady_chunks": st.steady_chunks,
            "carry_entities": st.carry_entities,
            "chunk_device_bytes": st.chunk_device_bytes,
            "corpus_bytes": st.corpus_bytes,
            "residency_ratio": st.chunk_device_bytes
            / max(st.corpus_bytes, 1),
        }
    for variant in ["srp", "repsn", "jobsn"]:
        for engine in ["scan", "pallas"]:
            if variant == "repsn":        # already resolved by the timing
                mono, sres = timed[engine]  # loop — don't pay it twice
            else:
                cfg = api.ERConfig(window=w, variant=variant, hops=r - 1,
                                   runner="vmap", num_shards=r,
                                   band_engine=engine)
                mono = api.resolve(ents, cfg)
                sres = stream.resolve_stream(chunks(), cfg,
                                             chunk_size=chunk)
            out["parity"][f"{variant}/{engine}"] = {
                "blocked_equal": sres.pairs == mono.pairs,
                "matched_equal": sres.matches == mono.matches,
                "pairs": len(sres.pairs),
            }
    out["parity_all"] = all(v["blocked_equal"] and v["matched_equal"]
                            for v in out["parity"].values())
    return out


def serve_body(n: int = 50_000, w: int = 10, n_keys: int = 4096,
               r: int = 4, batch: int = 200, ops: int = 24,
               warm: int = 4) -> dict:
    """Online incremental serving (ISSUE 6 acceptance).

    Bootstraps a ``ResolutionService`` with an ``n``-entity base corpus,
    then applies ``ops`` micro-batches of ``batch`` inserts with a delete
    of ``batch // 4`` random live entities interleaved every 4th op.  The
    first ``warm`` ops populate the shape-bucket grid; the measured tail
    must be ZERO-RETRACE — every delta call a pure executable-cache hit
    (``steady_after_warm``, the structural claim perf_smoke gates on).
    The service is pinned to a single delta-call bucket
    (``shard_buckets=(8,)``, ``cap_floor=256``) so the steady state is
    deterministic regardless of where the random inserts land; the
    multi-bucket grid is exercised in ``tests/test_serve.py``.

    Reports sustained insert throughput (entities/s over the measured
    insert ops), p50/p95 submit-to-result latency, cache/trace counters,
    the shape-bucket set, and final bit-parity of the served pair/match
    sets against one from-scratch ``resolve`` of the live corpus."""
    import jax
    from repro import api
    from repro.core import entities as E

    extra = ops * batch
    rng = np.random.default_rng(0)
    full = E.to_host(E.synth_entities(rng, n + extra, n_keys=n_keys,
                                      dup_frac=0.2))
    cfg = api.ERConfig(window=w, variant="repsn", hops=r - 1,
                       runner="vmap", num_shards=r)
    from repro.perf.cache import executable_cache
    executable_cache().clear()
    t0 = time.perf_counter()
    svc = api.serve(cfg, initial=E.host_take(full, slice(0, n)),
                    start=False, shard_buckets=(8,), cap_floor=256)
    bootstrap = time.perf_counter() - t0

    live = np.zeros(n + extra, bool)
    live[:n] = True
    del_rng = np.random.default_rng(1)
    insert_s = insert_n = 0.0
    traces_after_warm = 0
    for op in range(ops):
        lo = n + op * batch
        if op == warm:
            traces_after_warm = svc.stats().traces
        t0 = time.perf_counter()
        svc.resolve_incremental(E.host_take(full, slice(lo, lo + batch)))
        dt = time.perf_counter() - t0
        live[lo:lo + batch] = True
        if op >= warm:
            insert_s += dt
            insert_n += batch
        if op % 4 == 3:
            gone = del_rng.choice(np.flatnonzero(live), batch // 4,
                                  replace=False)
            svc.delete(full["eid"][gone])
            live[gone] = False

    st = svc.stats()
    h = E.host_take(full, np.flatnonzero(live))
    ref = api.resolve(E.make_entities(h["key"], h["eid"],
                                      payload=h["payload"],
                                      valid=h["valid"]), cfg)
    return {
        "n": n, "w": w, "r": r, "batch": batch, "ops": ops, "warm": warm,
        "backend": jax.default_backend(),
        "bootstrap_seconds": bootstrap,
        "seconds": insert_s / max(insert_n / batch, 1),
        "sustained_inserts_per_s": insert_n / max(insert_s, 1e-9),
        "p50_ms": st.p50_ms,
        "p95_ms": st.p95_ms,
        "batches": st.batches,
        "steady_batches": st.steady_batches,
        "traces": st.traces,
        "traces_after_warm": traces_after_warm,
        "steady_after_warm": st.traces == traces_after_warm,
        "cache_hits": st.cache_hits,
        "device_calls": st.device_calls,
        "shapes": [list(s) for s in st.shapes],
        "live_entities": st.live_entities,
        "compactions": st.compactions,
        "pairs": st.pairs,
        "matches": st.matches,
        "parity": {
            "blocked_equal": svc.pairs == ref.blocking.pairs,
            "matched_equal": svc.matches == ref.matches,
        },
    }


def overload_body(n: int = 6_000, w: int = 8, n_keys: int = 1024,
                  r: int = 4, batch: int = 120, ops: int = 24,
                  warm: int = 5, rates=(1.0, 2.0, 5.0),
                  queue_cap: int = 8, spike_s: float = 0.03,
                  hung_timeout_s: float = 120.0) -> dict:
    """Overload-hardened serving (ISSUE 9 acceptance).

    Bootstraps a ``ResolutionService`` under ``queue_policy=shed_oldest``
    with per-request deadlines and a ``ChaosPlan`` injecting latency
    spikes and matcher errors at fixed dispatch indices, measures the
    warm per-batch capacity with ``warm`` synchronous inserts, then runs
    one OPEN-LOOP submission phase per rate multiplier: ``ops`` requests
    paced at ``rate`` times capacity, submitted without waiting (a delete
    of ``batch // 4`` base entities every 5th op).  ``max_batch=batch``
    pins one request per micro-batch so the capacity ceiling is exact and
    5x arrival strictly exceeds it — the queue MUST fill and the policy
    MUST engage.

    Every submitted future is then accounted exactly once: ``ok``
    (applied), ``shed`` (OverloadError), ``expired``
    (DeadlineExceededError), ``chaos_errors`` (InjectedFault), ``hung``
    (not settled within ``hung_timeout_s``) or ``unexpected`` — the
    structural gates (perf_smoke ``check_overload``) require zero hung
    and zero unexpected at EVERY rate, the policy engaged (shed +
    expired + degraded > 0) at the top rate, and bit-parity of the
    served sets after ``repair()`` against a from-scratch resolve of
    exactly the mutations whose futures succeeded."""
    import jax
    from concurrent.futures import TimeoutError as FutTimeout
    from repro import api
    from repro.core import entities as E
    from repro.perf.cache import executable_cache
    from repro.resilience import ChaosEvent, ChaosPlan, InjectedFault
    from repro.serve import (AdmissionConfig, DeadlineExceededError,
                             OverloadError)

    total_ops = warm + len(rates) * ops
    rng = np.random.default_rng(0)
    full = E.to_host(E.synth_entities(rng, n + total_ops * batch,
                                      n_keys=n_keys, dup_frac=0.2))
    cfg = api.ERConfig(window=w, variant="repsn", hops=r - 1,
                       runner="vmap", num_shards=r)
    executable_cache().clear()

    # chaos at fixed dispatch indices past the warm window: a periodic
    # latency spike plus sparser injected matcher errors — events the
    # phases never reach are harmless, so the schedule is static
    events = [ChaosEvent(batch=k, kind="latency", seconds=spike_s)
              for k in range(warm + 3, warm + 1 + len(rates) * ops, 6)]
    events += [ChaosEvent(batch=k, kind="error")
               for k in range(warm + 7, warm + 1 + len(rates) * ops, 19)]
    adm = AdmissionConfig(queue_policy="shed_oldest")
    svc = api.serve(cfg, initial=E.host_take(full, slice(0, n)),
                    shard_buckets=(8,), cap_floor=256, max_batch=batch,
                    max_wait_ms=0.0, queue_cap=queue_cap, admission=adm,
                    chaos=ChaosPlan(tuple(events)))

    live = np.zeros(full["key"].shape[0], bool)
    live[:n] = True
    nxt = n
    times = []
    for _ in range(warm):           # warm-up doubles as capacity probe
        t0 = time.perf_counter()
        svc.resolve_incremental(E.host_take(full, slice(nxt, nxt + batch)))
        times.append(time.perf_counter() - t0)
        live[nxt:nxt + batch] = True
        nxt += batch
    t_op = float(np.median(times))
    deadline_ms = 1e3 * t_op * 40   # ~40 batches of queue wait

    del_ptr = 0                     # disjoint delete targets in the base
    phases = []
    for rate in rates:
        interval = t_op / rate
        before = svc.stats()
        done_at: dict = {}
        futs = []                   # (future, kind, lo, hi, t_submit)
        t0 = time.perf_counter()
        next_t = t0
        for op in range(ops):
            ts = time.perf_counter()
            if op % 5 == 4 and del_ptr + batch // 4 <= n:
                lo, hi = del_ptr, del_ptr + batch // 4
                f = svc.submit_delete(full["eid"][lo:hi],
                                      deadline_ms=deadline_ms)
                del_ptr = hi
                futs.append((f, "delete", lo, hi, ts))
            else:
                lo, hi = nxt, nxt + batch
                f = svc.submit_insert(E.host_take(full, slice(lo, hi)),
                                      deadline_ms=deadline_ms)
                nxt = hi
                futs.append((f, "insert", lo, hi, ts))
            f.add_done_callback(
                lambda fut, d=done_at: d.setdefault(
                    id(fut), time.perf_counter()))
            next_t += interval
            time.sleep(max(0.0, next_t - time.perf_counter()))
        submit_wall = time.perf_counter() - t0
        ok = shed = expired = chaos_err = hung = unexpected = 0
        lat = []
        for f, kind, lo, hi, ts in futs:
            try:
                exc = f.exception(timeout=hung_timeout_s)
            except FutTimeout:
                hung += 1
                continue
            lat.append(done_at.get(id(f), time.perf_counter()) - ts)
            if exc is None:
                ok += 1
                live[lo:hi] = kind == "insert"
            elif isinstance(exc, OverloadError):
                shed += 1
            elif isinstance(exc, DeadlineExceededError):
                expired += 1
            elif isinstance(exc, InjectedFault):
                chaos_err += 1
            else:
                unexpected += 1
        drain_wall = (max(done_at.values()) - t0) if done_at else 0.0
        after = svc.stats()
        phases.append({
            "rate": rate, "submitted": len(futs), "ok": ok,
            "shed": shed, "expired": expired, "chaos_errors": chaos_err,
            "hung": hung, "unexpected": unexpected,
            "degraded_batches": after.degraded_batches
            - before.degraded_batches,
            "goodput_rps": ok / max(drain_wall, 1e-9),
            "shed_rate": shed / max(len(futs), 1),
            "p95_ms": 1e3 * float(np.percentile(lat, 95)) if lat else 0.0,
            "p99_ms": 1e3 * float(np.percentile(lat, 99)) if lat else 0.0,
            "submit_wall_s": submit_wall, "drain_wall_s": drain_wall,
        })

    repaired = svc.repair()         # the worker may already have repaired
    st = svc.stats()
    h = E.host_take(full, np.flatnonzero(live))
    ref = api.resolve(E.make_entities(h["key"], h["eid"],
                                      payload=h["payload"],
                                      valid=h["valid"]), cfg)
    out = {
        "n": n, "w": w, "r": r, "batch": batch, "ops": ops, "warm": warm,
        "queue_cap": queue_cap, "backend": jax.default_backend(),
        "seconds": t_op,
        "capacity_batches_per_s": 1.0 / max(t_op, 1e-9),
        "deadline_ms": deadline_ms,
        "rates": phases,
        "chaos_events": len(events),
        "shed": st.shed, "expired": st.expired,
        "degraded_batches": st.degraded_batches,
        "repairs": st.repairs, "repaired_now": repaired,
        "dirty_after_repair": st.dirty_ranges,
        "health_final": st.health,
        "parity": {
            "blocked_equal": svc.pairs == ref.blocking.pairs,
            "matched_equal": svc.matches == ref.matches,
        },
    }
    svc.close()
    return out


def jobsn_vs_repsn_body(n: int = 60_000, w: int = 50, n_keys: int = 4096,
                        reps: int = 3) -> dict:
    """Variant comparison (paper §5.2) + collective op counts from HLO."""
    import jax
    from repro.api import ERConfig, ShardMapRunner
    from repro.core import partition as P
    from repro.perf import hlo_analysis
    r = len(jax.devices())
    mesh = jax.make_mesh((r,), ("data",))
    ents = _setup(n, n_keys)
    bounds = P.balanced_partition(np.asarray(ents["key"]), r)
    out = {}
    for variant in ["srp", "repsn", "jobsn"]:
        cfg = ERConfig(window=w, variant=variant, cap_factor=3.0,
                       runner="shard_map")
        cold, steady, n_pairs, _ = _time_pipeline(ents, mesh, bounds, cfg,
                                                  reps)
        # collective profile of the compiled pipeline
        import jax as _jax
        runner = ShardMapRunner(mesh=mesh, axis="data")
        lowered = _jax.jit(
            lambda e: runner.run_raw(e, bounds, cfg)
        ).lower(ents)
        an = hlo_analysis.analyze(lowered.compile().as_text())
        out[variant] = {
            "cold_seconds": cold, "steady_seconds": steady,
            "seconds": steady, "pairs": n_pairs,
            "collective_bytes": an["collective_bytes"],
            "permute_count": an["collectives"]["collective-permute"]["count"],
            "all_to_all_bytes": an["collectives"]["all-to-all"]["bytes"],
        }
    return out


def resilience_body(n: int = 24_000, chunk: int = 6_000, w: int = 10,
                    n_keys: int = 2048, r: int = 4, reps: int = 3,
                    kill_at: int = 2) -> dict:
    """Fault tolerance (ISSUE 7 acceptance): what durability costs and
    what recovery buys.

    Three measurements on the streaming corpus (``n = 4x chunk``, repsn):

      * checkpoint overhead — steady wall time of a checkpointed
        ``resolve_stream`` (fresh checkpoint directory per rep, so every
        rep pays the full spool/manifest write path) over a plain
        OUT-OF-CORE stream (``spool_dir`` set, fresh per rep): both paths
        spool raw chunks + sorted runs to disk, so the ratio isolates the
        durability writes — per-chunk pair spool, carry halo, manifest
        commit.  The gate holds it at <= 15%.  The in-memory plain stream
        is also reported (``inmem_steady_seconds``) as the no-disk
        reference point
      * kill/resume — a FaultPlan kills the run after chunk ``kill_at``
        commits; ``api.resume`` finishes from the checkpoint.  Reports
        both halves' wall time and resumed-vs-plain pair parity
      * overflow retry — srp pair emission under a deliberately tiny
        pair_cap with ``on_overflow="retry"``: the cap ladder must recover
        EVERY pair an unbounded run emits (zero dropped), and the retry /
        escalation counts show the sticky-cap convergence
    """
    import os
    import shutil
    import tempfile

    import jax
    from repro import api, stream
    from repro.data.corpus import synth_entity_chunks
    from repro.resilience import FaultPlan, InjectedFault, micro_caps

    def chunks():
        return synth_entity_chunks(0, n, chunk, n_keys=n_keys,
                                   dup_frac=0.2)

    cfg = api.ERConfig(window=w, variant="repsn", hops=r - 1,
                       runner="vmap", num_shards=r)
    root = tempfile.mkdtemp(prefix="bench_resilience_")
    try:
        _, inmem_steady, plain = _cold_steady(
            lambda: stream.resolve_stream(chunks(), cfg, chunk_size=chunk),
            steady_reps=reps)

        seq = {"plain": 0, "ck": 0}

        def spooled_run():
            d = os.path.join(root, f"plain{seq['plain']}")
            seq["plain"] += 1
            return stream.resolve_stream(chunks(), cfg, chunk_size=chunk,
                                         spool_dir=d)

        def ckpt_run():
            d = os.path.join(root, f"ck{seq['ck']}")
            seq["ck"] += 1
            return stream.resolve_stream(chunks(), cfg, chunk_size=chunk,
                                         checkpoint_dir=d)

        plain_cold, plain_steady, _ = _cold_steady(spooled_run,
                                                   steady_reps=reps)
        ck_cold, ck_steady, ck = _cold_steady(ckpt_run, steady_reps=reps)

        d = os.path.join(root, "kill")
        t0 = time.perf_counter()
        try:
            stream.resolve_stream(chunks(), cfg, chunk_size=chunk,
                                  checkpoint_dir=d,
                                  fault_plan=FaultPlan(
                                      crash_after_chunk=kill_at))
        except InjectedFault:
            pass
        killed_seconds = time.perf_counter() - t0
        t0 = time.perf_counter()
        resumed = api.resume(d)
        resume_seconds = time.perf_counter() - t0

        pcfg = cfg.with_(variant="srp", emit="pairs",
                         partitioner="uniform")
        base = stream.resolve_stream(chunks(), pcfg.with_(pair_cap=0),
                                     chunk_size=chunk)
        rcfg = micro_caps(pcfg, pair_cap=64).with_(
            cand_cap=None, on_overflow="retry", retry_limit=12)
        rres = stream.resolve_stream(chunks(), rcfg, chunk_size=chunk)

        return {
            "n": n, "chunk": chunk, "w": w, "r": r,
            "backend": jax.default_backend(),
            "inmem_steady_seconds": inmem_steady,
            "plain_cold_seconds": plain_cold,
            "plain_steady_seconds": plain_steady,
            "ckpt_cold_seconds": ck_cold,
            "ckpt_steady_seconds": ck_steady,
            "seconds": ck_steady,
            "checkpoint_overhead": ck_steady / max(plain_steady, 1e-9),
            "pairs": len(plain.pairs),
            "resume": {
                "kill_at": kill_at,
                "chunks": resumed.stream.chunks,
                "killed_seconds": killed_seconds,
                "resume_seconds": resume_seconds,
                "blocked_equal": resumed.pairs == plain.pairs,
                "matched_equal": resumed.matches == plain.matches,
            },
            "checkpointed_parity": ck.pairs == plain.pairs,
            "retry": {
                "start_pair_cap": 64,
                "final_pair_cap": rres.resilience.pair_cap,
                "retries": rres.resilience.retries,
                "escalations": rres.resilience.escalations,
                "pair_overflow": rres.blocking.pair_overflow,
                "dropped_pairs": len(base.pairs) - len(rres.pairs),
                "blocked_equal": rres.pairs == base.pairs,
                "matched_equal": rres.matches == base.matches,
            },
        }
    finally:
        shutil.rmtree(root, ignore_errors=True)


def obs_body(n: int = 12_000, chunk: int = 3_000, w: int = 8,
             n_keys: int = 2048, r: int = 4, reps: int = 5) -> dict:
    """Observability overhead + coverage (ISSUE 8 acceptance).

    Four claims, measured on one corpus:

      * **traced overhead** — steady resolve wall time with
        ``trace=True`` over the untraced steady time (median of ``reps``
        blocked warm calls each, same warm executable cache); the gate is
        <= 5%.
      * **disabled overhead** — the cost tracing adds when it is OFF,
        measured deterministically instead of as wall-clock jitter: the
        per-call cost of a no-op span (no active tracer, the exact
        disabled-path code) times the span count a traced run records,
        over the untraced steady time; the gate is <= 1%.
      * **zero extra retraces** — the traced loop runs on the cache the
        untraced loop warmed; ``trace`` is excluded from the executable
        fingerprint (invariant 12), so it must add ZERO traces.
      * **coverage** — a traced streamed run per variant: the root
        ``stream`` span's direct children must sum to >= 90% of its wall
        (per-chunk spans account for the run); the repsn trace is
        exported as ``BENCH_obs_trace.json`` for the Chrome-trace CI
        artifact + ``tools/trace_report.py``.
    """
    import jax
    from repro import api, obs, stream
    from repro.core import entities as E
    from repro.data.corpus import synth_entity_chunks
    from repro.perf.cache import executable_cache

    def chunks():
        return synth_entity_chunks(0, n, chunk, n_keys=n_keys,
                                   dup_frac=0.2)

    full = E.host_concat([E.to_host(c) for c in chunks()])
    ents = E.make_entities(full["key"], full["eid"],
                           payload=full["payload"])
    cfg = api.ERConfig(window=w, variant="repsn", hops=r - 1,
                       runner="vmap", num_shards=r)

    _cold, untraced_s, _ = _cold_steady(lambda: api.resolve(ents, cfg),
                                        steady_reps=reps)
    cache = executable_cache()
    before = cache.stats.snapshot()
    ts, res = [], None
    for _ in range(max(reps, 5)):
        t0 = time.perf_counter()
        res = jax.block_until_ready(
            api.resolve(ents, cfg.with_(trace=True)))
        ts.append(time.perf_counter() - t0)
    traced_s = float(np.median(ts))
    _h, _m, extra_traces = cache.stats.delta(before)
    spans_per_resolve = len(res.trace.spans)

    # the disabled path, timed directly: one with-block over the no-op
    # singleton per call site (there is no active tracer here)
    loops = 200_000
    t0 = time.perf_counter()
    for _ in range(loops):
        with obs.span("x", attr=1):
            pass
    noop_s = (time.perf_counter() - t0) / loops

    streams = {}
    trace_path = "BENCH_obs_trace.json"
    for variant in ["srp", "repsn", "jobsn"]:
        sres = stream.resolve_stream(chunks(),
                                     cfg.with_(variant=variant,
                                               trace=True),
                                     chunk_size=chunk)
        tr = sres.trace
        streams[variant] = {"wall_s": tr.wall, "spans": len(tr.spans),
                            "coverage": tr.coverage(),
                            "chunks": sres.stream.chunks}
        if variant == "repsn":
            tr.export_chrome(trace_path)

    return {
        "n": n, "chunk": chunk, "w": w, "r": r, "variant": "repsn",
        "backend": jax.default_backend(),
        "steady_untraced_seconds": untraced_s,
        "steady_traced_seconds": traced_s,
        "seconds": traced_s,
        "traced_overhead": traced_s / max(untraced_s, 1e-9) - 1.0,
        "noop_span_seconds": noop_s,
        "spans_per_resolve": spans_per_resolve,
        "disabled_overhead": spans_per_resolve * noop_s
        / max(untraced_s, 1e-9),
        "extra_traces_when_traced": int(extra_traces),
        "zero_extra_retraces": int(extra_traces) == 0,
        "span_totals": res.trace.span_totals(),
        "stream": streams,
        "coverage_all": all(v["coverage"] >= 0.9
                            for v in streams.values()),
        "trace_file": trace_path,
    }


def recall_body(n: int = 2_000, w: int = 4, wmax: int = 12, r: int = 4,
                reps: int = 3, typo_rate: float = 0.1,
                prune_threshold: float = 0.55) -> dict:
    """Quality frontier (ISSUE 10 acceptance): PC/RR/F Pareto.

    Runs the labeled skewed corpus (``data/truth.py``: known duplicate
    clusters up to ``wmax`` entities, ``typo_rate`` corrupted keys) through
    six blocking configurations and scores each against the gold pair set:

      * ``fixed_w`` / ``fixed_wmid`` / ``fixed_wmax`` — the classic fixed-
        window frontier (more recall only by paying more comparisons),
      * ``multipass``    — fixed ``wmax`` + a second identity pass on the
        uncorrupted ``alt`` key (the typo-recovery lever),
      * ``adaptive``     — ``window_policy="adaptive"``: base ``w`` grown
        to per-block density, capped at ``wmax``,
      * ``meta_blocked`` — adaptive + ``prune_policy="evidence"``: low-
        evidence candidates dropped before the expensive matcher stage.

    Per config: pairs-completeness / pairs-quality / reduction-ratio / F,
    blocked + pruned counts, steady wall seconds, and two parity bits
    (streamed-over-uneven-chunks and traced runs must reproduce the
    monolithic pair sets bit-identically).  ``gates`` distills the claims
    perf_smoke --recall enforces: PC=1.0 on the clean corpus at full
    window with pruning off, adaptive strictly dominating the mid fixed
    window (higher PC at fewer blocked pairs), pruning engaged without
    dropping a single gold pair (invariant 14), and all parity bits."""
    import jax
    from repro import api, quality, stream
    from repro.core import entities as E
    from repro.data.truth import labeled_corpus

    tc = labeled_corpus(1, n, max_cluster=wmax, typo_rate=typo_rate)
    wmid = (w + wmax) // 2

    def chunks(ents):
        h = E.to_host(ents)
        sizes, pos, k = [], 0, 0
        while pos < n:                     # deterministically uneven chunks
            s = min(n // 5 + (53 * k) % 97, n - pos)
            sizes.append(s)
            pos += s
            k += 1
        out, s0 = [], 0
        for s in sizes:
            out.append(E.host_take(h, slice(s0, s0 + s)))
            s0 += s
        return iter(out)

    base = dict(variant="repsn", hops=r - 1, runner="vmap", num_shards=r)
    alt_pass = (api.SortKeySpec(name="key"),
                api.SortKeySpec(name="alt", source="alt", kind="identity"))
    cfgs = {
        "fixed_w": api.ERConfig(window=w, **base),
        "fixed_wmid": api.ERConfig(window=wmid, **base),
        "fixed_wmax": api.ERConfig(window=wmax, **base),
        "multipass": api.ERConfig(window=wmax, passes=alt_pass, **base),
        "adaptive": api.ERConfig(window=w, window_policy="adaptive",
                                 window_max=wmax, **base),
        "meta_blocked": api.ERConfig(window=w, window_policy="adaptive",
                                     window_max=wmax,
                                     prune_policy="evidence",
                                     prune_threshold=prune_threshold,
                                     **base),
    }

    out = {"n": n, "w": w, "wmid": wmid, "wmax": wmax, "r": r,
           "typo_rate": typo_rate, "prune_threshold": prune_threshold,
           "gold_pairs": len(tc.gold), "n_typos": tc.n_typos,
           "max_block": tc.max_block, "backend": jax.default_backend(),
           "configs": {}}
    for name, cfg in cfgs.items():
        cold, steady, res = _cold_steady(
            lambda: api.resolve(tc.ents, cfg), steady_reps=reps)
        q = quality.evaluate(res, tc)
        sres = stream.resolve_stream(chunks(tc.ents), cfg,
                                     chunk_size=max(n // 5, wmax))
        tres = api.resolve(tc.ents, cfg.with_(trace=True))
        out["configs"][name] = {
            "pc": q.pairs_completeness, "pq": q.pairs_quality,
            "rr": q.reduction_ratio, "f": q.f_measure,
            "blocked": q.blocked_pairs, "true_positives": q.true_positives,
            "matched": len(res.matches),
            "pruned": int(res.blocking.pruned),
            "cold_seconds": cold, "steady_seconds": steady,
            "seconds": steady,
            "streamed_equal": sres.pairs == res.pairs
            and sres.matches == res.matches,
            "traced_equal": tres.pairs == res.pairs
            and tres.matches == res.matches,
        }

    # the clean-corpus full-window gate: with no typos, pruning off and
    # w >= the largest key block, boundary-complete SN must be exhaustive
    clean = labeled_corpus(2, n, max_cluster=wmax, typo_rate=0.0)
    clean_q = quality.evaluate(
        api.resolve(clean.ents, api.ERConfig(window=clean.max_block,
                                             **base)), clean)

    c = out["configs"]
    out["gates"] = {
        "full_window_pc": clean_q.pairs_completeness,
        "adaptive_dominates_fixed":
            c["adaptive"]["pc"] > c["fixed_wmid"]["pc"]
            and c["adaptive"]["blocked"] <= c["fixed_wmid"]["blocked"],
        "pruning_engaged": c["meta_blocked"]["pruned"] > 0
            and c["meta_blocked"]["blocked"] < c["adaptive"]["blocked"],
        "pruned_gold_dropped":
            c["adaptive"]["true_positives"]
            - c["meta_blocked"]["true_positives"],
        "multipass_recovers_typos":
            c["multipass"]["pc"] > c["fixed_wmax"]["pc"],
        "parity_all": all(v["streamed_equal"] and v["traced_equal"]
                          for v in c.values()),
    }
    return out
