"""Benchmark bodies for the paper's tables/figures.

These functions are invoked in subprocesses (benchmarks/_subproc.py) with a
controlled CPU device count, or inline for single-device measurements.

  * scalability_body     — Fig. 8: wall time of the full parallel SN pipeline
                           at r shards (real shard_map over r host devices)
  * skew_body            — Fig. 9 / Table 1: runtime + Gini per partitioner
  * jobsn_vs_repsn_body  — §5.2: variant comparison (time + collectives)
  * band_engine_body     — §5.1: scan vs pallas band engine (matcher FLOPs,
                           wall time, pairs/s) + packed-vs-set host
                           collection — the BENCH_band_engine.json baseline
  * balance_body         — skew-aware load balancing (ISSUE 3): uniform vs
                           blocksplit vs pairrange planners on a Zipfian
                           corpus (imbalance ratio, planned capacity, wall
                           time, oracle parity) — the BENCH_balance.json
                           baseline
"""
from __future__ import annotations

import time
from dataclasses import replace
from typing import Optional

import numpy as np


def _setup(n, n_keys, seed=0, skew=0.0):
    import jax
    from repro.core import entities as E
    rng = np.random.default_rng(seed)
    return E.synth_entities(rng, n, n_keys=n_keys, dup_frac=0.2, skew=skew)


def _time_pipeline(ents, mesh, bounds, cfg, reps=3):
    import jax
    from repro.api import ShardMapRunner
    runner = ShardMapRunner(mesh=mesh, axis="data")
    run = lambda: runner.run_raw(ents, bounds, cfg)
    out = run()                              # compile + warm
    jax.block_until_ready(out["main"]["match"])
    t0 = time.perf_counter()
    for _ in range(reps):
        out = run()
        jax.block_until_ready(out["main"]["match"])
    dt = (time.perf_counter() - t0) / reps
    n_pairs = int(np.asarray(out["main"]["match"]).sum())
    if "boundary" in out:
        n_pairs += int(np.asarray(out["boundary"]["match"]).sum())
    return dt, n_pairs, out


def scalability_body(n: int = 100_000, w: int = 10, n_keys: int = 4096,
                     variant: str = "repsn", reps: int = 3) -> dict:
    """Wall time of blocking+matching at r = #devices shards (paper Fig. 8)."""
    import jax
    from repro.api import ERConfig
    from repro.core import partition as P
    r = len(jax.devices())
    mesh = jax.make_mesh((r,), ("data",))
    ents = _setup(n, n_keys)
    bounds = P.balanced_partition(np.asarray(ents["key"]), r)
    cfg = ERConfig(window=w, variant=variant, cap_factor=3.0,
                   runner="shard_map")
    dt, n_pairs, out = _time_pipeline(ents, mesh, bounds, cfg, reps)
    # critical-path model: parallel time ~ max per-shard window work.  This
    # container exposes ONE physical core, so the r "devices" timeshare it
    # and measured wall time stays ~flat; the derived speedup is
    # total work / max-shard work (exactly the quantity the paper's Fig. 8
    # tracks — see EXPERIMENTS.md methodology).
    loads = np.asarray(out["load"])[0]
    total_work = int(loads.sum()) * (w - 1)
    max_work = int(loads.max()) * (w - 1)
    return {"r": r, "n": n, "w": w, "variant": variant,
            "seconds": dt, "pairs": n_pairs,
            "work_speedup": total_work / max(max_work, 1),
            "max_load": int(loads.max())}


def skew_body(n: int = 60_000, w: int = 20, n_keys: int = 4096,
              strategy: str = "manual", reps: int = 3) -> dict:
    """Runtime under skewed partitioning (paper Fig. 9 / Table 1).

    strategy: manual | even10->even mapped onto r | even8_40/55/70/85
    (hot_frac of entities forced into the last partition)."""
    import jax
    from repro.api import ERConfig
    from repro.core import partition as P
    r = len(jax.devices())
    mesh = jax.make_mesh((r,), ("data",))
    hot = 0.0
    if strategy.startswith("even") and "_" in strategy:
        hot = int(strategy.split("_")[1]) / 100.0
    ents = _setup(n, n_keys, skew=0.0)
    keys_np = np.asarray(ents["key"])
    if strategy == "manual":
        bounds = P.balanced_partition(keys_np, r)
    elif hot > 0:
        bounds = P.skewed_partition(n_keys, r, hot, keys_np)
    else:
        bounds = P.range_partition(n_keys, r)
    sizes = np.asarray(P.partition_sizes(bounds, ents["key"], r=r))
    g = P.gini(sizes)
    cfg = ERConfig(window=w, variant="repsn", cap_factor=3.0,
                   runner="shard_map")
    dt, n_pairs, _ = _time_pipeline(ents, mesh, bounds, cfg, reps)
    return {"strategy": strategy, "r": r, "gini": round(g, 3),
            "seconds": dt, "max_load": int(sizes.max()),
            "pairs": n_pairs}


def band_engine_body(n: int = 20_000, w: int = 10, n_keys: int = 2048,
                     r: int = 4, variant: str = "repsn", reps: int = 3,
                     collect_pairs: int = 100_000) -> dict:
    """Scan vs pallas band engine on the vmap runner (single device).

    Reports per engine: wall time, expensive-matcher evaluations ACTUALLY
    run (the §5.1 FLOP lever — scan pays one full cascade per band slot;
    pallas scores its cand_cap buffer, sized here by the DESIGN.md §6 rule:
    probe survivor counts with an unbounded buffer, then cap at ~1.25x the
    busiest shard so overflow is zero and parity holds), an estimated
    matcher FLOP count, and pairs/sec.  Off-TPU the pallas kernel runs
    under the interpreter, so WALL TIME on CPU is a correctness-path
    number; ``matcher_evals`` is the hardware-independent claim.  Also
    times host pair collection: packed uint64 (+np.unique) vs the
    set-of-tuples baseline at ~``collect_pairs`` pairs."""
    import jax
    from repro import api
    from repro.core import partition as P

    ents = _setup(n, n_keys)
    bounds = P.balanced_partition(np.asarray(ents["key"]), r)
    feat_dim = ents["payload"]["feat"].shape[1]
    sig_words = ents["payload"]["sig"].shape[1]
    # crude per-evaluation cascade cost: cosine 2F FLOPs + jaccard ~6W ops
    flops_per_eval = 2 * feat_dim + 6 * sig_words
    runner = api.VmapRunner(r)

    def survivors_per_shard(cfg):
        # the DESIGN.md §6 sizing probe, via the public result surface:
        # per-shard gate survivors with an unbounded buffer
        return max(runner.resolve(ents, bounds, cfg).cand_count)

    out = {"n": n, "w": w, "r": r, "variant": variant,
           "backend": jax.default_backend(), "engines": {}}
    results = {}
    for engine in ["scan", "pallas"]:
        cfg = api.ERConfig(window=w, variant=variant, hops=r - 1,
                           runner="vmap", num_shards=r, band_engine=engine)
        cand_cap = 0
        if engine == "pallas":
            cand_cap = int(survivors_per_shard(
                cfg.with_(cand_cap=0)) * 1.25) + 16
            cfg = cfg.with_(cand_cap=cand_cap)
        raw = runner.run_raw(ents, bounds, cfg)         # compile + warm
        jax.block_until_ready(raw["main"]["match"])
        t0 = time.perf_counter()
        for _ in range(reps):
            raw = runner.run_raw(ents, bounds, cfg)
            jax.block_until_ready(raw["main"]["match"])
        dt = (time.perf_counter() - t0) / reps
        res = runner.resolve(ents, bounds, cfg)
        results[engine] = res
        out["engines"][engine] = {
            "seconds": dt,
            "matcher_evals": res.matcher_evals,
            "matcher_flops_est": res.matcher_evals * flops_per_eval,
            "band_slots": (w - 1) * sum(res.load),
            "cand_cap": cand_cap,
            "cand_count": sum(res.cand_count),
            "cand_count_per_shard": list(res.cand_count),
            "cand_overflow": res.cand_overflow,
            "blocked": len(res.blocked),
            "matched": len(res.matched),
            "pairs_per_s": len(res.blocked) / max(dt, 1e-9),
        }
    out["parity"] = {
        "blocked_equal": results["scan"].blocked == results["pallas"].blocked,
        "matched_equal": results["scan"].matched == results["pallas"].matched,
    }

    # host pair collection: one synthetic stacked part with ~collect_pairs
    # band hits, timed through both extraction paths
    m = max(collect_pairs // (w - 1) + w, 4 * w)
    rng = np.random.default_rng(0)
    band = rng.random((1, w - 1, m)) < \
        collect_pairs / ((w - 1) * m)
    for d in range(1, w):                                # keep i + d < m
        band[0, d - 1, m - d:] = False
    part = {"ents": {"eid": np.arange(m, dtype=np.int32)[None, :]},
            "match": band}

    def timeit(fn, reps_c=5):
        fn()
        t0 = time.perf_counter()
        for _ in range(reps_c):
            fn()
        return (time.perf_counter() - t0) / reps_c

    t_set = timeit(lambda: api.pairs_from_band(part, "match"))
    t_packed = timeit(lambda: api.packed_pairs_from_band(part, "match"))
    out["collection"] = {
        "pairs": int(band.sum()),
        "set_seconds": t_set,
        "packed_seconds": t_packed,
        "speedup": t_set / max(t_packed, 1e-9),
    }
    return out


def balance_body(n: int = 6_000, w: int = 10, r: int = 8,
                 exponent: float = 1.0, n_clusters: int = 256,
                 dup_frac: float = 0.15, reps: int = 3) -> dict:
    """Uniform vs blocksplit vs pairrange partition planners on a Zipfian
    hot-head corpus (the ISSUE 3 acceptance benchmark).

    Per planner: planned/realized comparison-count imbalance (max/mean — the
    direct parallel-efficiency loss, since wall-clock is the max of
    per-shard work), the planned per-shard padded capacity (static shapes:
    every shard PAYS the padded band, so capacity is also the single-device
    FLOP lever measured by the vmap wall time here), and exact pair-set
    parity against the uniform planner and the sequential SN oracle."""
    import jax
    from repro import api
    from repro import balance as B
    from repro.core import sn
    from repro.data.corpus import zipf_entities

    ents = zipf_entities(0, n, n_clusters=n_clusters, exponent=exponent,
                         dup_frac=dup_frac)
    keys = np.asarray(ents["key"])
    eids = np.asarray(ents["eid"])
    oracle = sn.sequential_sn_pairs(keys, eids, w)
    hot_key_count = int(np.bincount(keys).max())

    out = {"n": n, "w": w, "r": r, "exponent": exponent,
           "n_clusters": n_clusters, "hot_key_count": hot_key_count,
           "backend": jax.default_backend(), "oracle_pairs": len(oracle),
           "planners": {}}
    pairs_by = {}
    for planner in ["uniform", "blocksplit", "pairrange"]:
        cfg = api.ERConfig(window=w, variant="repsn", hops=r - 1,
                           runner="vmap", num_shards=r, partitioner=planner)
        plan = B.plan_shards(ents, cfg, r)
        runner = api.VmapRunner(r)
        runner.resolve(ents, plan, cfg)          # compile + warm
        t0 = time.perf_counter()
        for _ in range(reps):
            res = runner.resolve(ents, plan, cfg)
        dt = (time.perf_counter() - t0) / reps
        pairs_by[planner] = res.blocked
        out["planners"][planner] = {
            "seconds": dt,
            "imbalance_planned": plan.imbalance,
            "imbalance_realized": B.imbalance_ratio(
                B.realized_comparisons(res.load, w)),
            "planned_load": [int(x) for x in plan.planned_load],
            "realized_load": [int(x) for x in res.load],
            "max_comparisons": int(np.max(plan.planned_comparisons)),
            "straggler_shard": plan.straggler,
            "cap_link": plan.cap_link,
            "band_slots_per_shard": (w - 1) * r * plan.cap_link,
            "split_routing": plan.dest is not None,
            "halo_entities": int(np.asarray(plan.halo).sum()),
            "overflow": res.overflow,
            "blocked": len(res.blocked),
            "matched": len(res.matched),
            "oracle_equal": set(res.blocked) == oracle,
        }
    imb = {p: out["planners"][p]["imbalance_planned"]
           for p in out["planners"]}
    out["parity"] = {
        "blocksplit_equals_uniform":
            pairs_by["blocksplit"] == pairs_by["uniform"],
        "pairrange_equals_uniform":
            pairs_by["pairrange"] == pairs_by["uniform"],
        "all_equal_oracle": all(v["oracle_equal"]
                                for v in out["planners"].values()),
    }
    out["imbalance_reduction"] = {
        "blocksplit": imb["uniform"] / max(imb["blocksplit"], 1e-9),
        "pairrange": imb["uniform"] / max(imb["pairrange"], 1e-9),
    }
    return out


def jobsn_vs_repsn_body(n: int = 60_000, w: int = 50, n_keys: int = 4096,
                        reps: int = 3) -> dict:
    """Variant comparison (paper §5.2) + collective op counts from HLO."""
    import jax
    from repro.api import ERConfig, ShardMapRunner
    from repro.core import partition as P
    from repro.perf import hlo_analysis
    r = len(jax.devices())
    mesh = jax.make_mesh((r,), ("data",))
    ents = _setup(n, n_keys)
    bounds = P.balanced_partition(np.asarray(ents["key"]), r)
    out = {}
    for variant in ["srp", "repsn", "jobsn"]:
        cfg = ERConfig(window=w, variant=variant, cap_factor=3.0,
                       runner="shard_map")
        dt, n_pairs, _ = _time_pipeline(ents, mesh, bounds, cfg, reps)
        # collective profile of the compiled pipeline
        import jax as _jax
        runner = ShardMapRunner(mesh=mesh, axis="data")
        lowered = _jax.jit(
            lambda e: runner.run_raw(e, bounds, cfg)
        ).lower(ents)
        an = hlo_analysis.analyze(lowered.compile().as_text())
        out[variant] = {
            "seconds": dt, "pairs": n_pairs,
            "collective_bytes": an["collective_bytes"],
            "permute_count": an["collectives"]["collective-permute"]["count"],
            "all_to_all_bytes": an["collectives"]["all-to-all"]["bytes"],
        }
    return out
