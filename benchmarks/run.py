"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (plus human-readable detail to
stderr-ish comment lines).  Usage:

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Tables:
  fig8_scalability      paper Fig. 8: speedup vs #cores, w in {10, 100}
  tbl1_fig9_skew        paper Table 1 + Fig. 9: Gini vs runtime
  sec52_jobsn_vs_repsn  paper §5.2: JobSN vs RepSN (+ SRP baseline)
  band_engine           §5.1 cascade: scan vs pallas band engine + packed
                        pair collection; writes BENCH_band_engine.json
  balance               skew-aware planners (uniform/blocksplit/pairrange)
                        on the Zipfian corpus; writes BENCH_balance.json
  stream                out-of-core resolve_stream vs monolithic resolve
                        (pairs/s, peak device bytes, parity for all
                        variants x engines); writes BENCH_stream.json
  serve                 online incremental serving: sustained micro-batch
                        inserts/deletes into a ResolutionService
                        (inserts/s, p50/p95 latency, zero-retrace steady
                        state, parity); writes BENCH_serve.json
  overload              overload-hardened serving: open-loop load at
                        1x/2x/5x warm capacity under chaos, shed/expired/
                        degraded accounting, goodput + p95/p99, repair
                        parity; writes BENCH_overload.json
  resilience            fault tolerance: checkpointed stream overhead,
                        kill/resume wall time + parity, overflow-retry
                        zero-dropped-pairs; writes BENCH_resilience.json
  obs                   observability: traced vs untraced steady resolve,
                        disabled-path cost, zero extra retraces, streamed
                        trace coverage per variant; writes BENCH_obs.json
                        + the Chrome trace BENCH_obs_trace.json
  recall                ground-truth match quality (repro.quality): the
                        PC/RR/F Pareto across fixed-w / multi-pass /
                        adaptive / meta-blocked blocking configs on the
                        labeled skewed corpus, plus the clean-corpus
                        full-window PC=1.0 gate and streamed/traced
                        parity; writes BENCH_recall.json
  kernels               Pallas band kernels vs jnp oracle (CPU timings)
  dedup_e2e             end-to-end corpus dedup throughput + SN-vs-n^2 factor
  roofline              summary of dry-run roofline terms (needs artifacts)

Every BENCH_*.json goes through ``write_bench``, which stamps the shared
``schema_version`` (``repro.obs.SCHEMA_VERSION``) and a ``machine_proxy_s``
host-speed micro-bench so cross-machine comparisons (perf_smoke) can
validate and normalize uniformly.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np


def _row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _machine_proxy(reps: int = 3) -> float:
    """Best-of-``reps`` seconds for a fixed synthetic numpy workload (the
    same dedup/concat shape the pair-collection path performs) — a
    machine-speed proxy stamped into every BENCH blob so perf_smoke can
    normalize absolute numbers across machine classes."""
    rng = np.random.default_rng(0)
    a = rng.integers(0, 2 ** 31, 200_000).astype(np.uint64)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        np.unique(np.concatenate([a, a[::2]]))
        best = min(best, time.perf_counter() - t0)
    return best


def write_bench(path: str, res: dict) -> None:
    """THE one BENCH_*.json writer: stamps the shared ``schema_version``
    (from ``repro.obs``) and the ``machine_proxy_s`` host-speed proxy,
    then writes the blob.  perf_smoke refuses blobs whose schema_version
    does not match its own — a drifted writer/reader pair fails loudly
    instead of silently comparing mismatched fields."""
    from repro.obs import SCHEMA_VERSION
    res = dict(res)
    res["schema_version"] = SCHEMA_VERSION
    res["machine_proxy_s"] = _machine_proxy()
    with open(path, "w") as f:
        json.dump(res, f, indent=2)


def fig8_scalability(quick: bool):
    from benchmarks._subproc import run_with_devices
    n = 20_000 if quick else 80_000
    windows = [10] if quick else [10, 100]
    base = {}
    for w in windows:
        for r in ([1, 4] if quick else [1, 2, 4, 8]):
            res = run_with_devices(
                r, "benchmarks.bench_sn", "scalability_body",
                {"n": n, "w": w, "reps": 2 if quick else 3})
            key = f"fig8_w{w}_r{r}"
            if r == 1:
                base[w] = res["seconds"]
            speedup = base[w] / res["seconds"]
            _row(key, res["seconds"] * 1e6,
                 f"wall_speedup={speedup:.2f};"
                 f"critical_path_speedup={res['work_speedup']:.2f};"
                 f"pairs={res['pairs']}")


def tbl1_fig9_skew(quick: bool):
    from benchmarks._subproc import run_with_devices
    n = 20_000 if quick else 60_000
    strategies = ["manual", "even", "even_40", "even_85"] if quick else \
        ["manual", "even", "even_40", "even_55", "even_70", "even_85"]
    for s in strategies:
        res = run_with_devices(
            8, "benchmarks.bench_sn", "skew_body",
            {"n": n, "w": 20, "strategy": s, "reps": 2 if quick else 3})
        _row(f"fig9_{s}", res["seconds"] * 1e6,
             f"gini={res['gini']};max_load={res['max_load']};"
             f"pairs={res['pairs']}")


def sec52_jobsn_vs_repsn(quick: bool):
    from benchmarks._subproc import run_with_devices
    n = 20_000 if quick else 60_000
    res = run_with_devices(
        8, "benchmarks.bench_sn", "jobsn_vs_repsn_body",
        {"n": n, "w": 20 if quick else 50, "reps": 2 if quick else 3},
        timeout=2400)
    for variant, v in res.items():
        _row(f"sec52_{variant}", v["seconds"] * 1e6,
             f"pairs={v['pairs']};coll_bytes={v['collective_bytes']:.2e};"
             f"permutes={v['permute_count']}")


def band_engine(quick: bool):
    """Scan vs pallas band engine + host pair collection; persists the full
    result dict to BENCH_band_engine.json so later PRs have a perf
    trajectory baseline (the perf-smoke CI gate compares steady-state
    ``pairs_per_s`` against the committed copy — benchmarks/perf_smoke.py)."""
    from benchmarks.bench_sn import band_engine_body
    res = band_engine_body(
        n=6_000 if quick else 20_000, w=8 if quick else 10,
        r=4, reps=5, collect_pairs=100_000)
    for engine, v in res["engines"].items():
        _row(f"band_engine_{engine}", v["steady_seconds"] * 1e6,
             f"cold_us={v['cold_seconds'] * 1e6:.0f};"
             f"matcher_evals={v['matcher_evals']};"
             f"band_slots={v['band_slots']};"
             f"cand_cap={v['cand_cap']};"
             f"pair_cap={v['pair_cap']};"
             f"pairs_per_s={v['pairs_per_s']:.2e}")
    c = res["collection"]
    _row("band_engine_collection", c["packed_seconds"] * 1e6,
         f"pairs={c['pairs']};set_us={c['set_seconds'] * 1e6:.0f};"
         f"packed_speedup={c['speedup']:.1f}x")
    write_bench("BENCH_band_engine.json", res)


def balance(quick: bool):
    """Skew-aware load balancing (ISSUE 3): uniform vs blocksplit vs
    pairrange on the Zipfian corpus; persists BENCH_balance.json (the
    acceptance record: >= 3x imbalance reduction at n >= 6000, 8 shards,
    exponent >= 1.0, with exact pair-set parity)."""
    from benchmarks.bench_sn import balance_body
    res = balance_body(n=6_000 if quick else 20_000, w=10, r=8,
                       exponent=1.0, reps=5)
    for planner, v in res["planners"].items():
        _row(f"balance_{planner}", v["steady_seconds"] * 1e6,
             f"cold_us={v['cold_seconds'] * 1e6:.0f};"
             f"imbalance={v['imbalance_planned']:.2f};"
             f"cap_link={v['cap_link']};"
             f"band_slots={v['band_slots_per_shard']};"
             f"split={v['split_routing']};"
             f"oracle_equal={v['oracle_equal']}")
    _row("balance_reduction", 0.0,
         f"blocksplit={res['imbalance_reduction']['blocksplit']:.1f}x;"
         f"pairrange={res['imbalance_reduction']['pairrange']:.1f}x;"
         f"parity={res['parity']['all_equal_oracle']}")
    write_bench("BENCH_balance.json", res)


def stream(quick: bool):
    """Out-of-core streaming (ISSUE 5): chunked resolve_stream vs
    monolithic resolve on a corpus 4x the chunk size; persists
    BENCH_stream.json (the acceptance record: bit-identical pair sets for
    all variants x engines with per-chunk device residency a fraction of
    the monolithic staging bytes)."""
    from benchmarks.bench_sn import stream_body
    res = stream_body(n=4_800 if quick else 24_000,
                      chunk=1_200 if quick else 6_000,
                      w=8 if quick else 10, r=4, reps=3)
    for engine, v in res["engines"].items():
        _row(f"stream_{engine}", v["stream_steady_seconds"] * 1e6,
             f"mono_us={v['mono_steady_seconds'] * 1e6:.0f};"
             f"stream_pairs_per_s={v['stream_pairs_per_s']:.2e};"
             f"mono_pairs_per_s={v['mono_pairs_per_s']:.2e};"
             f"residency={v['residency_ratio']:.3f};"
             f"steady_chunks={v['steady_chunks']}/{v['chunks']}")
    _row("stream_parity", 0.0,
         f"all_equal={res['parity_all']};"
         f"combos={len(res['parity'])}")
    write_bench("BENCH_stream.json", res)


def serve(quick: bool):
    """Online incremental serving (ISSUE 6 acceptance): sustained insert
    throughput + steady p50/p95 latency over an n-entity base corpus, the
    zero-retrace steady-state claim, and final parity vs a from-scratch
    resolve.  Writes BENCH_serve.json (gated by perf_smoke --serve)."""
    from benchmarks.bench_sn import serve_body
    res = serve_body(n=5_000 if quick else 50_000,
                     batch=100 if quick else 200,
                     ops=12 if quick else 24)
    _row("serve_insert", res["seconds"] * 1e6,
         f"inserts_per_s={res['sustained_inserts_per_s']:.2e};"
         f"p50_ms={res['p50_ms']:.1f};p95_ms={res['p95_ms']:.1f};"
         f"steady={res['steady_batches']}/{res['batches']};"
         f"zero_retrace={res['steady_after_warm']};"
         f"shapes={len(res['shapes'])}")
    _row("serve_parity", 0.0,
         f"blocked={res['parity']['blocked_equal']};"
         f"matched={res['parity']['matched_equal']};"
         f"pairs={res['pairs']};live={res['live_entities']}")
    write_bench("BENCH_serve.json", res)


def overload(quick: bool):
    """Overload-hardened serving (ISSUE 9 acceptance): an open-loop load
    generator at 1x/2x/5x measured warm capacity under chaos (latency
    spikes + injected matcher errors), queue_policy=shed_oldest +
    per-request deadlines.  Gates (perf_smoke --overload): zero hung and
    zero silently-dropped futures at every rate, the admission policy
    engaged at the top rate, and post-pressure ``repair()`` bit-parity.
    Writes BENCH_overload.json."""
    from benchmarks.bench_sn import overload_body
    res = overload_body(n=1_500 if quick else 6_000,
                        batch=60 if quick else 120,
                        ops=10 if quick else 24,
                        warm=4 if quick else 5)
    for ph in res["rates"]:
        _row(f"overload_{ph['rate']:g}x", ph["p95_ms"] * 1e3,
             f"goodput_rps={ph['goodput_rps']:.2f};ok={ph['ok']};"
             f"shed={ph['shed']};expired={ph['expired']};"
             f"chaos={ph['chaos_errors']};hung={ph['hung']};"
             f"degraded={ph['degraded_batches']};"
             f"p99_ms={ph['p99_ms']:.1f}")
    _row("overload_repair", 0.0,
         f"blocked={res['parity']['blocked_equal']};"
         f"matched={res['parity']['matched_equal']};"
         f"repairs={res['repairs']};dirty={res['dirty_after_repair']};"
         f"health={res['health_final']}")
    write_bench("BENCH_overload.json", res)


def resilience(quick: bool):
    """Fault tolerance (ISSUE 7 acceptance): checkpoint write overhead vs
    plain streaming, kill-at-chunk-k resume wall time + pair parity, and
    the overflow-retry ladder recovering every pair a tiny pair_cap would
    have dropped.  Writes BENCH_resilience.json (gated by perf_smoke
    --resilience: overhead <= 15%, zero dropped pairs, parity)."""
    from benchmarks.bench_sn import resilience_body
    res = resilience_body(n=4_800 if quick else 24_000,
                          chunk=1_200 if quick else 6_000,
                          w=8 if quick else 10, r=4, reps=3)
    _row("resilience_ckpt", res["ckpt_steady_seconds"] * 1e6,
         f"plain_us={res['plain_steady_seconds'] * 1e6:.0f};"
         f"overhead={res['checkpoint_overhead']:.3f};"
         f"parity={res['checkpointed_parity']}")
    rs = res["resume"]
    _row("resilience_resume", rs["resume_seconds"] * 1e6,
         f"killed_us={rs['killed_seconds'] * 1e6:.0f};"
         f"kill_at={rs['kill_at']}/{rs['chunks']};"
         f"blocked={rs['blocked_equal']};matched={rs['matched_equal']}")
    rt = res["retry"]
    _row("resilience_retry", 0.0,
         f"retries={rt['retries']};escalations={rt['escalations']};"
         f"pair_cap={rt['start_pair_cap']}->{rt['final_pair_cap']};"
         f"dropped={rt['dropped_pairs']};overflow={rt['pair_overflow']};"
         f"blocked={rt['blocked_equal']}")
    write_bench("BENCH_resilience.json", res)


def obs(quick: bool):
    """Observability layer (ISSUE 8 acceptance): traced vs untraced steady
    resolve, the deterministic disabled-path cost, zero extra retraces
    under tracing, and per-variant streamed trace coverage.  Writes
    BENCH_obs.json + the Chrome trace BENCH_obs_trace.json (gated by
    perf_smoke --obs: traced overhead <= 5%, disabled <= 1%, zero extra
    retraces, coverage >= 0.9)."""
    from benchmarks.bench_sn import obs_body
    res = obs_body(n=4_000 if quick else 12_000,
                   chunk=1_000 if quick else 3_000,
                   w=8, r=4, reps=5)
    _row("obs_traced", res["steady_traced_seconds"] * 1e6,
         f"untraced_us={res['steady_untraced_seconds'] * 1e6:.0f};"
         f"overhead={res['traced_overhead']:.4f};"
         f"spans={res['spans_per_resolve']};"
         f"zero_retrace={res['zero_extra_retraces']}")
    _row("obs_disabled", res["noop_span_seconds"] * 1e6,
         f"overhead={res['disabled_overhead']:.5f}")
    for variant, v in res["stream"].items():
        _row(f"obs_stream_{variant}", v["wall_s"] * 1e6,
             f"coverage={v['coverage']:.3f};spans={v['spans']};"
             f"chunks={v['chunks']}")
    write_bench("BENCH_obs.json", res)


def recall(quick: bool):
    """Ground-truth match quality (ISSUE 10 acceptance): PC / PQ / RR / F
    for >= 4 blocking configurations (fixed-w frontier, multi-pass,
    adaptive windows, evidence-pruned meta-blocking) on the labeled skewed
    corpus, with streamed + traced bit-parity per config; persists
    BENCH_recall.json (gated by perf_smoke --recall: Pareto points
    present, adaptive dominates the mid fixed window, PC=1.0 clean-corpus
    full-window gate, pruning engaged without dropping gold pairs)."""
    from benchmarks.bench_sn import recall_body
    res = recall_body(n=1_200 if quick else 4_000,
                      reps=2 if quick else 3)
    for name, v in res["configs"].items():
        _row(f"recall_{name}", v["steady_seconds"] * 1e6,
             f"pc={v['pc']:.4f};rr={v['rr']:.4f};f={v['f']:.4f};"
             f"blocked={v['blocked']};pruned={v['pruned']};"
             f"streamed={v['streamed_equal']};traced={v['traced_equal']}")
    g = res["gates"]
    _row("recall_gates", 0.0,
         f"full_window_pc={g['full_window_pc']:.4f};"
         f"adaptive_dominates={g['adaptive_dominates_fixed']};"
         f"pruning_engaged={g['pruning_engaged']};"
         f"gold_dropped={g['pruned_gold_dropped']};"
         f"multipass_recovers={g['multipass_recovers_typos']};"
         f"parity={g['parity_all']}")
    write_bench("BENCH_recall.json", res)


def kernels(quick: bool):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)
    m, f, w = (2048, 128, 64) if quick else (8192, 128, 128)
    feat = jnp.asarray(rng.normal(size=(m, f)).astype(np.float32))

    def timeit(fn, *args, reps=5, **kw):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args, **kw))
        return (time.perf_counter() - t0) / reps * 1e6

    us_ref = timeit(ref.banded_sim_ref, feat, window=w)
    flops = 2.0 * m * w * f
    _row("kernel_banded_sim_ref_jnp", us_ref,
         f"gflops={flops/us_ref/1e3:.2f}")
    us_k = timeit(ops.banded_dot_band, feat, window=w, interpret=True)
    _row("kernel_banded_sim_pallas_interp", us_k,
         "interpret-mode(correctness-path; native on TPU)")

    sig = jnp.asarray(rng.integers(0, 2**32, size=(m, 8),
                                   dtype=np.uint64).astype(np.uint32))
    us_j = timeit(ref.jaccard_band_ref, sig, window=w)
    _row("kernel_jaccard_ref_jnp", us_j, f"pairs_per_s={m*w/us_j*1e6:.2e}")

    bh, s, d, win = (4, 1024, 64, 256) if quick else (8, 4096, 128, 1024)
    q = jnp.asarray(rng.normal(size=(bh, s, d)).astype(np.float32))
    us_a = timeit(ref.local_attention_ref, q, q, q, window=win, reps=3)
    _row("kernel_local_attn_ref_jnp", us_a,
         f"gflops={4*bh*s*win*d/us_a/1e3:.2f}")


def dedup_e2e(quick: bool):
    from repro.data.corpus import dedup_corpus, synth_corpus
    n = 4096 if quick else 16384
    docs = synth_corpus(0, n_docs=n, doc_len=64, vocab=1000, dup_frac=0.25)
    t0 = time.perf_counter()
    res = dedup_corpus(docs, r=8, window=10)
    dt = time.perf_counter() - t0
    naive_cmp = n * (n - 1) / 2
    sn_cmp = n * 9
    _row("dedup_e2e", dt * 1e6,
         f"docs_per_s={n/dt:.0f};dropped={res.n_dropped};"
         f"cmp_reduction={naive_cmp/sn_cmp:.0f}x;gini={res.gini:.2f}")


def roofline(quick: bool):
    from benchmarks.roofline import load_all
    rows = load_all()
    if not rows:
        _row("roofline", 0.0, "no-dryrun-artifacts")
        return
    worst = min(rows, key=lambda r: r["roofline_fraction"])
    for r in rows:
        _row(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
             max(r["t_compute_s"], r["t_memory_s"],
                 r["t_collective_s"]) * 1e6,
             f"dominant={r['dominant']};frac={r['roofline_fraction']:.2f};"
             f"useful={r['useful_ratio']:.2f}")
    _row("roofline_worst_cell", 0.0,
         f"{worst['arch']}/{worst['shape']}:{worst['roofline_fraction']:.2f}")


TABLES = {
    "fig8_scalability": fig8_scalability,
    "tbl1_fig9_skew": tbl1_fig9_skew,
    "sec52_jobsn_vs_repsn": sec52_jobsn_vs_repsn,
    "band_engine": band_engine,
    "balance": balance,
    "stream": stream,
    "serve": serve,
    "overload": overload,
    "resilience": resilience,
    "obs": obs,
    "recall": recall,
    "kernels": kernels,
    "dedup_e2e": dedup_e2e,
    "roofline": roofline,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for name, fn in TABLES.items():
        if args.only and name != args.only:
            continue
        try:
            fn(args.quick)
        except Exception as e:  # noqa: BLE001 — keep the suite running
            _row(name, -1.0, f"ERROR:{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
