"""Shim: the analyzer lives in repro.perf.hlo_analysis (importable from src)."""
from repro.perf.hlo_analysis import *  # noqa: F401,F403
from repro.perf.hlo_analysis import analyze, analyze_compiled  # noqa: F401
