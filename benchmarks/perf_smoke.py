"""Perf-smoke gate: fail CI when steady-state throughput regresses.

Compares a freshly generated BENCH_band_engine.json against the committed
baseline and exits non-zero when any engine's steady-state ``pairs_per_s``
drops by more than ``--tolerance`` (default 30% — CPU CI runners are noisy;
the gate is meant to catch structural regressions like losing the
executable cache or re-introducing a per-call trace, not 5% jitter).
Improvements and new fields never fail the gate.

Because the committed baseline may have been generated on a different
machine class than the CI runner, the absolute throughput is normalized by
a machine-speed proxy (the collection micro-bench, which times identical
synthetic host work in both blobs) before the tolerance is applied, and is
backed by machine-INDEPENDENT structural gates that hold on any box:
baseline/current workload parameters must match, steady state must beat
cold by >= 2x per engine (the executable cache's signature), steady pallas
must beat steady scan (the cascade's signature), and engine parity must
hold.

Usage (what .github/workflows/ci.yml runs):

    cp BENCH_band_engine.json /tmp/baseline.json     # committed baseline
    PYTHONPATH=src python -m benchmarks.run --quick --only band_engine
    python -m benchmarks.perf_smoke /tmp/baseline.json BENCH_band_engine.json
"""
from __future__ import annotations

import argparse
import json
import sys

# every BENCH blob is stamped by benchmarks.run.write_bench with the shared
# repro.obs schema version; import it when the package is on the path, with
# a literal fallback so the gate stays runnable standalone (CI invokes this
# module without PYTHONPATH=src)
try:
    from repro.obs.schema import SCHEMA_VERSION as EXPECTED_SCHEMA
except ImportError:                                   # pragma: no cover
    EXPECTED_SCHEMA = 1


def check_schema(blob: dict, label: str) -> list:
    """Refuse a BENCH blob whose stamped ``schema_version`` does not match
    this checker's (pre-stamp blobs report None): comparing fields across
    schema drift produces silently wrong verdicts, so the mismatch itself
    is a loud failure."""
    got = blob.get("schema_version")
    if got != EXPECTED_SCHEMA:
        return [f"{label}: schema_version={got!r} != expected "
                f"{EXPECTED_SCHEMA} — regenerate with benchmarks.run "
                f"(write_bench stamps the shared version)"]
    return []


def _steady_pairs_per_s(engine_blob: dict) -> float:
    """pairs_per_s from a bench blob; pre-split baselines (no
    steady_seconds) already reported a warmed-loop pairs_per_s."""
    return float(engine_blob["pairs_per_s"])


def _machine_speed_ratio(baseline: dict, current: dict) -> float:
    """Crude machine-class normalizer for the absolute-throughput gate:
    the collection micro-bench times identical synthetic numpy work in
    both blobs, so its ratio approximates how much faster the current
    machine is than wherever the baseline was generated (committed
    baselines usually come from a different box than the CI runner).
    Clamped to [0.25, 4] so a wild outlier can't scale a real regression
    away; 1.0 when either blob lacks the section."""
    try:
        b = float(baseline["collection"]["packed_seconds"])
        c = float(current["collection"]["packed_seconds"])
    except (KeyError, TypeError, ValueError):
        return 1.0
    if b <= 0 or c <= 0:
        return 1.0
    return min(max(b / c, 0.25), 4.0)


def check(baseline: dict, current: dict, tolerance: float) -> list:
    """Returns a list of human-readable failures (empty = gate passes)."""
    failures = []
    # apples-to-apples: the absolute-throughput comparison is meaningless
    # across different workloads (e.g. a baseline regenerated without
    # --quick while CI runs --quick)
    for param in ("n", "w", "r", "variant"):
        if baseline.get(param) != current.get(param):
            failures.append(
                f"workload mismatch: baseline {param}={baseline.get(param)} "
                f"vs current {param}={current.get(param)} — regenerate the "
                f"committed baseline with the same bench parameters")
    if failures:
        return failures
    # machine-independent structural gates (shared CI runners differ in
    # absolute speed from wherever the baseline was generated; these catch
    # the structural regressions regardless of machine class):
    # losing the executable cache drives steady back toward cold,
    cur_engines = current.get("engines", {})
    for engine, blob in cur_engines.items():
        speedup = blob.get("steady_speedup_vs_cold")
        if speedup is not None and speedup < 2.0:
            failures.append(
                f"steady-state no longer beats cold for {engine!r} "
                f"(steady_speedup_vs_cold={speedup:.2f} < 2.0) — is the "
                f"executable cache still on the hot path?")
    # and losing the cascade win inverts the engine ordering
    if {"scan", "pallas"} <= cur_engines.keys():
        scan_ps = _steady_pairs_per_s(cur_engines["scan"])
        pallas_ps = _steady_pairs_per_s(cur_engines["pallas"])
        if pallas_ps <= scan_ps:
            failures.append(
                f"steady-state pallas ({pallas_ps:.3e} pairs/s) no longer "
                f"beats scan ({scan_ps:.3e}) — the cascade win regressed")
    speed = _machine_speed_ratio(baseline, current)
    for engine, base in baseline.get("engines", {}).items():
        cur = current.get("engines", {}).get(engine)
        if cur is None:
            failures.append(f"engine {engine!r} present in baseline but "
                            f"missing from current run")
            continue
        b = _steady_pairs_per_s(base)
        c = _steady_pairs_per_s(cur) / speed      # machine-normalized
        floor = b * (1.0 - tolerance)
        verdict = "OK" if c >= floor else "REGRESSED"
        print(f"perf_smoke {engine}: baseline={b:.3e} "
              f"current={c:.3e} (machine-normalized /{speed:.2f}) "
              f"floor={floor:.3e} pairs/s -> {verdict}")
        if c < floor:
            failures.append(
                f"steady-state pairs_per_s for {engine!r} regressed "
                f"{(1 - c / b) * 100:.1f}% (> {tolerance * 100:.0f}% "
                f"tolerance, machine-normalized): {b:.3e} -> {c:.3e}")
    if not baseline.get("engines"):
        failures.append("baseline has no 'engines' section — not a "
                        "BENCH_band_engine.json?")
    # structural honesty: the current run must keep engine parity
    parity = current.get("parity", {})
    for k, v in parity.items():
        if v is not True:
            failures.append(f"current run broke parity: {k}={v}")
    return failures


def check_serve(blob: dict) -> list:
    """Machine-independent structural gates over a BENCH_serve.json: every
    measured micro-batch must be served from the executable cache (zero
    retraces after warm-up — losing shape bucketing shows up here on any
    machine class), the shape-bucket set must stay small, and the served
    sets must keep bit-parity with a from-scratch resolve."""
    failures = []
    if not blob.get("steady_after_warm", False):
        failures.append(
            f"serve steady state retraced after warm-up "
            f"(traces {blob.get('traces_after_warm')} -> "
            f"{blob.get('traces')}) — shape bucketing no longer keeps the "
            f"delta calls on the executable cache")
    shapes = blob.get("shapes", [])
    if len(shapes) > 16:
        failures.append(f"serve used {len(shapes)} delta-call shapes — the "
                        f"bucket grid is fragmenting the executable cache")
    for k, v in blob.get("parity", {}).items():
        if v is not True:
            failures.append(f"serve run broke parity: {k}={v}")
    if float(blob.get("p95_ms", 0.0)) <= 0.0:
        failures.append("serve run reported no latency samples")
    print(f"perf_smoke serve: steady="
          f"{blob.get('steady_batches')}/{blob.get('batches')} "
          f"zero_retrace={blob.get('steady_after_warm')} "
          f"p95={blob.get('p95_ms', 0.0):.1f}ms "
          f"inserts_per_s={blob.get('sustained_inserts_per_s', 0.0):.2e} "
          f"-> {'OK' if not failures else 'FAIL'}")
    return failures


def check_overload(blob: dict) -> list:
    """Overload gates over a BENCH_overload.json (ISSUE 9 acceptance).

    All machine-independent exact counts: at EVERY load rate the open-loop
    run must account for each submitted future exactly once with zero hung
    and zero unexpected failures (no future may dangle, no error may leave
    the typed taxonomy), goodput must stay positive (the service keeps
    serving THROUGH overload instead of collapsing), the admission policy
    must actually engage at the top rate (shed + expired + degraded > 0 —
    otherwise the bench stopped generating overload), and after pressure
    drops the ``repair()`` pass must leave zero dirty ranges and served
    sets bit-identical to a from-scratch resolve of exactly the applied
    mutations (invariant 13)."""
    failures = []
    rates = blob.get("rates", [])
    if not rates:
        failures.append("overload blob has no 'rates' section — not a "
                        "BENCH_overload.json?")
    for ph in rates:
        label = f"{ph.get('rate')}x"
        accounted = sum(int(ph.get(k, 0)) for k in
                        ("ok", "shed", "expired", "chaos_errors",
                         "hung", "unexpected"))
        if accounted != int(ph.get("submitted", -1)):
            failures.append(
                f"overload {label}: {ph.get('submitted')} futures "
                f"submitted but only {accounted} accounted — requests "
                f"are being silently dropped")
        if int(ph.get("hung", 1)) != 0:
            failures.append(
                f"overload {label}: {ph.get('hung')} future(s) never "
                f"settled — every request must complete with a result or "
                f"a typed error")
        if int(ph.get("unexpected", 1)) != 0:
            failures.append(
                f"overload {label}: {ph.get('unexpected')} future(s) "
                f"failed outside the typed admission taxonomy")
        if int(ph.get("ok", 0)) < 1 \
                or float(ph.get("goodput_rps", 0.0)) <= 0.0:
            failures.append(
                f"overload {label}: goodput collapsed "
                f"(ok={ph.get('ok')}, goodput_rps="
                f"{ph.get('goodput_rps')}) — the service must keep "
                f"serving through overload")
    if rates:
        top = max(rates, key=lambda ph: float(ph.get("rate", 0.0)))
        engaged = sum(int(top.get(k, 0)) for k in
                      ("shed", "expired", "degraded_batches"))
        if engaged < 1:
            failures.append(
                f"overload {top.get('rate')}x: admission policy never "
                f"engaged (shed={top.get('shed')} "
                f"expired={top.get('expired')} "
                f"degraded={top.get('degraded_batches')}) — the bench no "
                f"longer generates real overload")
    if int(blob.get("dirty_after_repair", 1)) != 0:
        failures.append(
            f"overload left {blob.get('dirty_after_repair')} dirty "
            f"range(s) after repair() — the repair pass must drain all "
            f"brownout debt")
    for k, v in blob.get("parity", {}).items():
        if v is not True:
            failures.append(
                f"overload repair broke parity: {k}={v} — post-pressure "
                f"served sets must be bit-identical to a from-scratch "
                f"resolve (invariant 13)")
    print(f"perf_smoke overload: "
          f"hung={[int(ph.get('hung', -1)) for ph in rates]} "
          f"shed={[int(ph.get('shed', -1)) for ph in rates]} "
          f"degraded={[int(ph.get('degraded_batches', -1)) for ph in rates]} "
          f"parity={all(blob.get('parity', {}).values()) or False} "
          f"-> {'OK' if not failures else 'FAIL'}")
    return failures


def check_resilience(blob: dict) -> list:
    """Machine-independent structural gates over a BENCH_resilience.json:
    checkpointing must stay cheap (steady checkpointed stream <= 15% over
    the plain stream — both halves of the ratio come from the same run, so
    machine class divides out), the killed-and-resumed run must reproduce
    the plain stream's pair sets bit-identically, and the overflow-retry
    ladder must drop ZERO pairs while actually exercising a retry."""
    failures = []
    overhead = float(blob.get("checkpoint_overhead", 0.0))
    if overhead <= 0.0:
        failures.append("resilience run reported no checkpoint_overhead")
    elif overhead > 1.15:
        failures.append(
            f"checkpointed streaming costs {(overhead - 1) * 100:.1f}% "
            f"over the plain stream (> 15%): the spool/manifest write "
            f"path is no longer amortized by the chunk compute")
    if blob.get("checkpointed_parity") is not True:
        failures.append("checkpointed stream broke pair parity with the "
                        "plain stream")
    rs = blob.get("resume", {})
    if not (rs.get("blocked_equal") and rs.get("matched_equal")):
        failures.append(
            f"kill/resume broke parity (blocked={rs.get('blocked_equal')} "
            f"matched={rs.get('matched_equal')}): the resumed union must "
            f"be bit-identical to an uninterrupted run (invariant 11)")
    rt = blob.get("retry", {})
    if int(rt.get("dropped_pairs", 1)) != 0 \
            or rt.get("blocked_equal") is not True:
        failures.append(
            f"overflow retry dropped {rt.get('dropped_pairs')} pairs "
            f"(blocked_equal={rt.get('blocked_equal')}): the cap ladder "
            f"must recover every pair an unbounded run emits")
    if int(rt.get("pair_overflow", 1)) != 0:
        failures.append(
            f"overflow retry finished with pair_overflow="
            f"{rt.get('pair_overflow')}: the final execution must fit")
    if int(rt.get("retries", 0)) < 1:
        failures.append("overflow retry never retried — the micro-cap "
                        "workload no longer exercises the ladder")
    print(f"perf_smoke resilience: overhead={overhead:.3f} "
          f"resume_parity={rs.get('blocked_equal')} "
          f"retries={rt.get('retries')} "
          f"dropped={rt.get('dropped_pairs')} "
          f"-> {'OK' if not failures else 'FAIL'}")
    return failures


def check_obs(blob: dict) -> list:
    """Observability gates over a BENCH_obs.json (ISSUE 8 acceptance).

    All four are machine-independent ratios or exact counts: traced
    steady resolve must cost <= 5% over untraced (both halves timed in
    the same run on the same warm cache), the disabled path <= 1% (no-op
    span cost x spans-per-run over the untraced steady time — measured
    deterministically, not as wall jitter), a traced run must add ZERO
    executable-cache traces (``trace`` is excluded from the fingerprint —
    invariant 12), and every streamed variant's child spans must cover
    >= 90% of the root ``stream`` span (the trace accounts for the run)."""
    failures = []
    t = float(blob.get("traced_overhead", 1.0))
    if t > 0.05:
        failures.append(
            f"tracing costs {t * 100:.1f}% over untraced steady state "
            f"(> 5%): span recording is no longer amortized by the "
            f"resolve compute")
    d = float(blob.get("disabled_overhead", 1.0))
    if d > 0.01:
        failures.append(
            f"the DISABLED tracing path costs {d * 100:.2f}% of steady "
            f"resolve time (> 1%): the no-op span fast path regressed "
            f"(it must stay one thread-local lookup)")
    if not blob.get("zero_extra_retraces", False):
        failures.append(
            f"a traced run performed "
            f"{blob.get('extra_traces_when_traced')} extra executable "
            f"trace(s): trace=True changed an executable fingerprint "
            f"(invariant 12 — tracing must hit the untraced run's cache)")
    for variant, v in (blob.get("stream") or {}).items():
        cov = float(v.get("coverage", 0.0))
        if cov < 0.9:
            failures.append(
                f"streamed {variant!r} trace coverage {cov:.3f} < 0.9: "
                f"per-chunk spans no longer account for the stream wall")
    print(f"perf_smoke obs: traced_overhead={t:.4f} "
          f"disabled_overhead={d:.5f} "
          f"zero_retrace={blob.get('zero_extra_retraces')} "
          f"coverage={[round(float(v.get('coverage', 0.0)), 3) for v in (blob.get('stream') or {}).values()]} "
          f"-> {'OK' if not failures else 'FAIL'}")
    return failures


def check_recall(blob: dict) -> list:
    """Match-quality gates over a BENCH_recall.json (ISSUE 10 acceptance).

    All machine-independent exact counts and ratios over the labeled
    corpus: the Pareto table must carry every required configuration kind
    (fixed-w, multi-pass, adaptive, meta-blocked) with sane PC/RR values,
    the clean-corpus full-window run must be exhaustive (PC=1.0 with
    pruning off and w >= the largest key block), adaptive windows must
    strictly dominate the mid fixed window (higher pairs-completeness at
    no more blocked pairs — recomputed here from the rows, not trusted
    from the writer's gate bit), evidence pruning must have engaged
    without dropping a single gold pair (invariant 14), and every config's
    streamed + traced runs must keep bit-parity with monolithic resolve."""
    failures = []
    configs = blob.get("configs", {})
    required = ("fixed_w", "fixed_wmid", "multipass", "adaptive",
                "meta_blocked")
    for name in required:
        if name not in configs:
            failures.append(f"recall blob missing config {name!r} — the "
                            f"Pareto table lost a required point")
    if len(configs) < 4:
        failures.append(f"recall blob has {len(configs)} configs (< 4) — "
                        f"not a BENCH_recall.json?")
    for name, v in configs.items():
        for metric in ("pc", "rr"):
            x = float(v.get(metric, -1.0))
            if not 0.0 <= x <= 1.0:
                failures.append(f"recall {name}: {metric}={x} outside "
                                f"[0, 1] — metric math drifted")
        if not (v.get("streamed_equal") and v.get("traced_equal")):
            failures.append(
                f"recall {name}: streamed_equal={v.get('streamed_equal')} "
                f"traced_equal={v.get('traced_equal')} — quality-path "
                f"configs must keep streamed/traced pair sets "
                f"bit-identical to monolithic resolve")
    gates = blob.get("gates", {})
    if float(gates.get("full_window_pc", 0.0)) != 1.0:
        failures.append(
            f"clean-corpus full-window PC={gates.get('full_window_pc')} "
            f"!= 1.0 — boundary-complete SN at w >= max block with "
            f"pruning off must be exhaustive")
    if {"adaptive", "fixed_wmid"} <= configs.keys():
        a, f0 = configs["adaptive"], configs["fixed_wmid"]
        if not (float(a["pc"]) > float(f0["pc"])
                and int(a["blocked"]) <= int(f0["blocked"])):
            failures.append(
                f"adaptive windows no longer dominate the fixed window: "
                f"pc {a['pc']:.4f} vs {f0['pc']:.4f}, blocked "
                f"{a['blocked']} vs {f0['blocked']} — adaptive must reach "
                f"higher pairs-completeness at equal-or-better reduction "
                f"ratio")
    if {"adaptive", "meta_blocked"} <= configs.keys():
        a, m = configs["adaptive"], configs["meta_blocked"]
        if int(m.get("pruned", 0)) < 1:
            failures.append("meta-blocking pruned 0 candidates — the "
                            "evidence-pruning lever never engaged")
        if int(m["true_positives"]) < int(a["true_positives"]):
            failures.append(
                f"pruning dropped "
                f"{int(a['true_positives']) - int(m['true_positives'])} "
                f"gold pair(s) scoring above the evidence threshold "
                f"(invariant 14): {a['true_positives']} -> "
                f"{m['true_positives']}")
    print(f"perf_smoke recall: "
          f"pc={[round(float(v.get('pc', -1)), 4) for v in configs.values()]} "
          f"full_window_pc={gates.get('full_window_pc')} "
          f"pruned={configs.get('meta_blocked', {}).get('pruned')} "
          f"parity={all(v.get('streamed_equal') and v.get('traced_equal') for v in configs.values())} "
          f"-> {'OK' if not failures else 'FAIL'}")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="committed BENCH_band_engine.json")
    ap.add_argument("current", help="freshly generated BENCH_band_engine.json")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed fractional pairs_per_s drop (default 0.30)")
    ap.add_argument("--serve", default=None,
                    help="optional freshly generated BENCH_serve.json — "
                         "adds the serving structural gates (zero-retrace "
                         "steady state, parity)")
    ap.add_argument("--overload", default=None,
                    help="optional freshly generated BENCH_overload.json "
                         "— adds the overload structural gates (zero hung "
                         "/ silently-dropped futures at every rate, policy "
                         "engaged at the top rate, repair parity)")
    ap.add_argument("--resilience", default=None,
                    help="optional freshly generated BENCH_resilience.json "
                         "— adds the fault-tolerance structural gates "
                         "(checkpoint overhead <= 15%%, resume parity, "
                         "zero dropped pairs under retry)")
    ap.add_argument("--obs", default=None,
                    help="optional freshly generated BENCH_obs.json — adds "
                         "the observability gates (traced overhead <= 5%%, "
                         "disabled <= 1%%, zero extra retraces, streamed "
                         "trace coverage >= 0.9)")
    ap.add_argument("--recall", default=None,
                    help="optional freshly generated BENCH_recall.json — "
                         "adds the match-quality gates (Pareto points "
                         "present, adaptive dominates fixed-w, clean-"
                         "corpus full-window PC=1.0, pruning engaged "
                         "without dropping gold pairs, streamed/traced "
                         "parity)")
    args = ap.parse_args()
    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    failures = check_schema(baseline, "baseline") \
        + check_schema(current, "current")
    failures += check(baseline, current, args.tolerance)
    if args.serve:
        with open(args.serve) as f:
            blob = json.load(f)
        failures += check_schema(blob, "serve") + check_serve(blob)
    if args.overload:
        with open(args.overload) as f:
            blob = json.load(f)
        failures += check_schema(blob, "overload") + check_overload(blob)
    if args.resilience:
        with open(args.resilience) as f:
            blob = json.load(f)
        failures += check_schema(blob, "resilience") \
            + check_resilience(blob)
    if args.obs:
        with open(args.obs) as f:
            blob = json.load(f)
        failures += check_schema(blob, "obs") + check_obs(blob)
    if args.recall:
        with open(args.recall) as f:
            blob = json.load(f)
        failures += check_schema(blob, "recall") + check_recall(blob)
    if failures:
        for msg in failures:
            print(f"perf_smoke FAIL: {msg}", file=sys.stderr)
        sys.exit(1)
    print("perf_smoke: steady-state throughput within tolerance")


if __name__ == "__main__":
    main()
