"""Partition functions p: key -> reducer index (paper §4.1) + skew tooling.

A partitioner is a monotonically non-decreasing map from blocking keys to
shard ids, represented by r-1 int32 upper boundaries: shard i receives keys in
(bounds[i-1], bounds[i]].  Monotonicity gives sorted reduce partitions (SRP).

* ``range_partition``      — paper-faithful static range split (Even8/Even10)
* ``manual_partition``     — explicit boundaries (paper's hand-tuned 'Manual')
* ``sample_partition``     — BEYOND-PAPER: equi-depth boundaries from a key
                             sample (the load-balancing future work of §7)
* ``gini``                 — the paper's skew metric (§5.3)
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def shard_of(bounds: jax.Array, keys: jax.Array) -> jax.Array:
    """bounds: (r-1,) sorted upper bounds -> shard id in [0, r)."""
    return jnp.searchsorted(bounds, keys, side="left").astype(jnp.int32)


def range_partition(key_space: int, r: int) -> jax.Array:
    """Evenly split the KEY SPACE into r intervals (paper's Even8/Even10)."""
    edges = (np.arange(1, r) * key_space) // r
    return jnp.asarray(edges, jnp.int32)


def manual_partition(edges: Sequence[int]) -> jax.Array:
    return jnp.asarray(sorted(edges), jnp.int32)


def sample_partition(sample_keys: jax.Array, r: int) -> jax.Array:
    """Equi-depth boundaries from sampled keys (beyond-paper skew handling,
    the classic sample-sort splitter selection).  Works on-device."""
    s = jnp.sort(sample_keys)
    n = s.shape[0]
    idx = (jnp.arange(1, r) * n) // r
    return s[idx].astype(jnp.int32)


def balanced_partition(keys: np.ndarray, r: int) -> jax.Array:
    """Histogram-based equi-depth boundaries that respect duplicate keys
    (host-side; the auto-derived analogue of the paper's hand-tuned 'Manual'
    partitioning).

    The naive quantile splitter degenerates when one key dominates (all
    boundaries collapse onto the hot key and shard 0 receives everything).
    Two passes: keys with mass >= total/r are isolated into their own shards;
    the remaining light mass is split equi-depth.  A single key's mass can
    never be split across shards (MapReduce-inherent, paper §5.3) — the hot
    shards are the irreducible residual skew.

    Boundaries are INCLUSIVE upper bounds under ``shard_of`` (searchsorted
    side='left')."""
    ks = np.asarray(keys)
    uniq, counts = np.unique(ks, return_counts=True)
    total = int(counts.sum())
    hot = counts >= total / r
    n_hot = int(hot.sum())
    light_total = total - int(counts[hot].sum())
    light_shards = max(r - n_hot, 1)
    light_target = max(light_total / light_shards, 1.0)

    edges: list[int] = []
    acc = 0
    for u, c in zip(uniq, counts):
        if len(edges) >= r - 1:
            break
        u = int(u)
        if c >= total / r:                  # hot key: own shard
            if acc > 0:
                edges.append(u - 1)         # close the light shard before it
                acc = 0
            if len(edges) < r - 1:
                edges.append(u)             # close the hot key's shard
            continue
        acc += int(c)
        if acc >= light_target:
            edges.append(u)
            acc = 0
    # pad with strictly-increasing unused bounds
    hi = int(uniq[-1]) if len(uniq) else 0
    while len(edges) < r - 1:
        hi += 1
        edges.append(hi)
    edges = sorted(set(edges))
    while len(edges) < r - 1:               # dedup may shrink; repad
        edges.append(edges[-1] + 1)
    return jnp.asarray(edges[:r - 1], jnp.int32)


def partition_sizes(bounds: jax.Array, keys: jax.Array,
                    valid=None, r: int = None) -> jax.Array:
    r = r if r is not None else int(bounds.shape[0]) + 1
    sid = shard_of(bounds, keys)
    w = jnp.ones_like(sid, jnp.int32) if valid is None \
        else valid.astype(jnp.int32)
    return jnp.zeros((r,), jnp.int32).at[sid].add(w)


def gini(sizes) -> float:
    """Gini coefficient of partition sizes (paper §5.3):
    g = 2*sum(i*y_i)/(n*sum(y_i)) - (n+1)/n with y sorted ascending."""
    y = np.sort(np.asarray(sizes).astype(np.float64))
    n = len(y)
    tot = y.sum()
    if tot == 0 or n == 0:
        return 0.0
    i = np.arange(1, n + 1)
    return float(2.0 * (i * y).sum() / (n * tot) - (n + 1) / n)


def skewed_partition(key_space: int, r: int, hot_frac: float,
                     keys: np.ndarray) -> jax.Array:
    """Paper's Even8_40..Even8_85: boundaries chosen so that ``hot_frac`` of
    the entities land in the LAST partition, the rest evenly split."""
    ks = np.sort(np.asarray(keys))
    n = len(ks)
    cut = ks[min(int(n * (1.0 - hot_frac)), n - 1)]
    inner = np.linspace(0, cut, r, dtype=np.int64)[1:]      # r-1 edges <= cut
    return jnp.asarray(inner, jnp.int32)
