"""Blocking-key generation (the paper's map-side key function).

The paper uses "the lowercased first two letters of the title"; generally the
concatenated prefixes of a few attributes.  Here keys are generated fully
vectorized from padded byte strings: each of the first ``k`` characters is
folded to a 6-bit code (lowercased a-z -> 1..26, digits -> 27..36, other -> 0)
and packed big-endian into an int32 (k <= 5 keeps keys < 2^30, so the key
space is totally ordered exactly like the string prefix order the paper
sorts by).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def char_code(c: jax.Array) -> jax.Array:
    """uint8 char -> 6-bit code, case-folded."""
    c = c.astype(jnp.int32)
    lower = jnp.where((c >= 65) & (c <= 90), c + 32, c)    # fold A-Z -> a-z
    az = (lower >= 97) & (lower <= 122)
    dg = (lower >= 48) & (lower <= 57)
    return jnp.where(az, lower - 96, jnp.where(dg, lower - 48 + 27, 0))


def prefix_key(text: jax.Array, k: int = 2) -> jax.Array:
    """text: (N, L) uint8 padded strings -> (N,) int32 blocking keys."""
    assert k <= 5, "k>5 overflows int32 key space"
    codes = char_code(text[:, :k])                          # (N, k)
    weights = (64 ** np.arange(k - 1, -1, -1)).astype(np.int32)
    return (codes * weights[None, :]).sum(axis=1).astype(jnp.int32)


def multipass_keys(text: jax.Array, passes: int = 2, k: int = 2):
    """Multi-pass SN (paper §4): different key functions per pass.  Pass p
    uses the prefix starting at offset p (a standard multi-pass choice)."""
    return [prefix_key(text[:, p:], k=k) for p in range(passes)]


def key_range(k: int = 2) -> int:
    return 64 ** k
