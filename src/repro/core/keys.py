"""Blocking-key generation (the paper's map-side key function).

The paper uses "the lowercased first two letters of the title"; generally the
concatenated prefixes of a few attributes.  Here keys are generated fully
vectorized from padded byte strings: each of the first ``k`` characters is
folded to a 6-bit code (lowercased a-z -> 1..26, digits -> 27..36, other -> 0)
and packed big-endian into an int32 (k <= 5 keeps keys < 2^30, so the key
space is totally ordered exactly like the string prefix order the paper
sorts by).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def char_code(c: jax.Array) -> jax.Array:
    """uint8 char -> 6-bit code, case-folded."""
    c = c.astype(jnp.int32)
    lower = jnp.where((c >= 65) & (c <= 90), c + 32, c)    # fold A-Z -> a-z
    az = (lower >= 97) & (lower <= 122)
    dg = (lower >= 48) & (lower <= 57)
    return jnp.where(az, lower - 96, jnp.where(dg, lower - 48 + 27, 0))


def prefix_key(text: jax.Array, k: int = 2) -> jax.Array:
    """text: (N, L) uint8 padded strings -> (N,) int32 blocking keys."""
    assert k <= 5, "k>5 overflows int32 key space"
    codes = char_code(text[:, :k])                          # (N, k)
    weights = (64 ** np.arange(k - 1, -1, -1)).astype(np.int32)
    return (codes * weights[None, :]).sum(axis=1).astype(jnp.int32)


def multipass_keys(text: jax.Array, passes: int = 2, k: int = 2):
    """Multi-pass SN (paper §4): different key functions per pass.  Pass p
    uses the prefix starting at offset p (a standard multi-pass choice)."""
    return [prefix_key(text[:, p:], k=k) for p in range(passes)]


def key_range(k: int = 2) -> int:
    """Size of the key space a ``k``-character ``prefix_key`` can produce."""
    return 64 ** k


KEY_MASK = (1 << 30) - 1    # entities.py schema: keys non-negative, < 2^30


def derive_sort_key(ents: dict, spec) -> jax.Array:
    """Derive the sort key one multi-pass blocking pass uses.

    ``spec`` is an ``api.config.SortKeySpec``; see its docstring for the
    kinds.  Returns an (N,) int32 array in the entity key space (non-
    negative, < 2^30).  Raises ``KeyError`` when the named payload field is
    absent and ``ValueError`` when the field's shape does not match the
    kind (prefix needs (N, L) bytes, word needs a 2-D integer array)."""
    if spec.kind == "identity":
        src = ents["key"] if spec.source == "key" \
            else ents["payload"][spec.source]
        if src.ndim != 1:
            raise ValueError(f"identity sort key needs a 1-D field, got "
                             f"{spec.source!r} with shape {src.shape}")
        return (src.astype(jnp.int32) & KEY_MASK).astype(jnp.int32)
    field = ents["payload"][spec.source]
    if spec.kind == "prefix":
        if field.ndim != 2 or field.shape[1] < spec.offset + spec.width:
            raise ValueError(f"prefix sort key needs an (N, L) byte field "
                             f"with L >= offset+width="
                             f"{spec.offset + spec.width}, got "
                             f"{spec.source!r} with shape {field.shape}")
        return prefix_key(field[:, spec.offset:], k=spec.width)
    # spec.kind == "word" (validated at SortKeySpec construction)
    if field.ndim != 2 or spec.index >= field.shape[1]:
        raise ValueError(f"word sort key needs column {spec.index} of a 2-D "
                         f"field, got {spec.source!r} with shape "
                         f"{field.shape}")
    return (field[:, spec.index].astype(jnp.int32) & KEY_MASK) \
        .astype(jnp.int32)
