"""Sequential Sorted Neighborhood — the paper's baseline (§4, Figure 4).

This is the oracle for all parallel variants: sort by (key, eid), slide a
window of size w, emit all pairs within distance < w.  Pure numpy on host —
used by tests (pair-set equality) and the sequential rung of the scalability
benchmark.
"""
from __future__ import annotations

from typing import Callable, Optional, Set, Tuple

import numpy as np


def sequential_sn_pairs(keys: np.ndarray, eids: np.ndarray,
                        w: int) -> Set[Tuple[int, int]]:
    """All SN pairs as a set of (eid_lo, eid_hi) with the paper's window
    semantics: entities at sorted distance 1..w-1 are compared."""
    order = np.lexsort((eids, keys))
    se = eids[order]
    n = len(se)
    pairs = set()
    for i in range(n):
        for j in range(i + 1, min(i + w, n)):
            a, b = int(se[i]), int(se[j])
            pairs.add((min(a, b), max(a, b)))
    return pairs


def adaptive_sn_pairs(keys: np.ndarray, eids: np.ndarray,
                      weff: np.ndarray) -> Set[Tuple[int, int]]:
    """Adaptive-window SN oracle: each entity carries its OWN effective
    window, and the pair (i-d, i) exists iff d < weff[i] — the LATER sorted
    element owns the comparison (the same ownership rule the band mask and
    the profile cost model use).  ``weff`` is per-entity, aligned with
    ``keys``/``eids`` BEFORE sorting; constant weff == w reduces exactly to
    ``sequential_sn_pairs``."""
    order = np.lexsort((eids, keys))
    se = eids[order]
    sw = np.asarray(weff)[order]
    n = len(se)
    pairs = set()
    for j in range(n):
        for d in range(1, int(sw[j])):
            i = j - d
            if i < 0:
                break
            a, b = int(se[i]), int(se[j])
            pairs.add((min(a, b), max(a, b)))
    return pairs


def expected_pair_count(n: int, w: int) -> int:
    """Exact count of sliding-window pairs for n >= w (the paper states
    (n - w/2)(w-1); exactly: (n-w+1)(w-1) full windows + (w-1)w/2 tail... the
    closed form below is the true count of pairs with distance in [1, w-1]."""
    if n <= 1 or w <= 1:
        return 0
    we = min(w - 1, n - 1)
    # sum_{d=1..we} (n - d)
    return we * n - we * (we + 1) // 2


def srp_missed_boundary_pairs(r: int, w: int) -> int:
    """Paper §4.1: SRP alone misses (r-1) * w * (w-1) / 2 pairs (when every
    partition holds at least w-1 entities).  NOTE the paper's formula counts
    w(w-1)/2 per boundary = the number of cross-boundary pairs at distance
    < w."""
    return (r - 1) * w * (w - 1) // 2


def sequential_sn_matches(keys, eids, w: int,
                          sim_fn: Callable[[int, int], float],
                          threshold: float) -> Set[Tuple[int, int]]:
    """Sequential blocking + matching (the full ER workflow, Figure 2)."""
    order = np.lexsort((eids, keys))
    n = len(order)
    out = set()
    for oi in range(n):
        for oj in range(oi + 1, min(oi + w, n)):
            i, j = int(order[oi]), int(order[oj])
            if sim_fn(i, j) >= threshold:
                a, b = int(eids[i]), int(eids[j])
                out.add((min(a, b), max(a, b)))
    return out
