"""SRP — Sorted Reduce Partitions (paper §4.1) as a TPU collective program.

The MapReduce shuffle with composite key ``p(k).k`` becomes:

  1. map-side: compute dest = p(key) per entity (partition.shard_of)
  2. bucketize into a fixed-capacity (r, cap_link) buffer, ranked within each
     destination by a LOCAL stable sort (XLA collectives are static-shape, so
     the variable-size Hadoop shuffle becomes capacity + overflow accounting,
     like MoE capacity-factor routing — see DESIGN.md §2)
  3. one ``all_to_all`` over the shard axis
  4. reduce-side local sort by (key, eid)  ->  globally range-sorted shards

Every function here is written per-shard against a named axis, so the same
code runs under ``shard_map`` (real devices) and ``jax.vmap(axis_name=...)``
(single-device property tests).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import entities as E
from repro.core import partition as P


def bucketize(ents: dict, dest: jax.Array, r: int,
              cap_link: int) -> Tuple[dict, jax.Array]:
    """Scatter local entities into (r * cap_link) slots grouped by dest.

    Returns (bucketed_entities, overflow_count).  Entities beyond a bucket's
    capacity are dropped and counted (never silently lost)."""
    n = dest.shape[0]
    d = jnp.where(ents["valid"], dest, r)                 # invalid -> dump
    order = jnp.argsort(d, stable=True)
    sd = d[order]
    counts = jnp.zeros((r + 1,), jnp.int32).at[sd].add(1)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(n, dtype=jnp.int32) - offs[sd]
    keep = (pos < cap_link) & (sd < r)
    n_slots = r * cap_link
    slot = jnp.where(keep, sd * cap_link + pos, n_slots)

    src = E.permute(ents, order)
    out = E.empty_like(ents, n_slots + 1)

    def scat(buf, val):
        return buf.at[slot].set(val, mode="drop")

    out["key"] = scat(out["key"], jnp.where(keep, src["key"], E.INVALID_KEY))
    out["eid"] = scat(out["eid"], src["eid"])
    out["valid"] = scat(out["valid"], src["valid"] & keep)
    out["payload"] = {k: scat(out["payload"][k], v)
                      for k, v in src["payload"].items()}
    out = jax.tree.map(lambda a: a[:n_slots], out)
    overflow = jnp.sum((~keep) & (sd < r)).astype(jnp.int32)
    return out, overflow


def exchange(bucketed: dict, r: int, axis: str) -> dict:
    """The shuffle: one all_to_all per field over the shard axis."""
    def a2a(x):
        xr = x.reshape((r, x.shape[0] // r) + x.shape[1:])
        y = jax.lax.all_to_all(xr, axis, split_axis=0, concat_axis=0,
                               tiled=False)
        return y.reshape((-1,) + x.shape[1:])
    return jax.tree.map(a2a, bucketed)


def srp_shard(ents: dict, bounds: jax.Array, r: int, axis: str,
              cap_link: int) -> Tuple[dict, jax.Array]:
    """Full SRP for one mapper shard: returns (sorted reduce partition,
    global overflow count).  The result's shard index == partition index
    (monotone p => shard-local sort == global range sort).

    A ``_dest`` payload field (attached by the runners from a rank-granular
    ``repro.balance`` ShardPlan) overrides the key->shard partition function:
    it lets a planner split an oversized key block across shards while
    staying monotone in the global (key, eid) sort order, so the sorted-
    reduce-partition invariant — and every downstream window/halo step —
    holds unchanged.  The tag is consumed map-side and stripped before the
    shuffle (nothing reads it after routing; keeping it would waste
    all_to_all bandwidth and halo-permute bytes)."""
    dest = ents["payload"].get("_dest")
    if dest is None:
        dest = P.shard_of(bounds, ents["key"])
    else:
        ents = dict(ents)
        ents["payload"] = {k: v for k, v in ents["payload"].items()
                           if k != "_dest"}
    buf, overflow = bucketize(ents, dest, r, cap_link)
    recv = exchange(buf, r, axis)
    sorted_ents = E.sort_entities(recv)
    return sorted_ents, jax.lax.psum(overflow, axis)


def local_load(ents: dict, axis: str) -> jax.Array:
    """Per-shard valid counts, all-gathered (skew telemetry, paper §5.3)."""
    return jax.lax.all_gather(E.n_valid(ents), axis)
