"""Match strategies (paper §3, §5.1).

The paper's matcher: edit distance on title + TriGram similarity on abstract,
weighted average, threshold 0.75, with an internal optimization that SKIPS the
second matcher when the first one's score can no longer reach the threshold.

TPU adaptation (DESIGN.md §2): entities carry
  * "feat": unit-norm embeddings  -> cosine similarity  (cheap matcher)
  * "sig":  bit-packed trigram sets -> Jaccard via popcount (TriGram matcher)
  * "text": padded byte strings  -> exact edit distance (expensive matcher)

``CascadeMatcher`` reproduces the skip optimization: the cheap similarity
gates the expensive one (vectorized as a candidate mask; the pair-compaction
path in pipeline.py turns that mask into real FLOP savings, and the Pallas
band kernels implement the cheap stage at MXU rate).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# -- primitive similarities (operate on payload slices of paired entities) ----------

def cosine_sim(a: jax.Array, b: jax.Array) -> jax.Array:
    """a, b: (..., F) unit-ish vectors -> (...,) in [0, 1]."""
    s = jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32), axis=-1)
    return jnp.clip(0.5 * (s + 1.0), 0.0, 1.0)


def jaccard_sig(a: jax.Array, b: jax.Array) -> jax.Array:
    """a, b: (..., W) uint32 bit-packed sets -> Jaccard |a&b|/|a|b|."""
    inter = jax.lax.population_count(a & b).sum(axis=-1).astype(jnp.float32)
    union = jax.lax.population_count(a | b).sum(axis=-1).astype(jnp.float32)
    return jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 1.0)


def _edit_distance_scan(a32, b32, L, la, lb):
    BIG = jnp.int32(2 * L + 7)
    rows = jnp.arange(L + 1, dtype=jnp.int32)
    shape = a32.shape[:-1] + (L + 1,)
    ones = jnp.ones(shape, jnp.int32)
    prev2 = jnp.where(rows == 0, 0, BIG) * ones
    prev = jnp.where(rows <= 1, 1, BIG) * ones
    target_d = la + lb                                  # (...,)
    # capture dp[la, lb]: on diagonal d == la+lb at row i == la.  Diagonals
    # 0 and 1 are the scan init, so their answers are captured here.
    ans0 = jnp.where(target_d == 0, 0,
                     jnp.where(target_d == 1, 1, BIG))

    def step(carry, d):
        prev2, prev, ans = carry
        i = rows
        j = d - i
        on = (j >= 0) & (j <= L)
        up = jnp.concatenate(
            [jnp.full(shape[:-1] + (1,), BIG), prev[..., :-1]], axis=-1)
        left = prev
        diag = jnp.concatenate(
            [jnp.full(shape[:-1] + (1,), BIG), prev2[..., :-1]], axis=-1)
        ca = jnp.take(a32, jnp.clip(i - 1, 0, L - 1), axis=-1)
        cb_idx = jnp.clip(j - 1, 0, L - 1)
        cb = jnp.take(b32, cb_idx, axis=-1)
        sub = diag + jnp.where(ca == cb, 0, 1)
        cur = jnp.minimum(jnp.minimum(up + 1, left + 1), sub)
        cur = jnp.where(i == 0, jnp.minimum(d, BIG), cur)
        cur = jnp.where(j == 0, i, cur)
        cur = jnp.where(on, cur, BIG)
        hit = (d == target_d)[..., None] & (i == la[..., None])
        ans = jnp.where(jnp.any(hit, -1),
                        jnp.sum(jnp.where(hit, cur, 0), axis=-1), ans)
        return (prev, cur, ans), None

    (_, _, ans), _ = jax.lax.scan(
        step, (prev2, prev, ans0),
        jnp.arange(2, 2 * L + 1, dtype=jnp.int32))
    return ans


def edit_distance_impl(a: jax.Array, b: jax.Array) -> jax.Array:
    L = a.shape[-1]
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)
    la = jnp.sum((a32 > 0).astype(jnp.int32), axis=-1)
    lb = jnp.sum((b32 > 0).astype(jnp.int32), axis=-1)
    return _edit_distance_scan(a32, b32, L, la, lb)


def edit_sim(a: jax.Array, b: jax.Array) -> jax.Array:
    """1 - dist / max(len) in [0,1]."""
    a32 = a.astype(jnp.int32)
    b32 = b.astype(jnp.int32)
    la = jnp.sum((a32 > 0).astype(jnp.int32), axis=-1)
    lb = jnp.sum((b32 > 0).astype(jnp.int32), axis=-1)
    d = _edit_distance_scan(a32, b32, a.shape[-1], la, lb)
    mx = jnp.maximum(jnp.maximum(la, lb), 1)
    return jnp.clip(1.0 - d.astype(jnp.float32) / mx.astype(jnp.float32),
                    0.0, 1.0)


def edit_distance_ref(a: np.ndarray, b: np.ndarray) -> int:
    """Host oracle for tests."""
    sa = bytes(a[a > 0].tolist())
    sb = bytes(b[b > 0].tolist())
    m, n = len(sa), len(sb)
    dp = list(range(n + 1))
    for i in range(1, m + 1):
        prev = dp[0]
        dp[0] = i
        for j in range(1, n + 1):
            cur = dp[j]
            dp[j] = min(dp[j] + 1, dp[j - 1] + 1,
                        prev + (sa[i - 1] != sb[j - 1]))
            prev = cur
    return dp[n]


# -- matcher strategy objects -----------------------------------------------------

@dataclass(frozen=True)
class Matcher:
    """One similarity over a payload field."""
    field: str
    kind: str            # "cosine" | "jaccard" | "edit"
    weight: float = 1.0
    cost: float = 1.0    # relative cost (cascade ordering)

    def __call__(self, pa: Dict[str, jax.Array],
                 pb: Dict[str, jax.Array]) -> jax.Array:
        a, b = pa[self.field], pb[self.field]
        if self.kind == "cosine":
            return cosine_sim(a, b)
        if self.kind == "jaccard":
            return jaccard_sig(a, b)
        if self.kind == "edit":
            return edit_sim(a, b)
        raise ValueError(self.kind)


@dataclass(frozen=True)
class CascadeMatcher:
    """Weighted-average match strategy with the paper's skip optimization:
    matchers are evaluated cheap-to-expensive; if the best still-achievable
    combined score drops below the threshold, later matchers are skipped.

    ``combined(pa, pb)`` returns (score, evaluated_mask) vectorized over any
    leading shape."""
    matchers: Tuple[Matcher, ...]
    threshold: float = 0.75

    def ordered(self):
        return tuple(sorted(self.matchers, key=lambda m: m.cost))

    def combined(self, pa, pb, *, skip: bool = True):
        ms = self.ordered()
        wsum = sum(m.weight for m in ms)
        acc = None
        remaining = wsum
        evaluated = 0.0
        alive = None
        for m in ms:
            if acc is None:
                s = m(pa, pb)
                acc = m.weight * s
                alive = jnp.ones_like(s, bool)
            else:
                if skip:
                    # max achievable if every remaining matcher scored 1.0
                    best = (acc + remaining) / wsum
                    alive = alive & (best >= self.threshold)
                s = jnp.where(alive, m(pa, pb), 0.0)
                acc = acc + m.weight * s
            evaluated = evaluated + (alive.astype(jnp.float32)
                                     if alive is not None else 1.0)
            remaining -= m.weight
        return acc / wsum, evaluated

    def matches(self, pa, pb, *, skip: bool = True):
        score, _ = self.combined(pa, pb, skip=skip)
        return score >= self.threshold


def default_matcher() -> CascadeMatcher:
    """The paper's strategy: cheap trigram-style sim gates the edit distance;
    weighted average, threshold 0.75 (§5.1)."""
    return CascadeMatcher(
        matchers=(
            Matcher(field="feat", kind="cosine", weight=0.5, cost=1.0),
            Matcher(field="sig", kind="jaccard", weight=0.5, cost=2.0),
        ),
        threshold=0.75)
