"""Entity representation for the ER pipeline.

Entities are fixed-width records (TPU adaptation of the paper's (String,
String[]) Hadoop sequence files — see DESIGN.md §2):

  key:   (N,)  int32   blocking key (packed, non-negative, < 2^30)
  eid:   (N,)  int32   stable global entity id (lineage / test oracle)
  valid: (N,)  bool    slot occupancy (fixed-capacity shards carry padding)
  payload: dict of per-entity arrays, e.g.
     "sig":  (N, SIG_WORDS) uint32   bit-packed trigram signature
     "feat": (N, F)         float32  dense feature embedding
     "text": (N, L)         uint8    padded byte string (exact matchers)

All shard-level ops keep VALID ENTITIES CONTIGUOUS from slot 0 in blocking-key
order — the sliding-window distance is then slot distance (see window.py).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

INVALID_KEY = jnp.int32(2**31 - 1)   # sorts after every real key


def make_entities(key, eid, payload=None, valid=None) -> dict:
    key = jnp.asarray(key, jnp.int32)
    n = key.shape[0]
    return {
        "key": key,
        "eid": jnp.asarray(eid, jnp.int32),
        "valid": jnp.ones((n,), bool) if valid is None
        else jnp.asarray(valid, bool),
        "payload": dict(payload or {}),
    }


def n_valid(ents) -> jax.Array:
    return jnp.sum(ents["valid"].astype(jnp.int32))


def sort_key(ents) -> jax.Array:
    """int32 sort key: invalid slots pushed to the end."""
    return jnp.where(ents["valid"], ents["key"], INVALID_KEY)


def permute(ents, order) -> dict:
    take = lambda a: jnp.take(a, order, axis=0)
    return {
        "key": take(ents["key"]),
        "eid": take(ents["eid"]),
        "valid": take(ents["valid"]),
        "payload": {k: take(v) for k, v in ents["payload"].items()},
    }


def sort_entities(ents) -> dict:
    """Deterministic sort by (key, eid), invalid slots last.

    One ``lexsort`` + one payload permute (the old two-pass argsort-by-eid
    then stable-argsort-by-key permuted every payload field twice — this is
    the reduce-side sort on the shuffle hot path, paid once per shard per
    call)."""
    order = jnp.lexsort((ents["eid"], sort_key(ents)))
    return permute(ents, order)


def concat(a, b) -> dict:
    cat = lambda x, y: jnp.concatenate([x, y], axis=0)
    return {
        "key": cat(a["key"], b["key"]),
        "eid": cat(a["eid"], b["eid"]),
        "valid": cat(a["valid"], b["valid"]),
        "payload": {k: cat(a["payload"][k], b["payload"][k])
                    for k in a["payload"]},
    }


def empty_like(ents, n: int) -> dict:
    z = lambda a: jnp.zeros((n,) + a.shape[1:], a.dtype)
    return {
        "key": jnp.full((n,), INVALID_KEY, jnp.int32),
        "eid": z(ents["eid"]),
        "valid": jnp.zeros((n,), bool),
        "payload": {k: z(v) for k, v in ents["payload"].items()},
    }


def slice_entities(ents, start, size: int) -> dict:
    ds = lambda a: jax.lax.dynamic_slice_in_dim(a, start, size, axis=0)
    return {
        "key": ds(ents["key"]),
        "eid": ds(ents["eid"]),
        "valid": ds(ents["valid"]),
        "payload": {k: ds(v) for k, v in ents["payload"].items()},
    }


def roll(ents, shift) -> dict:
    r = lambda a: jnp.roll(a, shift, axis=0)
    return {
        "key": r(ents["key"]),
        "eid": r(ents["eid"]),
        "valid": r(ents["valid"]),
        "payload": {k: r(v) for k, v in ents["payload"].items()},
    }


# -- host-side chunk helpers (repro.stream: out-of-core resolution) -----------------
#
# The streaming subsystem holds the corpus as HOST numpy chunks (the paper's
# premise: n is bounded by host disk, not device memory) and only moves one
# [seam halo | chunk] window to device per resolve.  These helpers are the
# numpy mirror of the jnp ops above, operating on the same schema.

def to_host(ents) -> dict:
    """Entity dict with every array materialized as host numpy (same
    schema; a no-op view for arrays already on host)."""
    return {
        "key": np.asarray(ents["key"]),
        "eid": np.asarray(ents["eid"]),
        "valid": np.asarray(ents["valid"]),
        "payload": {k: np.asarray(v) for k, v in ents["payload"].items()},
    }


def host_take(ents: dict, idx) -> dict:
    """Row subset of a host entity dict (``idx``: slice, bool mask, or
    integer index array)."""
    return {
        "key": ents["key"][idx],
        "eid": ents["eid"][idx],
        "valid": ents["valid"][idx],
        "payload": {k: v[idx] for k, v in ents["payload"].items()},
    }


def host_concat(chunks) -> dict:
    """Concatenate host entity dicts row-wise (all must share the payload
    schema; an empty list is rejected — there is no schema to produce)."""
    chunks = list(chunks)
    if not chunks:
        raise ValueError("host_concat needs at least one chunk")
    if len(chunks) == 1:
        return chunks[0]
    cat = lambda f: np.concatenate([c[f] for c in chunks], axis=0)
    return {
        "key": cat("key"), "eid": cat("eid"), "valid": cat("valid"),
        "payload": {k: np.concatenate([c["payload"][k] for c in chunks],
                                      axis=0)
                    for k in chunks[0]["payload"]},
    }


def sort_chunk(ents, key=None) -> dict:
    """Device-sort one chunk by (key, eid) and return it as a host dict
    with invalid slots DROPPED — the per-chunk device sort of the external
    merge (``repro.stream``): the O(n log n) work runs as JAX ops, the
    sorted run lands back on host for spooling/merging.

    ``key`` optionally overrides ``ents["key"]`` (a multi-pass derived sort
    key); eids and payload ride unchanged."""
    e = ents if key is None else {
        "key": jnp.asarray(key, jnp.int32), "eid": ents["eid"],
        "valid": ents["valid"], "payload": ents["payload"]}
    h = to_host(sort_entities(e))
    return host_take(h, slice(0, int(h["valid"].sum())))


def composite_order_key(ents: dict) -> np.ndarray:
    """(N,) int64 merge key ``(key << 32) | eid`` — one scalar per entity
    that orders exactly like the (key, eid) lexsort (keys < 2^30 and eids
    non-negative int32 by schema), so sorted runs merge on a single int64
    comparison."""
    key = np.asarray(ents["key"], np.int64)
    eid = np.asarray(ents["eid"], np.int64)
    return (key << 32) | eid


# -- synthetic data (benchmarks / tests) ------------------------------------------

def synth_entities(rng: np.random.Generator, n: int, *,
                   n_keys: int = 1000, sig_words: int = 8,
                   feat_dim: int = 32, dup_frac: float = 0.2,
                   skew: float = 0.0, text_len: int = 0) -> dict:
    """Synthetic publication-like corpus (paper §5.1 analogue: 1.4M records,
    key = first letters of title).  ``skew`` in [0,1): fraction of entities
    concentrated on the largest key (paper's Even8_40..85 configurations).
    Duplicates get near-identical payloads (detectable by the matchers).

    ``text_len > 0`` adds a padded-bytes "text" field (random lowercase
    strings; duplicates copy the original with a single-character typo) —
    the payload for the paper's EXPENSIVE edit-distance matcher, so
    cascade benchmarks have a real cost gap between cheap and full
    evaluation."""
    keys = rng.integers(0, n_keys, size=n).astype(np.int32)
    if skew > 0:
        hot = rng.random(n) < skew
        keys[hot] = n_keys - 1
    feat = rng.normal(size=(n, feat_dim)).astype(np.float32)
    sig = rng.integers(0, 2**32, size=(n, sig_words), dtype=np.uint64) \
        .astype(np.uint32)
    text = rng.integers(ord("a"), ord("z") + 1, size=(n, text_len)) \
        .astype(np.uint8) if text_len else None
    # plant duplicates: copy an earlier entity's key/payload with tiny noise
    n_dup = int(n * dup_frac)
    if n_dup:
        src = rng.integers(0, n, size=n_dup)
        dst = rng.integers(0, n, size=n_dup)
        keys[dst] = keys[src]
        feat[dst] = feat[src] + 0.01 * rng.normal(size=(n_dup, feat_dim)) \
            .astype(np.float32)
        sig[dst] = sig[src]
        if text is not None:
            text[dst] = text[src]
            typo_pos = rng.integers(0, text_len, size=n_dup)
            text[dst, typo_pos] = rng.integers(
                ord("a"), ord("z") + 1, size=n_dup).astype(np.uint8)
    feat /= np.linalg.norm(feat, axis=1, keepdims=True) + 1e-9
    payload = {"feat": jnp.asarray(feat), "sig": jnp.asarray(sig)}
    if text is not None:
        payload["text"] = jnp.asarray(text)
    return make_entities(keys, np.arange(n, dtype=np.int32), payload=payload)
