"""RepSN — Sorted Neighborhood with entity replication (paper §4.3).

The paper replicates the w-1 highest-keyed entities of each partition to its
*successor* reducer via composite keys ((p(k)+1).p(k).k).  On a TPU mesh this
is exactly a **halo exchange**: after SRP, each shard sends its last w-1
valid entities one hop forward with a single ``collective-permute`` — no
second job, no extra shuffle, and the halo transfer overlaps with local
window compute under XLA async collectives.

Beyond the paper: ``hops > 1`` iterates the halo so that windows spanning
more than one partition boundary (possible when a partition holds fewer than
w-1 entities — the paper implicitly assumes partitions >= w) are also
complete; ``hops = r-1`` is always sufficient.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import entities as E


def tail_window(ents: dict, w: int, *, presorted: bool = False) -> dict:
    """Last w-1 valid entities (in key order), rolled so padding sits FIRST —
    prepending this to a sorted shard keeps valid slots contiguous.

    ``presorted=True`` skips the (key, eid) sort when the caller already
    holds a sorted shard (the post-SRP fast path: the shuffle output is
    sorted once in ``srp_shard``, so re-sorting here paid a redundant
    full-payload sort per halo hop on the steady-state hot path)."""
    s = ents if presorted else E.sort_entities(ents)
    nv = E.n_valid(s)
    start = jnp.clip(nv - (w - 1), 0, s["key"].shape[0])
    tail = E.slice_entities(s, start, w - 1)
    # if nv < w-1 the slice has trailing invalid: rotate them to the front
    shift = jnp.maximum((w - 1) - nv, 0)
    return E.roll(tail, shift)


def _ring_fwd(ents: dict, r: int, axis: str) -> dict:
    """One forward halo hop.  A full-ring collective-permute (vmap's batching
    rule requires complete permutations); the wrapped edge (shard r-1 ->
    shard 0) is invalidated — shard 0 has no predecessor."""
    fwd = [(i, (i + 1) % r) for i in range(r)]
    out = jax.tree.map(lambda x: jax.lax.ppermute(x, axis, fwd), ents)
    first = jax.lax.axis_index(axis) == 0
    out["valid"] = out["valid"] & ~first
    out["key"] = jnp.where(out["valid"], out["key"], E.INVALID_KEY)
    return out


def halo_exchange(sorted_ents: dict, w: int, r: int, axis: str,
                  hops: int = 1) -> dict:
    """Returns the (w-1)-slot halo = last w-1 global predecessors of this
    shard's key range (valid contiguous at the halo's tail)."""
    halo = _ring_fwd(tail_window(sorted_ents, w, presorted=True), r, axis)
    for _ in range(hops - 1):
        # [halo | native] interleaves the halo's leading padding with native
        # keys, so the multi-hop concat DOES need the sort
        halo = _ring_fwd(
            tail_window(E.concat(halo, sorted_ents), w), r, axis)
    return halo


def repsn_combine(sorted_ents: dict, w: int, r: int, axis: str,
                  hops: int = 1) -> Tuple[dict, int]:
    """Prepend the halo; returns (combined_entities, halo_len).

    The window over the combined array with mode="native" (window._pair_mask)
    emits exactly the SRP pairs plus this shard's boundary pairs — together
    across shards: the complete sequential-SN pair set."""
    halo = halo_exchange(sorted_ents, w, r, axis, hops=hops)
    return E.concat(halo, sorted_ents), w - 1
