"""Sliding-window matching over a sorted shard (the paper's reduce step).

The window is evaluated as a BAND: for a sorted array of M slots,
``band[d-1, i] = score(E[i], E[i+d])`` for distance d in 1..w-1.  Validity
masking + the slot conventions (valid entities contiguous in key order, halo
entities occupying the first ``halo_len`` slots) make slot distance equal
rank distance, so the band is exactly the paper's sliding window.

Three evaluation paths:
  * ``band_scores``         pure-JAX scan over distances (memory-safe oracle)
  * kernels.banded_ops      Pallas MXU band kernels (hot path; see kernels/)
  * ``band_matches_cascade``the paper's §5.1 two-stage skip optimization:
                            cheap band -> compact candidates -> exact matcher
"""
from __future__ import annotations

from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import entities as E
from repro.core.match import CascadeMatcher


def _pair_mask(valid: jax.Array, d: jax.Array, *, halo_len: int,
               mode: str) -> jax.Array:
    """Mask for pairs (i, i+d) of a combined [halo | native] array.

    mode:
      "all"      every valid pair (plain SRP shard)
      "native"   at least the LATER element is native (RepSN rule: halo-halo
                 pairs were already emitted by the predecessor shard)
      "cross"    earlier element in the first half, later in the second half
                 (JobSN boundary job: only cross-partition pairs; same-side
                 pairs were emitted in phase 1)
    """
    m = valid.shape[0]
    i = jnp.arange(m, dtype=jnp.int32)
    j = i + d
    ok = (j < m) & valid & jnp.roll(valid, -d)
    if mode == "native":
        ok &= j >= halo_len
    elif mode == "cross":
        ok &= (i < halo_len) & (j >= halo_len)
    return ok


def band_scores(ents: dict, w: int, matcher: CascadeMatcher, *,
                halo_len: int = 0, mode: str = "all",
                skip: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Returns (scores, mask), each (w-1, M): row d-1 holds distance-d pairs.

    Scans over distances; each step scores M pairs via a rolled payload view —
    O(M * F) live memory regardless of w."""
    payload = ents["payload"]
    valid = ents["valid"]

    def step(_, d):
        rolled = {k: jnp.roll(v, -d, axis=0) for k, v in payload.items()}
        score, _ = matcher.combined(payload, rolled, skip=skip)
        ok = _pair_mask(valid, d, halo_len=halo_len, mode=mode)
        return None, (jnp.where(ok, score, 0.0), ok)

    _, (scores, mask) = jax.lax.scan(
        step, None, jnp.arange(1, w, dtype=jnp.int32))
    return scores, mask


def band_matches(ents: dict, w: int, matcher: CascadeMatcher, *,
                 halo_len: int = 0, mode: str = "all") -> jax.Array:
    scores, mask = band_scores(ents, w, matcher, halo_len=halo_len, mode=mode)
    return (scores >= matcher.threshold) & mask


def compact_candidates(scores: jax.Array, mask: jax.Array, tau: float,
                       cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Stage-2 of the cascade: compact (d, i) band positions whose cheap
    score >= tau into a fixed-capacity candidate list.

    Returns (cand_i, cand_d, cand_valid) each (cap,)."""
    flat = (scores >= tau) & mask                      # (w-1, M)
    wm1, m = flat.shape
    flat1 = flat.reshape(-1)
    # stable order: candidates first
    order = jnp.argsort(~flat1, stable=True)[:cap]
    val = flat1[order]
    d = order // m + 1
    i = order % m
    return i.astype(jnp.int32), d.astype(jnp.int32), val


def score_candidates(ents: dict, cand_i, cand_d, cand_valid,
                     matcher: CascadeMatcher) -> jax.Array:
    """Run the full (expensive) matcher on compacted candidate pairs only —
    the real-FLOP realization of the paper's skip optimization."""
    j = cand_i + cand_d
    j = jnp.minimum(j, ents["valid"].shape[0] - 1)
    pa = {k: v[cand_i] for k, v in ents["payload"].items()}
    pb = {k: v[j] for k, v in ents["payload"].items()}
    score, _ = matcher.combined(pa, pb, skip=False)
    return jnp.where(cand_valid, score, 0.0)


def band_pair_count(mask: jax.Array) -> jax.Array:
    return jnp.sum(mask.astype(jnp.int32))
