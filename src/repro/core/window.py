"""Sliding-window matching over a sorted shard (the paper's reduce step).

The window is evaluated as a BAND: for a sorted array of M slots,
``band[d-1, i] = score(E[i], E[i+d])`` for distance d in 1..w-1.  Validity
masking + the slot conventions (valid entities contiguous in key order, halo
entities occupying the first ``halo_len`` slots) make slot distance equal
rank distance, so the band is exactly the paper's sliding window.

Band evaluation is a pluggable seam — a **BandEngine** selected by
``ERConfig.band_engine`` and used by every variant's ``_band`` hook:

  * ``scan``    (ScanBandEngine) pure-JAX scan over distances: w-1 shifted
                full-payload passes through ``CascadeMatcher.combined``.
                Memory-safe reference oracle; the §5.1 "skip" is a
                ``jnp.where`` that still computes both branches.
  * ``pallas``  (PallasBandEngine) the paper's §5.1 two-stage cascade with
                REAL FLOP savings: a fused Pallas kernel
                (kernels/fused_band.py) evaluates the cheap matchers for the
                whole band at MXU rate, cumsum-based compaction
                (``compact_candidates``) packs gate survivors into a
                ``cand_cap`` buffer (overflow counted, never silent), and
                the expensive matcher (``score_candidates``) runs ONLY on
                survivors.  Decisions match the scan engine exactly: the
                gate keeps every pair whose best-achievable combined score
                can still reach the threshold (plus an epsilon guard for
                kernel-vs-jnp rounding), and survivors are rescored with
                the full jnp cascade.

Engines register with ``@register_band_engine("name")``; both return the
same part dict (``mask``/``match``/``matcher_evals``/``cand_overflow``), so
variants and runners never branch on the engine.

The halo/seam convention generalizes beyond shard boundaries: the same
``[halo | native]`` layout that closes partition seams (RepSN) closes the
CHUNK seams of out-of-core streaming — ``repro.stream`` prepends the w-1
preceding global entities to every chunk and the band emits each SN pair
at its true sorted distance (the pair-ownership rule of the cost model
below is also why per-chunk pair unions dedup cleanly).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.match import CascadeMatcher

# epsilon guard on the cascade gate: the fused kernel's cheap scores can
# differ from the jnp oracle by reduction-order ulps; widening the gate by
# GATE_EPS (in normalized-score units) keeps every pair the scan engine
# could accept, and extra survivors are exactly rescored anyway.
GATE_EPS = 1e-5


def _pair_mask(valid: jax.Array, d: jax.Array, *, halo_len: int,
               mode: str, weff: Optional[jax.Array] = None) -> jax.Array:
    """Mask for pairs (i, i+d) of a combined [halo | native] array.

    mode:
      "all"      every valid pair (plain SRP shard)
      "native"   at least the LATER element is native (RepSN rule: halo-halo
                 pairs were already emitted by the predecessor shard)
      "cross"    earlier element in the first half, later in the second half
                 (JobSN boundary job: only cross-partition pairs; same-side
                 pairs were emitted in phase 1)

    ``weff`` (adaptive windows, DESIGN.md §14) is a per-slot effective
    window: pair (i, i+d) additionally requires d < weff[i+d] — the LATER
    element owns the comparison, the same ownership rule as the cost model,
    so per-entity windows compose with every mode/halo convention.
    """
    m = valid.shape[0]
    i = jnp.arange(m, dtype=jnp.int32)
    j = i + d
    ok = (j < m) & valid & jnp.roll(valid, -d)
    if weff is not None:
        ok &= d < jnp.roll(weff, -d)
    if mode == "native":
        ok &= j >= halo_len
    elif mode == "cross":
        ok &= (i < halo_len) & (j >= halo_len)
    return ok


def cross_source_rows(src: jax.Array, w: int) -> jax.Array:
    """(w-1, M) linkage mask: row d-1 true where src[i] != src[i+d] — THE
    one implementation of the cross-source rule (api.linkage and both band
    engines delegate here)."""
    def step(_, d):
        return None, src != jnp.roll(src, -d)
    _, rows = jax.lax.scan(step, None, jnp.arange(1, w, dtype=jnp.int32))
    return rows


def band_mask(valid: jax.Array, w: int, *, halo_len: int = 0,
              mode: str = "all", src: Optional[jax.Array] = None,
              weff: Optional[jax.Array] = None) -> jax.Array:
    """(w-1, M) validity band: row d-1 masks distance-d pairs.  ``src``
    (linkage mode) additionally restricts to cross-source pairs via
    ``cross_source_rows``; ``weff`` restricts each pair to the later
    element's effective window (adaptive policy)."""
    def step(_, d):
        return None, _pair_mask(valid, d, halo_len=halo_len, mode=mode,
                                weff=weff)
    _, rows = jax.lax.scan(step, None, jnp.arange(1, w, dtype=jnp.int32))
    if src is not None:
        rows = rows & cross_source_rows(src, w)
    return rows


def band_scores(ents: dict, w: int, matcher: CascadeMatcher, *,
                halo_len: int = 0, mode: str = "all",
                skip: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Returns (scores, mask), each (w-1, M): row d-1 holds distance-d pairs.

    Scans over distances; each step scores M pairs via a rolled payload view —
    O(M * F) live memory regardless of w."""
    payload = ents["payload"]
    valid = ents["valid"]
    weff = payload.get("_weff")      # adaptive per-entity windows, if riding

    def step(_, d):
        rolled = {k: jnp.roll(v, -d, axis=0) for k, v in payload.items()}
        score, _ = matcher.combined(payload, rolled, skip=skip)
        ok = _pair_mask(valid, d, halo_len=halo_len, mode=mode, weff=weff)
        return None, (jnp.where(ok, score, 0.0), ok)

    _, (scores, mask) = jax.lax.scan(
        step, None, jnp.arange(1, w, dtype=jnp.int32))
    return scores, mask


def band_matches(ents: dict, w: int, matcher: CascadeMatcher, *,
                 halo_len: int = 0, mode: str = "all") -> jax.Array:
    scores, mask = band_scores(ents, w, matcher, halo_len=halo_len, mode=mode)
    return (scores >= matcher.threshold) & mask


def compact_flat(band: jax.Array, cap: int
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Pack the True positions of a boolean band (w-1, M) into a fixed-
    capacity buffer of FLAT indices ``(d-1)*M + i``, in band order.

    Cumsum-based: each survivor's slot is its exclusive prefix count — O(wM)
    work and one scatter, vs a full-band argsort's O(wM log wM).

    Returns (flat_idx (cap,) int32, n_true () int32, overflow () int32);
    positions past ``cap`` are dropped but counted in ``overflow`` (never
    silent).  Buffer slots beyond ``min(n_true, cap)`` are zero-filled."""
    flat = band.reshape(-1)
    n = flat.shape[0]
    rank = jnp.cumsum(flat.astype(jnp.int32)) - 1          # survivor rank
    n_true = jnp.sum(flat.astype(jnp.int32))
    target = jnp.where(flat & (rank < cap), rank, cap)     # cap -> dump slot
    buf = jnp.zeros((cap + 1,), jnp.int32).at[target].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")
    overflow = jnp.maximum(n_true - cap, 0)
    return buf[:cap], n_true, overflow


def compact_candidates(gate: jax.Array, cap: int
                       ) -> Tuple[jax.Array, jax.Array, jax.Array,
                                  jax.Array, jax.Array]:
    """Stage-2 of the cascade: pack the True (d, i) band positions of
    ``gate`` (w-1, M) into a fixed-capacity candidate list, in band order
    (``compact_flat`` split back into (i, d) coordinates).

    Returns (cand_i, cand_d, cand_valid, n_cand, overflow); candidates past
    ``cap`` are dropped but counted in ``overflow`` (never silent)."""
    m = gate.shape[1]
    cand_flat, n_cand, overflow = compact_flat(gate, cap)
    kept = jnp.minimum(n_cand, cap)
    cand_valid = jnp.arange(cap, dtype=jnp.int32) < kept
    cand_d = cand_flat // m + 1
    cand_i = cand_flat % m
    return (cand_i.astype(jnp.int32), cand_d.astype(jnp.int32), cand_valid,
            n_cand, overflow)


def emit_band_indices(band: jax.Array, cap: int) -> dict:
    """Device-side pair emission (ISSUE 4): compact a boolean band (w-1, M)
    into a packed flat-index buffer so the host transfers ``cap`` int32
    slots + a count instead of the whole O(w*M) band.  The same capacity /
    overflow contract as the SRP shuffle and cand_cap: drops are counted,
    never silent.  Consumed by ``results.packed_pairs_from_idx`` (host eid
    translation is vectorized there)."""
    idx, n_true, overflow = compact_flat(band, cap)
    return {"idx": idx, "n": jnp.minimum(n_true, cap).astype(jnp.int32),
            "overflow": overflow.astype(jnp.int32)}


def cheap_band_jnp(payload: dict, split: "CascadeSplit",
                   w: int) -> jax.Array:
    """Band-shaped jnp evaluation of the cascade's cheap prefix: (w-1, M)
    unnormalized partial scores ``w_cos*cosine + w_jac*jaccard`` — the same
    math as the fused Pallas kernel, but computing only the w-1 band scores
    per row instead of the kernel's 2*block_i-wide tile.

    The tile shape is what the TPU MXU wants; off-TPU it is pure waste
    (~2*block_i/(w-1) extra cheap evaluations), so the pallas engine uses
    this path when the interpreter would otherwise run the tile kernel
    (band_interpret=None off-TPU).  Numerically this matches the scan
    oracle's per-matcher scores exactly (same jnp ops), so the GATE_EPS
    guard is strictly slack here."""
    from repro.core.match import cosine_sim, jaccard_sig

    feat = payload.get(split.feat_field) if split.feat_field else None
    sig = payload.get(split.sig_field) if split.sig_field else None

    def step(_, d):
        part = jnp.float32(0.0)
        if feat is not None:
            part = part + split.w_cos * cosine_sim(
                feat, jnp.roll(feat, -d, axis=0))
        if sig is not None:
            part = part + split.w_jac * jaccard_sig(
                sig, jnp.roll(sig, -d, axis=0))
        return None, part

    _, rows = jax.lax.scan(step, None, jnp.arange(1, w, dtype=jnp.int32))
    return rows


def score_candidates(ents: dict, cand_i, cand_d, cand_valid,
                     matcher: CascadeMatcher) -> jax.Array:
    """Run the full (expensive) matcher on compacted candidate pairs only —
    the real-FLOP realization of the paper's skip optimization."""
    j = cand_i + cand_d
    j = jnp.minimum(j, ents["valid"].shape[0] - 1)
    pa = {k: v[cand_i] for k, v in ents["payload"].items()}
    pb = {k: v[j] for k, v in ents["payload"].items()}
    score, _ = matcher.combined(pa, pb, skip=False)
    return jnp.where(cand_valid, score, 0.0)


def prune_low_evidence(payload: dict, matcher: CascadeMatcher, w: int,
                       mask: jax.Array, threshold: float
                       ) -> Tuple[jax.Array, jax.Array]:
    """Meta-blocking comparison pruning (DESIGN.md §14): shrink the blocked
    band to pairs whose CHEAP cascade evidence clears ``threshold`` (a
    fraction of the cheap prefix's weight), BEFORE the expensive matcher.

    The evidence is always ``cheap_band_jnp`` — the identical jnp math both
    engines' gates use — so prune decisions are bit-identical between scan
    and pallas, and the GATE_EPS slack guarantees a pair exactly at the bar
    is kept (invariant 14: no gold pair at/above the bar is ever pruned).

    Returns (kept_mask, pruned_count).  Raises when the matcher has no
    kernel-supported cheap prefix — there is no evidence to prune on."""
    split = split_cascade(matcher, payload)
    if split is None:
        raise ValueError(
            "prune_policy='evidence' needs a matcher whose cascade starts "
            "with a kernel-supported cheap stage (cosine/jaccard on a "
            "present payload field); split_cascade found none")
    cheap = cheap_band_jnp(payload, split, w)               # (w-1, M)
    bar = threshold * (split.w_cos + split.w_jac) - GATE_EPS
    kept = mask & (cheap >= bar)
    pruned = band_pair_count(mask) - band_pair_count(kept)
    return kept, pruned.astype(jnp.int32)


def band_pair_count(mask: jax.Array) -> jax.Array:
    """Number of True slots in a boolean band — the device-side pair count
    (blocked or matched, depending on which band is passed)."""
    return jnp.sum(mask.astype(jnp.int32))


# -- window comparison cost model (host-side; the balance subsystem's oracle) -------
#
# Under the band layout every SN pair (i-d, i) is OWNED by its later element
# i (RepSN mode="native": pairs whose later element is native to the shard),
# so the entity at global sorted rank i contributes exactly min(i, w-1)
# comparisons to whichever shard it lands on.  Contiguous rank ranges then
# have a closed-form comparison count — the cost model `repro.balance` plans
# against.

def rank_prefix_comparisons(rank, w: int) -> np.ndarray:
    """Closed-form sum of the per-rank marginal cost min(i, w-1) over ranks
    i < rank: the total SN pairs among the first ``rank`` sorted entities.
    Vectorized; equals ``sn.expected_pair_count(rank, w)``."""
    r = np.asarray(rank, np.int64)
    ramp = np.minimum(r, w - 1)
    return ramp * (ramp - 1) // 2 + np.maximum(r - (w - 1), 0) * (w - 1)


def rank_for_prefix_comparisons(target: float, w: int) -> int:
    """Inverse of ``rank_prefix_comparisons``: the smallest rank whose prefix
    comparison count reaches ``target`` (the pair-space -> rank-space map the
    pairrange planner and blocksplit's mid-block splits use)."""
    wm1 = w - 1
    if target <= 0:
        return 0
    tri = wm1 * (wm1 - 1) // 2                 # prefix at rank w-1
    if target <= tri:
        e = int(np.ceil((1.0 + np.sqrt(1.0 + 8.0 * float(target))) / 2.0))
        while e * (e - 1) // 2 < target:       # guard float rounding
            e += 1
        while e > 0 and (e - 1) * (e - 2) // 2 >= target:
            e -= 1
        return e
    return wm1 + int(np.ceil((float(target) - tri) / wm1))


# -- band engines -------------------------------------------------------------------

_BAND_ENGINES: Dict[str, Type["BandEngine"]] = {}


def register_band_engine(name: str):
    """Class decorator: ``@register_band_engine("pallas")``."""
    def deco(cls):
        cls.name = name
        _BAND_ENGINES[name] = cls
        return cls
    return deco


def get_band_engine(name: str) -> "BandEngine":
    try:
        return _BAND_ENGINES[name]()
    except KeyError:
        raise ValueError(
            f"unknown band engine {name!r}; registered: "
            f"{available_band_engines()}") from None


def available_band_engines() -> Tuple[str, ...]:
    return tuple(sorted(_BAND_ENGINES))


class BandEngine:
    """One way to evaluate the sliding-window band of a sorted shard.

    ``band(ents, cfg, halo_len=..., mode=...)`` returns the per-part dict
    consumed by variants/runners/collectors:

      mask           (w-1, M) bool   blocked (candidate) pairs
      match          (w-1, M) bool   matcher-accepted pairs
      matcher_evals  ()       int32  full-cascade evaluations ACTUALLY run
                                     (static-shape honest: the pallas
                                     engine's expensive stage scores its
                                     whole cand_cap buffer, so a finite
                                     cand_cap is what buys the FLOP cut)
      cand_count     ()       int32  cascade-gate survivors kept (pallas;
                                     0 for scan — no gate)
      cand_overflow  ()       int32  gate survivors dropped by cand_cap
      scores         (w-1, M) f32    only when cfg.return_scores
    """

    name = "?"

    def band(self, ents: dict, cfg, *, halo_len: int, mode: str) -> dict:
        raise NotImplementedError

    def match_bound(self, ents: dict, cfg) -> Optional[int]:
        """Static upper bound on True entries in this engine's MATCH band,
        beyond the band size itself, or None.  Device-side pair emission
        uses it to shrink the match index buffer (the match band is orders
        of magnitude sparser than the blocked mask)."""
        return None

    @staticmethod
    def _src(ents: dict, cfg) -> Optional[jax.Array]:
        if getattr(cfg, "linkage", False) and "src" in ents["payload"]:
            return ents["payload"]["src"]
        return None


@register_band_engine("scan")
class ScanBandEngine(BandEngine):
    """Reference oracle: w-1 shifted full-payload passes.  The cascade skip
    is a ``jnp.where`` — both branches are computed, so every band slot
    costs one full matcher evaluation."""

    def band(self, ents: dict, cfg, *, halo_len: int, mode: str) -> dict:
        scores, mask = band_scores(ents, cfg.window, cfg.matcher,
                                   halo_len=halo_len, mode=mode)
        src = self._src(ents, cfg)
        if src is not None:
            mask = mask & cross_source_rows(src, cfg.window)
        pruned = jnp.int32(0)
        if getattr(cfg, "prune_policy", "off") == "evidence":
            mask, pruned = prune_low_evidence(
                ents["payload"], cfg.matcher, cfg.window, mask,
                cfg.prune_threshold)
        match = (scores >= cfg.matcher.threshold) & mask
        m = ents["valid"].shape[0]
        out = {"mask": mask, "match": match,
               "matcher_evals": jnp.int32((cfg.window - 1) * m),
               "cand_count": jnp.int32(0),
               "cand_overflow": jnp.int32(0),
               "pruned": pruned}
        if cfg.return_scores:
            out["scores"] = scores
        return out


@dataclass(frozen=True)
class CascadeSplit:
    """How the matcher cascade maps onto the fused kernel: the cheap prefix
    (cosine and/or jaccard, kernel-supported) and the gate threshold for the
    UNNORMALIZED partial score the kernel emits."""
    feat_field: Optional[str]
    sig_field: Optional[str]
    w_cos: float
    w_jac: float
    tau_partial: float       # gate: cheap_partial >= tau_partial


def split_cascade(matcher: CascadeMatcher,
                  payload: dict) -> Optional[CascadeSplit]:
    """Split the cost-ordered cascade into a kernel-supported cheap prefix
    (one cosine field + one jaccard field, in cost order) and the remainder.
    Returns None when the FIRST matcher is unsupported (no cheap stage — the
    pallas engine then falls back to the scan oracle)."""
    w_cos = w_jac = 0.0
    feat_field = sig_field = None
    prefix_w = 0.0
    for m in matcher.ordered():
        if m.kind == "cosine" and feat_field is None and m.field in payload:
            feat_field, w_cos = m.field, m.weight
        elif m.kind == "jaccard" and sig_field is None and m.field in payload:
            sig_field, w_jac = m.field, m.weight
        else:
            break
        prefix_w += m.weight
    if feat_field is None and sig_field is None:
        return None
    wsum = sum(m.weight for m in matcher.matchers)
    remaining = wsum - prefix_w
    # gate passes iff (cheap + remaining)/wsum >= threshold - GATE_EPS
    tau = (matcher.threshold - GATE_EPS) * wsum - remaining
    return CascadeSplit(feat_field=feat_field, sig_field=sig_field,
                        w_cos=w_cos, w_jac=w_jac, tau_partial=tau)


@register_band_engine("pallas")
class PallasBandEngine(BandEngine):
    """The §5.1 cascade end-to-end on device: fused cheap-band kernel ->
    cumsum compaction -> exact matcher on survivors only.

    cand_cap (cfg.cand_cap; 0 = full band, never overflows) bounds the
    survivor buffer exactly like SRP's cap_link bounds the shuffle:
    candidates past the cap are dropped and counted in ``cand_overflow``.
    Dropped candidates can only LOSE matches (blocked pairs come from the
    pre-compaction mask), mirroring the paper's capacity/overflow
    accounting.

    Because XLA shapes are static, the expensive stage scores the WHOLE
    cand_cap buffer — cand_cap is therefore the FLOP *and memory* lever:
    cand_cap=0 (parity-safe default) keeps a full-band buffer, saving
    nothing on the expensive stage and gathering O(w*M*F) payload slices
    (vs the scan engine's O(M*F) live set — large w*M needs a finite cap);
    a finite cap sized above the survivor count (see DESIGN.md §6) gets
    the cascade cut with zero overflow."""

    def match_bound(self, ents: dict, cfg) -> Optional[int]:
        """Accepted matches are scattered from the cand_cap buffer, so a
        finite cand_cap bounds the match band's True count exactly — the
        emitted match index buffer never needs more slots (unless the
        cascade falls back to the scan oracle, where no such bound holds)."""
        cand_cap = cfg.cand_cap or 0   # None (unresolved auto) acts like 0
        if cand_cap > 0 and \
                split_cascade(cfg.matcher, ents["payload"]) is not None:
            return cand_cap
        return None

    def band(self, ents: dict, cfg, *, halo_len: int, mode: str) -> dict:
        from repro.kernels import ops

        split = split_cascade(cfg.matcher, ents["payload"])
        if split is None:     # no kernel-supported cheap stage
            return ScanBandEngine().band(ents, cfg, halo_len=halo_len,
                                         mode=mode)
        w = cfg.window
        valid = ents["valid"]
        m = valid.shape[0]
        payload = ents["payload"]
        mask = band_mask(valid, w, halo_len=halo_len, mode=mode,
                         src=self._src(ents, cfg),
                         weff=payload.get("_weff"))
        pruned = jnp.int32(0)
        if getattr(cfg, "prune_policy", "off") == "evidence":
            # prune BEFORE the gate: the blocked set itself shrinks (the
            # reduction-ratio lever), and the gate then only sees survivors
            mask, pruned = prune_low_evidence(payload, cfg.matcher, w, mask,
                                              cfg.prune_threshold)

        if cfg.band_interpret is None and ops.default_interpret():
            # auto mode off-TPU: band-shaped jnp cheap stage (the tile
            # kernel's 2*block_i scores per row only pay off on the MXU;
            # band_interpret=True still forces the interpreted kernel —
            # the kernel-validation path the parity tests exercise)
            cheap_rows = cheap_band_jnp(payload, split, w)  # (w-1, M)
        else:
            feat = payload[split.feat_field] if split.feat_field else \
                jnp.zeros((m, 1), jnp.float32)
            sig = payload[split.sig_field] if split.sig_field else \
                jnp.zeros((m, 1), jnp.uint32)
            cheap = ops.fused_cheap_band(
                feat, sig, window=w - 1, w_cos=split.w_cos,
                w_jac=split.w_jac, block_i=cfg.band_block,
                interpret=cfg.band_interpret)
            cheap_rows = cheap.T
        gate = (cheap_rows >= split.tau_partial) & mask     # (w-1, M)

        cand_cap = cfg.cand_cap or 0   # None (unresolved auto) acts like 0
        cap = cand_cap if cand_cap > 0 else (w - 1) * m
        cand_i, cand_d, cand_valid, n_cand, overflow = \
            compact_candidates(gate, cap)
        score = score_candidates(ents, cand_i, cand_d, cand_valid,
                                 cfg.matcher)
        accept = cand_valid & (score >= cfg.matcher.threshold)

        flat_idx = (cand_d - 1) * m + cand_i
        safe = jnp.where(cand_valid, flat_idx, (w - 1) * m)  # OOB -> dropped
        match = jnp.zeros(((w - 1) * m,), bool).at[safe].set(
            accept, mode="drop").reshape(w - 1, m)
        out = {"mask": mask, "match": match,
               # static shapes mean the expensive stage scores the whole
               # cand_cap buffer (invalid slots included) — report THAT,
               # not the survivor count: with cand_cap=0 the buffer is the
               # full band and there is no expensive-stage saving
               "matcher_evals": jnp.int32(cap),
               "cand_count": jnp.minimum(n_cand, cap).astype(jnp.int32),
               "cand_overflow": overflow.astype(jnp.int32),
               "pruned": pruned}
        if cfg.return_scores:
            # survivors carry their exact rescored value; gated-out slots are
            # 0 (they are sub-threshold by construction)
            out["scores"] = jnp.zeros(((w - 1) * m,), jnp.float32).at[
                safe].set(jnp.where(cand_valid, score, 0.0),
                          mode="drop").reshape(w - 1, m)
        return out
