"""JobSN — Sorted Neighborhood with an additional phase (paper §4.2).

Phase 1 = SRP + local sliding window (reduce also emits its first/last w-1
entities, keyed by boundary number).  Phase 2 = a second job that windows
each boundary group and filters pairs already produced in phase 1.

TPU mapping (DESIGN.md §2): the "second job" becomes a second collective
phase — boundary group i (= last w-1 of shard i ++ first w-1 of shard i+1)
is materialized on shard i by one *backward* collective-permute of the
successor's head.  The window then runs with mode="cross" (only pairs that
span the boundary — the paper's lineage-prefix filter).

The paper ran phase 2 with r=1 on Hadoop because boundary groups are tiny;
here every shard processes its own boundary in parallel.  The structural
difference vs RepSN that the paper measures (extra job-scheduling +
materialization vs inline replication) maps to: extra collective phase +
extra band compute vs halo prepend — compared in benchmarks/bench_jobsn_vs_repsn.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import entities as E
from repro.core.repsn import tail_window


def head_window(ents: dict, w: int, *, presorted: bool = False) -> dict:
    """First w-1 valid entities (sorted shards keep valid first, so this is a
    static slice; trailing slots may be invalid).  ``presorted=True`` skips
    the redundant (key, eid) sort for callers holding a post-SRP shard."""
    s = ents if presorted else E.sort_entities(ents)
    return E.slice_entities(s, 0, w - 1)


def boundary_group(sorted_ents: dict, w: int, r: int,
                   axis: str) -> Tuple[dict, int]:
    """Phase 2 input for this shard: [my_tail (w-1) | successor_head (w-1)].

    Shard r-1 has no successor: ppermute leaves its received head all-invalid
    (zero-filled), so its boundary band is empty.  Returns (group, halo_len)
    with halo_len = w-1 marking the boundary position for mode="cross"."""
    back = [(i, (i - 1) % r) for i in range(r)]
    head = head_window(sorted_ents, w, presorted=True)
    recv = jax.tree.map(lambda x: jax.lax.ppermute(x, axis, back), head)
    # full-ring permute (vmap requires completeness): drop the wrapped edge —
    # shard r-1 has no successor, so its received head is invalid.
    last = jax.lax.axis_index(axis) == r - 1
    recv["valid"] = recv["valid"] & ~last
    recv["key"] = jnp.where(recv["valid"], recv["key"], E.INVALID_KEY)
    tail = tail_window(sorted_ents, w, presorted=True)
    return E.concat(tail, recv), w - 1
