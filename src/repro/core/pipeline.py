"""End-to-end parallel entity-resolution pipeline (paper Figures 2/3).

   raw entity shards -> blocking key (map) -> SRP shuffle -> SN variant
   (srp | repsn | jobsn) -> banded window matching -> match pairs

``sn_shard`` is the per-shard program (named-axis collectives).  Runners:

  * ``run_vmap``       single device, shards on a vmapped named axis — used
                       by property tests and the skew benchmarks
  * ``run_shard_map``  real devices (multi-CPU subprocess / TPU mesh)

Both return the same artifact so the test oracle (sequential SN) applies to
either; ``extract_pairs`` converts band masks to host pair sets.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Dict, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import entities as E
from repro.core import jobsn as J
from repro.core import repsn as R
from repro.core import srp as S
from repro.core import window as W
from repro.core.match import CascadeMatcher, default_matcher


@dataclass(frozen=True)
class SNConfig:
    window: int = 10
    variant: str = "repsn"            # "srp" | "repsn" | "jobsn"
    hops: int = 1                      # halo hops (repsn; 1 = paper)
    cap_factor: float = 0.0           # link capacity = cap0*cap_factor/r;
                                       # 0 -> cap0 (never overflows)
    matcher: CascadeMatcher = field(default_factory=default_matcher)
    return_scores: bool = False        # band scores (B) vs match mask (M)


def sn_shard(ents: dict, bounds: jax.Array, r: int, axis: str,
             cfg: SNConfig) -> dict:
    """Per-shard SN program.  Returns a dict of per-shard outputs."""
    w = cfg.window
    cap0 = ents["key"].shape[0]
    cap_link = cap0 if cfg.cap_factor <= 0 else \
        max(1, int(np.ceil(cap0 * cfg.cap_factor / r)))
    sorted_ents, overflow = S.srp_shard(ents, bounds, r, axis, cap_link)
    load = S.local_load(sorted_ents, axis)

    def band(e, halo_len, mode):
        scores, mask = W.band_scores(e, w, cfg.matcher, halo_len=halo_len,
                                     mode=mode)
        match = (scores >= cfg.matcher.threshold) & mask
        out = {"mask": mask, "match": match}
        if cfg.return_scores:
            out["scores"] = scores
        return out

    out = {"overflow": overflow, "load": load}
    if cfg.variant == "srp":
        out["main"] = {"ents": sorted_ents, "halo_len": 0,
                       **band(sorted_ents, 0, "all")}
    elif cfg.variant == "repsn":
        combined, hl = R.repsn_combine(sorted_ents, w, r, axis,
                                       hops=cfg.hops)
        out["main"] = {"ents": combined, "halo_len": hl,
                       **band(combined, hl, "native")}
    elif cfg.variant == "jobsn":
        out["main"] = {"ents": sorted_ents, "halo_len": 0,
                       **band(sorted_ents, 0, "all")}
        group, hl = J.boundary_group(sorted_ents, w, r, axis)
        out["boundary"] = {"ents": group, "halo_len": hl,
                           **band(group, hl, "cross")}
    else:
        raise ValueError(cfg.variant)
    return out


# -- runners -------------------------------------------------------------------

def shard_input(ents: dict, r: int) -> dict:
    """Round-robin split into r mapper shards (paper: mappers scan disjoint
    input partitions), padded to equal capacity."""
    n = ents["key"].shape[0]
    cap0 = int(np.ceil(n / r))
    pad = r * cap0 - n
    padded = E.concat(ents, E.empty_like(ents, pad)) if pad else ents
    return jax.tree.map(
        lambda x: x.reshape((r, cap0) + x.shape[1:]), padded)


def run_vmap(ents: dict, r: int, bounds, cfg: SNConfig) -> dict:
    stacked = shard_input(ents, r)
    fn = partial(sn_shard, bounds=jnp.asarray(bounds, jnp.int32), r=r,
                 axis="sn", cfg=cfg)
    return jax.vmap(fn, axis_name="sn")(stacked)


def run_shard_map(ents: dict, mesh, axis: str, bounds,
                  cfg: SNConfig) -> dict:
    """Run on real devices: shards live on mesh axis ``axis``.  Output arrays
    carry a leading per-shard dim, exactly like run_vmap."""
    import warnings
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    r = mesh.shape[axis]
    stacked = shard_input(ents, r)
    fn = partial(sn_shard, bounds=jnp.asarray(bounds, jnp.int32), r=r,
                 axis=axis, cfg=cfg)

    def body(stacked_local):
        # stacked_local: (1, cap0, ...) — this shard's mapper partition
        local = jax.tree.map(lambda x: x[0], stacked_local)
        out = fn(local)
        return jax.tree.map(lambda x: jnp.expand_dims(x, 0), out)

    # out_specs from an abstract vmap pass (vmap binds the axis name so the
    # collectives trace; eval_shape alone would hit "unbound axis name")
    out_sds = jax.eval_shape(
        lambda st: jax.vmap(lambda l: fn(l), axis_name=axis)(st), stacked)
    out_specs = jax.tree.map(lambda _: P(axis), out_sds)
    out = shard_map(body, mesh=mesh,
                    in_specs=(jax.tree.map(lambda _: P(axis), stacked),),
                    out_specs=out_specs, check_rep=False)(stacked)
    return out


# -- host-side pair extraction ----------------------------------------------------

def extract_pairs(part: dict) -> Set[Tuple[int, int]]:
    """part: stacked per-shard output dict {'ents', 'match', ...} with leading
    shard dim.  Returns the global set of matched/blocked (eid, eid) pairs."""
    ents = jax.tree.map(np.asarray, part["ents"])
    band = np.asarray(part["match"])                  # (r, w-1, M)
    r, wm1, m = band.shape
    pairs = set()
    for s in range(r):
        eid = ents["eid"][s]
        ds, iis = np.nonzero(band[s])
        for d, i in zip(ds, iis):
            a, b = int(eid[i]), int(eid[i + d + 1])
            pairs.add((min(a, b), max(a, b)))
    return pairs


def result_pairs(out: dict) -> Set[Tuple[int, int]]:
    pairs = extract_pairs(out["main"])
    if "boundary" in out:
        pairs |= extract_pairs(out["boundary"])
    return pairs


def blocked_pairs(out: dict) -> Set[Tuple[int, int]]:
    """Pairs generated by BLOCKING (the band mask, pre-matching) — the paper
    reports B, the blocking correspondences (§4.1)."""
    def from_part(part):
        ents = jax.tree.map(np.asarray, part["ents"])
        band = np.asarray(part["mask"])
        pairs = set()
        for s in range(band.shape[0]):
            eid = ents["eid"][s]
            ds, iis = np.nonzero(band[s])
            for d, i in zip(ds, iis):
                a, b = int(eid[i]), int(eid[i + d + 1])
                pairs.add((min(a, b), max(a, b)))
        return pairs
    pairs = from_part(out["main"])
    if "boundary" in out:
        pairs |= from_part(out["boundary"])
    return pairs
