"""Data pipeline: deterministic synthetic corpus + SN-dedup integration.

The paper's technique is a *data-pipeline stage*: corpus deduplication before
LM training.  ``DedupPipeline`` runs documents through the distributed SN
blocking + matching workflow and yields a keep-mask; ``TokenBatcher`` then
serves deterministic, step-indexed token batches (resumable: batch(step) is a
pure function of (seed, step), so crash recovery replays exactly — see
train/loop.py fault handling).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import entities as E
from repro.core import keys as K
from repro.core import partition as P


# -- synthetic document corpus -----------------------------------------------------

def synth_corpus(seed: int, n_docs: int, *, doc_len: int = 64,
                 vocab: int = 1000, dup_frac: float = 0.25,
                 near_dup_noise: int = 2) -> np.ndarray:
    """Token documents (n_docs, doc_len) with planted near-duplicates."""
    rng = np.random.default_rng(seed)
    docs = rng.integers(1, vocab, size=(n_docs, doc_len), dtype=np.int32)
    n_dup = int(n_docs * dup_frac)
    src = rng.integers(0, n_docs, size=n_dup)
    dst = rng.integers(0, n_docs, size=n_dup)
    docs[dst] = docs[src]
    # near-duplicates: perturb a few tokens
    for d in dst[: n_dup // 2]:
        pos = rng.integers(0, doc_len, size=near_dup_noise)
        docs[d, pos] = rng.integers(1, vocab, size=near_dup_noise)
    return docs


def zipf_entities(seed: int, n: int, *, n_clusters: int = 256,
                  exponent: float = 1.1, dup_frac: float = 0.2,
                  cluster_width: int = 1, key_space: int = 1 << 20,
                  feat_dim: int = 32, sig_words: int = 8,
                  shuffle_clusters: bool = False) -> dict:
    """Skewed entity corpus: Zipfian sort-key clusters (the hot-key workload
    the repro.balance planners exist for).

    Cluster c (1-based rank) receives mass ∝ c^-exponent over ``n_clusters``
    clusters; exponent is unrestricted (>= 0), unlike numpy's ``zipf`` which
    needs a > 1.  Each cluster occupies ``cluster_width`` adjacent sort keys
    (1 = a single hot key, exercising mid-block splits), and clusters sit in
    rank order along the key space — hot keys contiguous at the low end, the
    shape that breaks uniform range partitioning hardest (real sort keys
    cluster the same way: surname prefixes, timestamps, geo codes).  Set
    ``shuffle_clusters`` for scattered hot keys instead.

    ``dup_frac`` of the entities are planted near-duplicates (same key,
    near-identical payload) so matchers find real matches, mirroring
    ``entities.synth_entities``.
    """
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, n_clusters + 1, dtype=np.float64)
    p = ranks ** -float(exponent)
    p /= p.sum()
    cluster = rng.choice(n_clusters, size=n, p=p)
    order = rng.permutation(n_clusters) if shuffle_clusters \
        else np.arange(n_clusters)
    stride = max(key_space // n_clusters, cluster_width)
    keys = (order[cluster] * stride
            + rng.integers(0, cluster_width, size=n)).astype(np.int32)
    feat = rng.normal(size=(n, feat_dim)).astype(np.float32)
    sig = rng.integers(0, 2 ** 32, size=(n, sig_words),
                       dtype=np.uint64).astype(np.uint32)
    n_dup = int(n * dup_frac)
    if n_dup:
        src = rng.integers(0, n, size=n_dup)
        dst = rng.integers(0, n, size=n_dup)
        keys[dst] = keys[src]
        feat[dst] = feat[src] + 0.01 * rng.normal(
            size=(n_dup, feat_dim)).astype(np.float32)
        sig[dst] = sig[src]
    feat /= np.linalg.norm(feat, axis=1, keepdims=True) + 1e-9
    return E.make_entities(
        keys, np.arange(n, dtype=np.int32),
        payload={"feat": jnp.asarray(feat), "sig": jnp.asarray(sig)})


def synth_entity_chunks(seed: int, n: int, chunk: int, *,
                        n_keys: int = 1000, sig_words: int = 8,
                        feat_dim: int = 32, dup_frac: float = 0.2,
                        skew: float = 0.0,
                        text_len: int = 0) -> Iterator[dict]:
    """Chunked ``entities.synth_entities``: the out-of-core corpus source
    for ``repro.stream`` (yields ceil(n / chunk) entity chunks, generated
    one at a time — nothing larger than ``chunk`` is ever materialized).

    Eids are globally unique (chunk c owns ``[c*chunk, c*chunk+len)``);
    duplicates are planted WITHIN each chunk (near-identical payloads),
    while cross-chunk near-neighbors arise from the shared key space —
    exactly the layout an external sort has to repair."""
    from repro.core import entities as E
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    rng = np.random.default_rng(seed)
    for start in range(0, n, chunk):
        size = min(chunk, n - start)
        ents = E.synth_entities(rng, size, n_keys=n_keys,
                                sig_words=sig_words, feat_dim=feat_dim,
                                dup_frac=dup_frac, skew=skew,
                                text_len=text_len)
        ents["eid"] = jnp.asarray(
            np.arange(start, start + size, dtype=np.int32))
        yield ents


def zipf_entity_chunks(seed: int, n: int, chunk: int, *,
                       n_clusters: int = 256, exponent: float = 1.1,
                       dup_frac: float = 0.2, cluster_width: int = 1,
                       key_space: int = 1 << 20, feat_dim: int = 32,
                       sig_words: int = 8) -> Iterator[dict]:
    """Chunked ``zipf_entities``: the skewed out-of-core corpus (hot-key
    clusters in every chunk) that exercises the streaming per-chunk
    planning hook.  Eids are globally unique, one chunk at a time."""
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    for i, start in enumerate(range(0, n, chunk)):
        size = min(chunk, n - start)
        ents = zipf_entities(seed + i, size, n_clusters=n_clusters,
                             exponent=exponent, dup_frac=dup_frac,
                             cluster_width=cluster_width,
                             key_space=key_space, feat_dim=feat_dim,
                             sig_words=sig_words)
        ents["eid"] = jnp.asarray(
            np.arange(start, start + size, dtype=np.int32))
        yield ents


def doc_entities(docs: np.ndarray, *, sig_words: int = 8,
                 feat_dim: int = 64) -> dict:
    """Documents -> entity records: blocking key from the leading tokens,
    minhash-style bit signature + mean-pooled hashed features as payload."""
    n, L = docs.shape
    # blocking key: first two tokens folded into <2^30 (the 'title prefix')
    key = (docs[:, 0].astype(np.int64) * 1009 + docs[:, 1]) % (1 << 24)
    rng = np.random.default_rng(0)
    proj = rng.normal(size=(1024, feat_dim)).astype(np.float32) / 8.0
    feat = proj[docs.astype(np.int64) % 1024].mean(axis=1)
    feat /= np.linalg.norm(feat, axis=1, keepdims=True) + 1e-9
    # token-set bit signature
    bits = (docs.astype(np.int64) * 2654435761 % (sig_words * 32)).astype(
        np.int64)
    sig = np.zeros((n, sig_words), np.uint32)
    rows = np.repeat(np.arange(n), L)
    w = bits.reshape(-1) // 32
    b = bits.reshape(-1) % 32
    np.bitwise_or.at(sig, (rows, w), (1 << b.astype(np.uint32)))
    return E.make_entities(
        key.astype(np.int32), np.arange(n, dtype=np.int32),
        payload={"feat": jnp.asarray(feat), "sig": jnp.asarray(sig)})


@dataclass
class DedupResult:
    keep: np.ndarray                 # (n_docs,) bool
    n_pairs: int
    n_dropped: int
    gini: float
    overflow: int


def dedup_corpus(docs: np.ndarray, *, r: int = 4, window: int = 10,
                 variant: str = "repsn", threshold: float = 0.9,
                 balance: bool = True) -> DedupResult:
    """The paper's workflow as a corpus-dedup stage.  Keeps the lowest-eid
    member of every matched pair (union-find-free greedy: drop the higher)."""
    from repro import api
    ents = doc_entities(docs)
    keys_np = np.asarray(ents["key"])
    bounds = P.balanced_partition(keys_np, r) if balance else \
        P.range_partition(1 << 24, r)
    from dataclasses import replace
    from repro.core.match import default_matcher
    matcher = replace(default_matcher(), threshold=threshold)
    cfg = api.ERConfig(window=window, variant=variant, matcher=matcher,
                       runner="vmap", num_shards=r)
    res = api.resolve(ents, cfg, bounds=bounds)
    keep = np.ones(docs.shape[0], bool)
    for a, b in sorted(res.matches):
        if keep[a]:
            keep[b] = False
    sizes = np.asarray(P.partition_sizes(bounds, ents["key"], r=r))
    return DedupResult(keep=keep, n_pairs=len(res.matches),
                       n_dropped=int((~keep).sum()),
                       gini=P.gini(sizes), overflow=res.blocking.overflow)


# -- deterministic token batcher ----------------------------------------------------

@dataclass
class TokenBatcher:
    """batch(step) is a pure function of (seed, step): crash recovery replays
    the exact data order (fault tolerance requires deterministic data)."""
    docs: np.ndarray                  # (n_docs, L) post-dedup
    seq_len: int
    global_batch: int
    seed: int = 0

    def __post_init__(self):
        flat = self.docs.reshape(-1)
        n_tok = (flat.shape[0] // self.seq_len) * self.seq_len
        self.stream = flat[:n_tok].reshape(-1, self.seq_len)

    @property
    def n_sequences(self) -> int:
        return self.stream.shape[0]

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed << 20) ^ step)
        idx = rng.integers(0, self.n_sequences, size=self.global_batch)
        toks = self.stream[idx].astype(np.int32)
        labels = np.concatenate(
            [toks[:, 1:], np.full((toks.shape[0], 1), -1, np.int32)], axis=1)
        return {"tokens": toks, "labels": labels}
