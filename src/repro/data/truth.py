"""Labeled corpus generator — entities with KNOWN duplicate clusters.

Everything else in ``repro.data`` plants duplicates and forgets where they
went; quality measurement needs the opposite: a corpus whose complete gold
pair set is known by construction.  ``labeled_corpus`` builds one
deterministically from a seed (DESIGN.md §14):

  * entities are generated in UNITS: singletons and duplicate clusters of
    size 2..max_cluster, cluster sizes drawn with P(s) ∝ s^-size_skew (the
    skew knob: higher = big clusters rarer); one max_cluster-sized cluster
    is always planted so the tail exists at every seed;
  * each unit owns a distinct blocking key, so a cluster of size c is a
    key block of density c — exactly the signal ``window_policy="adaptive"``
    reads (weff grows to c where fixed w < c misses the block's far pairs);
  * ``typo_rate`` corrupts the KEY of cluster members (never member 0 — a
    cluster is never fully lost): the classic dirty-key failure a single
    blocking pass cannot recover.  The ``alt`` payload field carries each
    unit's uncorrupted secondary key, so a multi-pass run with an
    ``identity``-on-``alt`` pass wins back exactly those pairs;
  * payloads follow the repo's matcher schema (unit-norm ``feat`` float32,
    bit-signature ``sig`` uint32): cluster members share a signature and a
    lightly-noised feature vector, so ``default_matcher`` scores duplicates
    ≈1.0 and random pairs ≈0.5 — the separation the pruning lever
    (``prune_policy="evidence"``) needs.

Gold pairs are all intra-cluster pairs, returned both as a frozenset of
(lo, hi) eid tuples and packed uint64 (``(lo << 32) | hi``, the repo-wide
set-algebra representation ``repro.quality.evaluate`` consumes).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import entities as E


@dataclass(frozen=True)
class TruthCorpus:
    """A labeled corpus: entities + their complete gold duplicate pair set.

    ents         entity dict (key/eid/valid/payload with feat, sig, alt)
    gold         frozenset of (lo, hi) gold eid pairs (all intra-cluster)
    gold_packed  the same pairs packed uint64, sorted unique
    n            entity count
    n_units      generated units (clusters + singletons)
    max_cluster  largest planted cluster size
    max_block    largest key-block density (== max_cluster here: one unit
                 per key) — PC is 1.0 for any boundary-complete fixed-w run
                 with w >= max_block when typo_rate == 0
    n_typos      cluster members whose key was corrupted
    """
    ents: dict
    gold: FrozenSet[Tuple[int, int]]
    gold_packed: np.ndarray
    n: int
    n_units: int
    max_cluster: int
    max_block: int
    n_typos: int


def labeled_corpus(seed: int, n: int, *, max_cluster: int = 12,
                   cluster_rate: float = 0.35, size_skew: float = 1.0,
                   typo_rate: float = 0.0, feat_dim: int = 32,
                   sig_words: int = 8,
                   key_space: int = 1 << 20) -> TruthCorpus:
    """Deterministic labeled corpus of ``n`` entities (see module doc).

    ``cluster_rate`` is the probability each new unit is a cluster (vs a
    singleton); ``size_skew`` shapes the cluster-size distribution
    P(s) ∝ s^-size_skew over 2..max_cluster."""
    if max_cluster < 2:
        raise ValueError(f"max_cluster must be >= 2, got {max_cluster}")
    if not 0.0 <= typo_rate < 1.0:
        raise ValueError(f"typo_rate must be in [0, 1), got {typo_rate}")
    rng = np.random.default_rng(seed)

    sizes_choices = np.arange(2, max_cluster + 1)
    size_p = sizes_choices.astype(np.float64) ** -float(size_skew)
    size_p /= size_p.sum()

    unit_sizes = []
    pos = 0
    while pos < n:
        room = n - pos
        if not unit_sizes and room >= max_cluster:
            s = max_cluster                       # the tail always exists
        elif room >= 2 and rng.random() < cluster_rate:
            s = min(int(rng.choice(sizes_choices, p=size_p)), room)
        else:
            s = 1
        unit_sizes.append(s)
        pos += s
    n_units = len(unit_sizes)

    stride = max(key_space // (n_units + 2), 2)
    keys = np.empty(n, np.int64)
    alt = np.empty(n, np.int32)
    feat = np.empty((n, feat_dim), np.float32)
    sig = np.empty((n, sig_words), np.uint32)
    unit_pos = []                                 # member positions per unit
    n_typos = 0
    pos = 0
    for u, s in enumerate(unit_sizes):
        ps = np.arange(pos, pos + s)
        unit_pos.append(ps)
        keys[ps] = (u + 1) * stride
        alt[ps] = u
        base = rng.normal(size=feat_dim).astype(np.float32)
        usig = rng.integers(0, 2 ** 32, size=sig_words,
                            dtype=np.uint64).astype(np.uint32)
        if s == 1:
            feat[ps] = base
            sig[ps] = rng.integers(0, 2 ** 32, size=sig_words,
                                   dtype=np.uint64).astype(np.uint32)
        else:
            feat[ps] = base[None, :] + 0.01 * rng.normal(
                size=(s, feat_dim)).astype(np.float32)
            sig[ps] = usig[None, :]
            if typo_rate:
                # corrupt keys of members 1.. (member 0 keeps the true key)
                bad = ps[1:][rng.random(s - 1) < typo_rate]
                keys[bad] = (rng.integers(1, n_units + 1, size=bad.size)
                             * stride
                             + rng.integers(1, stride, size=bad.size))
                n_typos += int(bad.size)
        pos += s
    feat /= np.linalg.norm(feat, axis=1, keepdims=True) + 1e-9

    perm = rng.permutation(n)                     # eid != generation order
    inv = np.argsort(perm)                        # original pos -> eid
    ents = E.make_entities(
        keys[perm].astype(np.int32), np.arange(n, dtype=np.int32),
        payload={"feat": jnp.asarray(feat[perm]),
                 "sig": jnp.asarray(sig[perm]),
                 "alt": jnp.asarray(alt[perm], jnp.int32)})

    gold = set()
    for ps in unit_pos:
        if ps.size < 2:
            continue
        eids = np.sort(inv[ps])
        for a in range(eids.size):
            for b in range(a + 1, eids.size):
                gold.add((int(eids[a]), int(eids[b])))
    if gold:
        arr = np.asarray(sorted(gold), np.uint64)
        gold_packed = np.unique((arr[:, 0] << np.uint64(32)) | arr[:, 1])
    else:
        gold_packed = np.empty((0,), np.uint64)
    return TruthCorpus(ents=ents, gold=frozenset(gold),
                       gold_packed=gold_packed, n=n, n_units=n_units,
                       max_cluster=max_cluster,
                       max_block=max(unit_sizes), n_typos=n_typos)
