"""The paper-family "analysis job": profile the sort-key distribution.

Kolb, Thor & Rahm (arXiv:1108.1631) precede BlockSplit/PairRange with a
lightweight MapReduce analysis job that counts entities per blocking key;
here that job is a single device pass over the sort keys — sort + marginal
comparison counts + cumulative sums run as JAX ops (the O(n log n) work),
and only the O(K) unique-key block structure is gathered to the host.

The resulting ``KeyProfile`` is everything a partition planner needs:

  * per-block (unique-key) entity counts and cumulative entity counts —
    candidate shard boundaries can only fall at block edges (key-bounds
    plans) or at explicit ranks inside a block (split plans);
  * window-induced comparison counts per block and cumulatively — the cost
    model (``window.rank_prefix_comparisons``) assigns the comparison for
    pair (i-d, i) to the later rank i, so contiguous rank ranges have exact
    closed-form costs;
  * the replication/halo cost of placing a boundary after each block: the
    min(rank, w-1) predecessor entities RepSN would replicate across it.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import window as W


@dataclass(frozen=True)
class KeyProfile:
    """Key-distribution profile of one entity set under window ``window``.

    All arrays are host numpy, indexed by sorted unique key ("block"):

      uniq               (K,) int64  sorted unique sort keys
      counts             (K,) int64  entities per key
      cum_entities       (K,) int64  inclusive cumulative entity counts
      block_comparisons  (K,) int64  window comparisons owned by the block
      cum_comparisons    (K,) int64  inclusive cumulative comparisons

    ``halo_cost`` (a property, derived from cum_entities) is the
    replication cost of each candidate boundary: the min(rank, w-1)
    predecessor entities RepSN would copy across a boundary placed after
    that block (``planners._plan_stats`` applies the same formula at rank
    granularity for split boundaries).
    """
    n: int
    window: int
    uniq: np.ndarray
    counts: np.ndarray
    cum_entities: np.ndarray
    block_comparisons: np.ndarray
    cum_comparisons: np.ndarray

    @property
    def halo_cost(self) -> np.ndarray:
        """(K,) replication cost of a boundary placed after each block: the
        min(rank, w−1) predecessors RepSN would copy across it."""
        return np.minimum(self.cum_entities, self.window - 1)

    @property
    def n_blocks(self) -> int:
        """Number of unique-key blocks (K)."""
        return int(self.uniq.shape[0])

    @property
    def total_comparisons(self) -> int:
        """Total SN window comparisons over the whole profiled key set."""
        return int(self.cum_comparisons[-1]) if self.n_blocks else 0

    def comparisons_in_rank_range(self, lo, hi) -> np.ndarray:
        """Exact window comparisons owned by sorted ranks in [lo, hi)."""
        return (W.rank_prefix_comparisons(hi, self.window)
                - W.rank_prefix_comparisons(lo, self.window))

    def rank_after_key(self, key_bounds: np.ndarray) -> np.ndarray:
        """For each inclusive key upper bound, the number of entities with
        key <= bound — the rank-space boundary a key-bounds plan induces."""
        idx = np.searchsorted(self.uniq, np.asarray(key_bounds, np.int64),
                              side="right")
        cum = np.concatenate([[0], self.cum_entities])
        return cum[idx]

    def key_at_rank(self, rank) -> np.ndarray:
        """Sort key of the entity at 0-based sorted rank (clipped)."""
        r = np.clip(np.asarray(rank, np.int64), 0, max(self.n - 1, 0))
        idx = np.searchsorted(self.cum_entities, r, side="right")
        return self.uniq[np.minimum(idx, self.n_blocks - 1)]

    def merge(self, other: "KeyProfile", *,
              remove: bool = False) -> "KeyProfile":
        """Combine two profiles into the profile of the CONCATENATED key
        sets — the incremental accumulator of the streaming analysis job
        (``repro.stream`` profiles each ingested chunk on device and folds
        the results, so planning sees the full corpus without ever holding
        it).

        Exact, not approximate: per-key counts are additive, and every
        derived column (cum_entities, comparison counts) is a closed-form
        function of the merged counts via ``window.rank_prefix_comparisons``
        — ``a.merge(b)`` equals ``profile_keys(concat(a_keys, b_keys))``
        bit-for-bit.  Windows must match; merging with an empty profile is
        the identity.

        ``remove=True`` is the delete path of the serving layer
        (``repro.serve``): ``other``'s counts are subtracted exactly —
        ``a.merge(b).merge(b, remove=True)`` equals ``a`` bit-for-bit, so
        planner cost models stay truthful under deletes.  Removing keys the
        profile does not hold (or more copies than it holds) raises."""
        if self.window != other.window:
            raise ValueError(
                f"cannot merge profiles with different windows "
                f"({self.window} vs {other.window})")
        if other.n == 0:
            return self
        if not remove and self.n == 0:
            return other
        sign = -1 if remove else 1
        allk = np.concatenate([self.uniq, other.uniq])
        allc = np.concatenate([self.counts, sign * other.counts])
        uniq, inv = np.unique(allk, return_inverse=True)
        counts = np.zeros(uniq.shape[0], np.int64)
        np.add.at(counts, inv, allc)
        if remove:
            if counts.min(initial=0) < 0:
                bad = uniq[counts < 0][:8]
                raise ValueError(
                    f"cannot remove keys the profile does not hold "
                    f"(over-removed keys, first few: {bad.tolist()})")
            keep = counts > 0                  # reclaim emptied key blocks
            uniq, counts = uniq[keep], counts[keep]
        cum_entities = np.cumsum(counts)
        cum_comparisons = np.asarray(
            W.rank_prefix_comparisons(cum_entities, self.window), np.int64)
        block_comparisons = np.diff(np.concatenate([[0], cum_comparisons]))
        return KeyProfile(n=self.n + sign * other.n, window=self.window,
                          uniq=uniq, counts=counts,
                          cum_entities=cum_entities,
                          block_comparisons=block_comparisons,
                          cum_comparisons=cum_comparisons)

    @classmethod
    def empty(cls, window: int) -> "KeyProfile":
        """The merge identity: a profile of zero keys under ``window``."""
        z = np.zeros((0,), np.int64)
        return cls(n=0, window=window, uniq=z, counts=z, cum_entities=z,
                   block_comparisons=z, cum_comparisons=z)


def profile_keys(keys, *, window: int, valid=None) -> KeyProfile:
    """Run the analysis job over ``keys`` (valid entries only).

    The sort and cumulative comparison sums run as JAX ops; the unique-key
    block gather (data-dependent K) happens on host.
    """
    keys = np.asarray(keys)
    if valid is not None:
        keys = keys[np.asarray(valid)]
    n = int(keys.shape[0])
    empty = np.zeros((0,), np.int64)
    if n == 0:
        return KeyProfile(n=0, window=window, uniq=empty, counts=empty,
                          cum_entities=empty, block_comparisons=empty,
                          cum_comparisons=empty)
    # keys are int32 by schema (entities.py: packed, < 2^30); the sort is the
    # O(n log n) device part of the analysis job
    sk = np.asarray(jnp.sort(jnp.asarray(keys, jnp.int32))).astype(np.int64)
    # block (unique-key) end positions in the sorted order
    is_end = np.concatenate([sk[1:] != sk[:-1], [True]])
    end = np.flatnonzero(is_end)                         # (K,) last rank/block
    cum_entities = end + 1
    counts = np.diff(np.concatenate([[0], cum_entities]))
    cum_comparisons = np.asarray(
        W.rank_prefix_comparisons(cum_entities, window), np.int64)
    block_comparisons = np.diff(np.concatenate([[0], cum_comparisons]))
    return KeyProfile(n=n, window=window, uniq=sk[end].copy(), counts=counts,
                      cum_entities=cum_entities,
                      block_comparisons=block_comparisons,
                      cum_comparisons=cum_comparisons)
