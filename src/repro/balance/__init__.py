"""``repro.balance`` — skew-aware load balancing for parallel SN.

The three-layer subsystem of ISSUE 3 (after Kolb, Thor & Rahm,
arXiv:1108.1631, adapted to sorted-neighborhood contiguity and static-shape
shard programs):

  1. **profile** — the "analysis job": one device pass over the sort keys
     producing a ``KeyProfile`` (per-key-block entity counts, window-induced
     comparison counts, halo/replication cost per candidate boundary).
  2. **plan** — ``Partitioner`` strategies (uniform | blocksplit |
     pairrange, plus the legacy balanced | range | sample) turn a profile
     into a ``ShardPlan``: shard boundaries (key bounds or rank-granular
     per-entity routing for split key blocks), planned per-shard loads /
     comparison counts, and exact padded capacities.
  3. **execute** — every runner accepts a ShardPlan wherever it accepts raw
     bounds (``repro.api.resolve`` builds one from ``ERConfig.partitioner``
     automatically), and results report planned vs realized load via
     ``ERResult.balance``.

    from repro import api, balance
    plan = balance.plan_shards(ents, cfg, r=8)
    plan.imbalance                  # planned max/mean comparison ratio
    api.resolve(ents, cfg, bounds=plan)
"""
from repro.balance.capacity import CapSuggestion, suggest_caps
from repro.balance.planners import (LEGACY_PARTITIONERS, Partitioner,
                                    ShardPlan, as_plan,
                                    available_partitioners, get_partitioner,
                                    imbalance_ratio, plan_from_profile,
                                    plan_shards, realized_comparisons,
                                    register_partitioner, validate_plan)
from repro.balance.profile import KeyProfile, profile_keys

__all__ = [
    "KeyProfile", "profile_keys",
    "ShardPlan", "Partitioner", "plan_shards", "plan_from_profile",
    "as_plan", "validate_plan",
    "register_partitioner", "get_partitioner", "available_partitioners",
    "imbalance_ratio", "realized_comparisons",
    "CapSuggestion", "suggest_caps",
    "LEGACY_PARTITIONERS",
]
