"""Capacity auto-sizing: KeyProfile -> band/pair buffer capacities.

The static-shape shard programs carry three capacity knobs whose sizing
used to be a manual probe loop in ``benchmarks/bench_sn.band_engine_body``
(resolve once unbounded, read the result counters, multiply by 1.25):

  cand_cap   per-shard survivor buffer of the pallas cascade compaction
             (overflow loses MATCHES, never blocked pairs)
  pair_cap   per-shard emitted-index buffer under ``emit="pairs"``
             (overflow loses BLOCKED pairs — must be a hard bound)
  cap_link   the SRP shuffle bucket capacity (planned exactly by
             ``plan_shards``; reported here for completeness)

``suggest_caps`` derives all of them from a ``KeyProfile`` alone: the
planned per-shard loads of ``plan_from_profile`` bound every band buffer —
a shard holding L entities (plus its w-1 halo) owns at most (w-1)*(L+w-1)
band slots, so capacities sized from the planned maximum load can never
overflow.  ``observed_cand`` optionally tightens ``cand_cap`` from measured
gate-survivor counts (the DESIGN.md §6 rule: ~1.25x the busiest shard) —
the FLOP lever the hard bound intentionally leaves on the table.

Used by the serving layer (``repro.serve`` sizes its delta-call buffers so
steady-state parity is capacity-independent) and by the bench bodies.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro.balance.planners import plan_from_profile
from repro.balance.profile import KeyProfile

# deterministic headroom on top of the exact bounds: keeps caps stable when
# a profile is re-derived with tiny count jitter (and mirrors the slack the
# old manual probe loop added)
_SLACK = 16


class CapSuggestion(NamedTuple):
    """Derived capacities for one (profile, cfg, r) combination.

    ``max_load`` is the planned busiest-shard entity count INCLUDING the
    w-1 halo — the quantity every band buffer scales with."""
    cand_cap: int
    pair_cap: int
    max_load: int


def suggest_caps(profile: KeyProfile, cfg, r: Optional[int] = None, *,
                 max_load: Optional[int] = None,
                 observed_cand: Optional[Sequence[int]] = None
                 ) -> CapSuggestion:
    """Derive ``cand_cap``/``pair_cap`` from a ``KeyProfile`` (see module
    doc).  ``r`` defaults to ``cfg.num_shards``; ``max_load`` overrides the
    planned busiest-shard load (the serving layer passes its padded region
    capacity directly); ``observed_cand`` — per-shard gate-survivor counts
    from a probe resolve — tightens ``cand_cap`` to ~1.25x the busiest
    shard instead of the never-overflows band bound."""
    w = cfg.window
    if r is None:
        r = cfg.num_shards
    if max_load is None:
        if profile.n == 0:
            raise ValueError("cannot size capacities from an empty profile; "
                             "pass max_load explicitly")
        plan = plan_from_profile(profile, cfg.partitioner, r)
        # every shard's band covers its owned entities plus the w-1 halo
        # slots a halo-slicing variant prepends
        max_load = int(np.max(plan.planned_load)) + (w - 1)
    band_bound = (w - 1) * int(max_load) + _SLACK
    if observed_cand is not None and len(observed_cand) > 0:
        cand_cap = min(int(max(observed_cand) * 1.25) + _SLACK, band_bound)
    else:
        cand_cap = band_bound
    return CapSuggestion(cand_cap=cand_cap, pair_cap=band_bound,
                         max_load=int(max_load))
