"""Partition planners: key profile -> ShardPlan (boundaries + capacities).

The skew problem (Kolb, Thor & Rahm, arXiv:1108.1631): wall-clock of a
parallel SN run is the MAX of per-shard matcher work, and with static-shape
shard programs every shard even PAYS the max (the band is evaluated over the
padded capacity).  A planner therefore decides three things from the
``KeyProfile``: where the shard boundaries fall, whether any oversized key
block must be split across shards at rank granularity, and how large the
padded per-shard capacity (``cap_link``) must be so nothing overflows.

Planners registered here (``ERConfig.partitioner``):

  uniform     even KEY-SPACE split — the skew-fragile baseline (the paper's
              Even8/Even10 ranges, extracted from ``partition.range_partition``)
  blocksplit  greedy walk over key blocks balancing COMPARISON counts;
              boundaries snap to block edges, and only blocks larger than a
              shard's fair share are split mid-block (Kolb's BlockSplit
              adapted to sorted-neighborhood contiguity: shards must own
              contiguous sorted rank ranges for the window band + halo to
              stay correct)
  pairrange   exact equal division of the global SN pair space: boundary
              ranks at comparison-count quantiles via the closed-form
              inverse cost model (Kolb's PairRange adapted from per-pair MR
              tasks to static-shape shard programs)

Legacy names (balanced | range | sample) keep their exact historical
boundary behavior and planned-capacity semantics (cap from ``cap_factor``).

A ``ShardPlan`` is consumed by every runner in place of raw bounds: rank-
granular plans carry a per-entity ``dest`` that overrides the key->shard
partition function inside ``srp.srp_shard`` (monotone in sorted rank, so
sorted-reduce-partition semantics, halo exchange, and boundary windows all
hold unchanged), and ``cap_link`` feeds the variants' padded capacities.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional, Tuple, Type

import numpy as np

from repro.balance.profile import KeyProfile, profile_keys
from repro.core import partition as P
from repro.core import window as W

LEGACY_PARTITIONERS = ("balanced", "range", "sample")


@dataclass(frozen=True)
class ShardPlan:
    """A planned partitioning of one entity set into ``num_shards`` shards.

    bounds        (r-1,) int32  inclusive key upper bounds (the legacy view;
                  for rank-granular plans these are telemetry — the device
                  routes by ``dest``)
    rank_bounds   (r-1,) int64  boundary ranks in the global (key, eid) sort:
                  shard s owns ranks [rank_bounds[s-1], rank_bounds[s])
                  (None for explicit-bounds plans without a profile)
    dest          (N,) int32    per-entity shard assignment aligned with the
                  ORIGINAL entity order (None: route by key via ``bounds``).
                  Present only when a boundary falls inside a key block.
    planned_load / planned_comparisons / halo   (r,) int64 per-shard entity
                  counts, window-comparison counts, and replicated (halo)
                  entities received, under the plan (None without a profile)
    cap_link      planned per-(mapper, destination) bucket capacity for the
                  SRP shuffle — exact (no overflow) and halo-legal
                  (r*cap_link >= w-1).  None -> derive from cfg.cap_factor.
    rank_granular True when some boundary falls INSIDE a key block, so
                  routing by key bounds alone would be wrong: entities must
                  be assigned by sorted rank (``dest`` when present, or the
                  caller's own rank bookkeeping — ``repro.stream`` routes
                  chunks of the globally merged stream by rank against
                  ``rank_bounds``).
    """
    partitioner: str
    num_shards: int
    bounds: np.ndarray
    rank_bounds: Optional[np.ndarray] = None
    dest: Optional[np.ndarray] = None
    planned_load: Optional[np.ndarray] = None
    planned_comparisons: Optional[np.ndarray] = None
    halo: Optional[np.ndarray] = None
    cap_link: Optional[int] = None
    rank_granular: bool = False

    @property
    def imbalance(self) -> float:
        """max/mean of planned per-shard comparison counts (1.0 = perfectly
        level; wall-clock scales with max while resources scale with mean)."""
        if self.planned_comparisons is None:
            return float("nan")
        return imbalance_ratio(self.planned_comparisons)

    @property
    def straggler(self) -> int:
        """Shard id with the largest planned comparison count."""
        if self.planned_comparisons is None:
            return 0
        return int(np.argmax(self.planned_comparisons))

    def assignment(self, keys: np.ndarray,
                   valid: Optional[np.ndarray] = None) -> np.ndarray:
        """Per-entity shard ids in the ORIGINAL entity order (valid-filtered
        when ``valid`` is given)."""
        if self.dest is not None:
            d = np.asarray(self.dest)
            return d[np.asarray(valid)] if valid is not None else d
        if self.rank_granular:
            raise ValueError(
                "rank-granular plan carries no per-entity dest: assignment "
                "must be derived from sorted ranks against rank_bounds "
                "(plan_shards attaches dest; repro.stream routes by global "
                "rank)")
        keys = np.asarray(keys)
        if valid is not None:
            keys = keys[np.asarray(valid)]
        return np.searchsorted(np.asarray(self.bounds), keys,
                               side="left").astype(np.int32)


def imbalance_ratio(comparisons) -> float:
    """max/mean of per-shard comparison counts (1.0 = perfectly level) —
    THE skew figure of merit: wall-clock scales with the max while paid
    resources scale with the mean."""
    c = np.asarray(comparisons, np.float64)
    mean = c.mean() if c.size else 0.0
    return float(c.max() / mean) if mean > 0 else 1.0


def realized_comparisons(load, window: int) -> np.ndarray:
    """Per-shard window comparison counts induced by realized per-shard
    valid counts: shards own contiguous sorted rank ranges, so the realized
    rank layout is the cumulative load run through the window cost model."""
    offs = np.concatenate([[0], np.cumsum(np.asarray(load, np.int64))])
    return np.asarray(W.rank_prefix_comparisons(offs[1:], window)
                      - W.rank_prefix_comparisons(offs[:-1], window),
                      np.int64)


def as_plan(bounds_or_plan) -> ShardPlan:
    """Normalize a runner's ``bounds`` argument: pass ShardPlans through,
    wrap raw boundary arrays in a stats-free explicit plan (legacy capacity
    semantics, no dest).  ``num_shards`` always derives from the plan/array
    itself, so shard-count mismatches stay detectable downstream."""
    if isinstance(bounds_or_plan, ShardPlan):
        return bounds_or_plan
    b = np.asarray(bounds_or_plan).astype(np.int32).reshape(-1)
    return ShardPlan(partitioner="explicit",
                     num_shards=int(b.shape[0]) + 1, bounds=b)


# -- planner registry ---------------------------------------------------------------

_PLANNERS: Dict[str, Type["Partitioner"]] = {}


def register_partitioner(name: str):
    """Class decorator: ``@register_partitioner("blocksplit")``."""
    def deco(cls):
        cls.name = name
        _PLANNERS[name] = cls
        return cls
    return deco


def get_partitioner(name: str) -> "Partitioner":
    """Instantiate the registered partition planner named ``name`` (raises
    ``ValueError`` listing registry + legacy names when unknown)."""
    try:
        return _PLANNERS[name]()
    except KeyError:
        raise ValueError(
            f"unknown partition planner {name!r}; registered: "
            f"{available_partitioners()} (legacy: {LEGACY_PARTITIONERS})"
        ) from None


def available_partitioners() -> Tuple[str, ...]:
    """Sorted names of every registered partition planner (legacy names —
    balanced | range | sample — live outside the registry)."""
    return tuple(sorted(_PLANNERS))


class Partitioner:
    """One boundary-selection strategy.  ``boundary_ranks(profile, r)``
    returns (rank_bounds (r-1,) int64, key_bounds (r-1,) int64 | None):
    nondecreasing boundary ranks in the global sorted order, plus — when
    every boundary sits on a key-block edge — the equivalent inclusive key
    upper bounds.  ``key_bounds=None`` marks rank-granular plans (a boundary
    inside a key block): those route entities by explicit per-entity
    destination instead of the key->shard partition function."""

    name = "?"

    def boundary_ranks(self, profile: KeyProfile,
                       r: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """Choose the r−1 shard boundaries for ``profile`` (see class doc
        for the (rank_bounds, key_bounds | None) contract)."""
        raise NotImplementedError


@register_partitioner("uniform")
class UniformPartitioner(Partitioner):
    """Even key-space ranges over the observed key extent (paper Even8/10):
    the baseline every balance benchmark measures skew against."""

    def boundary_ranks(self, profile, r):
        """Even key-space boundaries over [min key, max key]; always on
        block edges, so key_bounds are returned alongside the ranks."""
        lo, hi = int(profile.uniq[0]), int(profile.uniq[-1])
        span = hi - lo + 1
        key_bounds = lo + (np.arange(1, r, dtype=np.int64) * span) // r
        return profile.rank_after_key(key_bounds), key_bounds


@register_partitioner("blocksplit")
class BlockSplitPartitioner(Partitioner):
    """Greedy block walk balancing comparison counts (Kolb's BlockSplit,
    SN-adapted).  For each boundary the remaining comparison mass is divided
    by the remaining shards (so early over/undershoot self-corrects); the
    boundary snaps to the nearer edge of the block that straddles the goal —
    unless that block alone exceeds the fair share, in which case it is
    split mid-block at the exact rank (rank-granular routing)."""

    def boundary_ranks(self, profile, r):
        """Greedy comparison-balancing walk (see class doc); key_bounds is
        None exactly when some oversized block was split mid-block."""
        n, w = profile.n, profile.window
        cum_n = profile.cum_entities
        cum_c = profile.cum_comparisons
        total = profile.total_comparisons
        edges = []
        any_split = False
        rank0 = 0
        for made in range(r - 1):
            done = int(W.rank_prefix_comparisons(rank0, w))
            target = (total - done) / (r - made)
            goal = done + target
            j = int(np.searchsorted(cum_c, goal, side="left"))
            if j >= profile.n_blocks or rank0 >= n - 1:
                edges.append(n)                   # mass exhausted: empty tail
                continue
            start_rank = int(cum_n[j - 1]) if j > 0 else 0
            end_rank = int(cum_n[j])
            block_c = int(cum_c[j]) - (int(cum_c[j - 1]) if j > 0 else 0)
            if block_c > target:
                # oversized block: split it at the exact pair-space rank
                e = W.rank_for_prefix_comparisons(goal, w)
                e = int(np.clip(e, rank0 + 1, n))
                if start_rank < e < end_rank:
                    any_split = True
            else:
                # snap to the nearer block edge (never re-emit a past edge)
                lo_c = int(W.rank_prefix_comparisons(start_rank, w))
                hi_c = int(cum_c[j])
                if start_rank > rank0 and goal - lo_c <= hi_c - goal:
                    e = start_rank
                else:
                    e = end_rank
            edges.append(min(e, n))
            rank0 = edges[-1]
        edges = np.asarray(edges, np.int64)
        if any_split:
            return edges, None
        # every boundary on a block edge: the key of rank e-1 closes shard s
        return edges, np.asarray(
            profile.key_at_rank(np.maximum(edges - 1, 0)), np.int64)


@register_partitioner("pairrange")
class PairRangePartitioner(Partitioner):
    """Equal contiguous ranges of the global SN pair space (Kolb's
    PairRange, SN-adapted): boundary ranks at exact comparison-count
    quantiles via the closed-form inverse of the cost model.  Ignores block
    edges entirely — the finest balance, always rank-granular."""

    def boundary_ranks(self, profile, r):
        """Boundary ranks at exact comparison-count quantiles (inverse cost
        model); always rank-granular, so key_bounds is always None."""
        n, w = profile.n, profile.window
        total = profile.total_comparisons
        edges = [W.rank_for_prefix_comparisons(total * (s + 1) / r, w)
                 for s in range(r - 1)]
        edges = np.minimum(np.maximum.accumulate(np.asarray(edges, np.int64)),
                           n)
        return edges, None


# -- plan construction --------------------------------------------------------------

def _legacy_bounds(keys: np.ndarray, partitioner: str, r: int) -> np.ndarray:
    """Exact historical boundary behavior of the pre-planner facade."""
    if partitioner == "balanced":
        return np.asarray(P.balanced_partition(keys, r))
    if partitioner == "range":
        return np.asarray(P.range_partition(int(keys.max()) + 1, r))
    if partitioner == "sample":
        return np.asarray(P.sample_partition(np.sort(keys), r))
    raise ValueError(f"unknown partitioner {partitioner!r}")


def _plan_stats(profile: KeyProfile, rank_bounds: np.ndarray):
    edges = np.concatenate([[0], np.asarray(rank_bounds, np.int64),
                            [profile.n]])
    load = np.diff(edges)
    comp = np.asarray(profile.comparisons_in_rank_range(edges[:-1], edges[1:]),
                      np.int64)
    halo = np.minimum(edges[:-1], profile.window - 1)
    halo[0] = 0
    return load, comp, halo


def _planned_cap_link(assign_valid: np.ndarray, valid_pos: np.ndarray,
                      n_slots: int, r: int, window: int) -> int:
    """Exact per-(mapper, destination) bucket capacity for the SRP shuffle,
    replicating ``runners.shard_input``'s contiguous mapper chunks; floored
    so the halo slice stays legal (r*cap_link >= w-1) and >= 1."""
    cap0 = int(np.ceil(n_slots / r))
    mapper = valid_pos // cap0
    counts = np.zeros((r, r), np.int64)
    np.add.at(counts, (mapper, assign_valid), 1)
    need = int(counts.max())
    halo_floor = int(np.ceil((window - 1) / r))
    return max(need, halo_floor, 1)


def validate_plan(plan: ShardPlan, cfg, n_valid: int) -> None:
    """Reject planner/config combinations that would SILENTLY truncate a
    shard's halo (satellite of ISSUE 3): pairs lost with zero overflow
    accounting.  Applies to halo-slicing variants under profile-backed
    plans; capacity overflow (cap_factor too tight) stays an accounted
    outcome, not an error.  ``plan_shards`` calls this on every plan it
    builds; ``repro.stream`` calls it once on the GLOBAL plan so a config
    the monolithic facade would reject fails the stream loudly too."""
    from repro.api.variants import get_variant     # lazy: avoid import cycle
    variant = get_variant(cfg.variant)
    if not variant.halo_slices or plan.planned_load is None:
        return
    w, r = cfg.window, plan.num_shards
    loads = np.asarray(plan.planned_load, np.int64)
    edges = np.concatenate([[0], np.asarray(plan.rank_bounds, np.int64)])
    if variant.name == "repsn":
        need_hops = 1
        for s in range(1, r):
            # an empty shard emits nothing, so it needs no halo at all
            need = min(int(edges[s]), w - 1) if loads[s] > 0 else 0
            acc, h = 0, 0
            for q in range(s - 1, -1, -1):
                if acc >= need:
                    break
                acc += int(loads[q])
                h += 1
            need_hops = max(need_hops, h)
        if cfg.hops < need_hops:
            raise ValueError(
                f"partitioner {plan.partitioner!r} gives some shard fewer "
                f"than window-1={w - 1} predecessors within hops="
                f"{cfg.hops}: its halo would be silently truncated and "
                f"boundary pairs lost.  Set hops>={need_hops} (hops="
                f"{r - 1} is always complete), lower window, or use fewer "
                f"shards")
    elif variant.name == "jobsn" and n_valid > w - 1:
        # pairs can only be lost ACROSS an undersized shard, so only shards
        # strictly between nonempty neighbors count (trailing empty shards
        # from padded key bounds are harmless)
        nonempty = np.flatnonzero(loads)
        first = int(nonempty[0]) if nonempty.size else 0
        last = int(nonempty[-1]) if nonempty.size else 0
        small = [s for s in range(first + 1, last) if loads[s] < w - 1]
        if small:
            raise ValueError(
                f"partitioner {plan.partitioner!r} plans interior shard(s) "
                f"{small} with fewer than window-1={w - 1} entities; "
                f"JobSN's single boundary pass cannot reach across them "
                f"and would silently drop pairs.  Use variant='repsn' with "
                f"hops={r - 1}, lower num_shards, or lower window")


def plan_from_profile(profile: KeyProfile, partitioner: str,
                      r: int) -> ShardPlan:
    """Plan shard boundaries from a ``KeyProfile`` ALONE — the streaming
    planning hook: no entity arrays are needed, so a profile merged
    incrementally across chunks (``KeyProfile.merge``) plans exactly like
    the monolithic ``plan_shards`` would on the full corpus.

    Handles both the planner registry and the legacy names (boundaries
    reconstructed from the profile's sorted key multiset — exact, since the
    legacy derivations only read sorted keys).  The returned plan carries
    boundaries, planned stats, and the ``rank_granular`` flag, but neither
    per-entity ``dest`` nor ``cap_link`` (those need the concrete entity
    layout; ``plan_shards`` attaches them, and ``repro.stream`` routes each
    chunk by global rank against ``rank_bounds`` instead)."""
    if profile.n == 0:
        bounds = np.asarray(P.manual_partition(range(1, r)) if r > 1
                            else P.manual_partition([]))
        return ShardPlan(partitioner=partitioner, num_shards=r,
                         bounds=bounds.astype(np.int32))
    if partitioner in LEGACY_PARTITIONERS:
        sorted_keys = np.repeat(profile.uniq, profile.counts)
        bounds = _legacy_bounds(sorted_keys, partitioner, r) \
            .astype(np.int32)
        rank_bounds = profile.rank_after_key(bounds)
        load, comp, halo = _plan_stats(profile, rank_bounds)
        return ShardPlan(partitioner=partitioner, num_shards=r,
                         bounds=bounds, rank_bounds=rank_bounds,
                         planned_load=load, planned_comparisons=comp,
                         halo=halo)
    planner = get_partitioner(partitioner)
    rank_bounds, key_bounds = planner.boundary_ranks(profile, r)
    rank_bounds = np.asarray(rank_bounds, np.int64)
    load, comp, halo = _plan_stats(profile, rank_bounds)
    if key_bounds is None:
        # key-view bounds are telemetry only: the key of the last entity of
        # each shard (routing must happen by rank — rank_granular)
        bounds = np.asarray(profile.key_at_rank(
            np.maximum(rank_bounds - 1, 0)), np.int64).astype(np.int32)
    else:
        bounds = np.asarray(key_bounds, np.int64).astype(np.int32)
    return ShardPlan(partitioner=partitioner, num_shards=r, bounds=bounds,
                     rank_bounds=rank_bounds, planned_load=load,
                     planned_comparisons=comp, halo=halo,
                     rank_granular=key_bounds is None)


def plan_shards(ents: dict, cfg, r: int) -> ShardPlan:
    """Profile ``ents`` and build the ShardPlan for ``cfg.partitioner``.

    Legacy partitioners (balanced | range | sample) keep their historical
    boundaries and capacity semantics but still gain planned-load telemetry;
    the planner registry names (uniform | blocksplit | pairrange) also emit
    exact planned capacities and rank-granular routing where needed.
    """
    valid = np.asarray(ents["valid"])
    keys_all = np.asarray(ents["key"])
    keys = keys_all[valid]
    if keys.size == 0:
        return plan_from_profile(KeyProfile.empty(cfg.window),
                                 cfg.partitioner, r)
    profile = profile_keys(keys, window=cfg.window)
    plan = plan_from_profile(profile, cfg.partitioner, r)

    if cfg.partitioner in LEGACY_PARTITIONERS:
        # legacy plans are profile-backed too: a halo-truncating combination
        # is just as silent there, so it is rejected the same way
        validate_plan(plan, cfg, int(keys.shape[0]))
        return plan

    dest = None
    if plan.rank_granular:
        # rank-granular plan: route by explicit per-entity destination
        eids = np.asarray(ents["eid"])[valid]
        order = np.lexsort((eids, keys))
        ranks = np.empty(keys.shape[0], np.int64)
        ranks[order] = np.arange(keys.shape[0])
        assign_valid = np.searchsorted(plan.rank_bounds, ranks,
                                       side="right").astype(np.int32)
        dest = np.zeros(keys_all.shape[0], np.int32)
        dest[np.flatnonzero(valid)] = assign_valid
    else:
        assign_valid = np.searchsorted(plan.bounds, keys,
                                       side="left").astype(np.int32)

    cap_link = _planned_cap_link(assign_valid, np.flatnonzero(valid),
                                 keys_all.shape[0], r, cfg.window)
    plan = replace(plan, dest=dest, cap_link=cap_link)
    validate_plan(plan, cfg, int(keys.shape[0]))
    return plan
