"""Pallas TPU kernel: banded Jaccard over bit-packed trigram signatures.

The paper's TriGram matcher, TPU-adapted: each entity's trigram set is a
SIG_WORDS*32-bit signature; Jaccard(a,b) = popcount(a&b)/popcount(a|b).
Band structure identical to banded_sim (tiles of (Bi, 2*Bi)); the inner loop
is VPU integer work: broadcast AND/OR + population_count, reduced over the
signature words.

VMEM: (Bi, W) sigs *2 + (Bi, 2Bi) out + a (Bi, 2Bi) int32 accumulator pair;
the (Bi, 2Bi, W) broadcast is avoided by looping over words (W is small,
static) so the live set stays ~2 MB at Bi=256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _jaccard_kernel(a_ref, nxt_ref, o_ref, *, window: int, sig_words: int):
    a = a_ref[...]                                  # (Bi, W) uint32
    nxt = nxt_ref[...]
    bi = a.shape[0]
    both = jnp.concatenate([a, nxt], axis=0)        # (2Bi, W)
    inter = jnp.zeros((bi, 2 * bi), jnp.int32)
    union = jnp.zeros((bi, 2 * bi), jnp.int32)
    for wd in range(sig_words):                     # static unroll
        x = a[:, wd][:, None]                       # (Bi, 1)
        y = both[:, wd][None, :]                    # (1, 2Bi)
        inter = inter + jax.lax.population_count(x & y).astype(jnp.int32)
        union = union + jax.lax.population_count(x | y).astype(jnp.int32)
    r = jax.lax.broadcasted_iota(jnp.int32, inter.shape, 0)
    c = jax.lax.broadcasted_iota(jnp.int32, inter.shape, 1)
    band = (c > r) & (c - r <= window)
    jac = inter.astype(jnp.float32) / jnp.maximum(
        union.astype(jnp.float32), 1.0)
    o_ref[...] = jnp.where(band, jac, 0.0)


def jaccard_band_tiles(sig: jax.Array, *, window: int, block_i: int = 256,
                       interpret: bool = False) -> jax.Array:
    """sig: (M, SIG_WORDS) uint32.  Returns tiles (M, 2*block_i) f32."""
    m, words = sig.shape
    assert m % block_i == 0 and window <= block_i
    n_blocks = m // block_i
    kernel = functools.partial(_jaccard_kernel, window=window,
                               sig_words=words)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_i, words), lambda i: (i, 0)),
            pl.BlockSpec((block_i, words),
                         lambda i: (jnp.minimum(i + 1, n_blocks - 1), 0)),
        ],
        out_specs=pl.BlockSpec((block_i, 2 * block_i), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 2 * block_i), jnp.float32),
        interpret=interpret,
    )(sig, sig)
