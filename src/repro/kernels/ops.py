"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernels VALIDATE on CPU via the
Pallas interpreter and compile natively on TPU — same code path).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.banded_sim import banded_sim_tiles
from repro.kernels.jaccard_band import jaccard_band_tiles
from repro.kernels.local_attn import local_attention


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def band_from_tiles(tiles: jax.Array, *, window: int,
                    block_i: int) -> jax.Array:
    """(M, 2*Bi) tiles -> (M, window) band.

    band[g, d] = tiles[g, (g % Bi) + 1 + d]; entries with global j >= M are
    zeroed."""
    m = tiles.shape[0]
    r = jnp.arange(m, dtype=jnp.int32)
    local = r % block_i
    cols = local[:, None] + 1 + jnp.arange(window, dtype=jnp.int32)[None, :]
    band = jnp.take_along_axis(tiles, cols, axis=1)
    ok = (r[:, None] + 1 + jnp.arange(window)[None, :]) < m
    return jnp.where(ok, band, 0.0)


@partial(jax.jit, static_argnames=("window", "block_i", "interpret"))
def banded_dot_band(feat: jax.Array, *, window: int, block_i: int = 256,
                    interpret: bool = None) -> jax.Array:
    """Banded <feat_i, feat_j> similarity: (M, F) -> (M, window)."""
    interpret = default_interpret() if interpret is None else interpret
    m, f = feat.shape
    bi = min(block_i, m)
    pad = (-m) % bi
    if pad:
        feat = jnp.pad(feat, ((0, pad), (0, 0)))
    tiles = banded_sim_tiles(feat, window=window, block_i=bi,
                             interpret=interpret)
    return band_from_tiles(tiles, window=window, block_i=bi)[:m]


@partial(jax.jit, static_argnames=("window", "block_i", "interpret"))
def jaccard_band(sig: jax.Array, *, window: int, block_i: int = 256,
                 interpret: bool = None) -> jax.Array:
    """Banded Jaccard over bit signatures: (M, W32) -> (M, window)."""
    interpret = default_interpret() if interpret is None else interpret
    m, words = sig.shape
    bi = min(block_i, m)
    pad = (-m) % bi
    if pad:
        sig = jnp.pad(sig, ((0, pad), (0, 0)))
    tiles = jaccard_band_tiles(sig, window=window, block_i=bi,
                               interpret=interpret)
    return band_from_tiles(tiles, window=window, block_i=bi)[:m]


@partial(jax.jit,
         static_argnames=("window", "block_q", "block_k", "softcap",
                          "interpret"))
def local_attn(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int,
               block_q: int = 256, block_k: int = 256, softcap: float = 0.0,
               interpret: bool = None) -> jax.Array:
    """Sliding-window flash attention: (BH, S, D) x3 -> (BH, S, D)."""
    interpret = default_interpret() if interpret is None else interpret
    s = q.shape[1]
    bq = bk = min(block_q, block_k, s)
    return local_attention(q, k, v, window=window, block_q=bq, block_k=bk,
                           softcap=softcap, interpret=interpret)
