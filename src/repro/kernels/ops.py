"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernels VALIDATE on CPU via the
Pallas interpreter and compile natively on TPU — same code path).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.banded_sim import banded_sim_tiles
from repro.kernels.fused_band import fused_band_scores
from repro.kernels.jaccard_band import jaccard_band_tiles
from repro.kernels.local_attn import local_attention


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_block_i(m: int, window: int, block_i: int) -> int:
    """Pick the row-block size for a band kernel.

    The band kernels require ``window <= block_i`` (each row's whole band
    lives in its own tile + the successor tile).  Naively clamping
    ``bi = min(block_i, m)`` violates that for small M, so when the clamped
    block is too small for the window we grow it back up to ``window`` (the
    caller pads M up to a multiple of the block — safe, padded rows are
    masked).  A window that cannot fit in ``block_i`` at all is a config
    error, reported actionably instead of tripping the kernel's assert."""
    if window > block_i:
        raise ValueError(
            f"band window={window} exceeds block_i={block_i}; the band "
            f"kernels need window <= block_i (one tile + successor covers "
            f"the whole band).  Raise block_i (VMEM grows as block_i^2) or "
            f"use the scan band engine")
    return max(min(block_i, m), window)


def band_from_tiles(tiles: jax.Array, *, window: int,
                    block_i: int) -> jax.Array:
    """(M, 2*Bi) tiles -> (M, window) band.

    band[g, d] = tiles[g, (g % Bi) + 1 + d]; entries with global j >= M are
    zeroed."""
    m = tiles.shape[0]
    r = jnp.arange(m, dtype=jnp.int32)
    local = r % block_i
    cols = local[:, None] + 1 + jnp.arange(window, dtype=jnp.int32)[None, :]
    band = jnp.take_along_axis(tiles, cols, axis=1)
    ok = (r[:, None] + 1 + jnp.arange(window)[None, :]) < m
    return jnp.where(ok, band, 0.0)


@partial(jax.jit, static_argnames=("window", "block_i", "interpret"))
def banded_dot_band(feat: jax.Array, *, window: int, block_i: int = 256,
                    interpret: bool = None) -> jax.Array:
    """Banded <feat_i, feat_j> similarity: (M, F) -> (M, window)."""
    interpret = default_interpret() if interpret is None else interpret
    m, f = feat.shape
    bi = resolve_block_i(m, window, block_i)
    pad = (-m) % bi
    if pad:
        feat = jnp.pad(feat, ((0, pad), (0, 0)))
    tiles = banded_sim_tiles(feat, window=window, block_i=bi,
                             interpret=interpret)
    return band_from_tiles(tiles, window=window, block_i=bi)[:m]


@partial(jax.jit, static_argnames=("window", "block_i", "interpret"))
def jaccard_band(sig: jax.Array, *, window: int, block_i: int = 256,
                 interpret: bool = None) -> jax.Array:
    """Banded Jaccard over bit signatures: (M, W32) -> (M, window)."""
    interpret = default_interpret() if interpret is None else interpret
    m, words = sig.shape
    bi = resolve_block_i(m, window, block_i)
    pad = (-m) % bi
    if pad:
        sig = jnp.pad(sig, ((0, pad), (0, 0)))
    tiles = jaccard_band_tiles(sig, window=window, block_i=bi,
                               interpret=interpret)
    return band_from_tiles(tiles, window=window, block_i=bi)[:m]


@partial(jax.jit, static_argnames=("window", "w_cos", "w_jac", "block_i",
                                   "interpret"))
def fused_cheap_band(feat: jax.Array, sig: jax.Array, *, window: int,
                     w_cos: float, w_jac: float, block_i: int = 256,
                     interpret: bool = None) -> jax.Array:
    """Fused cheap-cascade band: (M, F) x (M, W32) -> (M, window) weighted
    partial score ``w_cos*cosine + w_jac*jaccard`` (unnormalized — the
    cascade gate in core/window.py compares against a pre-scaled tau).

    Either half is disabled by a zero weight (pass a (M, 1) dummy array for
    the unused input).  The band is emitted directly by the kernel — no
    (M, 2*block_i) tile intermediate, no host-side gather."""
    interpret = default_interpret() if interpret is None else interpret
    m = feat.shape[0]
    bi = resolve_block_i(m, window, block_i)
    pad = (-m) % bi
    if pad:
        feat = jnp.pad(feat, ((0, pad), (0, 0)))
        sig = jnp.pad(sig, ((0, pad), (0, 0)))
    return fused_band_scores(feat, sig, window=window, w_cos=w_cos,
                             w_jac=w_jac, block_i=bi, m_valid=m,
                             interpret=interpret)[:m]


@partial(jax.jit,
         static_argnames=("window", "block_q", "block_k", "softcap",
                          "interpret"))
def local_attn(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int,
               block_q: int = 256, block_k: int = 256, softcap: float = 0.0,
               interpret: bool = None) -> jax.Array:
    """Sliding-window flash attention: (BH, S, D) x3 -> (BH, S, D)."""
    interpret = default_interpret() if interpret is None else interpret
    s = q.shape[1]
    bq = bk = min(block_q, block_k, s)
    return local_attention(q, k, v, window=window, block_q=bq, block_k=bk,
                           softcap=softcap, interpret=interpret)
