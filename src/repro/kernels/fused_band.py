"""Pallas TPU kernel: fused cheap-cascade band (cosine + bit-packed Jaccard).

This is stage 1 of the paper's §5.1 skip optimization on device: the CHEAP
matchers of the cascade are evaluated for every sliding-window pair in one
``pallas_call``, and the result gates the expensive matcher (see
``core/window.PallasBandEngine``).

Compared to running ``banded_sim`` and ``jaccard_band`` back to back, the
fused kernel

  * loads each (Bi, F) feature block and (Bi, W) signature block into VMEM
    once and emits one weighted partial score ``w_cos*cos + w_jac*jac``;
  * extracts the (Bi, window) band IN-KERNEL (``take_along_axis`` over the
    (Bi, 2*Bi) tile) instead of materializing (M, 2*Bi) tiles in HBM and
    gathering on the host (``ops.band_from_tiles``), cutting the kernel's
    HBM write traffic by 2*Bi/window;
  * masks out-of-range pairs (global j >= M) in-kernel.

VMEM per block: (Bi,F) f32 *2 + (Bi,W) u32 *2 + (Bi,2Bi) f32 tile +
(Bi,window) out; Bi=256, F<=512, W<=16: ~1.9 MB — comfortably resident.
Either half of the cascade can be disabled statically (weight 0.0) and its
input replaced by a (M, 1) dummy; the kernel body then never touches it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_band_kernel(feat_ref, nfeat_ref, sig_ref, nsig_ref, o_ref, *,
                       block_i: int, window: int, w_cos: float, w_jac: float,
                       sig_words: int, m_total: int):
    bi = block_i
    acc = jnp.zeros((bi, 2 * bi), jnp.float32)
    if w_cos > 0.0:
        x = feat_ref[...].astype(jnp.float32)            # (Bi, F)
        nxt = nfeat_ref[...].astype(jnp.float32)
        s1 = jax.lax.dot_general(                        # row-block self
            x, x, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s2 = jax.lax.dot_general(                        # vs successor block
            x, nxt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        dots = jnp.concatenate([s1, s2], axis=1)         # (Bi, 2Bi)
        acc = acc + w_cos * jnp.clip(0.5 * (dots + 1.0), 0.0, 1.0)
    if w_jac > 0.0:
        a = sig_ref[...]                                 # (Bi, W) uint32
        both = jnp.concatenate([a, nsig_ref[...]], axis=0)   # (2Bi, W)
        inter = jnp.zeros((bi, 2 * bi), jnp.int32)
        union = jnp.zeros((bi, 2 * bi), jnp.int32)
        for wd in range(sig_words):                      # static unroll
            x = a[:, wd][:, None]
            y = both[:, wd][None, :]
            inter = inter + jax.lax.population_count(x & y).astype(jnp.int32)
            union = union + jax.lax.population_count(x | y).astype(jnp.int32)
        # match core.match.jaccard_sig exactly: empty-vs-empty -> 1.0
        jac = jnp.where(union > 0,
                        inter.astype(jnp.float32) /
                        jnp.maximum(union.astype(jnp.float32), 1.0), 1.0)
        acc = acc + w_jac * jac
    # in-kernel band extraction: band[r, d] = acc[r, r + 1 + d]
    r = jax.lax.broadcasted_iota(jnp.int32, (bi, window), 0)
    d = jax.lax.broadcasted_iota(jnp.int32, (bi, window), 1)
    band = jnp.take_along_axis(acc, r + 1 + d, axis=1)
    grow = pl.program_id(0) * bi + r                     # global row index
    ok = (grow + 1 + d) < m_total
    o_ref[...] = jnp.where(ok, band, 0.0)


def fused_band_scores(feat: jax.Array, sig: jax.Array, *, window: int,
                      w_cos: float, w_jac: float, block_i: int = 256,
                      m_valid: int = None, interpret: bool = False
                      ) -> jax.Array:
    """feat: (M, F) f32-ish, sig: (M, W) uint32; M % block_i == 0 and
    window <= block_i.  Returns the (M, window) weighted cheap-score band
    ``w_cos*cosine + w_jac*jaccard``.  Entries pairing past ``m_valid``
    (default M — callers that padded pass the unpadded row count) are
    zeroed in-kernel."""
    m, f = feat.shape
    _, words = sig.shape
    assert m % block_i == 0, (m, block_i)
    assert window <= block_i, (window, block_i)
    n_blocks = m // block_i
    kernel = functools.partial(
        _fused_band_kernel, block_i=block_i, window=window,
        w_cos=float(w_cos), w_jac=float(w_jac), sig_words=words,
        m_total=m if m_valid is None else m_valid)
    # the last block's successor view wraps to itself; every such entry has
    # global j >= M and is zeroed by the in-kernel ``ok`` mask.
    nxt = lambda i: (jnp.minimum(i + 1, n_blocks - 1), 0)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_i, f), lambda i: (i, 0)),
            pl.BlockSpec((block_i, f), nxt),
            pl.BlockSpec((block_i, words), lambda i: (i, 0)),
            pl.BlockSpec((block_i, words), nxt),
        ],
        out_specs=pl.BlockSpec((block_i, window), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, window), jnp.float32),
        interpret=interpret,
    )(feat, feat, sig, sig)
