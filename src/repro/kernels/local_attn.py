"""Pallas TPU kernel: sliding-window (local) flash attention.

Causal attention restricted to a window w — the LM-side twin of the SN band
(gemma2 local layers, mixtral SWA, recurrentgemma local attention).

Grid (B*KH, n_q, n_kv): for query block iq, the innermost grid dim walks the
``nkv = window/Bk + 1`` kv blocks that can intersect [iq*Bq - w, iq*Bq + Bq).
Flash accumulators (m, l, acc) live in VMEM scratch and persist across the
innermost (sequential on TPU) grid dimension; the output block is written on
the last kv iteration.  Out-of-range kv block indices are clamped by the
BlockSpec index_map and fully masked inside the kernel via the true block id.

VMEM at Bq=Bk=256, D=128 heads: q/k/v blocks 3*64KB + acc 128KB + scores
(256,256) f32 256KB -> well under budget; all dims 128-aligned for the MXU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _local_attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                       block_q: int, block_k: int, window: int, nkv: int,
                       scale: float, softcap: float, q_per_kv: int):
    iq = pl.program_id(1)
    ikv = pl.program_id(2)

    @pl.when(ikv == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    true_j = iq - (nkv - 1) + ikv                 # true kv block index
    valid_block = true_j >= 0

    @pl.when(valid_block)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (Bq, D)
        k = k_ref[0].astype(jnp.float32)          # (Bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if softcap:
            s = softcap * jnp.tanh(s / softcap)
        qp = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kp = true_j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = (kp <= qp) & (kp > qp - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                       # (Bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ikv == nkv - 1)
    def _flush():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def local_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    window: int, block_q: int = 256, block_k: int = 256,
                    softcap: float = 0.0,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, S, D); k, v: (BH, S, D) — heads pre-flattened into the batch
    dim (GQA: repeat kv outside or pass q_per_kv-grouped views).  Causal with
    sliding window ``window``.  Returns (BH, S, D)."""
    bh, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0
    assert block_q == block_k, "block walk assumes equal q/kv blocks"
    nq = s // block_q
    # kv blocks intersecting [iq*Bq - window, iq*Bq + Bq): ceil((w-1)/Bk) back
    # plus the diagonal block (a partially-masked extra block is harmless).
    nkv = (max(window, 1) + block_k - 1) // block_k + 1
    scale = 1.0 / math.sqrt(d)
    from jax.experimental.pallas import tpu as pltpu

    kernel = functools.partial(
        _local_attn_kernel, block_q=block_q, block_k=block_k, window=window,
        nkv=nkv, scale=scale, softcap=softcap, q_per_kv=1)

    def kv_map(b, i, j):
        return (b, jnp.maximum(i - (nkv - 1) + j, 0), 0)

    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), kv_map),
            pl.BlockSpec((1, block_k, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),     # m
            pltpu.VMEM((block_q, 1), jnp.float32),     # l
            pltpu.VMEM((block_q, d), jnp.float32),     # acc
        ],
        interpret=interpret,
    )(q, k, v)
