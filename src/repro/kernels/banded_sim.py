"""Pallas TPU kernel: banded dot-product similarity (the SN window hot spot).

For M sorted entities with feature vectors (M, F), computes similarity of
every pair within sliding-window distance <= W.  This is the matcher's inner
loop (paper reduce phase): instead of w-1 shifted vector passes, each (Bi, F)
row block does two MXU matmuls against itself and its successor block,
yielding the full band when W <= Bi:

  out[i*Bi + r, c] = <feat[i*Bi + r], feat[i*Bi + c]>          (c <  Bi)
                     <feat[i*Bi + r], feat[(i+1)*Bi + c - Bi]>  (c >= Bi)

masked to the band 1 <= c - r <= W.  ``ops.band_from_tiles`` gathers the
(M, W) band from the (M, 2*Bi) tile output.

VMEM per block: (Bi,F)*2 inputs + (Bi, 2Bi) f32 out; with Bi=256, F<=512:
~1.3 MB — comfortably resident.  Dims aligned to 128 for the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _banded_sim_kernel(x_ref, nxt_ref, o_ref, *, block_i: int, window: int):
    x = x_ref[...].astype(jnp.float32)          # (Bi, F)
    nxt = nxt_ref[...].astype(jnp.float32)      # (Bi, F)
    s1 = jax.lax.dot_general(                   # (Bi, Bi) row-block self
        x, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    s2 = jax.lax.dot_general(                   # (Bi, Bi) vs successor block
        x, nxt, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    s = jnp.concatenate([s1, s2], axis=1)       # (Bi, 2*Bi)
    r = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    c = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    band = (c > r) & (c - r <= window)
    o_ref[...] = jnp.where(band, s, 0.0)


def banded_sim_tiles(feat: jax.Array, *, window: int, block_i: int = 256,
                     interpret: bool = False) -> jax.Array:
    """feat: (M, F); M % block_i == 0; window <= block_i.
    Returns tiles (M, 2*block_i) f32 (see module docstring)."""
    m, f = feat.shape
    assert m % block_i == 0, (m, block_i)
    assert window <= block_i, (window, block_i)
    n_blocks = m // block_i
    # successor block view: block i reads rows of block i+1.  The last block
    # wraps to itself, producing garbage in its s2 half — every such entry
    # has global j >= M and is masked by the caller's band extraction.
    kernel = functools.partial(_banded_sim_kernel, block_i=block_i,
                               window=window)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_i, f), lambda i: (i, 0)),
            pl.BlockSpec((block_i, f),
                         lambda i: (jnp.minimum(i + 1, n_blocks - 1), 0)),
        ],
        out_specs=pl.BlockSpec((block_i, 2 * block_i), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 2 * block_i), jnp.float32),
        interpret=interpret,
    )(feat, feat)
