"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np


def banded_sim_ref(feat: jax.Array, *, window: int) -> jax.Array:
    """(M, F) -> band (M, window): band[i, d] = <feat[i], feat[i+1+d]>,
    zero past the end."""
    m = feat.shape[0]
    f32 = feat.astype(jnp.float32)
    cols = []
    for d in range(1, window + 1):
        s = jnp.sum(f32 * jnp.roll(f32, -d, axis=0), axis=-1)
        ok = jnp.arange(m) + d < m
        cols.append(jnp.where(ok, s, 0.0))
    return jnp.stack(cols, axis=1)


def jaccard_band_ref(sig: jax.Array, *, window: int) -> jax.Array:
    m = sig.shape[0]
    cols = []
    for d in range(1, window + 1):
        o = jnp.roll(sig, -d, axis=0)
        inter = jax.lax.population_count(sig & o).sum(-1).astype(jnp.float32)
        union = jax.lax.population_count(sig | o).sum(-1).astype(jnp.float32)
        jac = inter / jnp.maximum(union, 1.0)
        ok = jnp.arange(m) + d < m
        cols.append(jnp.where(ok, jac, 0.0))
    return jnp.stack(cols, axis=1)


def local_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: int, softcap: float = 0.0) -> jax.Array:
    """(BH, S, D) causal sliding-window attention, materialized scores."""
    bh, s, d = q.shape
    sc = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(d)
    if softcap:
        sc = softcap * jnp.tanh(sc / softcap)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = (kp <= qp) & (kp > qp - window)
    sc = jnp.where(mask, sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
