"""Out-of-core streaming resolution: ``resolve_stream`` / ``link_stream``.

Every other path in the repo materializes the full sorted corpus on device
inside one ``resolve()`` — capping n at device memory, the opposite of the
paper's premise that MapReduce SN exists for datasets no single node holds.
``resolve_stream`` lifts that cap: it consumes an ITERATOR of entity
chunks, globally sort-partitions them out-of-core (per-chunk device sorts +
a k-way host merge — ``external_sort``), and drives the existing variant ×
runner × engine machinery chunk-by-chunk.  Peak device residency is one
``[seam halo | chunk]`` window, so n is bounded by host disk (the
``spool_dir`` option), not device memory.

**Seam halo.**  The merged stream is cut into fixed-width native chunks;
each chunk is resolved together with the w−1 immediately preceding GLOBAL
entities (the carry).  Any SN pair whose later element is native to chunk k
reaches back at most w−1 ranks — i.e. into chunk k or its carry — so the
union of per-chunk pair sets is bit-identical to a monolithic ``resolve``:

  * **RepSN / JobSN** (boundary-complete): each chunk is a contiguous slice
    of the global (key, eid) order, so its SN pairs are a subset of the
    global set, and the carry closes every seam.  Chunks are re-planned
    individually (``balance.plan_shards`` — the per-chunk planning hook, so
    skew handling survives streaming); chunks too small to plan legally
    (n < r·w) collapse to one shard, counted in ``degenerate_chunks``.
  * **SRP** (pair set DEPENDS on the partitioning): the monolithic plan is
    reproduced exactly from the incrementally merged ``KeyProfile``
    (``balance.plan_from_profile``), and every chunk routes by GLOBAL
    sorted rank against that plan's ``rank_bounds`` — each device shard
    then holds (global partition ∩ chunk), whose windows union to
    precisely the monolithic per-partition pair set.

**Steady state.**  Chunks share one shape (natives padded to ``chunk_size``
+ a w−1 halo prefix), boundaries/destinations ride as traced arguments, and
planner capacities are normalized off the cache key — so every chunk after
the first hits the ``repro.perf`` executable cache (``steady_chunks`` in
``StreamStats`` reports it).

**Multi-pass.**  With ``cfg.passes`` the whole pipeline (sort → merge →
chunked resolve) reruns per derived sort key over the SAME ingested chunk
store, and the union rides on ``StreamResult.passes`` — the streaming twin
of ``facade.resolve``'s ``MultiPassResult``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import FrozenSet, Iterable, Optional, Set, Tuple

import numpy as np

from repro import balance as B
from repro import obs as OBS
from repro.api import facade as F
from repro.api import linkage as LK
from repro.api import results as RES
from repro.api.config import ERConfig
from repro.api.results import BlockingResult, ERMetrics, compute_metrics
from repro.api.variants import get_variant
from repro.core import entities as E
from repro.core import sn
from repro.quality import adaptive as QA
from repro.perf import cache as PC
# the leaf retry module only (never the package __init__): repro.resilience
# imports checkpoint -> stream.store -> this module, so importing the
# package here would re-enter its half-executed __init__
from repro.resilience import retry as RZ
from repro.stream.external_sort import merged_blocks, rechunk
from repro.stream.store import ChunkStore

Pair = Tuple[int, int]


@dataclass(frozen=True)
class StreamStats:
    """Telemetry of one streaming pass (or the ingest-wide aggregate).

    chunks             native chunks resolved (ceil(n / chunk_size))
    chunk_size         native rows per chunk (the device-residency knob)
    entities           total valid entities ingested
    runs               sorted runs merged (== ingested chunks)
    carry_entities     total seam-halo rows re-resolved across chunk seams
                       (≈ (chunks−1)·(w−1): the streaming overhead)
    degenerate_chunks  chunks too small to plan r shards legally (n < r·w),
                       collapsed to one shard — correctness kept, balance
                       lost; a healthy stream has 0 (raise chunk_size)
    steady_chunks      chunks served entirely from the executable cache
                       (hits > 0, zero builds/traces); after the first
                       chunk every chunk should be steady
    cache_hits/cache_misses/traces   executable-cache deltas over the pass
    spooled_bytes      bytes written to the disk spool (0 in-memory); the
                       top-level result counts raw chunks + sorted runs,
                       per-pass results only their own runs (the shared raw
                       store is never double-counted across passes)
    chunk_device_bytes max host->device bytes staged per chunk resolve —
                       the PEAK device-input residency of the stream
    corpus_bytes       total entity bytes of the whole corpus (what one
                       monolithic resolve would stage instead)
    """
    chunks: int
    chunk_size: int
    entities: int
    runs: int
    carry_entities: int
    degenerate_chunks: int
    steady_chunks: int
    cache_hits: int
    cache_misses: int
    traces: int
    spooled_bytes: int
    chunk_device_bytes: int
    corpus_bytes: int


@dataclass(frozen=True)
class StreamResult:
    """Outcome of a streaming resolution (mirrors ``ERResult``; multi-pass
    runs additionally mirror ``MultiPassResult`` via ``passes``).

    ``blocking.load`` / ``blocking.cand_count`` report the elementwise MAX
    over chunks (peak per-shard residency / gate survivors — the quantities
    that size ``cap_link``-style capacities for the stream), while the
    overflow counters aggregate additively.  ``stream`` carries the
    streaming telemetry; per-pass results keep their own."""
    blocking: BlockingResult
    matches: FrozenSet[Pair]
    stream: StreamStats
    metrics: Optional[ERMetrics] = None
    passes: Tuple["StreamResult", ...] = ()
    pass_names: Tuple[str, ...] = ()
    # overflow-recovery telemetry (DESIGN.md §11): retry/escalation counts
    # and the caps the final executions ran under; multi-pass unions sum
    # the counters across passes
    resilience: Optional[RZ.ResilienceStats] = None
    # repro.obs.TraceReport when the run executed under ERConfig.trace=True
    # (DESIGN.md §12); per-pass results share the owner's tracer and carry
    # no report of their own
    trace: Optional[object] = None

    @property
    def pairs(self) -> FrozenSet[Pair]:
        """The blocked (candidate) pair set — sugar for blocking.pairs."""
        return self.blocking.pairs


def _ingest(chunks: Iterable[dict], spool_dir: Optional[str], *,
            store: Optional[ChunkStore] = None, transform=None):
    """Consume the chunk iterator once: strip invalid slots, move to host,
    apply the optional per-chunk ``transform`` (``link_stream``'s source
    tagging), spool.  Returns (store, max_chunk_rows, total_rows,
    corpus_bytes); pass ``store`` to keep appending to an existing spool
    (counters restart — callers accumulate)."""
    store = store if store is not None else ChunkStore(spool_dir,
                                                       prefix="raw")
    max_len = total = nbytes = 0
    for ents in chunks:
        h = E.to_host(ents)
        valid = np.asarray(h["valid"], bool)
        if not valid.all():        # all-valid chunks skip the mask copy
            h = E.host_take(h, valid)
        if int(h["key"].shape[0]) == 0:
            continue
        if transform is not None:
            h = transform(h)
        max_len = max(max_len, int(h["key"].shape[0]))
        total += int(h["key"].shape[0])
        nbytes += _entity_bytes(h)
        store.append(h)
    return store, max_len, total, nbytes


def _entity_bytes(h: dict) -> int:
    """Total bytes of one host entity dict (key/eid/valid + payload)."""
    return (h["key"].nbytes + h["eid"].nbytes + h["valid"].nbytes
            + sum(v.nbytes for v in h["payload"].values()))


def _host_pad(ents: dict, cap: int) -> dict:
    """Pad a host entity dict to exactly ``cap`` rows with invalid slots
    (keys pushed past every real key) — the fixed combined-chunk shape that
    keeps every streamed shard program cache-identical."""
    n = int(ents["key"].shape[0])
    if n == cap:
        return ents
    pad = cap - n
    z = lambda a: np.zeros((pad,) + a.shape[1:], a.dtype)
    tail = {
        "key": np.full((pad,), int(E.INVALID_KEY), np.int32),
        "eid": z(ents["eid"]),
        "valid": np.zeros((pad,), bool),
        "payload": {k: z(v) for k, v in ents["payload"].items()},
    }
    return E.host_concat([ents, tail])


def _sorted_runs(raw: ChunkStore, spec, window: int,
                 spool_dir: Optional[str], label: str, *,
                 runs: Optional[ChunkStore] = None):
    """Phase 1 of a pass: device-sort every raw chunk by the pass's derived
    key and fold each chunk's key distribution into ONE merged profile
    (``KeyProfile.merge``) — planning sees the whole corpus without ever
    holding it.  Returns (runs store, merged profile); ``runs`` lets the
    checkpoint path supply its own (durable, pre-swept) store."""
    from repro.core import keys as K
    if runs is None:
        runs = ChunkStore(spool_dir and f"{spool_dir}/runs-{label}",
                          prefix="run")
    profile = B.KeyProfile.empty(window)
    for h in raw:
        dev = E.make_entities(h["key"], h["eid"], payload=h["payload"],
                              valid=h["valid"])
        key = None if spec is None else K.derive_sort_key(dev, spec)
        run = E.sort_chunk(dev, key=key)
        profile = profile.merge(B.profile_keys(run["key"], window=window))
        runs.append(run)
    return runs, profile


def _chunk_plan(cfg: ERConfig, variant, gplan: B.ShardPlan, dev: dict,
                padded: dict, ranks: np.ndarray, r: int):
    """The per-chunk ShardPlan (see module doc): global-rank routing for
    partition-dependent variants (SRP), per-chunk re-planning for boundary-
    complete ones.  Every plan is normalized to dest-based routing with
    ``cap_link=None`` so all chunks share one executable-cache entry.
    Returns (plan, degenerate: bool)."""
    cap = int(padded["key"].shape[0])
    n_comb = int(ranks.shape[0])
    if not variant.boundary_complete:
        dest = np.zeros(cap, np.int32)
        dest[:n_comb] = np.searchsorted(
            gplan.rank_bounds, ranks, side="right").astype(np.int32)
        return replace(gplan, num_shards=r, dest=dest, cap_link=None,
                       rank_granular=True), False
    if n_comb >= r * cfg.window:
        try:
            plan = B.plan_shards(dev, cfg, r)
            dest = plan.dest if plan.dest is not None else \
                plan.assignment(padded["key"])
            return replace(plan, dest=np.asarray(dest, np.int32),
                           cap_link=None), False
        except ValueError:
            # the GLOBAL plan already validated this cfg (config-static
            # errors raised before any chunk ran), so a failure here is
            # chunk-local data shape (this chunk's key distribution plans
            # an illegal halo): collapse below, counted as degenerate
            pass
    # too small (or unplannable) for r shards: one shard holds the chunk —
    # correct for boundary-complete variants, counted as degenerate
    return B.ShardPlan(partitioner="stream-collapse", num_shards=r,
                       bounds=np.zeros(max(r - 1, 0), np.int32),
                       dest=np.zeros(cap, np.int32)), True


def _stream_pass(raw: ChunkStore, cfg: ERConfig, spec, chunk_size: int,
                 runner, spool_dir: Optional[str], label: str,
                 total_comparisons: int, *, ckpt=None, fault=None):
    """Run ONE full streaming pass (sort → merge → chunked resolve) and
    return (StreamResult, oracle_pair_set | None) — the oracle set is kept
    so multi-pass callers can union per-pass oracles for union metrics.

    With ``ckpt`` (a ``resilience.StreamCheckpoint``) the pass is durable:
    sorted runs + profile commit once, then every resolved chunk commits
    its pair spool, seam halo, and accumulators — and a pass whose
    manifest already records progress FAST-FORWARDS: committed chunks are
    skipped in the (deterministic) merged stream, their pairs reloaded
    from the spool, the carry/rank/counters restored.  ``fault`` is the
    test-only ``FaultPlan`` crash injector."""
    with OBS.span("pass", name=label, variant=cfg.variant):
        return _stream_pass_body(raw, cfg, spec, chunk_size, runner,
                                 spool_dir, label, total_comparisons,
                                 ckpt=ckpt, fault=fault)


def _stream_pass_body(raw: ChunkStore, cfg: ERConfig, spec,
                      chunk_size: int, runner, spool_dir: Optional[str],
                      label: str, total_comparisons: int, *,
                      ckpt=None, fault=None):
    """``_stream_pass`` proper (the wrapper above only opens the pass's
    root span so every phase below nests under it)."""
    w_base = cfg.window
    if cfg.window_policy == "adaptive":
        # the same rewrite facade._adaptive_rewrite applies: the band
        # program (and every derived width — seam carry, combined_cap,
        # halo validation) runs at window_max; per-chunk weff is computed
        # below from the MERGED profile, whose per-key counts are exactly
        # the monolithic corpus's — so streamed weff == monolithic weff
        # and invariant 9 holds unchanged
        cfg = cfg.with_(window=cfg.window_max)
    w, r = cfg.window, runner.shards
    variant = get_variant(cfg.variant)
    with OBS.span("sort_runs"):
        if ckpt is not None:
            runs, sorted_done = ckpt.runs_store(label)
            if sorted_done:
                profile = ckpt.load_profile(label)
            else:
                runs, profile = _sorted_runs(raw, spec, w, None, label,
                                             runs=runs)
                ckpt.commit_sorted(label, runs, profile)
        else:
            runs, profile = _sorted_runs(raw, spec, w, spool_dir, label)
    with OBS.span("plan", partitioner=cfg.partitioner, n=profile.n):
        gplan = B.plan_from_profile(profile, cfg.partitioner, r)
        # config-level feasibility is judged ONCE, against the global
        # plan — exactly what the monolithic facade would reject (halo-
        # truncating hops/window/shard combinations fail the stream
        # loudly, not as a silent cascade of collapsed chunks)
        B.validate_plan(gplan, cfg, profile.n)

        combined_cap = (w - 1) + chunk_size
        # unset (None) caps resolve from the merged profile's planned
        # loads — floored at the combined chunk width, since a degenerate
        # (collapsed) chunk puts the whole [halo | chunk] window on one
        # shard
        cfg, auto_caps = RZ.autosize_caps(cfg, plan=gplan, profile=profile,
                                          r=r, floor_load=combined_cap)
    cache = PC.executable_cache()
    blocked_parts, matched_parts = [], []
    load_max = np.zeros(r, np.int64)
    cand_max = np.zeros(r, np.int64)
    overflow = cand_overflow = matcher_evals = pair_overflow = 0
    pruned = 0
    chunks = steady = degenerate = carry_total = 0
    hits = misses = traces = 0
    retries = escalations = 0
    device_bytes = 0
    oracle: Optional[Set[Pair]] = set() if cfg.compute_metrics else None

    carry: Optional[dict] = None
    rank_offset = 0
    completed = 0
    state = ckpt.pass_state(label) if ckpt is not None else None
    if state is not None and state["completed_chunks"] > 0:
        completed = state["completed_chunks"]
        for i in range(completed):
            bl, ma = ckpt.load_pairs(label, i)
            blocked_parts.append(bl)
            matched_parts.append(ma)
        carry = ckpt.load_carry(label)
        rank_offset = state["rank_offset"]
        chunks, carry_total = state["chunks"], state["carry_total"]
        degenerate, steady = state["degenerate"], state["steady"]
        hits, misses = state["hits"], state["misses"]
        traces = state["traces"]
        overflow = state["overflow"]
        cand_overflow = state["cand_overflow"]
        matcher_evals = state["matcher_evals"]
        pair_overflow = state["pair_overflow"]
        pruned = state.get("pruned", 0)
        retries, escalations = state["retries"], state["escalations"]
        device_bytes = state["device_bytes"]
        if state["load_max"]:
            load_max = np.asarray(state["load_max"], np.int64)
        if state["cand_max"]:
            cand_max = np.asarray(state["cand_max"], np.int64)

    # the ladder's escalated caps are STICKY across chunks: once one chunk
    # forced a doubling, later chunks start at the doubled (cache-warm)
    # shape instead of re-climbing the ladder per chunk
    run_cfg = cfg
    ci = -1
    # the merge is pulled through ``next`` by hand (rather than a plain
    # ``for``) so the k-way merge's own time lands in ``merge`` spans,
    # separate from the ``chunk`` resolve spans it feeds
    merged = iter(rechunk(merged_blocks(runs, chunk_size), chunk_size))
    while True:
        with OBS.span("merge"):
            native = next(merged, None)
        if native is None:
            break
        ci += 1
        if ci < completed:
            continue   # fast-forward: committed by a previous (killed) run
        csp = OBS.span("chunk", index=ci)
        with csp:
            n_nat = int(native["key"].shape[0])
            combined = native if carry is None else \
                E.host_concat([carry, native])
            n_comb = int(combined["key"].shape[0])
            n_carry = n_comb - n_nat
            padded = _host_pad(combined, combined_cap)
            if cfg.window_policy == "adaptive":
                # weff rides only the per-chunk PADDED COPY — the carry
                # (and its checkpointed form) keeps the raw payload
                # schema, so host_concat sees matching fields every chunk
                padded = dict(padded, payload=dict(
                    padded["payload"],
                    _weff=QA.weff_for_keys(np.asarray(padded["key"]),
                                           profile, w_base, w)))
            dev = E.make_entities(padded["key"], padded["eid"],
                                  payload=padded["payload"],
                                  valid=padded["valid"])
            ranks = np.arange(rank_offset - n_carry, rank_offset + n_nat,
                              dtype=np.int64)
            plan, degen = _chunk_plan(cfg, variant, gplan, dev, padded,
                                      ranks, r)
            if csp.enabled:
                csp.set(natives=n_nat, carry=n_carry,
                        degenerate=bool(degen))
                OBS.current_tracer().metrics.counter(
                    "carry_entities").inc(n_carry)

            before = cache.stats.snapshot()
            po, run_cfg, rt, esc = RZ.run_with_recovery(
                lambda c, attempt: runner.resolve_packed(dev, plan, c),
                run_cfg)
            retries, escalations = retries + rt, escalations + esc
            dh, dm, dt = cache.stats.delta(before)
            hits, misses, traces = hits + dh, misses + dm, traces + dt
            steady += int(dh > 0 and dm == 0 and dt == 0)
            degenerate += int(degen)

            blocked_parts.append(po.blocked)
            matched_parts.append(po.matched)
            load_max = np.maximum(load_max, np.asarray(po.load, np.int64))
            if po.cand_count:
                cand_max = np.maximum(cand_max,
                                      np.asarray(po.cand_count, np.int64))
            overflow += po.overflow
            cand_overflow += po.cand_overflow
            matcher_evals += po.matcher_evals
            pair_overflow += po.pair_overflow
            pruned += po.pruned
            device_bytes = max(device_bytes,
                               _entity_bytes(padded) + 4 * combined_cap)

            if oracle is not None:
                # the FULL sequential-SN oracle, accumulated chunk-wise
                # (each combined slice is contiguous in the global order,
                # so chunk oracles union to the global one) — deliberately
                # NOT the variant-faithful set: like facade._host_oracle,
                # the metric must EXPOSE SRP's missed boundary pairs, not
                # absolve them
                if cfg.window_policy == "adaptive":
                    cw = QA.weff_for_keys(np.asarray(combined["key"]),
                                          profile, w_base, w)
                    pairs = sn.adaptive_sn_pairs(combined["key"],
                                                 combined["eid"], cw)
                else:
                    pairs = sn.sequential_sn_pairs(combined["key"],
                                                   combined["eid"], w)
                if cfg.linkage and "src" in combined["payload"]:
                    pairs = LK.filter_cross_source(
                        pairs, combined["eid"], combined["payload"]["src"])
                oracle |= pairs

            chunks += 1
            carry_total += n_carry
            keep = min(w - 1, n_comb)
            carry = E.host_take(combined, slice(n_comb - keep, n_comb))
            rank_offset += n_nat

            if ckpt is not None:
                # commit protocol (checkpoint module doc): pair spool,
                # then seam halo + manifest — the manifest write is the
                # commit point
                t0 = time.perf_counter()
                sp = OBS.span("checkpoint_commit", chunk=ci)
                with sp:
                    ckpt.spool_chunk(label, ci, po.blocked, po.matched)
                    if fault is not None:
                        fault.before_commit(label, ci)
                    ckpt.commit_chunk(
                        label, carry, rank_offset=rank_offset,
                        chunks=chunks, carry_total=carry_total,
                        degenerate=degenerate, steady=steady, hits=hits,
                        misses=misses, traces=traces,
                        overflow=int(overflow),
                        cand_overflow=int(cand_overflow),
                        matcher_evals=int(matcher_evals),
                        pair_overflow=int(pair_overflow),
                        pruned=int(pruned),
                        retries=retries, escalations=escalations,
                        device_bytes=int(device_bytes),
                        load_max=[int(x) for x in load_max],
                        cand_max=[int(x) for x in cand_max])
                if sp.enabled:
                    OBS.current_tracer().metrics.histogram(
                        "checkpoint_commit_ms").observe(
                            1e3 * (time.perf_counter() - t0))
                if fault is not None:
                    fault.after_commit(label, ci)

    dedup = lambda parts: np.unique(np.concatenate(parts)) if parts \
        else np.empty((0,), RES.PACKED_DTYPE)
    blocked = dedup(blocked_parts)
    matched = dedup(matched_parts)
    blocking = BlockingResult(
        pairs=RES.packed_to_frozenset(blocked),
        load=tuple(int(x) for x in load_max), overflow=overflow,
        variant=cfg.variant, runner=runner.name, window=w, num_shards=r,
        cand_count=tuple(int(x) for x in cand_max),
        cand_overflow=cand_overflow, matcher_evals=matcher_evals,
        pair_overflow=pair_overflow, pruned=pruned)
    metrics = None
    if oracle is not None:
        metrics = compute_metrics(blocking.pairs, oracle, total_comparisons)
    stats = StreamStats(
        chunks=chunks, chunk_size=chunk_size, entities=rank_offset,
        runs=len(runs), carry_entities=carry_total,
        degenerate_chunks=degenerate, steady_chunks=steady,
        cache_hits=hits, cache_misses=misses, traces=traces,
        # this pass's own spool only (its sorted runs); the shared raw
        # store is stamped ONCE at the top level — summing per-pass stats
        # must not multiply it by the pass count
        spooled_bytes=runs.spooled_bytes,
        chunk_device_bytes=device_bytes, corpus_bytes=0)
    resilience = RZ.ResilienceStats(
        policy=cfg.on_overflow, retries=retries, escalations=escalations,
        cand_cap=run_cfg.cand_cap or 0, pair_cap=run_cfg.pair_cap or 0,
        auto_caps=auto_caps)
    if ckpt is not None:
        ckpt.mark_pass_done(label)
    return StreamResult(
        blocking=blocking, matches=RES.packed_to_frozenset(matched),
        stream=stats, metrics=metrics, resilience=resilience), oracle


def _union_stream(results: Tuple[StreamResult, ...], cfg: ERConfig,
                  names: Tuple[str, ...], oracle: Optional[Set[Pair]],
                  total_comparisons: int) -> StreamResult:
    """Union per-pass StreamResults: pair/accounting union through the ONE
    shared implementation (``facade.union_blocking``) + additive streaming
    telemetry."""
    blocking = F.union_blocking(results, cfg, results[0].blocking.runner)
    s0 = results[0].stream
    stats = StreamStats(
        chunks=sum(r.stream.chunks for r in results),
        chunk_size=s0.chunk_size, entities=s0.entities,
        runs=sum(r.stream.runs for r in results),
        carry_entities=sum(r.stream.carry_entities for r in results),
        degenerate_chunks=sum(r.stream.degenerate_chunks for r in results),
        steady_chunks=sum(r.stream.steady_chunks for r in results),
        cache_hits=sum(r.stream.cache_hits for r in results),
        cache_misses=sum(r.stream.cache_misses for r in results),
        traces=sum(r.stream.traces for r in results),
        spooled_bytes=sum(r.stream.spooled_bytes for r in results),
        chunk_device_bytes=max(r.stream.chunk_device_bytes
                               for r in results),
        corpus_bytes=s0.corpus_bytes)
    metrics = None
    if oracle is not None:
        metrics = compute_metrics(blocking.pairs, oracle,
                                  total_comparisons)
    rz = [r.resilience for r in results if r.resilience is not None]
    resilience = None if not rz else RZ.ResilienceStats(
        policy=rz[0].policy,
        retries=sum(x.retries for x in rz),
        escalations=sum(x.escalations for x in rz),
        cand_cap=max(x.cand_cap for x in rz),
        pair_cap=max(x.pair_cap for x in rz),
        auto_caps=any(x.auto_caps for x in rz))
    return StreamResult(
        blocking=blocking,
        matches=frozenset().union(*(r.matches for r in results)),
        stream=stats, metrics=metrics, passes=results, pass_names=names,
        resilience=resilience)


def _finalize(res: StreamResult, nbytes: int,
              raw_spool: int) -> StreamResult:
    """Stamp the ingest-wide totals onto a result's stats: the corpus byte
    count and the shared raw store's spool bytes (added exactly once —
    per-pass stats only count their own sorted-run spool)."""
    return replace(res, stream=replace(
        res.stream, corpus_bytes=nbytes,
        spooled_bytes=res.stream.spooled_bytes + raw_spool))


def resolve_stream(chunks: Iterable[dict], cfg: ERConfig, *,
                   chunk_size: Optional[int] = None, mesh=None,
                   axis: str = "data", spool_dir: Optional[str] = None,
                   checkpoint_dir: Optional[str] = None,
                   fault_plan=None) -> StreamResult:
    """Resolve an out-of-core entity stream (see module doc).

    ``chunks``: an iterable of entity dicts (``entities.make_entities``
    schema, any sizes, consumed ONCE); keys may arrive in any order — the
    external merge establishes the global sort.  ``chunk_size``: native
    rows resolved per device call (defaults to the largest ingested chunk);
    peak device residency is one (w−1 + chunk_size)-row window.
    ``spool_dir``: directory for the host spool (None keeps chunks in
    memory).  ``mesh``/``axis`` select devices for the shard_map runner.

    ``checkpoint_dir`` makes the run DURABLE (DESIGN.md §11): progress
    commits crash-atomically after every ingested chunk and every resolved
    chunk, and re-running the same call — or ``api.resume(checkpoint_dir)``
    — continues at the last committed chunk with a bit-identical result.
    The directory doubles as the spool (``spool_dir`` is ignored);
    ``compute_metrics`` is not supported on checkpointed runs.
    ``fault_plan`` (a ``resilience.FaultPlan``) injects deterministic
    crashes at the commit seams — the kill/resume test harness.

    The union of per-chunk pair sets is bit-identical to a monolithic
    ``resolve(all_chunks, cfg)`` — provided capacities don't truncate
    (finite ``cand_cap``/``pair_cap``/``cap_factor`` drop-counts apply per
    chunk, exactly as they would per monolithic call;
    ``on_overflow="retry"`` re-executes overflowed chunks instead).

    Returns a ``StreamResult``; with ``cfg.passes`` the top level holds the
    multi-pass union and ``result.passes`` the per-pass results.  Under
    ``cfg.trace`` the result additionally carries a ``repro.obs``
    ``TraceReport`` (root ``stream`` span over ingest / per-pass sort,
    merge, chunk, and checkpoint-commit child spans — DESIGN.md §12)."""
    if cfg.trace and OBS.current_tracer() is None:
        tracer = OBS.Tracer()
        with OBS.activate(tracer), OBS.span(
                "stream", variant=cfg.variant, runner=cfg.runner,
                window=cfg.window):
            res = _resolve_stream(chunks, cfg, chunk_size=chunk_size,
                                  mesh=mesh, axis=axis, spool_dir=spool_dir,
                                  checkpoint_dir=checkpoint_dir,
                                  fault_plan=fault_plan)
        return F.attach_trace(res, tracer)
    return _resolve_stream(chunks, cfg, chunk_size=chunk_size, mesh=mesh,
                           axis=axis, spool_dir=spool_dir,
                           checkpoint_dir=checkpoint_dir,
                           fault_plan=fault_plan)


def _resolve_stream(chunks: Iterable[dict], cfg: ERConfig, *,
                    chunk_size: Optional[int], mesh, axis: str,
                    spool_dir: Optional[str],
                    checkpoint_dir: Optional[str],
                    fault_plan) -> StreamResult:
    """``resolve_stream`` minus the owner-tracer wrapper (the body runs
    inside the ambient ``stream`` span when tracing is on)."""
    if checkpoint_dir is not None:
        from repro.resilience.checkpoint import StreamCheckpoint
        ckpt = StreamCheckpoint.open(checkpoint_dir, cfg, chunk_size)
        return _resolve_checkpointed(chunks, cfg, ckpt, mesh=mesh,
                                     axis=axis, fault=fault_plan)
    if fault_plan is not None:
        raise ValueError("fault_plan injects crashes at checkpoint commit "
                         "seams and requires checkpoint_dir")
    with OBS.span("ingest"):
        raw, max_len, total, nbytes = _ingest(chunks, spool_dir)
    return _resolve_ingested(raw, max_len, total, nbytes, cfg,
                             chunk_size=chunk_size, mesh=mesh, axis=axis,
                             spool_dir=spool_dir)


def _ingest_checkpointed(chunks: Iterable[dict], store: ChunkStore,
                         ckpt) -> None:
    """The durable twin of ``_ingest``: append each (valid-stripped) chunk
    to the checkpoint's raw store and commit the running ingest totals
    after every append.  A resumed run re-supplies the SAME deterministic
    iterator; the first ``ingest.chunks`` non-empty chunks are skipped —
    they are already durable."""
    skip = ckpt.ingest["chunks"]
    max_len = ckpt.ingest["max_len"]
    total, nbytes = ckpt.ingest["total"], ckpt.ingest["nbytes"]
    seen = 0
    for ents in chunks:
        h = E.to_host(ents)
        valid = np.asarray(h["valid"], bool)
        if not valid.all():
            h = E.host_take(h, valid)
        if int(h["key"].shape[0]) == 0:
            continue
        seen += 1
        if seen <= skip:
            continue         # durably committed by the previous run
        max_len = max(max_len, int(h["key"].shape[0]))
        total += int(h["key"].shape[0])
        nbytes += _entity_bytes(h)
        store.append(h)
        ckpt.commit_raw(max_len, total, nbytes)


def _resolve_checkpointed(chunks: Optional[Iterable[dict]], cfg: ERConfig,
                          ckpt, *, mesh, axis: str,
                          fault) -> StreamResult:
    """Drive one checkpointed run (fresh or resumed) to completion: finish
    ingest if the manifest says it never completed, then resolve with
    every pass fast-forwarding over its committed chunks."""
    if cfg.compute_metrics:
        raise ValueError(
            "compute_metrics is not supported with checkpoint_dir: the "
            "host oracle accumulates over the whole run and is not "
            "persisted; compute metrics on a separate un-checkpointed run")
    raw = ckpt.raw_store()
    if ckpt.phase == "ingest":
        if chunks is None:
            raise ValueError(
                f"checkpoint {ckpt.path!r} stopped during ingest "
                f"({ckpt.ingest['chunks']} chunks committed); resuming "
                f"needs the original chunk iterator re-supplied via "
                f"chunks=...")
        with OBS.span("ingest"):
            _ingest_checkpointed(chunks, raw, ckpt)
        ckpt.ingest_done()
    ing = ckpt.ingest
    res = _resolve_ingested(raw, ing["max_len"], ing["total"],
                            ing["nbytes"], cfg,
                            chunk_size=ckpt.manifest["chunk_size"],
                            mesh=mesh, axis=axis, spool_dir=None,
                            ckpt=ckpt, fault=fault)
    ckpt.mark_done()
    return res


def _total_stream_comparisons(raw: ChunkStore, total: int, cfg: ERConfig,
                              n_r: Optional[int]) -> int:
    """Comparison-space size for the streaming reduction ratio: all pairs,
    or R × S cross-source pairs in linkage mode.  ``link_stream`` passes
    the left-source count it already tallied at ingest; only a direct
    ``resolve_stream`` over PRE-tagged chunks falls back to re-reading src
    columns from the store (metrics path only)."""
    if not cfg.linkage:
        return total * (total - 1) // 2
    if n_r is None:
        n_r = 0
        if "src" in raw.payload_fields():
            n_r = sum(int((raw.load_field(i, "src") == 0).sum())
                      for i in range(len(raw)))
    return n_r * (total - n_r)


def _resolve_ingested(raw: ChunkStore, max_len: int, total: int,
                      nbytes: int, cfg: ERConfig, *, chunk_size, mesh,
                      axis: str, spool_dir, n_lhs: Optional[int] = None,
                      ckpt=None, fault=None) -> StreamResult:
    """The post-ingest half of ``resolve_stream`` (shared with
    ``link_stream``, which builds its own tagged store and passes its
    left-source entity count as ``n_lhs``; the checkpoint path passes
    ``ckpt``/``fault`` through to every pass)."""
    runner = F.make_runner(cfg, mesh=mesh, axis=axis)
    size = chunk_size if chunk_size is not None else max(max_len, 1)
    if size < 1:
        raise ValueError(f"chunk_size must be >= 1, got {size}")
    total_cmp = _total_stream_comparisons(raw, total, cfg, n_lhs) \
        if cfg.compute_metrics else 0
    if not cfg.passes:
        res, _ = _stream_pass(raw, cfg, None, size, runner, spool_dir,
                              "key", total_cmp, ckpt=ckpt, fault=fault)
        return _finalize(res, nbytes, raw.spooled_bytes)
    sub = cfg.with_(passes=())
    results, oracle = [], (set() if cfg.compute_metrics else None)
    for spec in cfg.passes:
        res, orc = _stream_pass(raw, sub, spec, size, runner, spool_dir,
                                spec.name, total_cmp, ckpt=ckpt,
                                fault=fault)
        results.append(res)
        if oracle is not None:
            oracle |= orc
    return _finalize(
        _union_stream(tuple(results), cfg,
                      tuple(p.name for p in cfg.passes), oracle, total_cmp),
        nbytes, raw.spooled_bytes)


def _untag_stream(res: StreamResult, offset: int) -> StreamResult:
    """Map a StreamResult (and its passes) from the merged linkage eid
    space back to (lhs_eid, rhs_eid) tuples."""
    blocking = replace(
        res.blocking,
        pairs=frozenset(LK.untag_pairs(res.blocking.pairs, offset)))
    return replace(
        res, blocking=blocking,
        matches=frozenset(LK.untag_pairs(res.matches, offset)),
        passes=tuple(_untag_stream(p, offset) for p in res.passes))


def link_stream(lhs_chunks: Iterable[dict], rhs_chunks: Iterable[dict],
                cfg: ERConfig, *, chunk_size: Optional[int] = None,
                mesh=None, axis: str = "data",
                spool_dir: Optional[str] = None) -> StreamResult:
    """Dual-source (R × S) record linkage over out-of-core streams.

    Both iterables are ingested once, straight into the (spoolable) chunk
    store — lhs first, because its maximum eid fixes the id-space offset
    rhs entities are shifted by, exactly like ``linkage.tag_sources``.
    Pairs come back untagged as (lhs_eid, rhs_eid) in each source's
    original id space.  Everything else matches ``resolve_stream``,
    including the ``cfg.trace`` TraceReport."""
    cfg = cfg.with_(linkage=True)
    if cfg.trace and OBS.current_tracer() is None:
        tracer = OBS.Tracer()
        with OBS.activate(tracer), OBS.span(
                "stream", variant=cfg.variant, runner=cfg.runner,
                linkage=True):
            res = _link_stream(lhs_chunks, rhs_chunks, cfg,
                               chunk_size=chunk_size, mesh=mesh, axis=axis,
                               spool_dir=spool_dir)
        return F.attach_trace(res, tracer)
    return _link_stream(lhs_chunks, rhs_chunks, cfg, chunk_size=chunk_size,
                        mesh=mesh, axis=axis, spool_dir=spool_dir)


def _link_stream(lhs_chunks: Iterable[dict], rhs_chunks: Iterable[dict],
                 cfg: ERConfig, *, chunk_size: Optional[int], mesh,
                 axis: str, spool_dir: Optional[str]) -> StreamResult:
    """``link_stream`` minus the owner-tracer wrapper (``cfg`` arrives with
    ``linkage`` already set)."""
    store = ChunkStore(spool_dir, prefix="raw")
    max_eid = -1

    def tagger(tag: int, shift: int):
        def transform(h: dict) -> dict:
            nonlocal max_eid
            n = int(h["key"].shape[0])
            shifted = h["eid"].astype(np.int64) + shift
            if int(shifted.max()) >= 2 ** 31:
                # a wrapped int32 eid would sign-extend into the composite
                # merge key's high bits and silently corrupt the global
                # sort order (and untag_pairs' >= offset test)
                raise ValueError(
                    f"rhs eid {int(shifted.max()) - shift} + id-space "
                    f"offset {shift} overflows the int32 eid schema; "
                    f"renumber source eids below 2^31 - offset")
            h = {"key": h["key"], "eid": shifted.astype(np.int32),
                 "valid": h["valid"],
                 "payload": dict(h["payload"],
                                 src=np.full((n,), tag, np.int32))}
            max_eid = max(max_eid, int(h["eid"].max()))
            return h
        return transform

    with OBS.span("ingest"):
        _, len_l, total_l, bytes_l = _ingest(lhs_chunks, spool_dir,
                                             store=store,
                                             transform=tagger(0, 0))
        offset = max_eid + 1
        _, len_r, total_r, bytes_r = _ingest(rhs_chunks, spool_dir,
                                             store=store,
                                             transform=tagger(1, offset))
    max_len = max(len_l, len_r)
    total = total_l + total_r
    nbytes = bytes_l + bytes_r
    res = _resolve_ingested(store, max_len, total, nbytes, cfg,
                            chunk_size=chunk_size, mesh=mesh, axis=axis,
                            spool_dir=spool_dir, n_lhs=total_l)
    return _untag_stream(res, offset)
