"""External merge sort over entity chunks (the streaming sort phase).

The paper's MapReduce shuffle sorts the corpus globally by blocking key; on
one accelerator the same global order is produced out-of-core in two steps:

  1. **Per-chunk device sort** (``entities.sort_chunk``): each ingested
     chunk is sorted by (key, eid) on device — the O(n log n) work — and
     lands back on host as a *sorted run* (spooled via ``ChunkStore``).
  2. **K-way galloping merge** (``merged_blocks``): runs are merged on the
     single int64 composite key ``(key << 32) | eid``
     (``entities.composite_order_key``).  Each step takes the longest
     prefix of the smallest-headed run that stays below every other run's
     head (one ``searchsorted`` — a gallop, not an element-wise heap), so
     the merge is O(total + k·log) with only run INDICES (key/eid) resident
     plus the runs currently contributing rows; payload arrays are loaded
     per run on first contribution and released when the run is exhausted.

The merged stream is yielded as host blocks of at most ``block`` rows — the
consumer (``resolver``) never sees, and the process never materializes, the
full sorted corpus in one array.
"""
from __future__ import annotations

from typing import Iterator, List

import numpy as np

from repro.core import entities as E
from repro.stream.store import ChunkStore


def _composites(runs: ChunkStore) -> List[np.ndarray]:
    """Per-run int64 merge keys, loaded from the index columns only."""
    return [E.composite_order_key(runs.load_index(i))
            for i in range(len(runs))]


def merged_blocks(runs: ChunkStore, block: int) -> Iterator[dict]:
    """Yield the globally (key, eid)-sorted stream of all ``runs`` as host
    entity blocks of at most ``block`` rows (see module doc).

    Runs must each already be sorted by (key, eid) — ``entities.sort_chunk``
    output.  Equal composite keys across runs (duplicate (key, eid) pairs)
    are emitted in run order, one row at a time, so the merge always makes
    progress and stays deterministic."""
    if block < 1:
        raise ValueError(f"block must be >= 1, got {block}")
    comps = _composites(runs)
    cursors = [0] * len(runs)
    active = [i for i in range(len(runs)) if comps[i].shape[0] > 0]
    open_runs: dict = {}
    while active:
        i = min(active, key=lambda j: comps[j][cursors[j]])
        others = [comps[j][cursors[j]] for j in active if j != i]
        ci = comps[i]
        if others:
            end = int(np.searchsorted(ci, min(others), side="left"))
            if end <= cursors[i]:           # tie on the composite key:
                end = cursors[i] + 1        # emit one row, stay stable
        else:
            end = ci.shape[0]
        end = min(end, cursors[i] + block)
        if i not in open_runs:              # payload loads lazily, once
            open_runs[i] = runs.load(i)
        yield E.host_take(open_runs[i], slice(cursors[i], end))
        cursors[i] = end
        if end == ci.shape[0]:
            active.remove(i)
            open_runs.pop(i, None)          # release the exhausted run


def rechunk(blocks: Iterator[dict], size: int) -> Iterator[dict]:
    """Re-block a stream of host entity dicts into chunks of EXACTLY
    ``size`` rows (the final chunk may be shorter) — the fixed native chunk
    width that keeps every streamed shard program the same shape, so each
    chunk after the first hits the executable cache."""
    if size < 1:
        raise ValueError(f"size must be >= 1, got {size}")
    buf: List[dict] = []
    total = 0
    for b in blocks:
        buf.append(b)
        total += int(b["key"].shape[0])
        while total >= size:
            big = E.host_concat(buf)
            yield E.host_take(big, slice(0, size))
            rest = E.host_take(big, slice(size, None))
            total = int(rest["key"].shape[0])
            buf = [rest] if total else []
    if total:
        yield E.host_concat(buf)
