"""``repro.stream`` — out-of-core streaming entity resolution.

The streaming twin of ``repro.api``: consume an ITERATOR of entity chunks,
globally sort-partition them out-of-core (per-chunk device sorts + k-way
host merge, optionally spooled to disk), and drive the existing variant ×
runner × engine machinery chunk-by-chunk with a w−1 seam halo — the union
of emitted pairs is bit-identical to a monolithic ``api.resolve`` while
peak device residency stays bounded by ``chunk_size``.

    from repro import stream
    from repro.data.corpus import synth_entity_chunks

    res = stream.resolve_stream(
        synth_entity_chunks(seed=0, n=100_000, chunk=10_000),
        api.ERConfig(variant="repsn", hops=7, runner="vmap", num_shards=8),
        spool_dir="/tmp/er-spool")        # host disk, not device memory
    res.pairs                  # == monolithic resolve on the full corpus
    res.stream.steady_chunks   # chunks served from the executable cache
    res.stream.chunk_device_bytes  # peak device input bytes (vs corpus_bytes)

Pieces:

  * resolver      ``resolve_stream`` / ``link_stream`` + ``StreamResult``
                  / ``StreamStats`` (the chunked drive loop, seam-halo
                  carry, SRP global-rank routing, multi-pass orchestration)
  * external_sort per-chunk device sorts + galloping k-way merge
  * store         ``ChunkStore``: the in-memory-or-disk chunk spool
"""
from repro.stream.external_sort import merged_blocks, rechunk
from repro.stream.resolver import (StreamResult, StreamStats, link_stream,
                                   resolve_stream)
from repro.stream.store import ChunkStore

__all__ = [
    "resolve_stream", "link_stream",
    "StreamResult", "StreamStats",
    "ChunkStore", "merged_blocks", "rechunk",
]
