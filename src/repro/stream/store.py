"""Spooled chunk storage — the out-of-core buffer behind ``resolve_stream``.

A ``ChunkStore`` holds a sequence of HOST entity chunks (the numpy schema of
``core.entities.to_host``) either in memory (default) or spooled to disk as
``.npz`` files (``spool_dir``) — the stand-in for the paper's HDFS sequence
files.  Spooled chunks are written once at append time and re-read on
demand, so the resident set during the external merge is the per-run index
plus the runs currently being consumed, never the whole corpus.

Two access granularities keep the merge cheap:

  * ``load(i)``        the full chunk (key/eid/valid + payload) — read when
                       a merge block actually gathers the chunk's rows
  * ``load_index(i)``  only ``key``/``eid`` — the 8–12 bytes/entity the
                       k-way merge needs to ORDER the stream (``.npz``
                       members are decompressed lazily, so payload bytes
                       stay on disk)
"""
from __future__ import annotations

import os
from typing import Dict, Iterator, List, Optional

import numpy as np

_PAYLOAD_PREFIX = "payload__"
_TMP_SUFFIX = ".tmp"


def atomic_savez(path: str, **arrays) -> None:
    """Write an ``.npz`` crash-atomically: serialize to ``{path}.tmp`` in
    the same directory, then ``os.replace`` onto the final name.  A reader
    (or a resumed run) therefore sees either the complete previous file or
    the complete new one, never a torn write; a crash mid-write leaves only
    a ``.tmp`` leftover that re-attachment/disposal sweeps up."""
    tmp = path + _TMP_SUFFIX
    with open(tmp, "wb") as f:       # file object: savez must not append
        np.savez(f, **arrays)        # its .npz suffix to the tmp name
    os.replace(tmp, path)


def atomic_write_json(path: str, obj) -> None:
    """Crash-atomic JSON write (same tmp-then-``os.replace`` contract as
    ``atomic_savez``) — the manifest writer of ``repro.resilience``."""
    import json
    tmp = path + _TMP_SUFFIX
    with open(tmp, "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)
    os.replace(tmp, path)


class ChunkStore:
    """Append-only sequence of host entity chunks, optionally disk-spooled.

    ``spool_dir=None`` keeps chunks in memory (tests, small corpora);
    otherwise each appended chunk is written to
    ``{spool_dir}/{prefix}{i:06d}.npz`` and dropped from memory.  All
    chunks must share one payload schema (validated on append)."""

    def __init__(self, spool_dir: Optional[str] = None,
                 prefix: str = "chunk"):
        self.spool_dir = spool_dir
        self.prefix = prefix
        self.spooled_bytes = 0
        self._mem: List[Optional[dict]] = []
        self._paths: List[str] = []
        self._schema: Optional[tuple] = None
        if spool_dir is not None:
            os.makedirs(spool_dir, exist_ok=True)

    def __len__(self) -> int:
        return len(self._mem)

    @classmethod
    def attach(cls, spool_dir: str, prefix: str = "chunk",
               count: Optional[int] = None) -> "ChunkStore":
        """Re-open an existing on-disk spool (the checkpoint/resume path).

        Adopts ``{prefix}{i:06d}.npz`` for consecutive ``i`` from 0; with
        ``count`` (a manifest's durably-committed chunk total) exactly that
        many files are adopted — later files and ``.tmp`` leftovers are
        DELETED, since they can only be the un-committed debris of the
        append that was in flight when the previous run died."""
        store = cls(spool_dir, prefix=prefix)
        i = 0
        while count is None or i < count:
            path = os.path.join(spool_dir, f"{prefix}{i:06d}.npz")
            if not os.path.exists(path):
                break
            store._mem.append(None)
            store._paths.append(path)
            store.spooled_bytes += os.path.getsize(path)
            i += 1
        if count is not None and i < count:
            raise FileNotFoundError(
                f"spool {spool_dir!r} holds only {i} '{prefix}' chunks but "
                f"the manifest committed {count}; the checkpoint is "
                f"corrupt (files deleted behind the manifest's back)")
        for name in os.listdir(spool_dir):   # sweep un-committed debris
            if not name.startswith(prefix):
                continue
            path = os.path.join(spool_dir, name)
            if name.endswith(_TMP_SUFFIX) or path not in store._paths:
                try:
                    os.remove(path)
                except OSError:
                    pass
        if len(store) > 0:
            store._check_schema(store.load(0))
        return store

    @property
    def n_entities(self) -> int:
        """Total rows across all stored chunks."""
        return sum(self.load_index(i)["key"].shape[0]
                   for i in range(len(self)))

    def _check_schema(self, ents: dict) -> None:
        schema = tuple(sorted(ents["payload"]))
        if self._schema is None:
            self._schema = schema
        elif schema != self._schema:
            raise ValueError(f"chunk payload schema {schema} does not match "
                             f"the store's {self._schema}")

    def append(self, ents: dict) -> None:
        """Store one host entity chunk (spooling it to disk when the store
        was built with a ``spool_dir``)."""
        self._check_schema(ents)
        if self.spool_dir is None:
            self._mem.append(ents)
            self._paths.append("")
            return
        i = len(self._mem)
        path = os.path.join(self.spool_dir, f"{self.prefix}{i:06d}.npz")
        # tmp-then-rename: a crash mid-append can never leave a torn chunk
        # file behind for a resumed run to trip over
        atomic_savez(path, key=ents["key"], eid=ents["eid"],
                     valid=ents["valid"],
                     **{_PAYLOAD_PREFIX + k: v
                        for k, v in ents["payload"].items()})
        self.spooled_bytes += os.path.getsize(path)
        self._mem.append(None)
        self._paths.append(path)

    def load(self, i: int) -> dict:
        """Read chunk ``i`` back as a host entity dict."""
        if self._mem[i] is not None:
            return self._mem[i]
        with np.load(self._paths[i], allow_pickle=False) as z:
            return {
                "key": z["key"], "eid": z["eid"], "valid": z["valid"],
                "payload": {k[len(_PAYLOAD_PREFIX):]: z[k]
                            for k in z.files
                            if k.startswith(_PAYLOAD_PREFIX)},
            }

    def load_index(self, i: int) -> Dict[str, np.ndarray]:
        """Read only chunk ``i``'s ``key``/``eid`` columns (the merge
        index; payload members stay unread on disk)."""
        if self._mem[i] is not None:
            return {"key": self._mem[i]["key"], "eid": self._mem[i]["eid"]}
        with np.load(self._paths[i], allow_pickle=False) as z:
            return {"key": z["key"], "eid": z["eid"]}

    def load_field(self, i: int, name: str) -> np.ndarray:
        """Read one payload column of chunk ``i`` (``.npz`` members load
        lazily, so other payload arrays stay on disk — the metrics path
        counts ``src`` tags this way without re-reading the corpus)."""
        if self._mem[i] is not None:
            return self._mem[i]["payload"][name]
        with np.load(self._paths[i], allow_pickle=False) as z:
            return z[_PAYLOAD_PREFIX + name]

    def payload_fields(self) -> tuple:
        """Sorted payload field names of the stored schema (empty before
        the first append)."""
        return self._schema or ()

    def dispose(self) -> None:
        """Drop every stored chunk and delete its spooled file (best-effort
        — a file already gone is not an error).  The compaction path of the
        serving index (``repro.serve``) rewrites its sorted runs into a
        fresh store and disposes the old one so tombstoned bytes are
        actually reclaimed from the spool directory."""
        for path in self._paths:
            if path:
                for p in (path, path + _TMP_SUFFIX):
                    try:
                        os.remove(p)
                    except OSError:
                        pass     # already gone (e.g. a crash raced us)
        self.spooled_bytes = 0
        self._mem = []
        self._paths = []

    def __iter__(self) -> Iterator[dict]:
        """Yield every chunk in append order (each loaded on demand)."""
        for i in range(len(self)):
            yield self.load(i)
