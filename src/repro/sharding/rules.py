"""Logical-axis sharding rules.

Params and activations are annotated with *logical* axis names; a ``Rules``
instance (bound to a mesh) resolves them to ``PartitionSpec``s, dropping any
mesh axis that does not divide the concrete dim (GSPMD requires divisibility
for jit inputs; intermediates may be constrained unevenly but we stay even for
anything that is a step-function input, i.e. params / optimizer state / caches).

Logical axes used throughout the framework:

  batch        activation batch                  -> ("pod","data")
  seq          activation sequence               -> None (or "model" for SP)
  kv_seq       kv-cache sequence (decode)        -> "model" when seq_shard_kv
  embed        param d_model dim (FSDP)          -> "data" when fsdp else None
  embed_act    activation d_model dim            -> None
  qkv          fused attention proj out dim      -> "model"
  heads        per-head activation dim           -> "model" (uneven ok)
  d_ff         mlp hidden                        -> "model"
  experts      MoE expert dim                    -> "model" (EP)
  vocab        vocab / logits dim                -> "model"
  layers       scan-stacked layer-group dim      -> None
  none         explicitly replicated             -> None
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, None, Tuple[str, ...]]


class Rules:
    def __init__(self, mesh: Mesh, *, fsdp: bool = True,
                 seq_shard_kv: bool = False, context_parallel: bool = False,
                 seq_parallel: bool = False):
        self.mesh = mesh
        self.fsdp = fsdp
        axes = mesh.axis_names
        batch: Tuple[str, ...] = tuple(a for a in ("pod", "data") if a in axes)
        self.table: dict[str, Axis] = {
            "batch": batch,
            "seq": None,
            # Megatron-style sequence parallelism: the residual stream is
            # sharded over 'model' on the seq dim between blocks, turning
            # activation(-gradient) all-reduces into reduce-scatter+
            # all-gather pairs (~1/8 the ring bytes at 16-way).
            "residual_seq": ("model",) if seq_parallel else None,
            "kv_seq": ("model",) if seq_shard_kv else None,
            "embed": ("data",) if fsdp and "data" in axes else None,
            "embed_act": None,
            "qkv": ("model",),
            "heads": ("model",),
            "d_ff": ("model",),
            "experts": ("model",),
            "vocab": ("model",),
            "layers": None,
            "none": None,
        }
        if context_parallel:
            # long-context decode (batch=1): spread kv over data+model
            self.table["kv_seq"] = tuple(
                a for a in ("data", "model") if a in axes)
            self.table["batch"] = tuple(a for a in ("pod",) if a in axes)

    def _present(self, axis: Axis) -> Tuple[str, ...]:
        """Filter to axes that exist in the mesh (partial meshes: tests and
        single-axis CPU topologies)."""
        if axis is None:
            return ()
        if isinstance(axis, str):
            axis = (axis,)
        return tuple(a for a in axis if a in self.mesh.shape)

    def axis_size(self, axis: Axis) -> int:
        n = 1
        for a in self._present(axis):
            n *= self.mesh.shape[a]
        return n

    def spec(self, logical: Sequence[Optional[str]],
             dims: Optional[Sequence[int]] = None) -> P:
        """Resolve logical axis names to a PartitionSpec.

        If ``dims`` is given, any mesh axis that does not evenly divide the
        corresponding dim is dropped (replicated) — keeps jit inputs legal.
        """
        out = []
        for i, name in enumerate(logical):
            if name is None:
                out.append(None)
                continue
            phys = self._present(self.table[name])
            if len(phys) == 0:
                out.append(None)
                continue
            if dims is not None:
                sz = self.axis_size(phys)
                if sz == 0 or dims[i] % sz != 0:
                    out.append(None)
                    continue
            out.append(phys if len(phys) > 1 else phys[0])
        return P(*out)

    def sharding(self, logical: Sequence[Optional[str]],
                 dims: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical, dims))

    def constrain(self, x: jax.Array,
                  logical: Sequence[Optional[str]]) -> jax.Array:
        """with_sharding_constraint by logical names (uneven dims allowed)."""
        out = []
        for i, name in enumerate(logical):
            if name is None:
                out.append(None)
                continue
            phys = self._present(self.table[name])
            if len(phys) == 0:
                out.append(None)
            else:
                out.append(phys if len(phys) > 1 else phys[0])
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*out)))


def tree_shardings(rules: Rules, spec_tree, shape_tree):
    """Map a tree of logical-axis tuples + matching ShapeDtypeStructs to
    NamedShardings (dropping non-divisible axes per leaf)."""
    return jax.tree.map(
        lambda logical, sds: rules.sharding(logical, sds.shape),
        spec_tree, shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
