"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Step functions: train_step / prefill_step / decode_step factories.

Each factory returns (fn, in_shardings, out_shardings, example_inputs) so the
launcher can jit + lower uniformly for real runs and for the dry-run.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import lm
from repro.sharding.rules import Rules
from repro.train import optim


# -- state -----------------------------------------------------------------------

def train_state_init(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    params = lm.lm_init(key, cfg, dtype)
    return {"params": params, "opt": optim.adamw_init(params)}


def train_state_specs(cfg: ModelConfig):
    ps = lm.lm_specs(cfg)
    return {"params": ps, "opt": optim.adamw_specs(ps)}


# -- logical->sharding resolution ---------------------------------------------------

def _is_logical_leaf(x):
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def resolve_shardings(rules: Rules, spec_tree, shape_tree):
    """spec_tree of logical tuples + shape tree (arrays or SDS) -> NamedShardings."""
    def resolve(logical, arr):
        return rules.sharding(logical, arr.shape)
    return jax.tree.map(resolve, spec_tree, shape_tree,
                        is_leaf=_is_logical_leaf)


# -- train -------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, run: RunConfig, rules: Rules,
                    oc: Optional[optim.OptConfig] = None):
    oc = oc or optim.OptConfig()

    def loss_fn(params, batch):
        return lm.lm_loss(params, cfg, batch, rules=rules, remat=run.remat,
                          chunk_q=run.attn_chunk_q, chunk_kv=run.attn_chunk_kv)

    def train_step(state, batch):
        if run.microbatch and run.microbatch > 1:
            nmb = run.microbatch
            b = batch["tokens" if "tokens" in batch else "embeds"].shape[0]
            assert b % nmb == 0
            mb = jax.tree.map(
                lambda x: x.reshape((nmb, b // nmb) + x.shape[1:]), batch)

            def acc_body(carry, mbatch):
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(state["params"], mbatch)
                carry = jax.tree.map(jnp.add, carry, g)
                return carry, metrics
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            grads, metrics = jax.lax.scan(acc_body, g0, mb)
            grads = jax.tree.map(lambda g: g / nmb, grads)
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(state["params"], batch)
        new_params, new_opt, om = optim.adamw_update(
            grads, state["opt"], state["params"], oc)
        metrics = dict(metrics, **om)
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def train_batch_spec(cfg: ModelConfig, run: RunConfig):
    """Logical sharding spec tree for a train batch."""
    if cfg.frontend:
        return {"embeds": ("batch", None, None), "labels": ("batch", None)}
    return {"tokens": ("batch", None), "labels": ("batch", None)}


def train_batch_shapes(cfg: ModelConfig, run: RunConfig):
    b, s = run.shape.global_batch, run.shape.seq_len
    if cfg.frontend:
        return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}


# -- serve: prefill ---------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, run: RunConfig, rules: Rules):
    def prefill_step(params, batch, cache):
        logits, new_cache, _ = lm.forward(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), cache=cache, rules=rules,
            remat="none", chunk_q=run.attn_chunk_q,
            chunk_kv=run.attn_chunk_kv, logits_last_only=True)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return prefill_step


# -- serve: decode ----------------------------------------------------------------

def make_decode_step(cfg: ModelConfig, run: RunConfig, rules: Rules):
    def decode_step(params, tokens, cache, cache_pos):
        """tokens: (B,1) int32 — current token; cache_pos: () int32 = number
        of tokens so far including this one. Returns (next_tok, new_cache)."""
        logits, new_cache, _ = lm.forward(
            params, cfg, tokens=tokens, cache=cache, cache_pos=cache_pos,
            rules=rules, remat="none")
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, new_cache
    return decode_step


def serve_batch_shapes(cfg: ModelConfig, run: RunConfig, *, decode: bool):
    b, s = run.shape.global_batch, run.shape.seq_len
    if decode:
        return {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
    if cfg.frontend:
        return {"embeds": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                               jnp.bfloat16)}
    return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}


def serve_batch_spec(cfg: ModelConfig, *, decode: bool):
    if decode:
        return {"tokens": ("batch", None)}
    if cfg.frontend:
        return {"embeds": ("batch", None, None)}
    return {"tokens": ("batch", None)}


def cache_shapes(cfg: ModelConfig, run: RunConfig, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree for the cache (no allocation)."""
    return jax.eval_shape(
        lambda: lm.cache_init(cfg, run.shape.global_batch,
                              run.shape.seq_len, dtype))
