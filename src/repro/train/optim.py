"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

AdamW with FSDP/ZeRO-sharded states, global-norm clipping, wd, schedules.

Optimizer moments inherit the parameter sharding (params are already sharded
over data x model when FSDP is on, so the optimizer state is fully sharded —
ZeRO comes for free).  All moments in f32 regardless of param dtype.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    schedule: str = "cosine"      # "cosine" | "constant"


def lr_at(oc: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    if oc.schedule == "constant":
        return oc.lr * warm
    prog = jnp.clip((step - oc.warmup_steps) /
                    jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (oc.min_lr_ratio + (1 - oc.min_lr_ratio) * cos)


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_specs(param_specs):
    """Moments share the params' logical sharding; step replicated."""
    return {"m": param_specs, "v": param_specs, "step": ()}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, oc: OptConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if oc.clip_norm > 0 else jnp.float32(1.0)
    lr = lr_at(oc, step)
    b1c = 1 - oc.b1 ** step.astype(jnp.float32)
    b2c = 1 - oc.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = oc.b1 * m + (1 - oc.b1) * g
        v_new = oc.b2 * v + (1 - oc.b2) * g * g
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + oc.eps)
        # decoupled weight decay (skip 1-d params: norms, biases)
        if p.ndim >= 2:
            delta = delta + oc.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
