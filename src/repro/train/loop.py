"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Fault-tolerant training loop.

Production behaviours (1000+ node posture, scaled to this harness):
  * checkpoint every N steps (atomic, async-capable) + resume-from-latest
  * deterministic data order (batch = f(seed, step)) so recovery replays
    the exact token stream
  * step-level fault barrier: a failing step (device error, NaN loss with
    ``halt_on_nan``) triggers restore-from-checkpoint instead of crashing
    the job; repeated failures at the same step abort (poison-pill guard)
  * straggler mitigation hook: per-step wall-time EWMA; steps slower than
    ``straggler_factor``x the EWMA are counted and reported (on real fleets
    this signal feeds re-scheduling; here it feeds telemetry/tests)
  * elastic rescale: ``resume onto a different mesh`` is exercised by
    tests/test_checkpoint.py via Checkpointer.restore(shardings=new_mesh)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.train.checkpoint import Checkpointer


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    log_every: int = 10
    halt_on_nan: bool = True
    max_retries_per_step: int = 2
    straggler_factor: float = 3.0


@dataclass
class LoopStats:
    steps: int = 0
    restores: int = 0
    stragglers: int = 0
    losses: list = field(default_factory=list)
    step_times: list = field(default_factory=list)


def train_loop(train_step: Callable, state, batcher, ckpt: Checkpointer,
               cfg: LoopConfig, *, shardings=None,
               inject_fault_at: Optional[int] = None) -> tuple[Any, LoopStats]:
    """Runs to cfg.total_steps with checkpoint/restart fault tolerance.

    ``inject_fault_at``: test hook — raises a simulated device failure once
    at that step to exercise the restore path."""
    stats = LoopStats()
    start_step, restored = ckpt.restore_latest(state, shardings=shardings) \
        if ckpt.latest_step() is not None else (None, None)
    step = 0
    if restored is not None:
        state = restored
        step = start_step
    injected = [False]
    ewma = None
    retries = 0

    while step < cfg.total_steps:
        batch = batcher.batch(step)
        t0 = time.time()
        try:
            if inject_fault_at is not None and step == inject_fault_at \
                    and not injected[0]:
                injected[0] = True
                raise RuntimeError("injected device failure")
            new_state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            if cfg.halt_on_nan and not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")
        except (RuntimeError, FloatingPointError) as e:
            stats.restores += 1
            retries += 1
            if retries > cfg.max_retries_per_step:
                raise RuntimeError(
                    f"step {step} failed {retries}x; aborting") from e
            last = ckpt.latest_step()
            if last is not None:
                step, state = last, ckpt.restore(
                    last, state, shardings=shardings)
            else:
                # no checkpoint yet: restart from the initial state
                pass
            continue
        retries = 0
        dt = time.time() - t0
        ewma = dt if ewma is None else 0.9 * ewma + 0.1 * dt
        if dt > cfg.straggler_factor * ewma and stats.steps > 3:
            stats.stragglers += 1
        state = new_state
        step += 1
        stats.steps += 1
        stats.losses.append(loss)
        stats.step_times.append(dt)
        if step % cfg.ckpt_every == 0 or step == cfg.total_steps:
            ckpt.save(step, state)
        if step % cfg.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"({dt*1e3:.0f} ms/step)", flush=True)
    ckpt.wait()
    return state, stats
