"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Checkpointing: atomic, resumable, mesh-elastic.

  * save: gather to host, write <dir>/step_N.npz.tmp, fsync, atomic rename,
    then update manifest.json — a crash mid-write never corrupts the latest
    checkpoint.
  * restore: load the newest complete step; ``shardings`` may target ANY mesh
    (elastic re-scale: checkpoints are stored unsharded, device_put lays them
    out for the new topology — tested in tests/test_checkpoint.py).
  * async: optional background thread so the train loop overlaps the write
    with the next step (double-buffered via host copies).
"""
from __future__ import annotations

import json
import os
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[jax.tree_util.keystr(path)] = np.asarray(leaf)
    return flat


def _unflatten_into(tree_like, flat: dict):
    leaves, treedef = jax.tree_util.tree_flatten(tree_like)
    paths = [jax.tree_util.keystr(p) for p, _ in
             jax.tree_util.tree_flatten_with_path(tree_like)[0]]
    new_leaves = []
    for p, ref in zip(paths, leaves):
        arr = flat[p]
        assert arr.shape == ref.shape, (p, arr.shape, ref.shape)
        new_leaves.append(arr.astype(ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


class Checkpointer:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, state, *, extra: Optional[dict] = None):
        flat = _flatten(state)            # host copies (synchronous gather)
        if self.async_save:
            if self._thread is not None:
                self._thread.join()
            self._thread = threading.Thread(
                target=self._write, args=(step, flat, extra or {}))
            self._thread.start()
        else:
            self._write(step, flat, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, flat: dict, extra: dict):
        tmp = self.dir / f"step_{step}.npz.tmp"
        final = self.dir / f"step_{step}.npz"
        with open(tmp, "wb") as f:
            np.savez(f, **{k: v for k, v in flat.items()})
            f.flush()
            os.fsync(f.fileno())
        os.rename(tmp, final)            # atomic on POSIX
        manifest = {"latest_step": step, "time": time.time(), **extra}
        mtmp = self.dir / "manifest.json.tmp"
        mtmp.write_text(json.dumps(manifest))
        os.rename(mtmp, self.dir / "manifest.json")
        self._gc()

    def _gc(self):
        ckpts = sorted(self.dir.glob("step_*.npz"),
                       key=lambda p: int(p.stem.split("_")[1]))
        for p in ckpts[:-self.keep]:
            p.unlink()

    # -- restore -------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        m = self.dir / "manifest.json"
        if not m.exists():
            ckpts = sorted(self.dir.glob("step_*.npz"),
                           key=lambda p: int(p.stem.split("_")[1]))
            return int(ckpts[-1].stem.split("_")[1]) if ckpts else None
        return int(json.loads(m.read_text())["latest_step"])

    def restore(self, step: int, state_like, *, shardings=None):
        """state_like: pytree of arrays/SDS giving structure+shape+dtype.
        shardings: optional matching tree of NamedShardings (ANY mesh —
        elastic restore)."""
        path = self.dir / f"step_{step}.npz"
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_into(state_like, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state

    def restore_latest(self, state_like, *, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, state_like, shardings=shardings)
