"""Persistent sorted index — the long-lived corpus behind the serving layer.

An LSM-flavored adaptation of the streaming sort phase (``repro.stream``):
the corpus lives as SORTED RUNS in a ``stream.store.ChunkStore`` (payload on
spool, exactly like the external merge), while a small resident rank index —
the per-run int64 composite keys ``(key << 32) | eid`` plus one flat sorted
array of all LIVE composites — answers the only questions the delta matcher
asks in O(log n): where does an entity land in the global (key, eid) sort
order, and which entities occupy a contiguous rank range.

  * ``insert(run)``   appends one device-sorted run (``entities.sort_chunk``
                      output) and folds its key distribution into the
                      incrementally-merged ``balance.KeyProfile`` — planning
                      state stays exact under writes.
  * ``delete(eids)``  tombstones rows in place (per-run live masks; the
                      profile is decremented exactly via
                      ``KeyProfile.merge(..., remove=True)``).  Deleted rows
                      stay on spool until compaction.
  * ``take_comp_range``  materializes the live entities of one composite-key
                      range — the w-neighborhood gather of the delta matcher.
  * ``compact()``     rewrites every run into fresh generation runs through
                      the external-sort machinery (``merged_blocks`` k-way
                      gallop over a tombstone-masked view + ``rechunk``),
                      reclaiming tombstoned rows and spool bytes;
                      ``maybe_compact`` triggers it when the run count or
                      tombstone fraction crosses a threshold.

The flat live-composite array costs 8 bytes/entity resident (the payload
never is) and is maintained incrementally — one ``np.insert``/``np.delete``
per micro-batch, not a re-sort.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro import balance as B
from repro.core import entities as E
from repro.stream.external_sort import merged_blocks, rechunk
from repro.stream.store import ChunkStore, atomic_write_json

_INDEX_MANIFEST = "INDEX.json"

_EID_MASK = np.int64(0xFFFFFFFF)


class _MaskedRuns:
    """Duck-typed ChunkStore view that hides tombstoned rows: masking a
    sorted run keeps it sorted, so ``external_sort.merged_blocks`` consumes
    the view unchanged — the compaction merge IS the streaming merge."""

    def __init__(self, runs: ChunkStore, masks: List[np.ndarray]):
        self._runs = runs
        self._masks = masks

    def __len__(self) -> int:
        return len(self._runs)

    def load_index(self, i: int) -> Dict[str, np.ndarray]:
        idx = self._runs.load_index(i)
        m = self._masks[i]
        return {"key": idx["key"][m], "eid": idx["eid"][m]}

    def load(self, i: int) -> dict:
        return E.host_take(self._runs.load(i), self._masks[i])


class SortedIndex:
    """Persistent sorted index over one entity corpus (see module doc).

    ``spool_dir=None`` keeps runs in memory; ``segment_rows`` is the run
    width compaction rewrites to; ``max_runs``/``max_tombstone_frac`` are
    the ``maybe_compact`` thresholds."""

    def __init__(self, window: int, *, spool_dir: Optional[str] = None,
                 segment_rows: int = 4096, max_runs: int = 12,
                 max_tombstone_frac: float = 0.25, merge_block: int = 4096):
        self.window = window
        self.spool_dir = spool_dir
        self.segment_rows = segment_rows
        self.max_runs = max_runs
        self.max_tombstone_frac = max_tombstone_frac
        self.merge_block = merge_block
        self._gen = 0
        self._runs = ChunkStore(spool_dir, prefix="g000-")
        self._comps: List[np.ndarray] = []      # per-run sorted composites
        self._live: List[np.ndarray] = []       # per-run bool masks
        self._loc: Dict[int, Tuple[int, int]] = {}   # live eid -> (run, row)
        self._all = np.empty((0,), np.int64)    # sorted LIVE composites
        self.profile = B.KeyProfile.empty(window)
        self.tombstones = 0
        self.compactions = 0

    # -- introspection -------------------------------------------------------

    @property
    def n_live(self) -> int:
        """Live (non-tombstoned) entity count."""
        return int(self._all.shape[0])

    @property
    def n_rows(self) -> int:
        """Stored rows including tombstones (reclaimed by compaction)."""
        return sum(int(c.shape[0]) for c in self._comps)

    @property
    def n_runs(self) -> int:
        """Current sorted-run count (compaction folds them back down)."""
        return len(self._comps)

    @property
    def live_comps(self) -> np.ndarray:
        """The flat sorted array of live composites (read-only view): rank
        r holds the composite of the entity at global sorted rank r."""
        return self._all

    def eids_at_ranks(self, lo: int, hi: int) -> np.ndarray:
        """Eids of the live entities at global sorted ranks [lo, hi)."""
        return (self._all[lo:hi] & _EID_MASK).astype(np.int64)

    def comps_of(self, eids: np.ndarray) -> np.ndarray:
        """Composite keys of live entities by eid (aligned with ``eids``);
        raises on an eid that is unknown or already deleted."""
        out = np.empty(len(eids), np.int64)
        for i, e in enumerate(np.asarray(eids, np.int64).tolist()):
            loc = self._loc.get(int(e))
            if loc is None:
                raise ValueError(f"eid {e} is not live in the index")
            out[i] = self._comps[loc[0]][loc[1]]
        return out

    def assert_new_eids(self, eids: np.ndarray) -> None:
        """Reject eids that are already live (re-inserting a DELETED eid is
        fine — its tombstoned row is invisible and reclaimed on compaction)."""
        arr = np.asarray(eids, np.int64)
        uniq = np.unique(arr)
        if uniq.shape[0] != arr.shape[0]:
            raise ValueError("insert batch contains duplicate eids")
        clash = [int(e) for e in uniq.tolist() if int(e) in self._loc]
        if clash:
            raise ValueError(f"eids already live in the index: {clash[:8]}")

    # -- mutation ------------------------------------------------------------

    def insert(self, run: dict) -> np.ndarray:
        """Append one sorted run (host dict from ``entities.sort_chunk``:
        (key, eid)-sorted, invalid rows dropped) and fold its keys into the
        merged profile.  Returns the run's composite keys."""
        comps = E.composite_order_key(run)
        if comps.shape[0] == 0:
            return comps
        if np.any(np.diff(comps) < 0):
            raise ValueError("insert expects a (key, eid)-sorted run "
                             "(entities.sort_chunk output)")
        eids = np.asarray(run["eid"], np.int64)
        self.assert_new_eids(eids)
        run_id = len(self._comps)
        self._runs.append(run)
        self._comps.append(comps)
        self._live.append(np.ones(comps.shape[0], bool))
        for row, e in enumerate(eids.tolist()):
            self._loc[int(e)] = (run_id, row)
        pos = np.searchsorted(self._all, comps)
        self._all = np.insert(self._all, pos, comps)
        self.profile = self.profile.merge(
            B.profile_keys(np.asarray(run["key"]), window=self.window))
        return comps

    def delete(self, eids: np.ndarray) -> np.ndarray:
        """Tombstone live entities by eid (profile decremented exactly).
        Returns their composite keys, sorted."""
        comps = np.sort(self.comps_of(eids))
        keys = (comps >> np.int64(32)).astype(np.int32)
        for e in np.asarray(eids, np.int64).tolist():
            run, row = self._loc.pop(int(e))
            self._live[run][row] = False
        ranks = np.searchsorted(self._all, comps)
        self._all = np.delete(self._all, ranks)
        self.profile = self.profile.merge(
            B.profile_keys(keys, window=self.window), remove=True)
        self.tombstones += int(comps.shape[0])
        return comps

    # -- reads ---------------------------------------------------------------

    def take_comp_range(self, c_lo: int, c_hi: int) -> Optional[dict]:
        """Materialize the LIVE entities with composite key in the inclusive
        range [c_lo, c_hi] as one (key, eid)-sorted host dict (payload
        gathered from the spooled runs); None when the range is empty."""
        comp_parts: List[np.ndarray] = []
        row_parts: List[dict] = []
        for i, comps in enumerate(self._comps):
            lo = int(np.searchsorted(comps, c_lo, side="left"))
            hi = int(np.searchsorted(comps, c_hi, side="right"))
            if lo == hi:
                continue
            rows = lo + np.flatnonzero(self._live[i][lo:hi])
            if rows.shape[0] == 0:
                continue
            comp_parts.append(comps[rows])
            row_parts.append(E.host_take(self._runs.load(i), rows))
        if not row_parts:
            return None
        order = np.argsort(np.concatenate(comp_parts), kind="stable")
        return E.host_take(E.host_concat(row_parts), order)

    def scan_live(self, block: int = 4096) -> Iterator[dict]:
        """The galloping merge view: yield every live entity in global
        (key, eid) order as host blocks (``external_sort.merged_blocks``
        over the tombstone-masked runs)."""
        return merged_blocks(_MaskedRuns(self._runs, self._live), block)

    # -- durability ----------------------------------------------------------

    def snapshot(self, snapshot_dir: str) -> dict:
        """Persist the LIVE corpus to ``snapshot_dir``: the tombstone-masked
        galloping merge (the compaction view) re-blocked into sorted
        ``seg%06d.npz`` segments plus an ``INDEX.json`` manifest, every file
        written atomically with the manifest LAST — a crash mid-snapshot
        leaves the previous snapshot (or no manifest), never a torn one.
        Tombstoned rows are not persisted; a restored index starts
        compacted.  Returns the manifest dict."""
        os.makedirs(snapshot_dir, exist_ok=True)
        store = ChunkStore(snapshot_dir, prefix="seg")
        for chunk in rechunk(self.scan_live(self.merge_block),
                             self.segment_rows):
            store.append(chunk)
        manifest = {"version": 1, "window": self.window,
                    "segment_rows": self.segment_rows,
                    "segments": len(store), "n_live": self.n_live}
        atomic_write_json(os.path.join(snapshot_dir, _INDEX_MANIFEST),
                          manifest)
        return manifest

    @classmethod
    def restore(cls, snapshot_dir: str, *, spool_dir: Optional[str] = None,
                **kwargs) -> "SortedIndex":
        """Rebuild an index from a ``snapshot`` directory.  Segments replay
        through the ordinary ``insert`` path, and ``KeyProfile.merge`` is
        exact, so the restored profile — and therefore every plan and
        served pair set derived from it — is identical to the live index's
        at snapshot time.  ``spool_dir``/remaining kwargs configure the NEW
        index (the snapshot dir itself is never written to)."""
        mpath = os.path.join(snapshot_dir, _INDEX_MANIFEST)
        if not os.path.exists(mpath):
            raise FileNotFoundError(
                f"no index snapshot manifest at {mpath!r}")
        with open(mpath) as f:
            manifest = json.load(f)
        if manifest.get("version") != 1:
            raise ValueError(f"unsupported index snapshot version "
                             f"{manifest.get('version')!r}")
        store = ChunkStore.attach(snapshot_dir, "seg",
                                  count=manifest["segments"])
        idx = cls(manifest["window"], spool_dir=spool_dir,
                  segment_rows=manifest["segment_rows"], **kwargs)
        for chunk in store:
            idx.insert(chunk)
        return idx

    # -- compaction ----------------------------------------------------------

    def maybe_compact(self) -> bool:
        """Compact when the run count exceeds ``max_runs`` or tombstones
        exceed ``max_tombstone_frac`` of stored rows; returns True when a
        compaction ran."""
        rows = self.n_rows
        if self.n_runs > self.max_runs or (
                rows > 0 and self.tombstones > self.max_tombstone_frac * rows):
            self.compact()
            return True
        return False

    def compact(self) -> None:
        """Rewrite all runs into a fresh generation (k-way galloping merge
        of the live rows, re-blocked to ``segment_rows``), dropping
        tombstoned rows and their spool bytes.  The live entity set, the
        flat rank index, and the merged profile are all unchanged —
        compaction is invisible to readers."""
        self._gen += 1
        fresh = ChunkStore(self.spool_dir, prefix=f"g{self._gen:03d}-")
        for chunk in rechunk(self.scan_live(self.merge_block),
                             self.segment_rows):
            fresh.append(chunk)
        old = self._runs
        self._runs = fresh
        self._comps = [E.composite_order_key(fresh.load_index(i))
                       for i in range(len(fresh))]
        self._live = [np.ones(c.shape[0], bool) for c in self._comps]
        self._loc = {}
        for run_id in range(len(fresh)):
            for row, e in enumerate(
                    np.asarray(fresh.load_index(run_id)["eid"],
                               np.int64).tolist()):
                self._loc[int(e)] = (run_id, row)
        old.dispose()
        self.tombstones = 0
        self.compactions += 1
