"""Neighborhood-delta matching: turn one micro-batch into exact pair edits.

The serving layer maintains the CURRENT pair sets of the corpus, so every
mutation must produce both sides of the edit: inserts create pairs around
the new entities but also retire old×old pairs pushed apart beyond w−1
ranks, and deletes retire pairs but also create old×old pairs pulled
together.  The delta matcher computes those edits without touching the
rest of the corpus, from one locality fact about sorted neighborhood:

  **Every pair whose status changes lies wholly inside one merged expanded
  interval around a mutated rank.**  Take the per-mutation intervals
  [k−w+1, k+w) around each inserted/deleted rank ``k`` and merge overlaps.
  If pair (a, b) changes status, some mutation sits between (or at) the
  endpoints' ranks at distance ≤ w−1 from each — otherwise both the pair's
  rank distance and its SN membership are untouched — so ``a`` and ``b``
  fall inside that mutation's interval; and when several mutations sit
  between them, consecutive ones are ≤ w−1 ranks apart (the pair spans
  ≤ w−1 old entities total), so their intervals chain into ONE merged
  interval containing both endpoints.

That reduces the edit to per-interval set algebra:

  after_i    the complete SN pairs of interval i in the POST-mutation
             order — ONE shard-program call over all intervals (each
             interval routed to its own shard via a rank-granular
             ``ShardPlan``, exactly the stream's chunk plans), hitting the
             ``repro.perf`` executable cache through shape bucketing;
  before_i   the restriction of the maintained sets to pairs with BOTH
             endpoints in interval i — pure host array work;
  updated    (maintained \\ ∪before_i) ∪ ∪after_i.

The device call runs the SRP variant with ``emit="pairs"`` — intervals are
mutually independent (each is a complete window over a contiguous rank
range), so per-partition SN with no boundary completion is exactly right —
and matcher decisions are per-pair deterministic, so the edited sets stay
bit-identical to a from-scratch resolve over the live corpus.
"""
from __future__ import annotations

from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro import balance as B
from repro.api import results as RES
from repro.api.runners import VmapRunner
from repro.core import entities as E

_EMPTY = np.empty((0,), RES.PACKED_DTYPE)


class DeltaStats(NamedTuple):
    """Telemetry of one applied mutation.

    ``added_*``/``removed_*`` are the packed pair edits (the serving
    result's payload); ``regions``/``region_rows`` size the touched
    neighborhoods; ``shapes`` lists the (num_shards, shard_cap) buckets of
    the device calls — a steady workload cycles through few of them.
    ``degraded`` marks a mutation applied through the brownout path (see
    ``insert``/``delete``); ``comp_ranges`` are the inclusive composite
    ranges (c_lo, c_hi) of the touched regions — composites are immutable
    per entity, so these ranges stay valid anchors for a later ``refresh``
    no matter how the corpus mutates in between."""
    batch: int
    regions: int
    region_rows: int
    device_calls: int
    shapes: Tuple[Tuple[int, int], ...]
    added_blocked: np.ndarray
    removed_blocked: np.ndarray
    added_matched: np.ndarray
    removed_matched: np.ndarray
    degraded: bool = False
    comp_ranges: Tuple[Tuple[int, int], ...] = ()


def merge_intervals(ranks: np.ndarray, window: int, n: int
                    ) -> List[Tuple[int, int]]:
    """Expanded intervals [k−w+1, k+w) around each mutated rank, clipped to
    [0, n) and merged (``ranks`` must be sorted).  Touching intervals merge
    too — over-merging is always safe, it only widens a region."""
    out: List[List[int]] = []
    w = window
    for k in np.asarray(ranks, np.int64).tolist():
        lo, hi = max(0, k - (w - 1)), min(n, k + w)
        if hi <= lo:
            continue
        if out and lo <= out[-1][1]:
            out[-1][1] = max(out[-1][1], hi)
        else:
            out.append([lo, hi])
    return [(a, b) for a, b in out]


def _restrict(packed: np.ndarray, eid_sorted: np.ndarray,
              iv_of: np.ndarray) -> np.ndarray:
    """Pairs of the maintained set with BOTH endpoints inside the SAME
    interval (``eid_sorted``: sorted region eids; ``iv_of``: their interval
    ids).  Same-interval matters: a pair spanning two different merged
    intervals is unchanged by construction and must stay untouched."""
    if packed.shape[0] == 0 or eid_sorted.shape[0] == 0:
        return _EMPTY
    lo, hi = RES.unpack_pairs(packed)
    il = np.searchsorted(eid_sorted, lo)
    ih = np.searchsorted(eid_sorted, hi)
    last = eid_sorted.shape[0] - 1
    ilc = np.minimum(il, last)
    ihc = np.minimum(ih, last)
    mask = ((il <= last) & (eid_sorted[ilc] == lo)
            & (ih <= last) & (eid_sorted[ihc] == hi)
            & (iv_of[ilc] == iv_of[ihc]))
    return packed[mask]


def _pad(ents: dict, cap: int) -> dict:
    """Pad a host entity dict to ``cap`` rows with invalid slots (the
    stream's combined-chunk padding, applied to the region batch)."""
    n = int(ents["key"].shape[0])
    if n == cap:
        return ents
    pad = cap - n
    z = lambda a: np.zeros((pad,) + a.shape[1:], a.dtype)
    tail = {
        "key": np.full((pad,), int(E.INVALID_KEY), np.int32),
        "eid": z(ents["eid"]),
        "valid": np.zeros((pad,), bool),
        "payload": {k: z(v) for k, v in ents["payload"].items()},
    }
    return E.host_concat([ents, tail])


def _diff(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    return np.setdiff1d(a, b) if a.shape[0] else _EMPTY


class DeltaMatcher:
    """Stateless-per-call delta engine bound to one (cfg, index) pair.

    ``insert``/``delete`` take the maintained packed COMPLETE pair sets
    and return the updated sets plus a ``DeltaStats``; the index mutation
    is applied as the final step (a raised error leaves both the index and
    the maintained sets untouched).

    ``shard_buckets``/``cap_floor`` define the shape-bucket grid: a
    mutation with R merged regions of max length L runs as one call per
    ⌈R / max_bucket⌉ group, each padded to (next bucket ≥ group size) ×
    (cap_floor · 2^k ≥ L) — so a steady workload re-traces nothing."""

    def __init__(self, cfg, index, *,
                 shard_buckets: Sequence[int] = (2, 4, 8),
                 cap_floor: int = 64):
        if cfg.passes:
            raise ValueError("the serving layer resolves under ONE sort key;"
                             " multi-pass configs are batch-only")
        if cfg.linkage:
            raise ValueError("linkage mode is batch-only; serve single-"
                             "source configs")
        if cfg.return_scores:
            raise ValueError("return_scores is unsupported when serving "
                             "(delta calls emit packed pairs)")
        self.cfg = cfg
        self.index = index
        self.shard_buckets = tuple(sorted(shard_buckets))
        self.cap_floor = int(cap_floor)
        self._runners: Dict[int, VmapRunner] = {}
        self._cfgs: Dict[Tuple[int, int], object] = {}

    # -- shape-bucketed device call -----------------------------------------

    def _delta_cfg(self, r_b: int, cap_b: int):
        key = (r_b, cap_b)
        cfg_d = self._cfgs.get(key)
        if cfg_d is None:
            # capacities sized from the bucket cap itself: a shard holds at
            # most one region of <= cap_b rows, so the suggestion's band
            # bound can never overflow (guarded below anyway)
            caps = B.suggest_caps(self.index.profile, self.cfg, r_b,
                                  max_load=cap_b)
            cfg_d = self.cfg.with_(
                variant="srp", runner="vmap", num_shards=r_b, emit="pairs",
                cand_cap=caps.cand_cap, pair_cap=caps.pair_cap,
                cap_factor=0.0, compute_metrics=False, passes=(),
                linkage=False)
            self._cfgs[key] = cfg_d
        return cfg_d

    def _runner(self, r_b: int) -> VmapRunner:
        runner = self._runners.get(r_b)
        if runner is None:
            runner = VmapRunner(r_b)
            self._runners[r_b] = runner
        return runner

    def _device_pairs(self, regions: List[dict]
                      ) -> Tuple[np.ndarray, np.ndarray, int,
                                 Tuple[Tuple[int, int], ...]]:
        """Complete SN pairs of each region under the POST-mutation order:
        regions ride as SRP shards of bucketed shard programs (dest = the
        region id), so cross-region pairs are structurally impossible."""
        if not regions:
            return _EMPTY, _EMPTY, 0, ()
        bparts: List[np.ndarray] = []
        mparts: List[np.ndarray] = []
        shapes: List[Tuple[int, int]] = []
        max_r = self.shard_buckets[-1]
        for g0 in range(0, len(regions), max_r):
            group = regions[g0:g0 + max_r]
            r_b = next(b for b in self.shard_buckets if b >= len(group))
            need = max(int(reg["key"].shape[0]) for reg in group)
            cap_b = self.cap_floor
            while cap_b < need:
                cap_b *= 2
            padded = _pad(E.host_concat(group), r_b * cap_b)
            dest = np.zeros(r_b * cap_b, np.int32)
            dest[:sum(int(reg["key"].shape[0]) for reg in group)] = \
                np.concatenate([np.full(int(reg["key"].shape[0]), i,
                                        np.int32)
                                for i, reg in enumerate(group)])
            dev = E.make_entities(padded["key"], padded["eid"],
                                  payload=padded["payload"],
                                  valid=padded["valid"])
            plan = B.ShardPlan(partitioner="serve-delta", num_shards=r_b,
                               bounds=np.zeros(max(r_b - 1, 0), np.int32),
                               dest=dest, cap_link=None, rank_granular=True)
            po = self._runner(r_b).resolve_packed(
                dev, plan, self._delta_cfg(r_b, cap_b))
            if po.overflow or po.cand_overflow or po.pair_overflow:
                raise RuntimeError(
                    f"serve delta call overflowed (link={po.overflow}, "
                    f"cand={po.cand_overflow}, pair={po.pair_overflow}) — "
                    f"capacity sizing bug, shape=({r_b}, {cap_b})")
            bparts.append(po.blocked)
            mparts.append(po.matched)
            shapes.append((r_b, cap_b))
        blocked = np.unique(np.concatenate(bparts)) if len(bparts) > 1 \
            else bparts[0]
        matched = np.unique(np.concatenate(mparts)) if len(mparts) > 1 \
            else mparts[0]
        return blocked, matched, len(shapes), tuple(shapes)

    # -- degraded (brownout) path -------------------------------------------

    def _host_pairs(self, regions: List[dict]) -> np.ndarray:
        """Complete SN blocked pairs of each region, computed EXACTLY on
        host: a region is a contiguous rank range in composite order, so
        its blocked set is every pair at sorted distance 1..w-1 — pure
        index arithmetic, no matcher, no device dispatch.  Bit-identical
        to the blocked half of ``_device_pairs`` by construction, which is
        why brownout never degrades the BLOCKED set (DESIGN.md §13)."""
        w = self.cfg.window
        parts: List[np.ndarray] = []
        for reg in regions:
            eids = np.asarray(reg["eid"], np.int64)
            for d in range(1, min(w, int(eids.shape[0]))):
                parts.append(RES.pack_pairs(eids[:-d], eids[d:]))
        if not parts:
            return _EMPTY
        return np.unique(np.concatenate(parts))

    # -- mutations -----------------------------------------------------------

    def _apply(self, blocked, matched, regions, region_eids, region_ivs,
               batch_n, *, degraded: bool = False, comp_ranges=()):
        if degraded:
            # brownout: blocked stays exact (host SN arithmetic); matched
            # is the conservative carry-forward gate — a pair stays
            # matched while it stays blocked (matcher decisions are
            # per-pair deterministic over immutable payloads, so every
            # carried match is one an exact re-resolve would confirm);
            # NEW matches are deferred to ``refresh`` over comp_ranges
            after_b = self._host_pairs(regions)
            after_m, calls, shapes = None, 0, ()
        else:
            after_b, after_m, calls, shapes = self._device_pairs(regions)
        if region_eids:
            eids = np.concatenate(region_eids)
            ivs = np.concatenate(region_ivs)
            order = np.argsort(eids, kind="stable")
            eid_sorted, iv_of = eids[order], ivs[order]
        else:
            eid_sorted = np.empty((0,), np.int64)
            iv_of = np.empty((0,), np.int64)
        before_b = _restrict(blocked, eid_sorted, iv_of)
        before_m = _restrict(matched, eid_sorted, iv_of)
        if degraded:
            after_m = np.intersect1d(before_m, after_b)
        new_blocked = np.union1d(_diff(blocked, before_b), after_b)
        new_matched = np.union1d(_diff(matched, before_m), after_m)
        stats = DeltaStats(
            batch=batch_n, regions=len(region_eids),
            region_rows=int(eid_sorted.shape[0]),
            device_calls=calls, shapes=shapes,
            added_blocked=_diff(after_b, before_b),
            removed_blocked=_diff(before_b, after_b),
            added_matched=_diff(after_m, before_m),
            removed_matched=_diff(before_m, after_m),
            degraded=degraded, comp_ranges=tuple(comp_ranges))
        return new_blocked, new_matched, stats

    def insert(self, batch, blocked: np.ndarray, matched: np.ndarray,
               *, degraded: bool = False
               ) -> Tuple[np.ndarray, np.ndarray, DeltaStats]:
        """Fold one batch of NEW entities (device entity dict) into the
        maintained sets.  Returns (blocked', matched', stats); the sorted
        batch is appended to the index as a run.

        ``degraded=True`` is the brownout path: zero device calls — the
        blocked edit is computed exactly on host, the matched edit is the
        conservative carry-forward gate (previously confirmed matches that
        stay blocked stay matched; new matches are DEFERRED).  The caller
        must record ``stats.comp_ranges`` and later ``refresh`` them to
        restore matched exactness."""
        srun = E.sort_chunk(batch)
        q = E.composite_order_key(srun)
        if q.shape[0] == 0:
            return blocked, matched, DeltaStats(0, 0, 0, 0, (), _EMPTY,
                                                _EMPTY, _EMPTY, _EMPTY)
        self.index.assert_new_eids(srun["eid"])
        old_all = self.index.live_comps
        pos = np.searchsorted(old_all, q)
        new_ranks = pos + np.arange(q.shape[0], dtype=np.int64)
        n_new = old_all.shape[0] + q.shape[0]
        new_all = np.insert(old_all, pos, q)
        regions: List[dict] = []
        region_eids: List[np.ndarray] = []
        region_ivs: List[np.ndarray] = []
        comp_ranges: List[Tuple[int, int]] = []
        w = self.cfg.window
        for iv, (lo, hi) in enumerate(merge_intervals(new_ranks, w, n_new)):
            c_lo, c_hi = int(new_all[lo]), int(new_all[hi - 1])
            comp_ranges.append((c_lo, c_hi))
            old_part = self.index.take_comp_range(c_lo, c_hi)
            blo = int(np.searchsorted(q, c_lo, side="left"))
            bhi = int(np.searchsorted(q, c_hi, side="right"))
            new_part = E.host_take(srun, np.arange(blo, bhi))
            if old_part is None:
                region = new_part
            else:
                both = E.host_concat([old_part, new_part])
                region = E.host_take(
                    both, np.argsort(E.composite_order_key(both),
                                     kind="stable"))
            regions.append(region)
            region_eids.append(np.asarray(region["eid"], np.int64))
            region_ivs.append(np.full(int(region["eid"].shape[0]), iv,
                                      np.int64))
        out = self._apply(blocked, matched, regions, region_eids,
                          region_ivs, int(q.shape[0]), degraded=degraded,
                          comp_ranges=comp_ranges)
        self.index.insert(srun)
        return out

    def delete(self, eids, blocked: np.ndarray, matched: np.ndarray,
               *, degraded: bool = False
               ) -> Tuple[np.ndarray, np.ndarray, DeltaStats]:
        """Remove live entities by eid from the maintained sets.  Returns
        (blocked', matched', stats); the index rows are tombstoned.
        ``degraded`` works exactly as in ``insert``."""
        eids = np.unique(np.asarray(eids, np.int64))
        if eids.shape[0] == 0:
            return blocked, matched, DeltaStats(0, 0, 0, 0, (), _EMPTY,
                                                _EMPTY, _EMPTY, _EMPTY)
        comps = np.sort(self.index.comps_of(eids))
        all_ = self.index.live_comps
        ranks = np.searchsorted(all_, comps)
        regions: List[dict] = []
        region_eids: List[np.ndarray] = []
        region_ivs: List[np.ndarray] = []
        comp_ranges: List[Tuple[int, int]] = []
        w = self.cfg.window
        for iv, (lo, hi) in enumerate(
                merge_intervals(ranks, w, int(all_.shape[0]))):
            # the region is taken in the PRE-delete order (deleted rows
            # included — they anchor the before-restriction); the device
            # call sees only the survivors, i.e. the post-delete order
            c_lo, c_hi = int(all_[lo]), int(all_[hi - 1])
            comp_ranges.append((c_lo, c_hi))
            region = self.index.take_comp_range(c_lo, c_hi)
            r_eids = np.asarray(region["eid"], np.int64)
            region_eids.append(r_eids)
            region_ivs.append(np.full(r_eids.shape[0], iv, np.int64))
            keep = np.flatnonzero(~np.isin(r_eids, eids))
            if keep.shape[0]:
                regions.append(E.host_take(region, keep))
        out = self._apply(blocked, matched, regions, region_eids,
                          region_ivs, int(eids.shape[0]), degraded=degraded,
                          comp_ranges=comp_ranges)
        self.index.delete(eids)
        return out

    def refresh(self, comp_ranges: Sequence[Tuple[int, int]],
                blocked: np.ndarray, matched: np.ndarray
                ) -> Tuple[np.ndarray, np.ndarray, DeltaStats]:
        """The repair pass: re-resolve the given inclusive composite
        ranges EXACTLY (full device path, real matcher) against the
        CURRENT live corpus and fold the results into the maintained
        sets.  No index mutation.

        Correctness (DESIGN.md §13): a degraded mutation's matched errors
        are confined to pairs with both endpoints inside one recorded
        comp_range at the time — composites never change, later exact
        mutations self-heal any overlap they touch, and a contiguous
        composite range is a contiguous rank range, so the exact
        maintained set restricted to in-range pairs equals the range's
        complete SN pairs.  Re-deriving that restriction from a device
        call therefore erases every residual error; over-coverage (ranges
        grown by merging, or entities inserted into a dirty range after
        it was recorded) is idempotent."""
        regions: List[dict] = []
        region_eids: List[np.ndarray] = []
        region_ivs: List[np.ndarray] = []
        for c_lo, c_hi in comp_ranges:
            region = self.index.take_comp_range(int(c_lo), int(c_hi))
            if region is None:
                continue
            iv = len(regions)
            r_eids = np.asarray(region["eid"], np.int64)
            regions.append(region)
            region_eids.append(r_eids)
            region_ivs.append(np.full(r_eids.shape[0], iv, np.int64))
        return self._apply(blocked, matched, regions, region_eids,
                           region_ivs, 0, comp_ranges=tuple(
                               (int(a), int(b)) for a, b in comp_ranges))


def srp_straddle_packed(index, cfg) -> np.ndarray:
    """The SRP-variant serving correction: packed pairs of the COMPLETE set
    that cross a partition boundary of the plan
    ``plan_from_profile(index.profile, cfg.partitioner, cfg.num_shards)``
    — exactly the plan a from-scratch SRP resolve of the live corpus would
    run (the profile is merged incrementally but exactly).  SRP's served
    set is complete \\ straddle; boundary-complete variants need none.

    O(r · w²) host work against the flat rank index per call.
    """
    n = index.n_live
    r = cfg.num_shards
    w = cfg.window
    if n == 0 or r <= 1:
        return _EMPTY
    plan = B.plan_from_profile(index.profile, cfg.partitioner, r)
    lo_l, hi_l = [], []
    for b in np.unique(plan.rank_bounds).tolist():
        if b <= 0 or b >= n:
            continue
        lo, hi = max(0, b - (w - 1)), min(n, b + (w - 1))
        eids = index.eids_at_ranks(lo, hi)
        for jr in range(b, hi):
            for ir in range(max(lo, jr - (w - 1)), b):
                lo_l.append(int(eids[ir - lo]))
                hi_l.append(int(eids[jr - lo]))
    if not lo_l:
        return _EMPTY
    return np.unique(RES.pack_pairs(np.asarray(lo_l, np.int64),
                                    np.asarray(hi_l, np.int64)))
