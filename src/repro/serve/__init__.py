"""``repro.serve`` — online incremental entity resolution (ISSUE 6).

Every batch path in the repo answers "resolve THIS corpus"; this subsystem
answers "keep a corpus resolved while it changes".  Three layers:

  1. **index** — a persistent sorted index (``SortedIndex``): the corpus
     as sorted runs in a ``stream.store.ChunkStore`` + a resident flat
     rank index of live ``(key << 32) | eid`` composites + an
     incrementally merged ``balance.KeyProfile``; tombstone deletes,
     threshold-triggered compaction through the external-sort machinery.
  2. **delta** — neighborhood-delta matching (``DeltaMatcher``): a
     mutation only changes pairs inside merged w-neighborhood intervals
     around the mutated ranks, so each micro-batch costs one
     shape-bucketed shard-program call over those intervals plus host set
     algebra — never a re-resolve.
  3. **service** — the micro-batched front end (``ResolutionService``):
     bounded queue, request coalescing, per-request futures, stable pair
     ids, latency/cache telemetry (``ServeStats``).
  4. **admission** — the overload policy (ISSUE 9): queue policies
     (block / reject / shed_oldest) behind ``AdmissionConfig``,
     per-request deadlines, the brownout watermark controller that
     degrades the delta path under pressure, the stuck-batch watchdog,
     and the typed error taxonomy (``OverloadError``,
     ``DeadlineExceededError``, ``BatchTimeoutError``).

Invariant (tested property-style): after any interleaving of inserts and
deletes, ``service.pairs``/``service.matches`` are bit-identical to a
from-scratch ``api.resolve`` over the live entities under the same
config, for all three variants and both band engines.  Under brownout
the invariant relaxes to EVENTUALLY-exact (DESIGN.md §13): blocked pairs
stay exact throughout, new matches may be deferred, and ``repair()``
restores full bit-parity once pressure drops.

(This package previously quarantined the seed repo's LM-serving
scaffolding; that scaffold is gone — the SN serving layer lives here.)
"""
from repro.serve.admission import (AdmissionConfig, AdmissionError,
                                   BatchTimeoutError, DeadlineExceededError,
                                   OverloadError, WatermarkController)
from repro.serve.delta import DeltaMatcher, DeltaStats, srp_straddle_packed
from repro.serve.index import SortedIndex
from repro.serve.service import (IncrementalResult, ResolutionService,
                                 ServeStats)

__all__ = [
    "SortedIndex", "DeltaMatcher", "DeltaStats", "srp_straddle_packed",
    "ResolutionService", "IncrementalResult", "ServeStats",
    "AdmissionConfig", "AdmissionError", "OverloadError",
    "DeadlineExceededError", "BatchTimeoutError", "WatermarkController",
]
