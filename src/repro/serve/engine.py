"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Batched serving engine: prefill + decode with jit'd steps.

Serves batched requests (fixed batch, left-aligned prompts) against any arch
config: prefill fills the KV/recurrent caches and emits the first token;
decode steps extend one token at a time.  Used by examples/serve_lm.py and
the serving integration test.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import lm


@dataclass
class ServeEngine:
    cfg: ModelConfig
    params: dict
    max_len: int
    batch: int
    rules: object = None
    dtype: object = jnp.float32

    def __post_init__(self):
        cfg, rules = self.cfg, self.rules

        def prefill(params, tokens, cache):
            logits, new_cache, _ = lm.forward(
                params, cfg, tokens=tokens, cache=cache, rules=rules,
                remat="none", logits_last_only=True)
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), new_cache

        def decode(params, tok, cache, pos):
            logits, new_cache, _ = lm.forward(
                params, cfg, tokens=tok, cache=cache, cache_pos=pos,
                rules=rules, remat="none")
            return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), new_cache

        self._prefill = jax.jit(prefill, donate_argnums=(2,))
        self._decode = jax.jit(decode, donate_argnums=(2,))

    def generate(self, prompts: np.ndarray, n_new: int) -> np.ndarray:
        """prompts: (B, P) int32.  Greedy-decodes n_new tokens."""
        b, p = prompts.shape
        assert b == self.batch and p + n_new <= self.max_len
        cache = lm.cache_init(self.cfg, b, self.max_len, self.dtype)
        tok, cache = self._prefill(self.params, jnp.asarray(prompts), cache)
        out = [tok]
        for t in range(1, n_new):
            tok, cache = self._decode(
                self.params, out[-1][:, None], cache, jnp.int32(p + t))
            out.append(tok)
        return np.stack([np.asarray(t) for t in out], axis=1)
