"""Micro-batched serving layer: ``ResolutionService``.

The front end of the online subsystem: callers submit entity inserts and
deletes; a worker thread coalesces adjacent same-kind requests into
micro-batches (up to ``max_batch`` entities or ``max_wait_ms``), drives the
``DeltaMatcher`` once per batch, and resolves every request's future with
the batch's ``IncrementalResult``.  Because delta calls ride the
shape-bucket grid, a steady request stream hits the ``repro.perf``
executable cache on every batch — the serving-path analogue of the
stream's ``steady_chunks``.

The service maintains the CURRENT pair sets (not a monotone union): the
**served** sets are exactly what a from-scratch ``api.resolve`` of the
live corpus under the same config would produce — for boundary-complete
variants (repsn, jobsn) the maintained complete sets themselves; for SRP,
complete minus the pairs straddling the profile-planned partition bounds
(``delta.srp_straddle_packed``).  That equality holds after ANY
interleaving of inserts and deletes and is what ``tests/test_serve.py``
asserts property-style.

Ordering semantics: requests apply in submission order; only ADJACENT
same-kind requests coalesce, so a delete never leapfrogs the insert before
it.  All requests in one micro-batch share the batch's result (``batched``
reports the coalescing width).  Pair ids are stable for the service's
lifetime: a pair that is retired and later re-created keeps its id.

Under load the service absorbs pressure instead of collapsing (DESIGN.md
§13): an ``AdmissionConfig`` picks the queue policy (block / reject /
shed_oldest) and per-request deadlines, a watermark controller browns the
delta path out to the degraded (zero-device-call) matcher when the queue
or p95 latency crosses its high watermark, and the dirty composite ranges
the brownout touched are re-resolved exactly by the ``repair`` pass once
pressure drops — eventually-exact (invariant 13).  A ``ChaosPlan`` from
``repro.resilience`` injects latency/stall/error disturbances at exact
batch indices for the overload property tests.
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time
from concurrent.futures import Future, InvalidStateError
from typing import Dict, FrozenSet, List, NamedTuple, Optional, Tuple

import numpy as np

from repro import obs as OBS
from repro.api import results as RES
from repro.api.variants import get_variant
from repro.core import entities as E
from repro.perf import cache as PC
from repro.resilience.faults import InjectedFault
from repro.serve import admission as ADM
from repro.serve.delta import DeltaMatcher, srp_straddle_packed
from repro.serve.index import SortedIndex
from repro.stream.store import atomic_savez, atomic_write_json

_SERVICE_MANIFEST = "SERVICE.json"

Pair = Tuple[int, int]
_EMPTY = np.empty((0,), RES.PACKED_DTYPE)
_STOP = object()


class ServeStats(NamedTuple):
    """Service telemetry snapshot (rides on every ``IncrementalResult``).

    ``steady_batches`` counts micro-batches served ENTIRELY from the
    executable cache (hits, zero builds/traces) — after warm-up every
    batch should be steady; ``shapes`` lists the distinct (num_shards,
    shard_cap) delta-call buckets seen, the quantity that must stay small
    for that to hold.  ``batch_fill`` is the mean coalesced batch size
    over ``max_batch``; ``p50_ms``/``p95_ms`` are submit-to-result
    latencies over a sliding window.  ``failure`` is None while the
    service is healthy; after an unexpected worker error it carries that
    error's repr (the service refuses further submissions — DESIGN.md
    §11).

    The overload block (DESIGN.md §13): ``shed``/``rejected``/``expired``
    count futures failed by admission policy (shed_oldest eviction,
    reject-at-submit, deadline expiry at batch formation);
    ``degraded_batches`` counts batches applied through the brownout
    path, ``repairs`` the repair passes that re-resolved them exactly,
    ``dirty_ranges`` the composite ranges still awaiting repair
    (``repair()`` drives this to 0); ``brownout`` is the watermark
    controller's current state and ``health`` the derived
    ``ok | degraded | overloaded | failed`` summary."""
    requests: int
    batches: int
    steady_batches: int
    queue_depth: int
    batch_fill: float
    cache_hits: int
    cache_misses: int
    traces: int
    device_calls: int
    p50_ms: float
    p95_ms: float
    live_entities: int
    index_runs: int
    index_rows: int
    tombstones: int
    compactions: int
    pairs: int
    matches: int
    shapes: Tuple[Tuple[int, int], ...]
    failure: Optional[str] = None
    shed: int = 0
    rejected: int = 0
    expired: int = 0
    degraded_batches: int = 0
    repairs: int = 0
    dirty_ranges: int = 0
    brownout: bool = False
    health: str = "ok"


class IncrementalResult(NamedTuple):
    """Outcome of one request (shared by its whole micro-batch).

    ``new_pairs``/``retired_pairs`` are the SERVED blocked-set edits this
    batch caused (both directions are real: an insert can retire old
    pairs, a delete can create them); ``*_matches`` the matched-set edits.
    ``pair_ids`` maps each NEW pair to its stable service-wide id.
    ``degraded=True`` marks a batch applied through the brownout path:
    its blocked edits are exact, but new matches are deferred until the
    ``repair`` pass re-resolves the touched ranges (DESIGN.md §13)."""
    new_pairs: FrozenSet[Pair]
    retired_pairs: FrozenSet[Pair]
    new_matches: FrozenSet[Pair]
    retired_matches: FrozenSet[Pair]
    pair_ids: Dict[Pair, int]
    batched: int
    stats: ServeStats
    degraded: bool = False


class _Request:
    __slots__ = ("kind", "data", "n", "future", "t0", "deadline")

    def __init__(self, kind: str, data, n: int,
                 deadline_ms: Optional[float] = None):
        self.kind = kind
        self.data = data
        self.n = n
        self.future: "Future[IncrementalResult]" = Future()
        self.t0 = time.perf_counter()
        # absolute monotonic expiry; None = wait forever (legacy)
        self.deadline = None if deadline_ms is None \
            else time.monotonic() + deadline_ms * 1e-3


class ResolutionService:
    """Online incremental entity resolution over one persistent corpus.

        svc = ResolutionService(cfg, initial=base_corpus)
        res = svc.resolve_incremental(new_ents)   # sync insert
        res.new_pairs, res.retired_pairs
        svc.delete([17, 42])                      # sync delete by eid
        svc.pairs                                 # currently served set

    ``submit_insert``/``submit_delete`` are the async forms (futures);
    the sync forms go through the same queue, so concurrent callers
    coalesce.  ``start=False`` skips the worker thread and processes
    every request inline (single-caller tests/benchmarks).

    ``admission`` (an ``AdmissionConfig``) sets the overload policy:
    queue policy, default deadline, brownout watermarks, stuck-batch
    watchdog — all service-level, none change what a correct resolve
    produces (invariant 13).  ``chaos`` (a ``resilience.ChaosPlan``)
    injects deterministic latency/stall/error disturbances at exact
    batch indices — the overload test harness, never set in production.

    The config must be single-pass, non-linkage, without
    ``return_scores``; the service always executes delta calls on the
    vmap runner, and SRP straddle correction uses ``cfg.num_shards`` —
    served sets match a from-scratch vmap ``resolve`` under ``cfg``.
    """

    def __init__(self, cfg, *, initial=None, max_batch: int = 512,
                 max_wait_ms: float = 2.0, queue_cap: int = 1024,
                 spool_dir: Optional[str] = None, start: bool = True,
                 segment_rows: int = 4096, max_runs: int = 12,
                 max_tombstone_frac: float = 0.25,
                 shard_buckets=(2, 4, 8), cap_floor: int = 64,
                 admission: Optional[ADM.AdmissionConfig] = None,
                 chaos=None):
        self.cfg = cfg
        self._boundary_complete = get_variant(cfg.variant).boundary_complete
        self._shard_buckets = shard_buckets     # kept for restore()
        self._cap_floor = cap_floor
        self.index = SortedIndex(cfg.window, spool_dir=spool_dir,
                                 segment_rows=segment_rows,
                                 max_runs=max_runs,
                                 max_tombstone_frac=max_tombstone_frac)
        self._delta = DeltaMatcher(cfg, self.index,
                                   shard_buckets=shard_buckets,
                                   cap_floor=cap_floor)
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self._blocked = _EMPTY      # maintained COMPLETE sets
        self._matched = _EMPTY
        self._served_b = _EMPTY     # derived SERVED sets (post-straddle)
        self._served_m = _EMPTY
        self._pair_ids: Dict[int, int] = {}     # packed pair -> stable id
        self._lock = threading.Lock()
        # submit-to-result latencies (seconds) over a bounded sliding
        # window — the obs ring buffer keeps the historical deque's
        # percentile semantics bit-for-bit (DESIGN.md §12)
        self._latency = OBS.Histogram("latency_s", 2048)
        # per-batch spans accumulate here when the config asks for
        # tracing; the service owns its tracer for its whole lifetime
        # (batches arrive forever — there is no single "run" to scope it)
        self._tracer = OBS.Tracer() if getattr(cfg, "trace", False) \
            else None
        self._requests = 0
        self._batches = 0
        self._dispatched = 0
        self._steady = 0
        self._fill = 0.0
        self._hits = self._misses = self._traces = 0
        self._device_calls = 0
        self._shapes: set = set()
        self._adm = admission if admission is not None \
            else ADM.AdmissionConfig()
        self._chaos = chaos
        self._watermark = ADM.WatermarkController(self._adm, queue_cap)
        self._brownout = False
        self._dirty: List[Tuple[int, int]] = []   # merged (c_lo, c_hi)
        self._shed = self._rejected = self._expired = 0
        self._degraded_batches = self._repairs = 0
        self._q: "queue.Queue" = queue.Queue(maxsize=queue_cap)
        self._worker: Optional[threading.Thread] = None
        self._closed = False
        self._failure: Optional[BaseException] = None
        if start:
            self._worker = threading.Thread(target=self._run,
                                            name="resolution-serve",
                                            daemon=True)
            self._worker.start()
        if initial is not None:
            self.resolve_incremental(initial)

    # -- submission ----------------------------------------------------------

    def submit_insert(self, ents, *, deadline_ms: Optional[float] = None
                      ) -> "Future[IncrementalResult]":
        """Enqueue an insert of NEW entities (device or host entity dict;
        invalid rows are dropped, live-eid collisions raise).  Under the
        default ``queue_policy="block"`` a full queue blocks for
        backpressure (failing fast if the worker dies meanwhile); see
        ``AdmissionConfig`` for the reject/shed policies.  ``deadline_ms``
        bounds this request's QUEUE WAIT (falls back to the admission
        config's ``default_deadline_ms``): an expired request fails with
        ``DeadlineExceededError`` at batch-formation time."""
        h = ents if isinstance(ents.get("key"), np.ndarray) \
            else E.to_host(ents)
        return self._submit(_Request("insert", h, int(h["key"].shape[0]),
                                     self._deadline(deadline_ms)))

    def submit_delete(self, eids, *, deadline_ms: Optional[float] = None
                      ) -> "Future[IncrementalResult]":
        """Enqueue a delete of live entities by eid (unknown or already-
        deleted eids fail the whole request).  ``deadline_ms`` as in
        ``submit_insert``."""
        arr = np.asarray(eids, np.int64).reshape(-1)
        return self._submit(_Request("delete", arr, int(arr.shape[0]),
                                     self._deadline(deadline_ms)))

    def resolve_incremental(self, ents) -> IncrementalResult:
        """Synchronous insert: submit and wait for the batch result."""
        return self.submit_insert(ents).result()

    def delete(self, eids) -> IncrementalResult:
        """Synchronous delete: submit and wait for the batch result."""
        return self.submit_delete(eids).result()

    def _deadline(self, deadline_ms: Optional[float]) -> Optional[float]:
        return self._adm.default_deadline_ms if deadline_ms is None \
            else deadline_ms

    def _check_open(self) -> None:
        if self._failure is not None:
            raise RuntimeError(
                "service failed and no longer accepts requests"
            ) from self._failure
        if self._closed:
            raise RuntimeError("service is closed")

    def _submit(self, req: _Request) -> "Future[IncrementalResult]":
        self._check_open()
        if self._worker is None:
            self._dispatch(self._drop_expired([req]))
            return req.future
        policy = self._adm.queue_policy
        if policy == "reject":
            try:
                self._q.put_nowait(req)
            except queue.Full:
                self._rejected += 1
                if self._tracer is not None:
                    self._tracer.metrics.counter("rejected").inc()
                raise ADM.OverloadError(
                    f"queue full ({self._q.maxsize} deep) under "
                    f"queue_policy='reject'") from None
        elif policy == "shed_oldest":
            while True:
                try:
                    self._q.put_nowait(req)
                    break
                except queue.Full:
                    pass
                self._check_open()
                try:
                    old = self._q.get_nowait()
                except queue.Empty:
                    continue
                if old is _STOP:
                    # the service is closing under us: put the sentinel
                    # back and refuse the new request
                    try:
                        self._q.put_nowait(old)
                    except queue.Full:
                        pass
                    raise RuntimeError("service is closed")
                self._shed += 1
                if self._tracer is not None:
                    self._tracer.metrics.counter("shed").inc()
                self._settle(old.future, exc=ADM.OverloadError(
                    "shed: evicted by a newer request under "
                    "queue_policy='shed_oldest'"))
        else:   # "block" — legacy backpressure, but never block into a
            # dead service: re-check failed/closed between bounded put
            # attempts so a worker failure releases every waiting
            # submitter with the ORIGINAL error
            while True:
                try:
                    self._q.put(req, timeout=0.05)
                    break
                except queue.Full:
                    self._check_open()
        if self._failure is not None:
            # the worker died while we waited (its queue drain is what
            # freed our slot) — nothing will ever consume this request,
            # so fail it here rather than let the future dangle
            try:
                self._check_open()
            except RuntimeError as exc:
                self._settle(req.future, exc=exc)
                raise
        return req.future

    # -- worker --------------------------------------------------------------

    def _run(self) -> None:
        pending: Optional[_Request] = None
        running = True
        while running:
            req = pending if pending is not None else self._next_request()
            pending = None
            if req is _STOP:
                break
            group = [req]
            n = req.n
            deadline = time.monotonic() + self.max_wait_ms * 1e-3
            while n < self.max_batch:
                wait = deadline - time.monotonic()
                if wait <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=wait)
                except queue.Empty:
                    break
                if nxt is _STOP:
                    running = False
                    break
                if nxt.kind != req.kind:
                    # a kind change closes the batch: submission order is
                    # preserved exactly
                    pending = nxt
                    break
                group.append(nxt)
                n += nxt.n
            self._dispatch(self._drop_expired(group))
            if self._failure is not None:
                running = False        # dead worker: stop consuming
        if pending is not None and pending is not _STOP:
            if self._failure is not None:
                self._settle(pending.future, exc=self._failure)
            else:
                self._dispatch(self._drop_expired([pending]))
        # anything still queued raced the shutdown (enqueued after the
        # stop sentinel or after a failure drain): fail it on the way out
        # so no future can dangle behind the worker's exit
        exc = self._failure if self._failure is not None \
            else RuntimeError("service is closed")
        while True:
            try:
                nxt = self._q.get_nowait()
            except queue.Empty:
                break
            if nxt is not _STOP:
                self._settle(nxt.future, exc=exc)

    def _next_request(self):
        """Blocking queue get, interleaving the background repair pass:
        when the queue drains while repair debt is outstanding, pressure
        is gone by definition — release the brownout through the
        watermark (depth 0; latency is NOT consulted, its sliding window
        decays too slowly to gate recovery) and re-resolve the dirty
        ranges exactly before going back to sleep."""
        while True:
            try:
                if not self._dirty:
                    return self._q.get()
                return self._q.get(timeout=0.02)
            except queue.Empty:
                self._brownout = self._watermark.update(0, 0.0)
                if not self._brownout:
                    self.repair()

    def _drop_expired(self, group) -> list:
        """Batch-formation deadline check: fail every expired request
        with ``DeadlineExceededError`` BEFORE any work is spent on it and
        return the survivors.  A request that makes it into the returned
        group runs to completion — deadlines bound queue wait, not
        compute."""
        now = time.monotonic()
        alive = []
        for r in group:
            if r.deadline is not None and now > r.deadline:
                self._expired += 1
                if self._tracer is not None:
                    self._tracer.metrics.counter("expired").inc()
                self._settle(r.future, exc=ADM.DeadlineExceededError(
                    f"deadline passed after "
                    f"{1e3 * (time.perf_counter() - r.t0):.1f}ms in the "
                    f"queue, before the request entered a batch"))
            else:
                alive.append(r)
        return alive

    def _dispatch(self, group) -> None:
        """Run one batch, under the stuck-batch watchdog when
        ``batch_timeout_s`` is set (the zero-overhead inline path is kept
        when it is not).  On expiry the batch fails with
        ``BatchTimeoutError`` instead of hanging the worker — and the
        service fails with it: the abandoned batch thread may still
        mutate state, so the parity invariant can no longer be
        guaranteed (DESIGN.md §13)."""
        if not group:
            return
        timeout = self._adm.batch_timeout_s
        if timeout is None:
            self._process(group)
            return
        done = threading.Event()

        def run() -> None:
            try:
                self._process(group)
            finally:
                done.set()

        t = threading.Thread(target=run, name="resolution-batch",
                             daemon=True)
        t.start()
        if not done.wait(timeout):
            self._fail(ADM.BatchTimeoutError(
                f"batch of {len(group)} request(s) exceeded "
                f"batch_timeout_s={timeout}"), group)

    @staticmethod
    def _settle(fut: "Future", exc: Optional[BaseException] = None,
                result=None) -> None:
        """Resolve a future exactly once: a watchdog-failed batch and its
        zombie thread may both reach the same future — whoever is second
        must be a no-op, not an InvalidStateError."""
        try:
            if exc is not None:
                fut.set_exception(exc)
            else:
                fut.set_result(result)
        except InvalidStateError:
            pass

    def _process(self, group) -> None:
        try:
            result = self._apply_batch(group)
        except (ValueError, InjectedFault) as exc:
            # request-level rejection: bad input (eid collisions, unknown
            # deletes, ...) or a chaos-injected matcher error — both are
            # raised BEFORE any state mutation, so the batch's callers
            # get the error and the service keeps serving
            for r in group:
                self._settle(r.future, exc=exc)
        except BaseException as exc:  # noqa: BLE001 — service-level failure
            # anything else means the worker can no longer guarantee its
            # parity invariant: mark the service failed (never die
            # silently), fail this batch AND everything still queued with
            # the ORIGINAL error, and refuse new submissions
            self._fail(exc, group)
        else:
            for r in group:
                self._settle(r.future, result=result)

    def _fail(self, exc: BaseException, group) -> None:
        self._failure = exc
        self._closed = True
        for r in group:
            self._settle(r.future, exc=exc)
        while True:              # queued requests must not hang forever
            try:
                nxt = self._q.get_nowait()
            except queue.Empty:
                break
            if nxt is not _STOP:
                self._settle(nxt.future, exc=exc)

    def _apply_batch(self, group) -> IncrementalResult:
        if self._tracer is None:
            return self._apply_batch_inner(group)
        t0 = time.perf_counter()
        for r in group:
            self._tracer.metrics.histogram("admission_ms").observe(
                1e3 * (t0 - r.t0))      # queue wait per admitted request
        with OBS.activate(self._tracer), OBS.span(
                "batch", kind=group[0].kind, requests=len(group),
                entities=sum(r.n for r in group)):
            result = self._apply_batch_inner(group)
        self._tracer.metrics.histogram("batch_ms").observe(
            1e3 * (time.perf_counter() - t0))
        return result

    def _apply_batch_inner(self, group) -> IncrementalResult:
        kind = group[0].kind
        # chaos + brownout decisions happen OUTSIDE the lock: an injected
        # stall must not hold stats() hostage, and an injected error must
        # fire before any state mutation (request-level by construction)
        # chaos indexes DISPATCHED batches, not completed ones: an
        # injected error must consume its slot, or it would re-fire on
        # every retry forever (``_batches`` only counts completions)
        idx = self._dispatched
        self._dispatched += 1
        if self._chaos is not None:
            self._chaos.on_batch(idx)
        p95 = 0.0 if self._adm.brownout_p95_ms is None \
            else 1e3 * self._latency.percentile(0.95)
        self._brownout = self._watermark.update(self._q.qsize(), p95)
        degraded = self._brownout
        if self._tracer is not None:
            self._tracer.metrics.gauge("brownout").set(
                1.0 if degraded else 0.0)
        with self._lock:
            cache = PC.executable_cache()
            before = cache.stats.snapshot()
            if kind == "insert":
                h = group[0].data if len(group) == 1 else \
                    E.host_concat([r.data for r in group])
                dev = E.make_entities(h["key"], h["eid"],
                                      payload=h["payload"],
                                      valid=h["valid"])
                nb, nm, dstats = self._delta.insert(dev, self._blocked,
                                                    self._matched,
                                                    degraded=degraded)
            else:
                eids = np.concatenate([r.data for r in group])
                nb, nm, dstats = self._delta.delete(eids, self._blocked,
                                                    self._matched,
                                                    degraded=degraded)
            self._blocked, self._matched = nb, nm
            if dstats.degraded:
                self._degraded_batches += 1
                self._record_dirty(dstats.comp_ranges)
                if self._tracer is not None:
                    self._tracer.metrics.counter("degraded_batches").inc()
            dh, dm, dt = cache.stats.delta(before)
            self._hits += dh
            self._misses += dm
            self._traces += dt
            self._steady += int(dstats.device_calls > 0
                                and dh > 0 and dm == 0 and dt == 0)
            self._batches += 1
            self._requests += len(group)
            self._fill += min(1.0, sum(r.n for r in group)
                              / max(self.max_batch, 1))
            self._device_calls += dstats.device_calls
            self._shapes.update(dstats.shapes)
            self.index.maybe_compact()

            old_sb, old_sm = self._served_b, self._served_m
            if self._boundary_complete:
                self._served_b, self._served_m = nb, nm
            else:
                straddle = srp_straddle_packed(self.index, self.cfg)
                self._served_b = np.setdiff1d(nb, straddle)
                self._served_m = np.setdiff1d(nm, straddle)
            new_p = np.setdiff1d(self._served_b, old_sb)
            gone_p = np.setdiff1d(old_sb, self._served_b)
            new_m = np.setdiff1d(self._served_m, old_sm)
            gone_m = np.setdiff1d(old_sm, self._served_m)
            ids = {}
            for packed in new_p.tolist():
                pid = self._pair_ids.get(packed)
                if pid is None:
                    pid = len(self._pair_ids)
                    self._pair_ids[packed] = pid
                ids[(packed >> 32, packed & 0xFFFFFFFF)] = pid
            now = time.perf_counter()
            for r in group:
                self._latency.observe(now - r.t0)
            stats = self._stats_locked()
        return IncrementalResult(
            new_pairs=RES.packed_to_frozenset(new_p),
            retired_pairs=RES.packed_to_frozenset(gone_p),
            new_matches=RES.packed_to_frozenset(new_m),
            retired_matches=RES.packed_to_frozenset(gone_m),
            pair_ids=ids, batched=len(group), stats=stats,
            degraded=dstats.degraded)

    # -- brownout repair -----------------------------------------------------

    def _record_dirty(self, ranges) -> None:
        """Fold the composite ranges a degraded batch touched into the
        merged dirty list (sorted, overlaps coalesced).  Composites are
        immutable per entity, so the ranges stay valid repair anchors no
        matter what mutates in between (DESIGN.md §13)."""
        merged = sorted(self._dirty
                        + [(int(a), int(b)) for a, b in ranges])
        out: List[Tuple[int, int]] = []
        for lo, hi in merged:
            if out and lo <= out[-1][1]:
                out[-1] = (out[-1][0], max(out[-1][1], hi))
            else:
                out.append((lo, hi))
        self._dirty = out

    def repair(self) -> int:
        """Re-resolve every dirty composite range EXACTLY (full device
        path, real matcher) and fold the results into the maintained and
        served sets — after this returns, the served sets are
        bit-identical to a from-scratch ``resolve`` of the live corpus
        (invariant 13, the eventually-exact half).  Returns the number of
        ranges repaired (0 = nothing was dirty).

        The worker runs this automatically whenever the queue drains
        while repair debt is outstanding; ``start=False`` services (and
        tests that want deterministic timing) call it directly."""
        with self._lock:
            return self._repair_locked()

    def _repair_locked(self) -> int:
        if not self._dirty:
            return 0
        ranges, self._dirty = self._dirty, []
        cache = PC.executable_cache()
        before = cache.stats.snapshot()
        nb, nm, dstats = self._delta.refresh(ranges, self._blocked,
                                             self._matched)
        self._blocked, self._matched = nb, nm
        dh, dm, dt = cache.stats.delta(before)
        self._hits += dh
        self._misses += dm
        self._traces += dt
        self._device_calls += dstats.device_calls
        self._shapes.update(dstats.shapes)
        self._repairs += 1
        if self._tracer is not None:
            self._tracer.metrics.counter("repairs").inc()
        if self._boundary_complete:
            self._served_b, self._served_m = nb, nm
        else:
            straddle = srp_straddle_packed(self.index, self.cfg)
            self._served_b = np.setdiff1d(nb, straddle)
            self._served_m = np.setdiff1d(nm, straddle)
        # the blocked set never degrades, so repair cannot mint pairs the
        # id table has not seen — guard anyway so ids stay total
        for packed in dstats.added_blocked.tolist():
            self._pair_ids.setdefault(packed, len(self._pair_ids))
        return len(ranges)

    # -- state ---------------------------------------------------------------

    @property
    def packed_pairs(self) -> np.ndarray:
        """Currently served blocked set, packed (sorted unique uint64)."""
        with self._lock:
            return self._served_b

    @property
    def packed_matches(self) -> np.ndarray:
        """Currently served matched set, packed."""
        with self._lock:
            return self._served_m

    @property
    def pairs(self) -> FrozenSet[Pair]:
        """Currently served blocked set as (lo, hi) eid tuples."""
        return RES.packed_to_frozenset(self.packed_pairs)

    @property
    def matches(self) -> FrozenSet[Pair]:
        """Currently served matched set as (lo, hi) eid tuples."""
        return RES.packed_to_frozenset(self.packed_matches)

    def pair_id(self, pair: Pair) -> int:
        """Stable id of a pair the service has served at any point."""
        return self._pair_ids[(int(pair[0]) << 32) | int(pair[1])]

    def _stats_locked(self) -> ServeStats:
        pct = lambda p: 1e3 * self._latency.percentile(p)
        depth = self._q.qsize()
        cap = self._q.maxsize
        return ServeStats(
            requests=self._requests, batches=self._batches,
            steady_batches=self._steady,
            queue_depth=depth,
            batch_fill=self._fill / max(self._batches, 1),
            cache_hits=self._hits, cache_misses=self._misses,
            traces=self._traces, device_calls=self._device_calls,
            p50_ms=pct(0.50), p95_ms=pct(0.95),
            live_entities=self.index.n_live,
            index_runs=self.index.n_runs, index_rows=self.index.n_rows,
            tombstones=self.index.tombstones,
            compactions=self.index.compactions,
            pairs=int(self._served_b.shape[0]),
            matches=int(self._served_m.shape[0]),
            shapes=tuple(sorted(self._shapes)),
            failure=None if self._failure is None else repr(self._failure),
            shed=self._shed, rejected=self._rejected,
            expired=self._expired,
            degraded_batches=self._degraded_batches,
            repairs=self._repairs, dirty_ranges=len(self._dirty),
            brownout=self._brownout,
            health=ADM.derive_health(
                failure=self._failure is not None,
                brownout=self._brownout,
                dirty_ranges=len(self._dirty),
                depth_frac=depth / cap if cap > 0 else 0.0,
                high=self._adm.brownout_high))

    def stats(self) -> ServeStats:
        """Current telemetry snapshot."""
        with self._lock:
            return self._stats_locked()

    def trace_report(self) -> Optional["OBS.TraceReport"]:
        """A ``repro.obs.TraceReport`` over every micro-batch served so
        far (one ``batch`` span per batch, the bounded ``batch_ms``
        latency histogram, and the current ``ServeStats`` behind the
        unified schema).  Requires the service config to carry
        ``trace=True``; returns None otherwise.  Can be called repeatedly
        — each call snapshots the tracer's current state."""
        if self._tracer is None:
            return None
        with self._lock:
            return OBS.TraceReport.from_tracer(self._tracer,
                                               (self._stats_locked(),))

    # -- durability ----------------------------------------------------------

    def snapshot(self, snapshot_dir: str) -> None:
        """Persist the full serving state to ``snapshot_dir`` (DESIGN.md
        §11): the live index segments (``SortedIndex.snapshot``), the
        maintained + served packed pair sets, the stable pair-id table,
        and a manifest carrying the config fingerprint.  All writes are
        atomic with the manifest last; a restored service serves the
        IDENTICAL pair set and continues under the same ids.  Outstanding
        brownout repair debt is drained FIRST — a snapshot is always
        exact, so restore never needs to know about dirty ranges."""
        with self._lock:
            self._repair_locked()
            self.index.snapshot(snapshot_dir)
            packed = np.fromiter(self._pair_ids.keys(), np.uint64,
                                 len(self._pair_ids))
            ids = np.fromiter(self._pair_ids.values(), np.int64,
                              len(self._pair_ids))
            atomic_savez(os.path.join(snapshot_dir, "pairs.npz"),
                         blocked=self._blocked, matched=self._matched,
                         served_b=self._served_b, served_m=self._served_m,
                         pair_packed=packed, pair_id=ids)
            atomic_write_json(
                os.path.join(snapshot_dir, _SERVICE_MANIFEST),
                {"version": 1,
                 "fingerprint": repr(self.cfg.static_fingerprint()),
                 "num_shards": self.cfg.num_shards})

    @classmethod
    def restore(cls, snapshot_dir: str, cfg,
                **kwargs) -> "ResolutionService":
        """Rebuild a service from a ``snapshot`` directory.  ``cfg`` must
        be the original config (validated against the stored fingerprint —
        the served set depends on it); remaining kwargs configure the new
        service exactly like the constructor.  The restored service serves
        the same pairs/matches under the same stable pair ids, and further
        mutations stay in parity with an uninterrupted service."""
        mpath = os.path.join(snapshot_dir, _SERVICE_MANIFEST)
        if not os.path.exists(mpath):
            raise FileNotFoundError(
                f"no service snapshot manifest at {mpath!r}")
        with open(mpath) as f:
            manifest = json.load(f)
        fp = repr(cfg.static_fingerprint())
        if fp != manifest["fingerprint"] \
                or cfg.num_shards != manifest["num_shards"]:
            raise ValueError(
                f"config does not match the snapshot at {snapshot_dir!r} "
                f"(the served pair set depends on it); restore with the "
                f"original configuration")
        svc = cls(cfg, **kwargs)
        with svc._lock:
            old = svc.index
            svc.index = SortedIndex.restore(
                snapshot_dir, spool_dir=old.spool_dir,
                max_runs=old.max_runs,
                max_tombstone_frac=old.max_tombstone_frac,
                merge_block=old.merge_block)
            svc._delta = DeltaMatcher(cfg, svc.index,
                                      shard_buckets=svc._shard_buckets,
                                      cap_floor=svc._cap_floor)
            with np.load(os.path.join(snapshot_dir, "pairs.npz"),
                         allow_pickle=False) as z:
                svc._blocked, svc._matched = z["blocked"], z["matched"]
                svc._served_b, svc._served_m = z["served_b"], z["served_m"]
                svc._pair_ids = dict(zip(z["pair_packed"].tolist(),
                                         z["pair_id"].tolist()))
        return svc

    # -- lifecycle -----------------------------------------------------------

    def close(self, drain: bool = True,
              timeout: Optional[float] = None) -> None:
        """Stop the worker and refuse new submissions.  ``drain=True``
        (default) processes everything already queued first — every
        previously returned future completes normally; ``drain=False``
        fails queued requests immediately with a RuntimeError instead.

        ``timeout`` (seconds) bounds the shutdown so it cannot hang
        behind a stuck batch: if the worker has not finished draining
        when it expires, every still-queued future fails with
        ``BatchTimeoutError``, the service marks itself failed, and the
        abandoned worker (a daemon thread) is left to die with the
        process.  ``timeout=None`` keeps the legacy unbounded drain."""
        if self._closed:
            return
        self._closed = True
        if self._worker is not None:
            if not drain:
                err = RuntimeError("service closed with drain=False before "
                                   "this request was processed")
                while True:
                    try:
                        nxt = self._q.get_nowait()
                    except queue.Empty:
                        break
                    if nxt is not _STOP:
                        self._settle(nxt.future, exc=err)
            try:
                self._q.put_nowait(_STOP)
            except queue.Full:
                # a full queue behind a stuck worker: only block for the
                # sentinel when the caller asked for an unbounded drain
                if timeout is None:
                    self._q.put(_STOP)
            self._worker.join(timeout)
            if self._worker.is_alive():
                exc = ADM.BatchTimeoutError(
                    f"close(timeout={timeout}) expired with the worker "
                    f"still busy; queued requests were abandoned")
                if self._failure is None:
                    self._failure = exc
                while True:
                    try:
                        nxt = self._q.get_nowait()
                    except queue.Empty:
                        break
                    if nxt is not _STOP:
                        self._settle(nxt.future, exc=exc)
                try:        # the drained queue has room for the sentinel
                    self._q.put_nowait(_STOP)   # now: a later-recovering
                except queue.Full:              # worker still stops
                    pass
            self._worker = None

    def __enter__(self) -> "ResolutionService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
