"""Micro-batched serving layer: ``ResolutionService``.

The front end of the online subsystem: callers submit entity inserts and
deletes; a worker thread coalesces adjacent same-kind requests into
micro-batches (up to ``max_batch`` entities or ``max_wait_ms``), drives the
``DeltaMatcher`` once per batch, and resolves every request's future with
the batch's ``IncrementalResult``.  Because delta calls ride the
shape-bucket grid, a steady request stream hits the ``repro.perf``
executable cache on every batch — the serving-path analogue of the
stream's ``steady_chunks``.

The service maintains the CURRENT pair sets (not a monotone union): the
**served** sets are exactly what a from-scratch ``api.resolve`` of the
live corpus under the same config would produce — for boundary-complete
variants (repsn, jobsn) the maintained complete sets themselves; for SRP,
complete minus the pairs straddling the profile-planned partition bounds
(``delta.srp_straddle_packed``).  That equality holds after ANY
interleaving of inserts and deletes and is what ``tests/test_serve.py``
asserts property-style.

Ordering semantics: requests apply in submission order; only ADJACENT
same-kind requests coalesce, so a delete never leapfrogs the insert before
it.  All requests in one micro-batch share the batch's result (``batched``
reports the coalescing width).  Pair ids are stable for the service's
lifetime: a pair that is retired and later re-created keeps its id.
"""
from __future__ import annotations

import json
import os
import queue
import threading
import time
from concurrent.futures import Future
from typing import Dict, FrozenSet, NamedTuple, Optional, Tuple

import numpy as np

from repro import obs as OBS
from repro.api import results as RES
from repro.api.variants import get_variant
from repro.core import entities as E
from repro.perf import cache as PC
from repro.serve.delta import DeltaMatcher, srp_straddle_packed
from repro.serve.index import SortedIndex
from repro.stream.store import atomic_savez, atomic_write_json

_SERVICE_MANIFEST = "SERVICE.json"

Pair = Tuple[int, int]
_EMPTY = np.empty((0,), RES.PACKED_DTYPE)
_STOP = object()


class ServeStats(NamedTuple):
    """Service telemetry snapshot (rides on every ``IncrementalResult``).

    ``steady_batches`` counts micro-batches served ENTIRELY from the
    executable cache (hits, zero builds/traces) — after warm-up every
    batch should be steady; ``shapes`` lists the distinct (num_shards,
    shard_cap) delta-call buckets seen, the quantity that must stay small
    for that to hold.  ``batch_fill`` is the mean coalesced batch size
    over ``max_batch``; ``p50_ms``/``p95_ms`` are submit-to-result
    latencies over a sliding window.  ``failure`` is None while the
    service is healthy; after an unexpected worker error it carries that
    error's repr (the service refuses further submissions — DESIGN.md
    §11)."""
    requests: int
    batches: int
    steady_batches: int
    queue_depth: int
    batch_fill: float
    cache_hits: int
    cache_misses: int
    traces: int
    device_calls: int
    p50_ms: float
    p95_ms: float
    live_entities: int
    index_runs: int
    index_rows: int
    tombstones: int
    compactions: int
    pairs: int
    matches: int
    shapes: Tuple[Tuple[int, int], ...]
    failure: Optional[str] = None


class IncrementalResult(NamedTuple):
    """Outcome of one request (shared by its whole micro-batch).

    ``new_pairs``/``retired_pairs`` are the SERVED blocked-set edits this
    batch caused (both directions are real: an insert can retire old
    pairs, a delete can create them); ``*_matches`` the matched-set edits.
    ``pair_ids`` maps each NEW pair to its stable service-wide id."""
    new_pairs: FrozenSet[Pair]
    retired_pairs: FrozenSet[Pair]
    new_matches: FrozenSet[Pair]
    retired_matches: FrozenSet[Pair]
    pair_ids: Dict[Pair, int]
    batched: int
    stats: ServeStats


class _Request:
    __slots__ = ("kind", "data", "n", "future", "t0")

    def __init__(self, kind: str, data, n: int):
        self.kind = kind
        self.data = data
        self.n = n
        self.future: "Future[IncrementalResult]" = Future()
        self.t0 = time.perf_counter()


class ResolutionService:
    """Online incremental entity resolution over one persistent corpus.

        svc = ResolutionService(cfg, initial=base_corpus)
        res = svc.resolve_incremental(new_ents)   # sync insert
        res.new_pairs, res.retired_pairs
        svc.delete([17, 42])                      # sync delete by eid
        svc.pairs                                 # currently served set

    ``submit_insert``/``submit_delete`` are the async forms (futures);
    the sync forms go through the same queue, so concurrent callers
    coalesce.  ``start=False`` skips the worker thread and processes
    every request inline (single-caller tests/benchmarks).

    The config must be single-pass, non-linkage, without
    ``return_scores``; the service always executes delta calls on the
    vmap runner, and SRP straddle correction uses ``cfg.num_shards`` —
    served sets match a from-scratch vmap ``resolve`` under ``cfg``.
    """

    def __init__(self, cfg, *, initial=None, max_batch: int = 512,
                 max_wait_ms: float = 2.0, queue_cap: int = 1024,
                 spool_dir: Optional[str] = None, start: bool = True,
                 segment_rows: int = 4096, max_runs: int = 12,
                 max_tombstone_frac: float = 0.25,
                 shard_buckets=(2, 4, 8), cap_floor: int = 64):
        self.cfg = cfg
        self._boundary_complete = get_variant(cfg.variant).boundary_complete
        self._shard_buckets = shard_buckets     # kept for restore()
        self._cap_floor = cap_floor
        self.index = SortedIndex(cfg.window, spool_dir=spool_dir,
                                 segment_rows=segment_rows,
                                 max_runs=max_runs,
                                 max_tombstone_frac=max_tombstone_frac)
        self._delta = DeltaMatcher(cfg, self.index,
                                   shard_buckets=shard_buckets,
                                   cap_floor=cap_floor)
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self._blocked = _EMPTY      # maintained COMPLETE sets
        self._matched = _EMPTY
        self._served_b = _EMPTY     # derived SERVED sets (post-straddle)
        self._served_m = _EMPTY
        self._pair_ids: Dict[int, int] = {}     # packed pair -> stable id
        self._lock = threading.Lock()
        # submit-to-result latencies (seconds) over a bounded sliding
        # window — the obs ring buffer keeps the historical deque's
        # percentile semantics bit-for-bit (DESIGN.md §12)
        self._latency = OBS.Histogram("latency_s", 2048)
        # per-batch spans accumulate here when the config asks for
        # tracing; the service owns its tracer for its whole lifetime
        # (batches arrive forever — there is no single "run" to scope it)
        self._tracer = OBS.Tracer() if getattr(cfg, "trace", False) \
            else None
        self._requests = 0
        self._batches = 0
        self._steady = 0
        self._fill = 0.0
        self._hits = self._misses = self._traces = 0
        self._device_calls = 0
        self._shapes: set = set()
        self._q: "queue.Queue" = queue.Queue(maxsize=queue_cap)
        self._worker: Optional[threading.Thread] = None
        self._closed = False
        self._failure: Optional[BaseException] = None
        if start:
            self._worker = threading.Thread(target=self._run,
                                            name="resolution-serve",
                                            daemon=True)
            self._worker.start()
        if initial is not None:
            self.resolve_incremental(initial)

    # -- submission ----------------------------------------------------------

    def submit_insert(self, ents) -> "Future[IncrementalResult]":
        """Enqueue an insert of NEW entities (device or host entity dict;
        invalid rows are dropped, live-eid collisions raise).  Blocks for
        backpressure when the bounded queue is full."""
        h = ents if isinstance(ents.get("key"), np.ndarray) \
            else E.to_host(ents)
        return self._submit(_Request("insert", h, int(h["key"].shape[0])))

    def submit_delete(self, eids) -> "Future[IncrementalResult]":
        """Enqueue a delete of live entities by eid (unknown or already-
        deleted eids fail the whole request)."""
        arr = np.asarray(eids, np.int64).reshape(-1)
        return self._submit(_Request("delete", arr, int(arr.shape[0])))

    def resolve_incremental(self, ents) -> IncrementalResult:
        """Synchronous insert: submit and wait for the batch result."""
        return self.submit_insert(ents).result()

    def delete(self, eids) -> IncrementalResult:
        """Synchronous delete: submit and wait for the batch result."""
        return self.submit_delete(eids).result()

    def _submit(self, req: _Request) -> "Future[IncrementalResult]":
        if self._failure is not None:
            raise RuntimeError(
                "service failed and no longer accepts requests"
            ) from self._failure
        if self._closed:
            raise RuntimeError("service is closed")
        if self._worker is None:
            self._process([req])
        else:
            self._q.put(req)
        return req.future

    # -- worker --------------------------------------------------------------

    def _run(self) -> None:
        pending: Optional[_Request] = None
        running = True
        while running:
            req = pending if pending is not None else self._q.get()
            pending = None
            if req is _STOP:
                break
            group = [req]
            n = req.n
            deadline = time.monotonic() + self.max_wait_ms * 1e-3
            while n < self.max_batch:
                wait = deadline - time.monotonic()
                if wait <= 0:
                    break
                try:
                    nxt = self._q.get(timeout=wait)
                except queue.Empty:
                    break
                if nxt is _STOP:
                    running = False
                    break
                if nxt.kind != req.kind:
                    # a kind change closes the batch: submission order is
                    # preserved exactly
                    pending = nxt
                    break
                group.append(nxt)
                n += nxt.n
            self._process(group)
            if self._failure is not None:
                running = False        # dead worker: stop consuming
        if pending is not None and pending is not _STOP:
            if self._failure is not None:
                pending.future.set_exception(self._failure)
            else:
                self._process([pending])

    def _process(self, group) -> None:
        try:
            result = self._apply_batch(group)
        except ValueError as exc:
            # request-level rejection (bad input: eid collisions, unknown
            # deletes, ...): the batch's callers get the error, the
            # service state is untouched and keeps serving
            for r in group:
                r.future.set_exception(exc)
        except BaseException as exc:  # noqa: BLE001 — service-level failure
            # anything else means the worker can no longer guarantee its
            # parity invariant: mark the service failed (never die
            # silently), fail this batch AND everything still queued with
            # the ORIGINAL error, and refuse new submissions
            self._fail(exc, group)
        else:
            for r in group:
                r.future.set_result(result)

    def _fail(self, exc: BaseException, group) -> None:
        self._failure = exc
        self._closed = True
        for r in group:
            r.future.set_exception(exc)
        while True:              # queued requests must not hang forever
            try:
                nxt = self._q.get_nowait()
            except queue.Empty:
                break
            if nxt is not _STOP:
                nxt.future.set_exception(exc)

    def _apply_batch(self, group) -> IncrementalResult:
        if self._tracer is None:
            return self._apply_batch_inner(group)
        t0 = time.perf_counter()
        with OBS.activate(self._tracer), OBS.span(
                "batch", kind=group[0].kind, requests=len(group),
                entities=sum(r.n for r in group)):
            result = self._apply_batch_inner(group)
        self._tracer.metrics.histogram("batch_ms").observe(
            1e3 * (time.perf_counter() - t0))
        return result

    def _apply_batch_inner(self, group) -> IncrementalResult:
        kind = group[0].kind
        with self._lock:
            cache = PC.executable_cache()
            before = cache.stats.snapshot()
            if kind == "insert":
                h = group[0].data if len(group) == 1 else \
                    E.host_concat([r.data for r in group])
                dev = E.make_entities(h["key"], h["eid"],
                                      payload=h["payload"],
                                      valid=h["valid"])
                nb, nm, dstats = self._delta.insert(dev, self._blocked,
                                                    self._matched)
            else:
                eids = np.concatenate([r.data for r in group])
                nb, nm, dstats = self._delta.delete(eids, self._blocked,
                                                    self._matched)
            self._blocked, self._matched = nb, nm
            dh, dm, dt = cache.stats.delta(before)
            self._hits += dh
            self._misses += dm
            self._traces += dt
            self._steady += int(dstats.device_calls > 0
                                and dh > 0 and dm == 0 and dt == 0)
            self._batches += 1
            self._requests += len(group)
            self._fill += min(1.0, sum(r.n for r in group)
                              / max(self.max_batch, 1))
            self._device_calls += dstats.device_calls
            self._shapes.update(dstats.shapes)
            self.index.maybe_compact()

            old_sb, old_sm = self._served_b, self._served_m
            if self._boundary_complete:
                self._served_b, self._served_m = nb, nm
            else:
                straddle = srp_straddle_packed(self.index, self.cfg)
                self._served_b = np.setdiff1d(nb, straddle)
                self._served_m = np.setdiff1d(nm, straddle)
            new_p = np.setdiff1d(self._served_b, old_sb)
            gone_p = np.setdiff1d(old_sb, self._served_b)
            new_m = np.setdiff1d(self._served_m, old_sm)
            gone_m = np.setdiff1d(old_sm, self._served_m)
            ids = {}
            for packed in new_p.tolist():
                pid = self._pair_ids.get(packed)
                if pid is None:
                    pid = len(self._pair_ids)
                    self._pair_ids[packed] = pid
                ids[(packed >> 32, packed & 0xFFFFFFFF)] = pid
            now = time.perf_counter()
            for r in group:
                self._latency.observe(now - r.t0)
            stats = self._stats_locked()
        return IncrementalResult(
            new_pairs=RES.packed_to_frozenset(new_p),
            retired_pairs=RES.packed_to_frozenset(gone_p),
            new_matches=RES.packed_to_frozenset(new_m),
            retired_matches=RES.packed_to_frozenset(gone_m),
            pair_ids=ids, batched=len(group), stats=stats)

    # -- state ---------------------------------------------------------------

    @property
    def packed_pairs(self) -> np.ndarray:
        """Currently served blocked set, packed (sorted unique uint64)."""
        with self._lock:
            return self._served_b

    @property
    def packed_matches(self) -> np.ndarray:
        """Currently served matched set, packed."""
        with self._lock:
            return self._served_m

    @property
    def pairs(self) -> FrozenSet[Pair]:
        """Currently served blocked set as (lo, hi) eid tuples."""
        return RES.packed_to_frozenset(self.packed_pairs)

    @property
    def matches(self) -> FrozenSet[Pair]:
        """Currently served matched set as (lo, hi) eid tuples."""
        return RES.packed_to_frozenset(self.packed_matches)

    def pair_id(self, pair: Pair) -> int:
        """Stable id of a pair the service has served at any point."""
        return self._pair_ids[(int(pair[0]) << 32) | int(pair[1])]

    def _stats_locked(self) -> ServeStats:
        pct = lambda p: 1e3 * self._latency.percentile(p)
        return ServeStats(
            requests=self._requests, batches=self._batches,
            steady_batches=self._steady,
            queue_depth=self._q.qsize(),
            batch_fill=self._fill / max(self._batches, 1),
            cache_hits=self._hits, cache_misses=self._misses,
            traces=self._traces, device_calls=self._device_calls,
            p50_ms=pct(0.50), p95_ms=pct(0.95),
            live_entities=self.index.n_live,
            index_runs=self.index.n_runs, index_rows=self.index.n_rows,
            tombstones=self.index.tombstones,
            compactions=self.index.compactions,
            pairs=int(self._served_b.shape[0]),
            matches=int(self._served_m.shape[0]),
            shapes=tuple(sorted(self._shapes)),
            failure=None if self._failure is None else repr(self._failure))

    def stats(self) -> ServeStats:
        """Current telemetry snapshot."""
        with self._lock:
            return self._stats_locked()

    def trace_report(self) -> Optional["OBS.TraceReport"]:
        """A ``repro.obs.TraceReport`` over every micro-batch served so
        far (one ``batch`` span per batch, the bounded ``batch_ms``
        latency histogram, and the current ``ServeStats`` behind the
        unified schema).  Requires the service config to carry
        ``trace=True``; returns None otherwise.  Can be called repeatedly
        — each call snapshots the tracer's current state."""
        if self._tracer is None:
            return None
        with self._lock:
            return OBS.TraceReport.from_tracer(self._tracer,
                                               (self._stats_locked(),))

    # -- durability ----------------------------------------------------------

    def snapshot(self, snapshot_dir: str) -> None:
        """Persist the full serving state to ``snapshot_dir`` (DESIGN.md
        §11): the live index segments (``SortedIndex.snapshot``), the
        maintained + served packed pair sets, the stable pair-id table,
        and a manifest carrying the config fingerprint.  All writes are
        atomic with the manifest last; a restored service serves the
        IDENTICAL pair set and continues under the same ids."""
        with self._lock:
            self.index.snapshot(snapshot_dir)
            packed = np.fromiter(self._pair_ids.keys(), np.uint64,
                                 len(self._pair_ids))
            ids = np.fromiter(self._pair_ids.values(), np.int64,
                              len(self._pair_ids))
            atomic_savez(os.path.join(snapshot_dir, "pairs.npz"),
                         blocked=self._blocked, matched=self._matched,
                         served_b=self._served_b, served_m=self._served_m,
                         pair_packed=packed, pair_id=ids)
            atomic_write_json(
                os.path.join(snapshot_dir, _SERVICE_MANIFEST),
                {"version": 1,
                 "fingerprint": repr(self.cfg.static_fingerprint()),
                 "num_shards": self.cfg.num_shards})

    @classmethod
    def restore(cls, snapshot_dir: str, cfg,
                **kwargs) -> "ResolutionService":
        """Rebuild a service from a ``snapshot`` directory.  ``cfg`` must
        be the original config (validated against the stored fingerprint —
        the served set depends on it); remaining kwargs configure the new
        service exactly like the constructor.  The restored service serves
        the same pairs/matches under the same stable pair ids, and further
        mutations stay in parity with an uninterrupted service."""
        mpath = os.path.join(snapshot_dir, _SERVICE_MANIFEST)
        if not os.path.exists(mpath):
            raise FileNotFoundError(
                f"no service snapshot manifest at {mpath!r}")
        with open(mpath) as f:
            manifest = json.load(f)
        fp = repr(cfg.static_fingerprint())
        if fp != manifest["fingerprint"] \
                or cfg.num_shards != manifest["num_shards"]:
            raise ValueError(
                f"config does not match the snapshot at {snapshot_dir!r} "
                f"(the served pair set depends on it); restore with the "
                f"original configuration")
        svc = cls(cfg, **kwargs)
        with svc._lock:
            old = svc.index
            svc.index = SortedIndex.restore(
                snapshot_dir, spool_dir=old.spool_dir,
                max_runs=old.max_runs,
                max_tombstone_frac=old.max_tombstone_frac,
                merge_block=old.merge_block)
            svc._delta = DeltaMatcher(cfg, svc.index,
                                      shard_buckets=svc._shard_buckets,
                                      cap_floor=svc._cap_floor)
            with np.load(os.path.join(snapshot_dir, "pairs.npz"),
                         allow_pickle=False) as z:
                svc._blocked, svc._matched = z["blocked"], z["matched"]
                svc._served_b, svc._served_m = z["served_b"], z["served_m"]
                svc._pair_ids = dict(zip(z["pair_packed"].tolist(),
                                         z["pair_id"].tolist()))
        return svc

    # -- lifecycle -----------------------------------------------------------

    def close(self, drain: bool = True) -> None:
        """Stop the worker and refuse new submissions.  ``drain=True``
        (default) processes everything already queued first — every
        previously returned future completes normally; ``drain=False``
        fails queued requests immediately with a RuntimeError instead."""
        if self._closed:
            return
        self._closed = True
        if self._worker is not None:
            if not drain:
                err = RuntimeError("service closed with drain=False before "
                                   "this request was processed")
                while True:
                    try:
                        nxt = self._q.get_nowait()
                    except queue.Empty:
                        break
                    if nxt is not _STOP:
                        nxt.future.set_exception(err)
            self._q.put(_STOP)
            self._worker.join()
            self._worker = None

    def __enter__(self) -> "ResolutionService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
