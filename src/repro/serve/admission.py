"""Admission control + graceful degradation policy for the serving layer.

``ResolutionService`` melts under sustained overload without this module:
the bounded queue blocks every submitter forever, requests have no
deadlines, and one slow batch stalls every caller behind it.  The source
paper leans on MapReduce because the framework absorbs stragglers and
task failures transparently (§2); serving has no framework, so the same
absorb-don't-collapse behavior must live at the REQUEST layer.  This
module is that policy, kept separate from the service mechanics:

  * ``AdmissionConfig``      the frozen policy knobs — queue policy
                             (``block`` | ``reject`` | ``shed_oldest``),
                             default per-request deadline, brownout
                             watermarks, stuck-batch watchdog timeout
  * ``WatermarkController``  queue-depth/p95-latency hysteresis deciding
                             when the service browns out to the degraded
                             delta path (and when it recovers)
  * the typed error taxonomy — every way a request can fail under
    pressure is a distinct exception type, so callers (and the chaos
    property tests) can tell "shed by policy" from "worker died"

Health is derived, never stored: ``derive_health`` maps the service's
observable state to ``ok | degraded | overloaded | failed`` for
``ServeStats.health``.

Invariant 13 (DESIGN.md §13): admission control changes WHEN work is
refused or deferred, never WHAT correct results contain — after pressure
drops and ``repair()`` drains the dirty ranges, the served sets are
bit-identical to a from-scratch resolve of the live corpus.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

QUEUE_POLICIES = ("block", "reject", "shed_oldest")

HEALTH_STATES = ("ok", "degraded", "overloaded", "failed")


class AdmissionError(RuntimeError):
    """Base of the admission-control error taxonomy.  Every subclass is a
    REQUEST-level outcome: the future that carries it was refused or
    abandoned by policy while the service itself keeps serving (contrast
    with a service-level failure, which poisons all further work)."""


class OverloadError(AdmissionError):
    """The request was refused because the queue was full: raised at
    ``submit`` time under ``queue_policy="reject"``, or set on the OLDEST
    queued future under ``queue_policy="shed_oldest"`` (the newest request
    wins the slot — fresh work is worth more than stale work that has
    already blown its latency budget)."""


class DeadlineExceededError(AdmissionError):
    """The request's deadline passed while it waited in the queue: set on
    the future at batch-formation time, before any work is spent on it.
    A request that ENTERS a batch runs to completion — deadlines bound
    queue wait, not compute."""


class BatchTimeoutError(AdmissionError):
    """A batch exceeded the stuck-batch watchdog (``batch_timeout_s``) or
    requests were still queued when ``close(timeout=...)`` expired.  For
    the watchdog case the service also marks itself failed: the abandoned
    batch thread may still mutate state, so parity can no longer be
    guaranteed (DESIGN.md §13)."""


@dataclass(frozen=True)
class AdmissionConfig:
    """Admission policy for one ``ResolutionService`` (all fields are
    service-level — none participate in ``ERConfig.static_fingerprint``,
    because none may change what a correct resolve produces).

    ``queue_policy``        ``"block"`` (legacy backpressure — submitters
                            wait, but now fail fast if the worker dies),
                            ``"reject"`` (full queue raises
                            ``OverloadError`` at submit), or
                            ``"shed_oldest"`` (evict + fail the oldest
                            queued future to admit the new request).
    ``default_deadline_ms`` deadline applied to every request that does
                            not pass its own ``deadline_ms`` (None = no
                            deadline).
    ``brownout_high``       queue-depth fraction (depth / queue_cap) at or
                            above which the brownout engages; the p95
                            batch latency crossing ``brownout_p95_ms``
                            (when set) also engages it.
    ``brownout_low``        depth fraction at or below which an engaged
                            brownout releases — the hysteresis gap
                            [low, high] prevents flapping.  Latency does
                            NOT gate release: the p95 window decays
                            slowly, so recovery is driven by the queue
                            actually draining.
    ``brownout_p95_ms``     optional latency watermark for engagement.
    ``batch_timeout_s``     stuck-batch watchdog: a batch that runs longer
                            than this fails with ``BatchTimeoutError``
                            instead of hanging the worker (None = off;
                            the zero-overhead inline path is kept).
    """
    queue_policy: str = "block"
    default_deadline_ms: Optional[float] = None
    brownout_high: float = 0.75
    brownout_low: float = 0.25
    brownout_p95_ms: Optional[float] = None
    batch_timeout_s: Optional[float] = None

    def __post_init__(self) -> None:
        if self.queue_policy not in QUEUE_POLICIES:
            raise ValueError(
                f"queue_policy={self.queue_policy!r} not in "
                f"{QUEUE_POLICIES}")
        if self.brownout_low > self.brownout_high:
            raise ValueError(
                f"brownout_low={self.brownout_low} must be <= "
                f"brownout_high={self.brownout_high}")
        if self.default_deadline_ms is not None \
                and self.default_deadline_ms < 0:
            raise ValueError("default_deadline_ms must be >= 0")
        if self.batch_timeout_s is not None and self.batch_timeout_s <= 0:
            raise ValueError("batch_timeout_s must be > 0")


class WatermarkController:
    """Hysteresis gate for the brownout state (DESIGN.md §13).

    ``update(depth, p95_ms)`` folds one observation and returns the
    current brownout decision: OFF -> ON when the queue-depth fraction
    reaches ``brownout_high`` or p95 batch latency reaches
    ``brownout_p95_ms``; ON -> OFF only when the depth fraction falls to
    ``brownout_low`` (see ``AdmissionConfig`` for why latency never gates
    release).  The controller is intentionally dumb — no EWMA, no clock:
    deterministic given the observation sequence, which is what the
    brownout unit tests pin."""

    def __init__(self, cfg: AdmissionConfig, queue_cap: int):
        self.cfg = cfg
        self.queue_cap = max(int(queue_cap), 1)
        self.engaged = False
        self.transitions = 0

    def update(self, depth: int, p95_ms: float) -> bool:
        """Fold one observation (current queue depth, p95 batch latency
        in ms) and return the brownout decision: engage when the depth
        fraction reaches ``brownout_high`` or p95 reaches
        ``brownout_p95_ms``; release only when depth falls to
        ``brownout_low`` (hysteresis — latency never gates release)."""
        frac = depth / self.queue_cap
        if self.engaged:
            if frac <= self.cfg.brownout_low:
                self.engaged = False
                self.transitions += 1
        else:
            hot = frac >= self.cfg.brownout_high
            if self.cfg.brownout_p95_ms is not None:
                hot = hot or p95_ms >= self.cfg.brownout_p95_ms
            if hot:
                self.engaged = True
                self.transitions += 1
        return self.engaged


def derive_health(*, failure: bool, brownout: bool, dirty_ranges: int,
                  depth_frac: float, high: float) -> str:
    """Map observable service state to the ``ServeStats.health`` value.

    Precedence: ``failed`` (the service refuses all work) over
    ``overloaded`` (queue at/above the high watermark RIGHT NOW) over
    ``degraded`` (brownout engaged, or repair debt outstanding — served
    matches may lag until ``repair()`` drains) over ``ok``."""
    if failure:
        return "failed"
    if depth_frac >= high:
        return "overloaded"
    if brownout or dirty_ranges:
        return "degraded"
    return "ok"
