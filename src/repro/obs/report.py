"""``TraceReport`` — the per-run observability artifact.

When ``ERConfig.trace`` is set, the owning call (``facade.resolve`` /
``link``, ``stream.resolve_stream``, or ``ResolutionService.
trace_report()``) attaches one of these to its result: the run's spans,
its metrics registry export, and every legacy stats object the run
produced — all behind the ONE ``metrics()`` accessor of DESIGN.md §12,
without touching the existing ``result.perf`` / ``.balance`` / ``.stream``
/ ``.resilience`` fields (those keep working; the report UNIFIES them, it
does not replace them).
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import List, Mapping, Tuple

from repro.obs.schema import SCHEMA_VERSION, pack_stats, unpack_stats
from repro.obs.trace import Tracer, write_chrome


@dataclass(frozen=True)
class TraceReport:
    """One traced run: spans + metrics + unified legacy stats.

    ``spans``      the tracer's SpanRecords, in start order
    ``wall``       seconds from tracer creation to report capture
    ``stats``      the run's legacy stats objects, packed through the
                   unified schema and keyed by kind ("PerfStats", ...)
    ``registry``   the tracer's MetricsRegistry export (counters/gauges/
                   histograms under the one ``to_dict`` schema)
    """
    spans: Tuple = ()
    wall: float = 0.0
    stats: Mapping[str, dict] = field(default_factory=dict)
    registry: Mapping[str, dict] = field(default_factory=dict)

    @classmethod
    def from_tracer(cls, tracer: Tracer, stats=(), wall=None
                    ) -> "TraceReport":
        """Capture ``tracer``'s current spans/metrics plus any legacy
        stats objects (Nones are skipped; each is packed under its kind).
        ``wall`` defaults to the tracer's elapsed time."""
        packed = {}
        for obj in stats:
            if obj is None:
                continue
            d = pack_stats(obj)
            packed[d["kind"]] = d
        return cls(spans=tracer.spans(),
                   wall=tracer.wall() if wall is None else wall,
                   stats=packed, registry=tracer.metrics.to_dict())

    def metrics(self) -> dict:
        """The unified JSON-able view of the whole run — schema version,
        wall clock, span count, every registered metric, and all legacy
        stats types behind one schema.  ``unpack_stats`` on any entry of
        ``["stats"]`` reconstructs the original typed object."""
        return {"schema_version": SCHEMA_VERSION,
                "wall_s": self.wall,
                "spans": len(self.spans),
                "metrics": dict(self.registry),
                "stats": {k: dict(v) for k, v in self.stats.items()}}

    def stat(self, kind: str):
        """The run's legacy stats object of ``kind`` ("PerfStats",
        "StreamStats", ...), reconstructed as its original type; KeyError
        when this run produced none of that kind."""
        return unpack_stats(dict(self.stats[kind]))

    def self_times(self) -> List[Tuple[str, float]]:
        """Total SELF time per span name (duration minus direct children),
        sorted descending — the top-spans view of ``tools/
        trace_report.py``."""
        child_sum: dict = defaultdict(float)
        for s in self.spans:
            if s.parent >= 0 and s.dur is not None:
                child_sum[s.parent] += s.dur
        agg: dict = defaultdict(float)
        for s in self.spans:
            if s.dur is None:
                continue
            agg[s.name] += max(0.0, s.dur - child_sum.get(s.index, 0.0))
        return sorted(agg.items(), key=lambda kv: -kv[1])

    def span_totals(self) -> dict:
        """Per-name aggregate {name: {"count", "total_s"}} over all
        finished spans (inclusive durations)."""
        out: dict = {}
        for s in self.spans:
            if s.dur is None:
                continue
            e = out.setdefault(s.name, {"count": 0, "total_s": 0.0})
            e["count"] += 1
            e["total_s"] += s.dur
        return out

    def coverage(self) -> float:
        """Fraction of the first root span's duration covered by its
        DIRECT children — the DESIGN.md §12 accounting-completeness check
        (a healthy instrumented run keeps this >= 0.9, i.e. per-phase /
        per-chunk spans sum to within ~10%% of the measured wall).
        Returns 0.0 when there is no finished root span."""
        roots = [s for s in self.spans if s.parent < 0 and s.dur]
        if not roots:
            return 0.0
        root = roots[0]
        kids = sum(s.dur for s in self.spans
                   if s.parent == root.index and s.dur is not None)
        return kids / root.dur

    def export_chrome(self, path: str) -> None:
        """Write this report as a Chrome/Perfetto ``trace.json`` with the
        full ``metrics()`` blob under the ``"repro"`` key."""
        write_chrome(path, self.spans, repro=self.metrics())
