"""The unified stats schema — pack/unpack for the five legacy stats types.

PR 3–7 each grew an ad-hoc stats type (``BalanceMetrics``, ``PerfStats``,
``StreamStats``, ``ServeStats``, ``ResilienceStats``) with no shared
serialization.  This module gives them ONE: ``pack_stats`` turns any of
them into a plain JSON-able dict tagged with its ``"kind"``, and
``unpack_stats`` reconstructs the original typed object — a lossless
round trip (``unpack(json.loads(json.dumps(pack(x)))) == x``) that
``TraceReport.metrics()`` and the BENCH_*.json writers ride.

Imports are deliberately lazy: this module sits UNDER ``repro.obs`` (a
leaf every instrumented subsystem imports), so pulling ``repro.api`` /
``repro.serve`` in at module scope would close an import cycle.  The
class table resolves at the first ``unpack_stats`` call instead.

``SCHEMA_VERSION`` stamps every serialized artifact of the observability
layer — Chrome-trace ``"repro"`` blobs, ``TraceReport.metrics()``, and
(through ``benchmarks/run.py``) every ``BENCH_*.json`` — so consumers can
fail loudly on drift instead of KeyError-ing into a half-parsed blob.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

SCHEMA_VERSION = 1

#: the stats types the unified schema covers (class name == "kind" tag)
STATS_KINDS: Tuple[str, ...] = ("BalanceMetrics", "PerfStats",
                                "StreamStats", "ServeStats",
                                "ResilienceStats")


def _plain(v):
    """JSON-able coercion: numpy scalars -> Python scalars, tuples ->
    lists (JSON has no tuple; unpack re-tuples from the class's types)."""
    if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
        return _plain(v.item())
    if isinstance(v, (list, tuple)):
        return [_plain(x) for x in v]
    return v


def pack_stats(obj) -> dict:
    """One of the five typed stats objects -> plain dict tagged with its
    ``"kind"`` (the class name), every field JSON-able.  Dataclasses pack
    via ``dataclasses.asdict``, NamedTuples via ``_asdict``; anything else
    raises (the schema is a closed set — register new kinds here)."""
    kind = type(obj).__name__
    if kind not in STATS_KINDS:
        raise TypeError(
            f"{kind} is not a unified-schema stats type; known kinds: "
            f"{STATS_KINDS}")
    if dataclasses.is_dataclass(obj):
        d = dataclasses.asdict(obj)
    elif hasattr(obj, "_asdict"):
        d = dict(obj._asdict())
    else:
        raise TypeError(f"{kind} is neither a dataclass nor a NamedTuple")
    return {"kind": kind, **{k: _plain(v) for k, v in d.items()}}


def _stats_class(kind: str):
    """Resolve a ``"kind"`` tag to its class (lazy imports — see module
    doc)."""
    if kind in ("BalanceMetrics", "PerfStats"):
        from repro.api import results as RES
        return getattr(RES, kind)
    if kind == "StreamStats":
        from repro.stream.resolver import StreamStats
        return StreamStats
    if kind == "ServeStats":
        from repro.serve.service import ServeStats
        return ServeStats
    if kind == "ResilienceStats":
        from repro.resilience.retry import ResilienceStats
        return ResilienceStats
    raise KeyError(f"unknown stats kind {kind!r}; known: {STATS_KINDS}")


def _retuple(v):
    """Invert JSON's tuple->list flattening (lists become tuples,
    recursively — every sequence field on the five stats types is a
    tuple in the typed originals)."""
    if isinstance(v, list):
        return tuple(_retuple(x) for x in v)
    return v


def unpack_stats(d: dict):
    """A ``pack_stats`` dict (possibly after a JSON round trip) -> the
    original typed stats object, equal to what was packed."""
    cls = _stats_class(d["kind"])
    kw = {k: _retuple(v) for k, v in d.items() if k != "kind"}
    return cls(**kw)
