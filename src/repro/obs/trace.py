"""Span tracer — the wall-clock attribution half of ``repro.obs``.

A ``Tracer`` records a flat list of ``SpanRecord``s (monotonic start +
duration, thread id, nesting depth, parent index, free-form attrs) and is
installed per-thread with ``activate(tracer)``.  Instrumented code calls
the module-level ``span(name, **attrs)`` context manager, which is the
whole overhead story:

  * **disabled** (no tracer active on this thread — the default): ``span``
    returns one shared no-op singleton after a single thread-local lookup.
    No allocation, no timestamp, no lock.  This is the near-zero-cost
    contract DESIGN.md §12 pins at <= 1% on a steady-state resolve.
  * **enabled**: one ``time.perf_counter`` pair per span plus one lock
    acquisition to append the record.  Device sections (``device=True``
    attrs) are additionally blocked with ``jax.block_until_ready`` BY THE
    INSTRUMENTATION SITE (not here) so async dispatch cannot under-report
    them; with ``Tracer(jax_profiler=True)`` they are also bracketed in
    ``jax.profiler.TraceAnnotation`` so they line up inside a device
    profile.

Invariant 12 (DESIGN.md): tracing never changes pair sets or retrace
counts — spans only read clocks; ``cfg.trace`` is excluded from
``static_fingerprint`` so traced and untraced runs share executables.

``export_chrome`` / ``write_chrome`` emit the Chrome/Perfetto
``trace.json`` format (``ph="X"`` complete events, microsecond
timestamps), with the repro metrics blob tucked under a ``"repro"``
top-level key that trace viewers ignore and ``tools/trace_report.py``
reads back.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Optional

from repro.obs.metrics import MetricsRegistry

_active = threading.local()


def current_tracer() -> Optional["Tracer"]:
    """The tracer active on the calling thread, or None when tracing is
    disabled (the default) — the ONE lookup every ``span()`` call pays."""
    return getattr(_active, "tracer", None)


class activate:
    """Install ``tracer`` as the calling thread's active tracer for the
    duration of the ``with`` block (restoring whatever was active before).
    Worker threads (the serve worker, stream helpers) activate their
    owner's tracer explicitly — thread-locality is what keeps unrelated
    concurrent runs from writing into each other's traces."""

    def __init__(self, tracer: "Tracer"):
        self.tracer = tracer
        self._prev: Optional[Tracer] = None

    def __enter__(self) -> "Tracer":
        self._prev = getattr(_active, "tracer", None)
        _active.tracer = self.tracer
        return self.tracer

    def __exit__(self, *exc) -> bool:
        _active.tracer = self._prev
        return False


class _NoopSpan:
    """The shared disabled-path span: every method is a no-op and
    ``enabled`` is False so call sites can skip computing expensive attrs
    (byte counts, device blocking) entirely."""
    __slots__ = ()
    enabled = False

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs) -> None:
        """No-op (attrs are dropped when tracing is disabled)."""


NOOP_SPAN = _NoopSpan()


def span(name: str, /, **attrs):
    """Open a span named ``name`` on the calling thread's active tracer.

    Returns the shared no-op singleton when no tracer is active — the
    disabled path is one thread-local lookup.  ``attrs`` become the span's
    Chrome-trace ``args`` (the span name is positional-only, so ``name``
    is a legal attr key); the reserved attr ``device=True`` marks a
    device section (call sites block on the result inside the span, and
    ``Tracer(jax_profiler=True)`` brackets it in a profiler annotation)."""
    t = getattr(_active, "tracer", None)
    if t is None:
        return NOOP_SPAN
    return t.span(name, **attrs)


class SpanRecord:
    """One finished (or open) span: ``name``, start ``t0`` (seconds since
    the tracer's epoch), ``dur`` (seconds; None while open), small-int
    thread id ``tid``, nesting ``depth``, its ``index`` in the tracer's
    record list, the ``parent`` span's index (-1 for roots), and the
    free-form ``attrs`` dict."""
    __slots__ = ("name", "t0", "dur", "tid", "depth", "index", "parent",
                 "attrs")

    def __init__(self, name: str, tid: int, depth: int, parent: int,
                 attrs: dict):
        self.name = name
        self.tid = tid
        self.depth = depth
        self.parent = parent
        self.attrs = attrs
        self.index = -1
        self.t0 = 0.0
        self.dur: Optional[float] = None

    def __repr__(self) -> str:
        d = "open" if self.dur is None else f"{self.dur * 1e3:.3f}ms"
        return (f"SpanRecord({self.name!r}, t0={self.t0:.6f}, {d}, "
                f"tid={self.tid}, depth={self.depth}, "
                f"parent={self.parent})")


class _Span:
    """The enabled-path span context manager (see ``Tracer.span``)."""
    __slots__ = ("_tracer", "_rec", "name", "attrs", "_ann")
    enabled = True

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self._rec: Optional[SpanRecord] = None
        self._ann = None

    def __enter__(self) -> "_Span":
        tr = self._tracer
        st = tr._thread_state()
        stack = st["stack"]
        parent = stack[-1].index if stack else -1
        rec = SpanRecord(self.name, tid=st["tid"], depth=len(stack),
                         parent=parent, attrs=self.attrs)
        with tr._lock:
            rec.index = len(tr._records)
            tr._records.append(rec)
        if tr.jax_profiler and self.attrs.get("device"):
            try:
                import jax
                self._ann = jax.profiler.TraceAnnotation(self.name)
                self._ann.__enter__()
            except Exception:          # noqa: BLE001 — profiler is optional
                self._ann = None
        stack.append(rec)
        self._rec = rec
        rec.t0 = time.perf_counter() - tr._epoch   # last: excludes setup
        return self

    def __exit__(self, *exc) -> bool:
        end = time.perf_counter() - self._tracer._epoch
        rec = self._rec
        rec.dur = end - rec.t0
        st = self._tracer._thread_state()
        if st["stack"] and st["stack"][-1] is rec:
            st["stack"].pop()
        if self._ann is not None:
            self._ann.__exit__(*exc)
            self._ann = None
        return False

    def set(self, **attrs) -> None:
        """Attach/overwrite attrs on the open span (call sites use this to
        record quantities known only after the work ran — transfer bytes,
        per-shard loads — guarded by ``if sp.enabled`` so the disabled
        path never computes them)."""
        self._rec.attrs = dict(self._rec.attrs, **attrs)


class Tracer:
    """Thread-safe span collector + metrics registry for one run.

    Create one per traced run (the facade/stream/serve owners do this when
    ``cfg.trace`` is set), install with ``activate``, and read the result
    as ``spans()`` / ``metrics`` / ``export_chrome``.  Span nesting is
    tracked per-thread (each thread gets its own parent stack and a small
    stable ``tid``), records land in ONE ordered list under a lock.

    ``jax_profiler=True`` additionally brackets ``device=True`` spans in
    ``jax.profiler.TraceAnnotation`` so they appear inside an
    xplane/perfetto device profile captured around the same run."""

    def __init__(self, jax_profiler: bool = False):
        self.jax_profiler = jax_profiler
        self.metrics = MetricsRegistry()
        self._lock = threading.Lock()
        self._records: list = []
        self._tls = threading.local()
        self._tids: dict = {}
        self._epoch = time.perf_counter()

    def _thread_state(self) -> dict:
        st = getattr(self._tls, "state", None)
        if st is None:
            with self._lock:
                tid = self._tids.setdefault(threading.get_ident(),
                                            len(self._tids))
            st = {"tid": tid, "stack": []}
            self._tls.state = st
        return st

    def span(self, name: str, /, **attrs) -> _Span:
        """Open a span on this tracer (prefer the module-level ``span``,
        which resolves the active tracer and has the no-op fast path)."""
        return _Span(self, name, attrs)

    def spans(self) -> tuple:
        """Snapshot of every recorded span, in start order."""
        with self._lock:
            return tuple(self._records)

    def wall(self) -> float:
        """Seconds elapsed since this tracer was created."""
        return time.perf_counter() - self._epoch

    def export_chrome(self, path: str, *, extra: Optional[dict] = None
                      ) -> None:
        """Write the recorded spans as a Chrome/Perfetto ``trace.json``
        (plus this tracer's metrics under the ``"repro"`` key; ``extra``
        entries are merged into that blob)."""
        blob = {"schema_version": _schema_version(),
                "metrics": self.metrics.to_dict()}
        if extra:
            blob.update(extra)
        write_chrome(path, self.spans(), repro=blob)


def _schema_version() -> int:
    from repro.obs.schema import SCHEMA_VERSION
    return SCHEMA_VERSION


def _jsonable(v):
    """Coerce an attr value to something json.dump accepts losslessly-ish
    (numpy scalars -> Python scalars, tuples survive as lists, anything
    exotic falls back to repr)."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    if hasattr(v, "item") and getattr(v, "ndim", 1) == 0:
        return v.item()
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return repr(v)


def write_chrome(path: str, spans, *, repro: Optional[dict] = None) -> None:
    """Write ``spans`` (SpanRecords) as Chrome/Perfetto ``trace.json``:
    one ``ph="X"`` complete event per finished span (microsecond ts/dur),
    span index/parent carried in ``args`` so ``tools/trace_report.py`` can
    rebuild the tree exactly.  ``repro`` lands under a top-level
    ``"repro"`` key trace viewers ignore."""
    events = []
    for rec in spans:
        if rec.dur is None:
            continue                    # open span: nothing to draw
        args = {k: _jsonable(v) for k, v in rec.attrs.items()}
        args["index"], args["parent"] = rec.index, rec.parent
        events.append({"name": rec.name, "ph": "X", "pid": 0,
                       "tid": rec.tid, "ts": rec.t0 * 1e6,
                       "dur": rec.dur * 1e6, "args": args})
    blob = {"traceEvents": events, "displayTimeUnit": "ms"}
    if repro is not None:
        blob["repro"] = repro
    with open(path, "w") as f:
        json.dump(blob, f)
