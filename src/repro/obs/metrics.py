"""Typed metrics — counters, gauges, bounded histograms, one registry.

The numeric half of ``repro.obs``: spans attribute WHERE time went, these
attribute HOW MUCH (pairs collected, bytes transferred, retries taken,
latency distributions).  Every metric serializes through one
``to_dict()`` schema so ``TraceReport.metrics()``, the Chrome-trace
``"repro"`` blob, and ``BENCH_obs.json`` all speak the same dialect:

    counter    {"type": "counter",   "value": <number>}
    gauge      {"type": "gauge",     "value": <number>}
    histogram  {"type": "histogram", "count": n, "p50": ..., "p95": ...,
                "mean": ..., "max": ...}

``Histogram`` is a fixed-capacity numpy ring buffer (the last ``capacity``
observations — a sliding window, NOT a lossy sketch), so long-lived
accumulators (the serve latency window, per-chunk commit latencies) hold
O(capacity) floats forever instead of growing per request.  Percentiles
use nearest-rank-below semantics — ``sorted[min(n-1, int(p*(n-1)))]`` —
deliberately identical to the historical ``ServeStats`` deque math so
swapping the serve window onto this type changes no reported number.
"""
from __future__ import annotations

import threading
from typing import Dict, Union

import numpy as np

Number = Union[int, float]


class Counter:
    """Monotonically increasing count (pairs, bytes, retries)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def inc(self, n: Number = 1) -> None:
        """Add ``n`` (default 1) to the count."""
        self.value += n

    def to_dict(self) -> dict:
        """The unified metric schema entry for this counter."""
        return {"type": "counter", "value": self.value}


class Gauge:
    """Last-write-wins scalar (current pair-set size, imbalance ratio)."""
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Number = 0

    def set(self, v: Number) -> None:
        """Record ``v`` as the gauge's current value."""
        self.value = v

    def to_dict(self) -> dict:
        """The unified metric schema entry for this gauge."""
        return {"type": "gauge", "value": self.value}


class Histogram:
    """Bounded sliding-window distribution over the LAST ``capacity``
    observations (preallocated numpy ring buffer — no per-observation
    Python objects, no unbounded growth).

    ``count`` is the lifetime observation total; ``percentile``/``mean``/
    ``max`` summarize the current window.  Percentile semantics match the
    pre-obs ServeStats deque exactly: sort the window, index
    ``min(n-1, int(p*(n-1)))``."""
    __slots__ = ("name", "capacity", "_buf", "_n")

    def __init__(self, name: str, capacity: int = 2048):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.name = name
        self.capacity = int(capacity)
        self._buf = np.zeros(self.capacity, np.float64)
        self._n = 0

    def observe(self, v: Number) -> None:
        """Record one observation (evicting the oldest once the window is
        full)."""
        self._buf[self._n % self.capacity] = v
        self._n += 1

    @property
    def count(self) -> int:
        """Lifetime observations (may exceed the window capacity)."""
        return self._n

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    def window(self) -> np.ndarray:
        """The current window's values (order not meaningful)."""
        return self._buf[:min(self._n, self.capacity)].copy()

    def percentile(self, p: float) -> float:
        """Window percentile with the historical serve-window semantics:
        ``sorted[min(n-1, int(p*(n-1)))]``; 0.0 on an empty window."""
        w = np.sort(self._buf[:min(self._n, self.capacity)])
        if w.size == 0:
            return 0.0
        return float(w[min(w.size - 1, int(p * (w.size - 1)))])

    def to_dict(self) -> dict:
        """The unified metric schema entry: lifetime count + window
        p50/p95/mean/max."""
        w = self._buf[:min(self._n, self.capacity)]
        if w.size == 0:
            return {"type": "histogram", "count": 0, "p50": 0.0,
                    "p95": 0.0, "mean": 0.0, "max": 0.0}
        return {"type": "histogram", "count": self._n,
                "p50": self.percentile(0.50), "p95": self.percentile(0.95),
                "mean": float(w.mean()), "max": float(w.max())}


class MetricsRegistry:
    """Get-or-create store of named metrics with one ``to_dict()`` export.

    Creation is lock-protected (tracers are shared across threads);
    re-requesting a name returns the existing metric, and re-requesting it
    AS A DIFFERENT TYPE raises — a silent counter/gauge aliasing bug would
    corrupt every downstream report."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, cls, *args):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, *args)
                self._metrics[name] = m
            elif type(m) is not cls:
                raise TypeError(
                    f"metric {name!r} already registered as "
                    f"{type(m).__name__}, requested {cls.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        """Get or create the counter named ``name``."""
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        """Get or create the gauge named ``name``."""
        return self._get(name, Gauge)

    def histogram(self, name: str, capacity: int = 2048) -> Histogram:
        """Get or create the histogram named ``name`` (``capacity`` only
        applies on first creation)."""
        return self._get(name, Histogram, capacity)

    def __len__(self) -> int:
        return len(self._metrics)

    def to_dict(self) -> dict:
        """Every registered metric through the unified schema, keyed by
        name (insertion-ordered)."""
        with self._lock:
            return {k: m.to_dict() for k, m in self._metrics.items()}
