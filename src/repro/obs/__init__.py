"""``repro.obs`` — unified tracing + metrics (DESIGN.md §12).

One observability substrate for the whole pipeline:

  * ``span(name, **attrs)``   the instrumentation primitive: a context
                              manager that is a shared no-op singleton
                              when no tracer is active (near-zero
                              disabled cost) and records monotonic
                              timing + nesting when one is
  * ``Tracer`` / ``activate`` per-run span collector, installed
                              per-thread; ``export_chrome`` writes a
                              Chrome/Perfetto ``trace.json``
  * ``Counter`` / ``Gauge`` / ``Histogram`` / ``MetricsRegistry``
                              typed metrics behind one ``to_dict`` schema
                              (``Histogram`` is a bounded ring buffer —
                              the serve latency window rides on it)
  * ``TraceReport``           the per-run artifact ``ERConfig.trace=True``
                              attaches to results: spans + metrics + the
                              five legacy stats types unified behind
                              ``metrics()`` (``pack_stats``/
                              ``unpack_stats`` round-trip them losslessly)

Every module here is a leaf (stdlib + numpy only at import time), so the
instrumented subsystems — ``repro.api``, ``repro.stream``, ``repro.serve``,
``repro.resilience`` — import ``repro.obs`` without cycles; the schema's
class lookups resolve lazily at unpack time.

Invariant 12: tracing never changes pair sets or retrace counts.
"""
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.report import TraceReport
from repro.obs.schema import (SCHEMA_VERSION, STATS_KINDS, pack_stats,
                              unpack_stats)
from repro.obs.trace import (NOOP_SPAN, SpanRecord, Tracer, activate,
                             current_tracer, span, write_chrome)

__all__ = [
    "span", "Tracer", "activate", "current_tracer", "SpanRecord",
    "NOOP_SPAN", "write_chrome",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "TraceReport", "pack_stats", "unpack_stats", "SCHEMA_VERSION",
    "STATS_KINDS",
]
