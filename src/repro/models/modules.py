"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Minimal functional module system.

No flax dependency: params are nested dicts of jnp arrays; every module is an
``init_*``/``apply_*`` function pair plus a ``specs_*`` function returning the
same-structure tree of *logical* sharding axis tuples (resolved by
``repro.sharding.rules.Rules``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

DEFAULT_INIT_SCALE = 0.02


def _normal(key, shape, dtype, scale=DEFAULT_INIT_SCALE):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# -- dense ------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, *, bias: bool = False,
               scale: Optional[float] = None):
    scale = DEFAULT_INIT_SCALE if scale is None else scale
    p = {"w": _normal(key, (in_dim, out_dim), dtype, scale)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def dense_specs(in_axis: Optional[str], out_axis: Optional[str],
                *, bias: bool = False):
    s = {"w": (in_axis, out_axis)}
    if bias:
        s["b"] = (out_axis,)
    return s


def dense_apply(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# -- norms ------------------------------------------------------------------

def norm_init(key, dim: int, dtype, *, kind: str = "rmsnorm"):
    del key
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((dim,), dtype)}      # gemma-style (1+scale)
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def norm_specs(kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return {"scale": ("none",)}
    return {"scale": ("none",), "bias": ("none",)}


def norm_apply(p, x, *, kind: str = "rmsnorm", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * (1.0 + p["scale"].astype(jnp.float32))).astype(x.dtype)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


# -- embedding ---------------------------------------------------------------

def embed_init(key, vocab: int, dim: int, dtype):
    return {"table": _normal(key, (vocab, dim), dtype, 1.0 / math.sqrt(dim))}


def embed_specs():
    # vocab-sharded only: sharding the d_model dim too (FSDP-style) makes the
    # token gather repartition awkwardly under SPMD (involuntary full remat —
    # observed on qwen1.5-110b multi-pod).  Replicating d costs <=160 MB/chip
    # for the largest vocab here.
    return {"table": ("vocab", None)}


def embed_apply(p, tokens):
    return jnp.take(p["table"], tokens, axis=0)


def embed_onehot_apply(p, tokens, rules):
    """Distributed embedding as one_hot @ table.

    With a vocab-sharded table, the backward of a plain gather is a
    scatter-add whose SPMD lowering all-gathers the full f32 activation
    cotangent per microbatch (~1 GB/device buffers observed).  As a dot, the
    table gradient is a shard-local contraction + psum instead."""
    v = p["table"].shape[0]
    oh = jax.nn.one_hot(tokens, v, dtype=p["table"].dtype)
    oh = rules.constrain(oh, ("batch", None, "vocab"))
    return oh @ p["table"]


def unembed_apply(p, x):
    """Tied read-out: (B,S,D) @ (V,D)^T."""
    return x @ p["table"].astype(x.dtype).T


# -- activations --------------------------------------------------------------

def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


def softcap(x, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# -- tree helpers --------------------------------------------------------------

def stack_init(init_fn, key, n: int):
    """vmap an init over n stacked copies (for lax.scan over layer groups)."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def prepend_layer_axis(spec_tree):
    """Add the scan ('layers') axis in front of every leaf's logical spec."""
    return jax.tree.map(
        lambda t: ("layers",) + t,
        spec_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def param_count(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def param_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
