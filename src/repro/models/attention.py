"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Attention: RoPE, chunk-pair flash attention, decode attention.

The training/prefill path uses *chunk-pair flash attention*: the (q-chunk,
kv-chunk) pairs that can contain unmasked entries are enumerated **statically**
(causal triangle, or sliding-window band), and a single ``lax.scan`` runs over
that pair list with running-softmax accumulators.  This does only the useful
chunk work (no 2x masked-half waste) and is the pure-JAX analogue of a flash
kernel; the Pallas local-attention kernel in ``repro.kernels`` covers the
window case for the hot path.

The decode path attends one query against a (possibly sequence-sharded) KV
cache; softmax reductions over the sharded axis lower to all-reduces under
GSPMD (distributed flash-decode).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.modules import (dense_apply, dense_init, dense_specs,
                                  softcap as _softcap)

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


# -- RoPE ---------------------------------------------------------------------

def rope_apply(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with positions (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angle = positions[..., None].astype(jnp.float32) * freq     # (..., S, half)
    cos = jnp.cos(angle)[..., None, :]                           # (..., S, 1, half)
    sin = jnp.sin(angle)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- static chunk-pair enumeration ---------------------------------------------

def chunk_pairs(s_q: int, s_kv: int, cq: int, ckv: int, *, causal: bool,
                window: int, q_offset: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Static list of (q_chunk, kv_chunk) pairs that contain unmasked work.

    q position p_q = q_offset + i_global; kv position p_k = j_global.
    Mask admits p_k <= p_q (causal) and p_k > p_q - window (if window>0).
    """
    n_q = math.ceil(s_q / cq)
    n_kv = math.ceil(s_kv / ckv)
    pi, pj = [], []
    for i in range(n_q):
        q_lo = q_offset + i * cq
        q_hi = q_offset + min((i + 1) * cq, s_q) - 1
        for j in range(n_kv):
            k_lo = j * ckv
            k_hi = min((j + 1) * ckv, s_kv) - 1
            if causal and k_lo > q_hi:
                continue
            if window and k_hi <= q_lo - window:
                continue
            pi.append(i)
            pj.append(j)
    return np.asarray(pi, np.int32), np.asarray(pj, np.int32)


# -- flash attention (train / prefill) -----------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0,
                    chunk_q: int = 512, chunk_kv: int = 1024,
                    q_offset: int = 0, rules=None) -> jax.Array:
    """q: (B, S, H, D); k, v: (B, T, KH, D).  Returns (B, S, H, D).

    ``rules``: when given, the chunked operands and accumulators are pinned
    to kv-head sharding over the model axis.  Without this, archs whose head
    count doesn't divide the axis (phi4: 24H/16) make GSPMD re-shard the f32
    probability block on EVERY chunk pair (386 GB/step of all-reduce observed
    on phi4 train) — pinning keeps the whole pair scan shard-local.

    Callers must only pass ``rules`` when n_heads % model_axis != 0: for
    evenly-dividing head counts GSPMD's flat-qkv layout is already optimal
    and forcing kv-head sharding REGRESSES (gemma2 +5.3x collective bytes
    measured) — see EXPERIMENTS.md §Perf iteration 5."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    cq = min(chunk_q, s)
    ckv = min(chunk_kv, t)
    scale = 1.0 / math.sqrt(d)

    if s % cq or t % ckv:
        # pad to chunk multiples (masked out below via positions)
        s_pad = math.ceil(s / cq) * cq
        t_pad = math.ceil(t / ckv) * ckv
        q = jnp.pad(q, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad - t), (0, 0), (0, 0)))
    s_pad, t_pad = q.shape[1], k.shape[1]
    n_q, n_kv = s_pad // cq, t_pad // ckv

    pi, pj = chunk_pairs(s, t, cq, ckv, causal=causal, window=window,
                         q_offset=q_offset)

    # (n_q, B, KH, G, cq, D) chunked operands
    qc = q.reshape(b, n_q, cq, kh, g, d).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(b, n_kv, ckv, kh, d).transpose(1, 0, 3, 2, 4)
    vc = v.reshape(b, n_kv, ckv, kh, d).transpose(1, 0, 3, 2, 4)
    if rules is not None:
        qc = rules.constrain(qc, (None, "batch", "heads", None, None, None))
        kc = rules.constrain(kc, (None, "batch", "heads", None, None))
        vc = rules.constrain(vc, (None, "batch", "heads", None, None))

    q_pos = q_offset + jnp.arange(s_pad, dtype=jnp.int32).reshape(n_q, cq)
    k_pos = jnp.arange(t_pad, dtype=jnp.int32).reshape(n_kv, ckv)

    o0 = jnp.zeros((n_q, b, kh, g, cq, d), jnp.float32)
    m0 = jnp.full((n_q, b, kh, g, cq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((n_q, b, kh, g, cq), jnp.float32)
    if rules is not None:
        o0 = rules.constrain(o0, (None, "batch", "heads", None, None, None))
        m0 = rules.constrain(m0, (None, "batch", "heads", None, None))
        l0 = rules.constrain(l0, (None, "batch", "heads", None, None))

    def body(carry, ij):
        o, m, l = carry
        i, j = ij
        qi = jax.lax.dynamic_index_in_dim(qc, i, 0, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kc, j, 0, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vc, j, 0, keepdims=False)
        qp = jax.lax.dynamic_index_in_dim(q_pos, i, 0, keepdims=False)
        kp = jax.lax.dynamic_index_in_dim(k_pos, j, 0, keepdims=False)
        # scores: (B, KH, G, cq, ckv) in f32
        sc = jnp.einsum("bkgqd,bkcd->bkgqc", qi.astype(jnp.float32),
                        kj.astype(jnp.float32)) * scale
        if logit_softcap:
            sc = _softcap(sc, logit_softcap)
        mask = jnp.ones((cq, ckv), bool)
        if causal:
            mask &= kp[None, :] <= qp[:, None]
        if window:
            mask &= kp[None, :] > qp[:, None] - window
        mask &= (kp < t)[None, :]
        sc = jnp.where(mask[None, None, None], sc, NEG_INF)

        mi = jax.lax.dynamic_index_in_dim(m, i, 0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 0, keepdims=False)
        oi = jax.lax.dynamic_index_in_dim(o, i, 0, keepdims=False)
        m_new = jnp.maximum(mi, sc.max(axis=-1))
        alpha = jnp.exp(mi - m_new)
        p = jnp.exp(sc - m_new[..., None])
        l_new = li * alpha + p.sum(axis=-1)
        o_new = oi * alpha[..., None] + jnp.einsum(
            "bkgqc,bkcd->bkgqd", p, vj.astype(jnp.float32))
        o = jax.lax.dynamic_update_index_in_dim(o, o_new, i, 0)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 0)
        return (o, m, l), None

    # remat the pair body: without this, the scan's backward saves the f32
    # probability block per pair iteration (~8 GB/device at mixtral train
    # shapes); recomputing p from the chunk operands is cheap.
    body = jax.checkpoint(body, prevent_cse=False)
    (o, m, l), _ = jax.lax.scan(
        body, (o0, m0, l0), (jnp.asarray(pi), jnp.asarray(pj)))
    l = jnp.where(l == 0.0, 1.0, l)
    out = (o / l[..., None]).astype(q.dtype)          # (n_q, B, KH, G, cq, D)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, s_pad, h, d)
    return out[:, :s]


def dense_attention(q, k, v, *, causal=True, window=0, logit_softcap=0.0,
                    q_offset: int = 0) -> jax.Array:
    """Reference (materialized-scores) attention — oracle + small shapes."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, s, kh, g, d)
    sc = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                    k.astype(jnp.float32)) / math.sqrt(d)
    if logit_softcap:
        sc = _softcap(sc, logit_softcap)
    qp = q_offset + jnp.arange(s)[:, None]
    kp = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= kp <= qp
    if window:
        mask &= kp > qp - window
    sc = jnp.where(mask, sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


# -- decode attention ------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, cache_pos, *, window: int = 0,
                     logit_softcap: float = 0.0, ring: bool = False) -> jax.Array:
    """q: (B, 1, H, D); caches: (B, T, KH, D); cache_pos: () int32 — number of
    tokens generated so far *including* the current token (already written).

    ``ring=True``: the cache is a rotating window buffer of size T == window;
    slot j holds the most recent position p with p % T == j, so every written
    slot is in-window and the mask reduces to slot-written.

    Works with sequence-sharded caches: the softmax reduction over T lowers to
    an all-reduce (distributed flash-decode)."""
    b, _, h, d = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, d)
    sc = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                    k_cache.astype(jnp.float32)) / math.sqrt(d)
    if logit_softcap:
        sc = _softcap(sc, logit_softcap)
    pos = jnp.arange(t, dtype=jnp.int32)
    if ring:
        mask = pos < cache_pos          # pre-wrap; post-wrap all slots valid
    else:
        mask = pos < cache_pos
        if window:
            mask &= pos > cache_pos - 1 - window
    sc = jnp.where(mask[None, None, None], sc, NEG_INF)
    m = sc.max(axis=-1, keepdims=True)
    p = jnp.exp(sc - m)
    l = p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgt,btkd->bkgd", p / l, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# -- full attention module ---------------------------------------------------------

def attn_init(key, cfg, dtype):
    d, h, kh, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    kq, kk, kv_, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, d, h * hd, dtype, bias=cfg.qkv_bias),
        "wk": dense_init(kk, d, kh * hd, dtype, bias=cfg.qkv_bias),
        "wv": dense_init(kv_, d, kh * hd, dtype, bias=cfg.qkv_bias),
        "wo": dense_init(ko, h * hd, d, dtype,
                         scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def attn_specs(cfg):
    return {
        "wq": dense_specs("embed", "qkv", bias=cfg.qkv_bias),
        "wk": dense_specs("embed", "qkv", bias=cfg.qkv_bias),
        "wv": dense_specs("embed", "qkv", bias=cfg.qkv_bias),
        "wo": dense_specs("qkv", "embed"),
    }


def attn_apply(p, x, cfg, *, rules=None, local: bool = False,
               positions=None, cache=None, cache_pos=None,
               chunk_q=512, chunk_kv=1024):
    """Returns (out, new_cache).  cache: dict(k,v) each (B, T, KH, D) or None.

    Modes: cache is None            -> train/prefill without cache retention
           cache given, S > 1       -> prefill writing into cache
           cache given, S == 1      -> decode (cache_pos = entries incl. current)
    """
    b, s, _ = x.shape
    h, kh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    window = cfg.window_size if local else 0
    q = dense_apply(p["wq"], x).reshape(b, s, h, hd)
    k = dense_apply(p["wk"], x).reshape(b, s, kh, hd)
    v = dense_apply(p["wv"], x).reshape(b, s, kh, hd)
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)[None, :]
    if cfg.rope:
        q = rope_apply(q, positions, cfg.rope_theta)
        k = rope_apply(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and s == 1:
        # decode: write current kv (ring-indexed for window caches)
        t_cache = cache["k"].shape[1]
        ring = bool(window) and t_cache == window
        idx = (cache_pos - 1) % t_cache if ring else cache_pos - 1
        kc = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, idx, 0, 0))
        vc = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, idx, 0, 0))
        if rules is not None:
            kc = rules.constrain(kc, ("batch", "kv_seq", None, None))
            vc = rules.constrain(vc, ("batch", "kv_seq", None, None))
        out = decode_attention(q, kc, vc, cache_pos, window=window,
                               logit_softcap=cfg.attn_logit_softcap, ring=ring)
        new_cache = {"k": kc, "v": vc}
    else:
        # pin head sharding only when the flat layout can't shard evenly
        # (see flash_attention docstring / EXPERIMENTS.md §Perf it5)
        pin_rules = None
        if rules is not None and rules.axis_size(("model",)) > 1 and \
                cfg.n_heads % rules.axis_size(("model",)) != 0:
            pin_rules = rules
        out = flash_attention(
            q, k, v, causal=True, window=window,
            logit_softcap=cfg.attn_logit_softcap,
            chunk_q=chunk_q, chunk_kv=chunk_kv, rules=pin_rules)
        if cache is not None:
            # prefill: persist kv into the cache buffer (last t_cache tokens
            # for ring/window caches; requires s % t_cache == 0 so that ring
            # slot j keeps holding positions p with p % t_cache == j)
            t_cache = cache["k"].shape[1]
            if t_cache < s:
                assert s % t_cache == 0, (s, t_cache)
                k_w, v_w = k[:, s - t_cache:], v[:, s - t_cache:]
            else:
                k_w, v_w = k, v
            kc = jax.lax.dynamic_update_slice(
                cache["k"], k_w.astype(cache["k"].dtype), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(
                cache["v"], v_w.astype(cache["v"].dtype), (0, 0, 0, 0))
            if rules is not None:
                kc = rules.constrain(kc, ("batch", "kv_seq", None, None))
                vc = rules.constrain(vc, ("batch", "kv_seq", None, None))
            new_cache = {"k": kc, "v": vc}

    out = out.reshape(b, s, h * hd)
    if rules is not None:
        out = rules.constrain(out, ("batch", None, "qkv"))
    out = dense_apply(p["wo"], out)
    return out, new_cache


def make_attn_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
                    *, local: bool = False):
    """Cache buffers for one attention layer.  Local layers cap at window."""
    t = min(max_len, cfg.window_size) if (local and cfg.window_size) else max_len
    kh, hd = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, t, kh, hd), dtype),
            "v": jnp.zeros((batch, t, kh, hd), dtype)}


def attn_cache_specs():
    return {"k": ("batch", "kv_seq", None, None),
            "v": ("batch", "kv_seq", None, None)}
