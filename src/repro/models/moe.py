"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Mixture-of-Experts layer.

Design (TPU-native, GSPMD-friendly): the MoE layer runs inside ``shard_map``.
Tokens are sharded over the (pod, data) axes and *replicated* over the model
axis; expert weights are sharded over the model axis — either by expert
(``partition="ep"``, e.g. qwen3: 128 experts / 16) or by expert-FFN width
(``partition="tp"``, e.g. mixtral: 8 fat experts, d_ff/16 each).

Each model-rank selects the (token, expert) assignments it owns, compacts them
into a fixed-capacity per-expert buffer via a *local* sort (no global sort —
this is exactly the paper's SRP idea applied to MoE dispatch: a monotonic
partition function over expert ids with per-partition local sorting), computes
its experts, and the partial outputs are combined with a single ``psum`` over
the model axis (row-parallel pattern).  Communication per layer = one psum of
the activation tensor; dispatch stays on-device.

Capacity overflow drops tokens (standard GShard semantics); drop fraction is
returned for telemetry.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

import warnings as _warnings
with _warnings.catch_warnings():
    _warnings.simplefilter("ignore", DeprecationWarning)
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models.modules import _normal, act_fn


def moe_init(key, cfg, dtype):
    d = cfg.d_model
    e = cfg.moe
    kg, k1, k2, k3, ks = jax.random.split(key, 5)
    p = {
        "wg": _normal(kg, (d, e.n_experts), jnp.float32),   # router in f32
        "w_gate": _normal(k1, (e.n_experts, d, e.expert_d_ff), dtype),
        "w_up": _normal(k2, (e.n_experts, d, e.expert_d_ff), dtype),
        "w_down": _normal(
            k3, (e.n_experts, e.expert_d_ff, d), dtype,
            0.02 / math.sqrt(2 * cfg.n_layers)),
    }
    if e.n_shared_experts:
        f = e.expert_d_ff * e.n_shared_experts
        p["shared"] = {
            "w_gate": _normal(ks, (d, f), dtype),
            "w_up": _normal(jax.random.fold_in(ks, 1), (d, f), dtype),
            "w_down": _normal(jax.random.fold_in(ks, 2), (f, d), dtype,
                              0.02 / math.sqrt(2 * cfg.n_layers)),
        }
    return p


def moe_specs(cfg):
    e = cfg.moe
    if e.partition == "ep":
        w13 = ("experts", "embed", None)
        w2 = ("experts", None, "embed")
    else:  # tp: shard expert width
        w13 = (None, "embed", "d_ff")
        w2 = (None, "d_ff", "embed")
    s = {"wg": ("embed", None), "w_gate": w13, "w_up": w13, "w_down": w2}
    if e.n_shared_experts:
        s["shared"] = {"w_gate": ("embed", "d_ff"), "w_up": ("embed", "d_ff"),
                       "w_down": ("d_ff", "embed")}
    return s


def _local_moe(x, wg, w_gate, w_up, w_down, *, cfg, mesh_axes, fsdp: bool,
               act_name: str = "silu"):
    """Per-shard MoE body (runs under shard_map).

    x: (N_loc, D) local tokens (replicated over 'model').
    weights: local slices per moe_specs.
    Returns (out_local (N_loc, D) — full combined via psum, aux_loss scalar,
    drop_frac scalar)."""
    e = cfg.moe
    n_loc, d = x.shape
    model_ax = "model"
    my_rank = jax.lax.axis_index(model_ax)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh_axes)

    if fsdp and "data" in mesh_axes:
        # FSDP: expert weights arrive sharded over 'data' on the d_model dim;
        # gather them for compute (the explicit FSDP all-gather).  Cast to
        # the compute dtype FIRST — otherwise XLA is free to gather the f32
        # upcast (2x ICI bytes; observed on qwen3 train).
        w_gate = jax.lax.all_gather(w_gate.astype(x.dtype), "data", axis=1,
                                    tiled=True)
        w_up = jax.lax.all_gather(w_up.astype(x.dtype), "data", axis=1,
                                  tiled=True)
        w_down = jax.lax.all_gather(w_down.astype(x.dtype), "data", axis=2,
                                    tiled=True)

    ep = e.partition == "ep"
    e_loc = w_gate.shape[0]          # local expert count (EP) or all (TP)
    k = e.top_k
    n_experts = e.n_experts

    # --- routing (replicated over model axis) ---
    logits = (x.astype(jnp.float32) @ wg)                   # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                  # (N, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # aux load-balancing loss (global over data axes)
    me = jnp.mean(probs, axis=0)                            # (E,)
    ce_local = jnp.zeros((n_experts,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (n_loc * k))
    if data_axes:
        me = jax.lax.pmean(me, data_axes)
        ce = jax.lax.pmean(ce_local, data_axes)
    else:
        ce = ce_local
    aux = e.router_aux_coef * n_experts * jnp.sum(me * ce)

    # --- local compaction (SRP-style: partition by expert id, local sort) ---
    flat_e = top_e.reshape(-1)                              # (N*K,)
    flat_t = jnp.repeat(jnp.arange(n_loc, dtype=jnp.int32), k)
    flat_w = top_w.reshape(-1)
    if ep:
        first = my_rank * e_loc
        mine = (flat_e >= first) & (flat_e < first + e_loc)
        local_e = jnp.where(mine, flat_e - first, e_loc)    # e_loc = dump
        cap = max(1, int(math.ceil(n_loc * k * e.capacity_factor / n_experts)))
    else:
        local_e = flat_e
        mine = jnp.ones_like(flat_e, bool)
        cap = max(1, int(math.ceil(n_loc * k * e.capacity_factor / n_experts)))

    order = jnp.argsort(local_e, stable=True)
    se = local_e[order]
    st = flat_t[order]
    sw = flat_w[order]
    counts = jnp.zeros((e_loc + 1,), jnp.int32).at[se].add(1)
    offs = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(se.shape[0], dtype=jnp.int32) - offs[se]
    keep = (pos < cap) & (se < e_loc)
    n_slots = e_loc * cap
    slot = jnp.where(keep, se * cap + pos, n_slots)         # n_slots = drop

    xb = jnp.zeros((n_slots + 1, d), x.dtype)
    xb = xb.at[slot].set(x[st], mode="drop")
    xb = xb[:n_slots].reshape(e_loc, cap, d)

    h = jnp.einsum("ecd,edf->ecf", xb, w_gate.astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", xb, w_up.astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", act_fn(act_name)(h) * u,
                   w_down.astype(x.dtype))
    y_flat = y.reshape(n_slots, d)
    gathered = jnp.take(y_flat, jnp.minimum(slot, n_slots - 1), axis=0)
    # NOTE: keep is in SORTED order (as are st/sw/slot); `se < e_loc` is the
    # sorted-order ownership mask, already folded into keep.
    gathered = gathered * keep[:, None]

    out = jnp.zeros((n_loc, d), jnp.float32).at[st].add(
        gathered.astype(jnp.float32) * sw[:, None])
    out = jax.lax.psum(out, model_ax)

    # drop fraction telemetry (of this rank's assignments; sorted order)
    smine = se < e_loc
    dropped = jnp.sum(smine & ~keep).astype(jnp.float32)
    total = jnp.maximum(jnp.sum(smine.astype(jnp.float32)), 1.0)
    drop_frac = jax.lax.pmax(dropped / total, model_ax)
    return out.astype(x.dtype), aux, drop_frac


def moe_apply(p, x, cfg, *, rules=None, act_name: str = "silu"):
    """x: (B, S, D). Returns (y, aux_loss, drop_frac)."""
    b, s, d = x.shape
    e = cfg.moe
    xf = x.reshape(b * s, d)

    if rules is None:
        # single-device path (smoke tests): emulate one shard, no collectives
        out, aux, drop = _local_moe_nodist(xf, p, cfg, act_name)
        y = out.reshape(b, s, d)
    else:
        mesh = rules.mesh
        axes = mesh.axis_names
        batch_axes = tuple(a for a in ("pod", "data") if a in axes)
        # decode/small batches: only shard token dim over axes that divide it
        sz = 1
        kept = []
        for a in batch_axes:
            if (b * s) % (sz * mesh.shape[a]) == 0:
                kept.append(a)
                sz *= mesh.shape[a]
        batch_axes = tuple(kept)
        ep = e.partition == "ep"
        w13_spec = P("model", rules.table["embed"] and "data" or None, None) \
            if ep else P(None, rules.table["embed"] and "data" or None, "model")
        w2_spec = P("model", None, rules.table["embed"] and "data" or None) \
            if ep else P(None, "model", rules.table["embed"] and "data" or None)
        fn = partial(_local_moe, cfg=cfg, mesh_axes=axes,
                     fsdp=rules.table["embed"] is not None,
                     act_name=act_name)
        tok_dim = batch_axes if batch_axes else None
        out, aux, drop = shard_map(
            fn, mesh=mesh,
            in_specs=(P(tok_dim, None), P(None, None),
                      w13_spec, w13_spec, w2_spec),
            out_specs=(P(tok_dim, None), P(), P()),
            check_rep=False,
        )(xf, p["wg"], p["w_gate"], p["w_up"], p["w_down"])
        y = out.reshape(b, s, d)

    if e.n_shared_experts:
        sp = p["shared"]
        h = act_fn(act_name)(xf @ sp["w_gate"].astype(x.dtype))
        u = xf @ sp["w_up"].astype(x.dtype)
        y = y + ((h * u) @ sp["w_down"].astype(x.dtype)).reshape(b, s, d)
    return y, aux, drop


def _local_moe_nodist(xf, p, cfg, act_name):
    """Single-device oracle (no collectives) — also the smoke-test path."""
    e = cfg.moe
    n, d = xf.shape
    k = e.top_k
    logits = xf.astype(jnp.float32) @ p["wg"]
    probs = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e.n_experts,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (n * k))
    aux = e.router_aux_coef * e.n_experts * jnp.sum(me * ce)

    out = jnp.zeros((n, d), jnp.float32)
    act = act_fn(act_name)
    for ei in range(e.n_experts):
        w = jnp.where(top_e == ei, top_w, 0.0).sum(-1)       # (N,)
        h = act(xf @ p["w_gate"][ei].astype(xf.dtype))
        u = xf @ p["w_up"][ei].astype(xf.dtype)
        y = (h * u) @ p["w_down"][ei].astype(xf.dtype)
        out = out + y.astype(jnp.float32) * w[:, None]
    return out.astype(xf.dtype), aux, jnp.zeros(())
