"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Block composition: (sequence mixer) + (channel mixer) with pre/post norms.

A *group* is one period of ``cfg.pattern`` (e.g. gemma2: (local, global);
recurrentgemma: (rglru, rglru, attn_local)); the LM scans over stacked groups.
"""
from __future__ import annotations

import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.modules import (act_fn, dense_apply, dense_init,
                                  dense_specs, norm_apply, norm_init,
                                  norm_specs)

ATTN_KINDS = ("attn_global", "attn_local")


# -- dense MLP -----------------------------------------------------------------

def mlp_init(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    down_scale = 0.02 / math.sqrt(2 * cfg.n_layers)
    if cfg.mlp in ("swiglu", "geglu"):
        return {"w_gate": dense_init(k1, d, f, dtype),
                "w_up": dense_init(k2, d, f, dtype),
                "w_down": dense_init(k3, f, d, dtype, scale=down_scale)}
    return {"w_up": dense_init(k1, d, f, dtype),
            "w_down": dense_init(k2, f, d, dtype, scale=down_scale)}


def mlp_specs(cfg):
    if cfg.mlp in ("swiglu", "geglu"):
        return {"w_gate": dense_specs("embed", "d_ff"),
                "w_up": dense_specs("embed", "d_ff"),
                "w_down": dense_specs("d_ff", "embed")}
    return {"w_up": dense_specs("embed", "d_ff"),
            "w_down": dense_specs("d_ff", "embed")}


def mlp_apply(p, x, cfg, *, rules=None):
    act = act_fn("silu" if cfg.mlp == "swiglu" else "gelu")
    if cfg.mlp in ("swiglu", "geglu"):
        h = act(dense_apply(p["w_gate"], x)) * dense_apply(p["w_up"], x)
    else:
        h = act(dense_apply(p["w_up"], x))
    if rules is not None:
        h = rules.constrain(h, ("batch", None, "d_ff"))
    return dense_apply(p["w_down"], h)


# -- one block -------------------------------------------------------------------

def _mixer_fns(kind: str):
    return {
        "attn_global": (attn.attn_init, attn.attn_specs),
        "attn_local": (attn.attn_init, attn.attn_specs),
        "mlstm": (rec.mlstm_init, rec.mlstm_specs),
        "slstm": (rec.slstm_init, rec.slstm_specs),
        "rglru": (rec.rglru_init, rec.rglru_specs),
    }[kind]


def block_has_mlp(cfg, kind: str) -> bool:
    # xLSTM blocks carry their own projections; d_ff == 0 disables the MLP.
    if cfg.d_ff == 0 and cfg.moe is None:
        return False
    return True


def block_init(key, cfg, kind: str, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    init, _ = _mixer_fns(kind)
    p: dict[str, Any] = {
        "norm1": norm_init(k1, cfg.d_model, dtype, kind=cfg.norm),
        "mixer": init(k2, cfg, dtype),
    }
    if cfg.post_block_norm:
        p["norm1_post"] = norm_init(k1, cfg.d_model, dtype, kind=cfg.norm)
    if block_has_mlp(cfg, kind):
        if not cfg.parallel_block:
            p["norm2"] = norm_init(k3, cfg.d_model, dtype, kind=cfg.norm)
        if cfg.moe is not None:
            p["mlp"] = moe_mod.moe_init(k4, cfg, dtype)
        else:
            p["mlp"] = mlp_init(k4, cfg, dtype)
        if cfg.post_block_norm:
            p["norm2_post"] = norm_init(k3, cfg.d_model, dtype, kind=cfg.norm)
    return p


def block_specs(cfg, kind: str):
    _, specs = _mixer_fns(kind)
    s: dict[str, Any] = {"norm1": norm_specs(cfg.norm), "mixer": specs(cfg)}
    if cfg.post_block_norm:
        s["norm1_post"] = norm_specs(cfg.norm)
    if block_has_mlp(cfg, kind):
        if not cfg.parallel_block:
            s["norm2"] = norm_specs(cfg.norm)
        s["mlp"] = moe_mod.moe_specs(cfg) if cfg.moe is not None \
            else mlp_specs(cfg)
        if cfg.post_block_norm:
            s["norm2_post"] = norm_specs(cfg.norm)
    return s


def block_cache_init(cfg, kind: str, batch: int, max_len: int,
                     dtype=jnp.bfloat16):
    if kind in ATTN_KINDS:
        return attn.make_attn_cache(cfg, batch, max_len, dtype,
                                    local=(kind == "attn_local"))
    if kind == "mlstm":
        return rec.mlstm_state_init(cfg, batch)
    if kind == "slstm":
        return rec.slstm_state_init(cfg, batch)
    if kind == "rglru":
        return rec.rglru_state_init(cfg, batch)
    raise ValueError(kind)


def block_cache_specs(kind: str):
    if kind in ATTN_KINDS:
        return attn.attn_cache_specs()
    if kind == "mlstm":
        return rec.mlstm_state_specs()
    if kind == "slstm":
        return rec.slstm_state_specs()
    if kind == "rglru":
        return rec.rglru_state_specs()
    raise ValueError(kind)


def block_apply(p, x, cfg, kind: str, *, rules=None, cache=None,
                cache_pos=None, positions=None, chunk_q=512, chunk_kv=1024):
    """Returns (x_out, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = norm_apply(p["norm1"], x, kind=cfg.norm, eps=cfg.norm_eps)

    new_cache = None
    if kind in ATTN_KINDS:
        mix, new_cache = attn.attn_apply(
            p["mixer"], h, cfg, rules=rules, local=(kind == "attn_local"),
            positions=positions, cache=cache, cache_pos=cache_pos,
            chunk_q=chunk_q, chunk_kv=chunk_kv)
    elif kind == "mlstm":
        mix, new_cache = rec.mlstm_apply(p["mixer"], h, cfg, state=cache,
                                         rules=rules)
    elif kind == "slstm":
        mix, new_cache = rec.slstm_apply(p["mixer"], h, cfg, state=cache,
                                         rules=rules)
    elif kind == "rglru":
        mix, new_cache = rec.rglru_apply(p["mixer"], h, cfg, state=cache,
                                         rules=rules)
    else:
        raise ValueError(kind)

    if cfg.post_block_norm:
        mix = norm_apply(p["norm1_post"], mix, kind=cfg.norm, eps=cfg.norm_eps)

    if cfg.parallel_block and block_has_mlp(cfg, kind):
        # shared-norm parallel attn+mlp (gptj/stablelm style)
        if cfg.moe is not None:
            mo, aux, _ = moe_mod.moe_apply(p["mlp"], h, cfg, rules=rules)
        else:
            mo = mlp_apply(p["mlp"], h, cfg, rules=rules)
        x = x + mix + mo
        if rules is not None:
            x = rules.constrain(x, ("batch", "residual_seq", None))
        return x, new_cache, aux

    x = x + mix
    if block_has_mlp(cfg, kind):
        h2 = norm_apply(p["norm2"], x, kind=cfg.norm, eps=cfg.norm_eps)
        if cfg.moe is not None:
            mo, aux, _ = moe_mod.moe_apply(p["mlp"], h2, cfg, rules=rules)
        else:
            mo = mlp_apply(p["mlp"], h2, cfg, rules=rules)
        if cfg.post_block_norm:
            mo = norm_apply(p["norm2_post"], mo, kind=cfg.norm,
                            eps=cfg.norm_eps)
        x = x + mo
    if rules is not None:
        x = rules.constrain(x, ("batch", "residual_seq", None))
    return x, new_cache, aux
