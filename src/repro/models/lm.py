"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Top-level language model: embedding -> scan over layer groups -> head.

Supports three execution modes through one ``forward``:
  train/eval:  tokens/embeds (B,S)  -> logits (B,S,V)
  prefill:     + cache buffers      -> logits, filled cache
  decode:      (B,1) + cache + pos  -> logits (B,1,V), updated cache

Layer groups (one period of cfg.pattern) are stacked and scanned
(``lax.scan``) so the HLO stays O(1) in depth; FSDP all-gathers then occur
per-group inside the loop (exactly the memory behaviour we want at scale).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.modules import (embed_apply, embed_init,
                                  embed_onehot_apply, embed_specs,
                                  norm_apply, norm_init, norm_specs,
                                  prepend_layer_axis, softcap, stack_init)


def group_init(key, cfg, dtype):
    ks = jax.random.split(key, len(cfg.pattern))
    return {f"b{i}": B.block_init(ks[i], cfg, kind, dtype)
            for i, kind in enumerate(cfg.pattern)}


def group_specs(cfg):
    return {f"b{i}": B.block_specs(cfg, kind)
            for i, kind in enumerate(cfg.pattern)}


def lm_init(key, cfg, dtype=jnp.bfloat16):
    k_embed, k_groups, k_norm, k_head = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "groups": stack_init(lambda k: group_init(k, cfg, dtype),
                             k_groups, cfg.n_groups),
        "final_norm": norm_init(k_norm, cfg.d_model, dtype, kind=cfg.norm),
    }
    if not cfg.tie_embeddings:
        params["head"] = {
            "w": (0.02 * jax.random.normal(
                k_head, (cfg.d_model, cfg.vocab_size), jnp.float32)
            ).astype(dtype)}
    return params


def lm_specs(cfg):
    s: dict[str, Any] = {
        "embed": embed_specs(),
        "groups": prepend_layer_axis(group_specs(cfg)),
        "final_norm": norm_specs(cfg.norm),
    }
    if not cfg.tie_embeddings:
        s["head"] = {"w": (None, "vocab")}
    return s


def cache_init(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked (G, ...) cache tree matching the scan structure."""
    def one_group(_):
        return {f"b{i}": B.block_cache_init(cfg, kind, batch, max_len, dtype)
                for i, kind in enumerate(cfg.pattern)}
    caches = [one_group(g) for g in range(cfg.n_groups)]
    return jax.tree.map(lambda *xs: jnp.stack(xs), *caches)


def cache_specs(cfg):
    s = {f"b{i}": B.block_cache_specs(kind)
         for i, kind in enumerate(cfg.pattern)}
    return prepend_layer_axis(s)


def forward(params, cfg, *, tokens=None, embeds=None, cache=None,
            cache_pos=None, positions=None, rules=None,
            remat: str = "block", chunk_q: int = 512, chunk_kv: int = 1024,
            logits_last_only: bool = False):
    """Returns (logits, new_cache, aux_loss)."""
    if embeds is not None:
        x = embeds
        bsz, s = embeds.shape[:2]
    elif rules is not None and tokens.shape[1] > 1:
        x = embed_onehot_apply(params["embed"], tokens, rules)
        bsz, s = tokens.shape
    else:
        x = embed_apply(params["embed"], tokens)
        bsz, s = tokens.shape
    x = x.astype(params["final_norm"]["scale"].dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if rules is not None:
        x = rules.constrain(x, ("batch", "residual_seq", None))
    if positions is None:
        if cache_pos is not None and s == 1:
            positions = (cache_pos - 1) * jnp.ones((bsz, 1), jnp.int32)
        else:
            positions = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None], (bsz, s))

    def make_block_fn(kind):
        def f(p, x, c):
            return B.block_apply(
                p, x, cfg, kind, rules=rules, cache=c, cache_pos=cache_pos,
                positions=positions, chunk_q=chunk_q, chunk_kv=chunk_kv)
        if remat in ("block", "full") and len(cfg.pattern) > 1:
            # nested remat: with a multi-block pattern period (gemma2: 2,
            # recurrentgemma: 19) the outer checkpoint would otherwise keep
            # every block's intermediates live during the group's backward.
            return jax.checkpoint(f, prevent_cse=False)
        return f

    block_fns = [make_block_fn(kind) for kind in cfg.pattern]

    def body(x, xs):
        gparams, gcache = xs
        new_gcache = {} if gcache is not None else None
        aux = jnp.zeros((), jnp.float32)
        for i, _kind in enumerate(cfg.pattern):
            c = gcache[f"b{i}"] if gcache is not None else None
            x, nc, a = block_fns[i](gparams[f"b{i}"], x, c)
            aux = aux + a
            if new_gcache is not None:
                new_gcache[f"b{i}"] = nc
        return x, (new_gcache, aux)

    if remat in ("block", "full"):
        policy = None if remat == "full" else \
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        body = jax.checkpoint(body, policy=policy, prevent_cse=False)

    if cache is not None:
        x, (new_cache, auxs) = jax.lax.scan(
            body, x, (params["groups"], cache))
    else:
        x, (new_cache, auxs) = jax.lax.scan(
            body, x, (params["groups"], None))
    aux = jnp.sum(auxs)

    x = norm_apply(params["final_norm"], x, kind=cfg.norm, eps=cfg.norm_eps)
    if logits_last_only and x.shape[1] > 1:
        x = x[:, -1:]
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].astype(x.dtype).T
    else:
        logits = x @ params["head"]["w"].astype(x.dtype)
    if rules is not None:
        logits = rules.constrain(logits, ("batch", None, "vocab"))
    logits = softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    return logits, new_cache, aux


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """logits: (B,S,V) f32; labels: (B,S) int32; mask: (B,S) or None.

    The true-logit gather is written as a masked reduction over the vocab dim
    so that a vocab-sharded logits tensor reduces shard-locally (+psum) under
    GSPMD instead of being all-gathered (take_along_axis would gather the
    full (B,S,V) f32 tensor — 52 GB/device for phi4 train_4k)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=jnp.int32)
    onehot = (vocab_iota[None, None, :] == labels[..., None])
    true_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - true_logit
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def lm_loss(params, cfg, batch, *, rules=None, remat="block",
            chunk_q=512, chunk_kv=1024):
    """batch: dict with tokens (B,S) [or embeds] and labels (B,S); labels <0
    are masked.  Returns (loss, metrics)."""
    logits, _, aux = forward(
        params, cfg, tokens=batch.get("tokens"), embeds=batch.get("embeds"),
        rules=rules, remat=remat, chunk_q=chunk_q, chunk_kv=chunk_kv)
    labels = batch["labels"]
    mask = (labels >= 0)
    ce = cross_entropy(logits, jnp.maximum(labels, 0), mask)
    loss = ce + aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}
