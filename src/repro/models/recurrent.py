"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Recurrent sequence-mixing blocks: xLSTM (mLSTM + sLSTM) and RG-LRU (Griffin).

mLSTM uses a stabilized *chunkwise-parallel* form (scan over chunks, dense
intra-chunk math on the MXU) for train/prefill and a single-step state update
for decode.  sLSTM is inherently sequential (recurrent weights) -> lax.scan.
RG-LRU uses jax.lax.associative_scan for train/prefill.

All decode paths carry explicit state pytrees ("recurrent caches") so that
``serve_step`` is O(1) per token regardless of context length — this is why
the ssm/hybrid archs run the long_500k cell.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.modules import _normal, dense_apply, dense_init, dense_specs

F32 = jnp.float32


# =====================================================================
# mLSTM
# =====================================================================

def mlstm_init(key, cfg, dtype):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 6)
    inner = h * hd
    return {
        "wq": dense_init(ks[0], d, inner, dtype),
        "wk": dense_init(ks[1], d, inner, dtype),
        "wv": dense_init(ks[2], d, inner, dtype),
        "wif": dense_init(ks[3], d, 2 * h, dtype, bias=True),   # i~, f~ gates
        "wo_gate": dense_init(ks[4], d, inner, dtype),          # output gate
        "wo": dense_init(ks[5], inner, d, dtype,
                         scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def mlstm_specs(cfg):
    return {
        "wq": dense_specs("embed", "qkv"),
        "wk": dense_specs("embed", "qkv"),
        "wv": dense_specs("embed", "qkv"),
        "wif": dense_specs("embed", None, bias=True),
        "wo_gate": dense_specs("embed", "qkv"),
        "wo": dense_specs("qkv", "embed"),
    }


def mlstm_state_init(cfg, batch: int, dtype=F32):
    h, hd = cfg.n_heads, cfg.head_dim
    return {
        "C": jnp.zeros((batch, h, hd, hd), dtype),   # stabilized Ĉ
        "n": jnp.zeros((batch, h, hd), dtype),
        "m": jnp.full((batch, h), -1e30, dtype),
    }


def mlstm_state_specs():
    return {"C": ("batch", "heads", None, None),
            "n": ("batch", "heads", None),
            "m": ("batch", "heads")}


def _mlstm_gates(p, x, h):
    """Returns (logi, logf) each (B, S, H) in f32."""
    g = dense_apply(p["wif"], x).astype(F32)
    logi, fraw = jnp.split(g, 2, axis=-1)
    logf = -jax.nn.softplus(-fraw)          # log sigmoid(f~)
    return logi, logf


def mlstm_apply(p, x, cfg, *, state=None, chunk: int = 256, rules=None):
    """x: (B, S, D).  Returns (y, new_state).

    S == 1 with state  -> decode step.
    S > 1              -> chunkwise-parallel scan (state optional, default 0).
    """
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    scale = 1.0 / math.sqrt(hd)
    q = dense_apply(p["wq"], x).reshape(b, s, h, hd)
    k = dense_apply(p["wk"], x).reshape(b, s, h, hd)
    v = dense_apply(p["wv"], x).reshape(b, s, h, hd)
    logi, logf = _mlstm_gates(p, x, h)

    if state is None:
        state = mlstm_state_init(cfg, b)

    if s == 1:
        y, new_state = _mlstm_step(
            q[:, 0], k[:, 0] * scale, v[:, 0],
            logi[:, 0], logf[:, 0], state)
        y = y[:, None]
    else:
        y, new_state = _mlstm_chunked(
            q, k * scale, v, logi, logf, state, chunk=min(chunk, s))

    o_gate = jax.nn.sigmoid(dense_apply(p["wo_gate"], x).astype(F32))
    y = (y.reshape(b, s, h * hd).astype(F32) * o_gate).astype(x.dtype)
    if rules is not None:
        y = rules.constrain(y, ("batch", None, "qkv"))
    return dense_apply(p["wo"], y), new_state


def _mlstm_step(q, k, v, logi, logf, state):
    """Single-token update.  q,k,v: (B,H,hd); gates: (B,H)."""
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(logf + m, logi)
    a = jnp.exp(logf + m - m_new)           # decay of old state
    bq = jnp.exp(logi - m_new)              # injection weight
    C_new = a[..., None, None] * C + bq[..., None, None] * (
        k[..., :, None] * v[..., None, :])  # (B,H,hd_k,hd_v)
    n_new = a[..., None] * n + bq[..., None] * k
    num = jnp.einsum("bhkv,bhk->bhv", C_new, q.astype(F32))
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q.astype(F32)))
    den = jnp.maximum(den, jnp.exp(-m_new))
    y = num / den[..., None]
    return y, {"C": C_new, "n": n_new, "m": m_new}


def _mlstm_chunked(q, k, v, logi, logf, state, *, chunk: int):
    """Chunkwise-parallel stabilized mLSTM.

    q,k,v: (B,S,H,hd); logi/logf: (B,S,H).  state: dict(C,n,m).
    """
    b, s, h, hd = q.shape
    if s % chunk:
        pad = chunk - s % chunk
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        q, k, v = zf(q), zf(k), zf(v)
        logi = jnp.pad(logi, ((0, 0), (0, pad), (0, 0)))
        # padded steps: f=1 (logf=0) keeps state, i -> -inf drops input
        logf = jnp.pad(logf, ((0, 0), (0, pad), (0, 0)))
        logi = logi.at[:, s:].set(-1e30)
    sp = q.shape[1]
    nc = sp // chunk
    # (B, nc, L, H, ...)
    rs = lambda t: t.reshape((b, nc, chunk) + t.shape[2:])
    qc, kc, vc = rs(q), rs(k), rs(v)
    lic = rs(logi).transpose(0, 1, 3, 2)     # (B,nc,H,L)
    lfc = rs(logf).transpose(0, 1, 3, 2)

    def chunk_body(carry, xs):
        C, n, m = carry                                       # Ĉ,n̂ (stab), m
        qi, ki, vi, li, lf = xs                               # per-chunk
        # qi: (B,L,H,hd) -> (B,H,L,hd)
        qi = qi.transpose(0, 2, 1, 3).astype(F32)
        ki = ki.transpose(0, 2, 1, 3).astype(F32)
        vi = vi.transpose(0, 2, 1, 3).astype(F32)
        F = jnp.cumsum(lf, axis=-1)                           # (B,H,L) inclusive
        Ftot = F[..., -1:]                                    # (B,H,1)
        # per-position stabilizer: m_i = max(m_prev + F_i, max_{j<=i}(li_j - F_j) + F_i)
        g = li - F                                            # (B,H,L)
        gmax = jax.lax.cummax(g, axis=g.ndim - 1)
        m_i = jnp.maximum(m[..., None], gmax) + F             # (B,H,L)
        m_i = jnp.maximum(m_i, -1e30)
        # inter contribution: exp(m_prev + F_i - m_i) * (Ĉ_prev^T q_i)
        w_inter = jnp.exp(m[..., None] + F - m_i)             # (B,H,L)
        inter_num = jnp.einsum("bhkv,bhlk->bhlv", C, qi)      # (B,H,L,hd)
        inter_den = jnp.einsum("bhk,bhlk->bhl", n, qi)
        # intra: D_ij = exp(li_j + F_i - F_j - m_i) for j<=i
        logD = li[..., None, :] + F[..., :, None] - F[..., None, :] \
            - m_i[..., :, None]                               # (B,H,L_i,L_j)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        D = jnp.where(tri, jnp.exp(logD), 0.0)
        sc = jnp.einsum("bhik,bhjk->bhij", qi, ki) * D        # (B,H,L,L)
        intra_num = jnp.einsum("bhij,bhjv->bhiv", sc, vi)
        intra_den = jnp.einsum("bhij->bhi", sc)
        num = intra_num + w_inter[..., None] * inter_num
        den = jnp.abs(intra_den + w_inter * inter_den)
        den = jnp.maximum(den, jnp.exp(-m_i))
        y = num / den[..., None]                              # (B,H,L,hd)
        # state update to end of chunk
        gk = li + Ftot - F                                    # weight for k_j v_j
        m_chunk = jnp.max(gk, axis=-1)                        # (B,H)
        m_new = jnp.maximum(m + Ftot[..., 0], m_chunk)
        wC = jnp.exp(gk - m_new[..., None])                   # (B,H,L)
        C_new = jnp.exp(m + Ftot[..., 0] - m_new)[..., None, None] * C + \
            jnp.einsum("bhl,bhlk,bhlv->bhkv", wC, ki, vi)
        n_new = jnp.exp(m + Ftot[..., 0] - m_new)[..., None] * n + \
            jnp.einsum("bhl,bhlk->bhk", wC, ki)
        return (C_new, n_new, m_new), y.transpose(0, 2, 1, 3)  # (B,L,H,hd)

    xs = (qc.transpose(1, 0, 2, 3, 4), kc.transpose(1, 0, 2, 3, 4),
          vc.transpose(1, 0, 2, 3, 4), lic.transpose(1, 0, 2, 3),
          lfc.transpose(1, 0, 2, 3))
    (C, n, m), ys = jax.lax.scan(
        chunk_body, (state["C"], state["n"], state["m"]), xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, sp, h, hd)[:, :s]
    return y, {"C": C, "n": n, "m": m}


# =====================================================================
# sLSTM
# =====================================================================

def slstm_init(key, cfg, dtype):
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    inner = h * hd
    ks = jax.random.split(key, 3)
    return {
        # input projections for z,i,f,o (fused)
        "wx": dense_init(ks[0], d, 4 * inner, dtype, bias=True),
        # recurrent (block-diagonal per head): (H, hd, 4*hd)
        "r": _normal(ks[1], (h, hd, 4 * hd), dtype),
        "wo": dense_init(ks[2], inner, d, dtype,
                         scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def slstm_specs(cfg):
    return {"wx": dense_specs("embed", None, bias=True),
            "r": ("heads", None, None),
            "wo": dense_specs(None, "embed")}


def slstm_state_init(cfg, batch: int, dtype=F32):
    h, hd = cfg.n_heads, cfg.head_dim
    z = lambda: jnp.zeros((batch, h, hd), dtype)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, h, hd), -1e30, dtype)}


def slstm_state_specs():
    t = ("batch", "heads", None)
    return {"c": t, "n": t, "h": t, "m": t}


def slstm_apply(p, x, cfg, *, state=None, rules=None):
    """x: (B,S,D) -> (y, new_state).  Sequential lax.scan over time."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    if state is None:
        state = slstm_state_init(cfg, b)
    wx = dense_apply(p["wx"], x).astype(F32)            # (B,S,4*inner)
    wx = wx.reshape(b, s, 4, h, hd)
    r = p["r"].astype(F32)

    def step(carry, xt):
        c, n, hprev, m = carry
        # recurrent contribution: (B,H,hd) @ (H,hd,4hd) -> (B,H,4,hd)
        rec = jnp.einsum("bhk,hkf->bhf", hprev, r).reshape(b, h, 4, hd)
        zi = xt[:, 0] + rec[:, :, 0]
        ii = xt[:, 1] + rec[:, :, 1]
        fi = xt[:, 2] + rec[:, :, 2]
        oi = xt[:, 3] + rec[:, :, 3]
        z = jnp.tanh(zi)
        o = jax.nn.sigmoid(oi)
        logf = -jax.nn.softplus(-fi)
        m_new = jnp.maximum(logf + m, ii)
        a = jnp.exp(logf + m - m_new)
        bq = jnp.exp(ii - m_new)
        c_new = a * c + bq * z
        n_new = a * n + bq
        h_new = o * c_new / jnp.maximum(n_new, 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    xs = wx.transpose(1, 0, 3, 2, 4)                    # (S,B,H,4,hd)
    (c, n, hh, m), ys = jax.lax.scan(
        step, (state["c"], state["n"], state["h"], state["m"]), xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, h * hd).astype(x.dtype)
    y = dense_apply(p["wo"], y)
    return y, {"c": c, "n": n, "h": hh, "m": m}


# =====================================================================
# RG-LRU (Griffin / recurrentgemma recurrent block)
# =====================================================================

def rglru_init(key, cfg, dtype):
    d = cfg.d_model
    rdim = cfg.rglru_dim or d
    ks = jax.random.split(key, 7)
    # Λ init so that a = sigmoid(Λ)^c in [0.9, 0.999]
    u = jax.random.uniform(ks[5], (rdim,), F32, 0.9, 0.999)
    lam = jnp.log((u ** (1.0 / 8.0)) / (1 - u ** (1.0 / 8.0)))
    return {
        "w_in": dense_init(ks[0], d, rdim, dtype),       # recurrence branch
        "w_gate_in": dense_init(ks[1], d, rdim, dtype),  # gelu gate branch
        "conv_w": _normal(ks[2], (4, rdim), dtype),      # temporal conv width 4
        "conv_b": jnp.zeros((rdim,), dtype),
        "w_rg": dense_init(ks[3], rdim, rdim, dtype),    # recurrence gate r
        "w_ig": dense_init(ks[4], rdim, rdim, dtype),    # input gate i
        "lam": lam,
        "w_out": dense_init(ks[6], rdim, d, dtype,
                            scale=0.02 / math.sqrt(2 * cfg.n_layers)),
    }


def rglru_specs(cfg):
    return {
        "w_in": dense_specs("embed", "d_ff"),
        "w_gate_in": dense_specs("embed", "d_ff"),
        "conv_w": (None, "d_ff"),
        "conv_b": ("d_ff",),
        # (R, R) gate maps: row-parallel (contract over the sharded R dim,
        # psum) — sharding both dims on 'model' is illegal in one spec.
        "w_rg": dense_specs("d_ff", None),
        "w_ig": dense_specs("d_ff", None),
        "lam": ("d_ff",),
        "w_out": dense_specs("d_ff", "embed"),
    }


def rglru_state_init(cfg, batch: int, dtype=F32):
    rdim = cfg.rglru_dim or cfg.d_model
    return {"h": jnp.zeros((batch, rdim), dtype),
            "conv": jnp.zeros((batch, 3, rdim), dtype)}


def rglru_state_specs():
    return {"h": ("batch", "d_ff"), "conv": ("batch", None, "d_ff")}


_RG_C = 8.0


def rglru_apply(p, x, cfg, *, state=None, rules=None):
    """Griffin recurrent block. x: (B,S,D) -> (y, new_state)."""
    b, s, d = x.shape
    rdim = cfg.rglru_dim or d
    if state is None:
        state = rglru_state_init(cfg, b)
    u = dense_apply(p["w_in"], x)                        # (B,S,R)
    gate = jax.nn.gelu(dense_apply(p["w_gate_in"], x).astype(F32))

    # temporal conv width 4 (causal), carrying last-3 inputs as decode state
    hist = state["conv"].astype(u.dtype)                 # (B,3,R)
    uc = jnp.concatenate([hist, u], axis=1)              # (B,S+3,R)
    w = p["conv_w"].astype(F32)
    conv = sum(uc[:, i:i + s].astype(F32) * w[i] for i in range(4))
    conv = conv + p["conv_b"].astype(F32)                # (B,S,R)
    new_conv = uc[:, -3:].astype(F32)

    r = jax.nn.sigmoid(dense_apply(p["w_rg"], conv.astype(u.dtype)).astype(F32))
    i = jax.nn.sigmoid(dense_apply(p["w_ig"], conv.astype(u.dtype)).astype(F32))
    log_a = -_RG_C * r * jax.nn.softplus(-p["lam"].astype(F32))  # log sigmoid(Λ)^(c·r)
    a = jnp.exp(log_a)                                   # (B,S,R)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * conv)

    if s == 1:
        h = a[:, 0] * state["h"] + gated[:, 0]
        hs = h[:, None]
        new_h = h
    else:
        # associative scan: h_t = a_t h_{t-1} + b_t, with h_0 folded into b_1
        bb = gated.at[:, 0].add(a[:, 0] * state["h"])

        def comb(l, r_):
            al, bl = l
            ar, br = r_
            return al * ar, ar * bl + br

        _, hs = jax.lax.associative_scan(comb, (a, bb), axis=1)
        new_h = hs[:, -1]

    y = (hs * gate).astype(x.dtype)                      # (B,S,R)
    if rules is not None:
        y = rules.constrain(y, ("batch", None, "d_ff"))
    y = dense_apply(p["w_out"], y)
    return y, {"h": new_h, "conv": new_conv}
