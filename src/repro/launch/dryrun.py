import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + \
    " --xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input-shape x mesh) cell:
  jit(step).lower(*ShapeDtypeStructs).compile()
on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh, recording
memory_analysis / cost_analysis / per-collective byte counts into a JSON
artifact consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cells, get_config
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.perf import hlo_analysis
from repro.sharding.rules import Rules
from repro.train import steps as S

ART_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """bytes of an HLO type string like 'bf16[128,4096]' or a tuple thereof."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-buffer bytes per collective kind from post-SPMD HLO.

    all-reduce is counted 2x (ring moves ~2x the payload); -start/-done async
    pairs are counted once (on the -start)."""
    out = {k: {"bytes": 0, "count": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        eq = s.find(" = ")
        if eq < 0:
            continue
        rhs = s[eq + 3:]
        for kind in _COLLECTIVES:
            # match op name at the call site, skip -done halves of async pairs
            if re.search(rf"\b{kind}(-start)?\(", rhs):
                b = _shape_bytes(rhs[:rhs.find(kind)])
                factor = 2 if kind == "all-reduce" else 1
                out[kind]["bytes"] += b * factor
                out[kind]["count"] += 1
                break
    out["total_bytes"] = sum(
        v["bytes"] for k, v in out.items() if isinstance(v, dict))
    return out


#: memory-safe defaults for the full-size train cells (see EXPERIMENTS.md
#: §Dry-run: per-device HBM on v5e is 16 GB; full remat + microbatching keeps
#: every assigned arch under budget).  NOTE the CPU-backend proxy measures
#: ~2x the remat stack because XLA:CPU promotes the saved bf16 stack through
#: a materialized f32 copy (native-bf16 TPUs don't) — see EXPERIMENTS.md
#: §Dry-run methodology.  Hillclimb overrides come in via ``run_overrides``.
TRAIN_DEFAULTS = {"remat": "full", "microbatch": 4}

#: per-arch microbatch bumps for the largest models (keeps the remat stack +
#: optimizer temps inside HBM; chosen from the mb sweep in EXPERIMENTS.md).
ARCH_TRAIN_OVERRIDES = {
    "qwen1.5-110b": {"microbatch": 8},
    "qwen3-moe-235b-a22b": {"microbatch": 16},
    "mixtral-8x22b": {"microbatch": 8},
    "recurrentgemma-9b": {"microbatch": 8},
}


def build_cell(arch: str, shape: str, mesh, *, run_overrides=None):
    """Returns (fn, args_sds, in_shardings, out_shardings=None)."""
    cfg = get_config(arch)
    shp = SHAPES[shape]
    overrides = dict(TRAIN_DEFAULTS) if shp.kind == "train" else {}
    if shp.kind == "train":
        overrides.update(ARCH_TRAIN_OVERRIDES.get(arch, {}))
    overrides.update(run_overrides or {})
    run = RunConfig(model=cfg, shape=shp, **overrides)
    ctx_parallel = shp.name == "long_500k"
    if shp.kind == "train":
        fsdp = run.fsdp
    else:
        # serving: model-axis TP alone leaves >8 GB of params per chip for
        # the biggest archs — shard over data too (per-layer gather).
        fsdp = cfg.param_count() * 2 / 16 > 8e9
    rules = Rules(mesh, fsdp=fsdp,
                  seq_shard_kv=run.seq_shard_kv and shp.kind != "train",
                  context_parallel=ctx_parallel,
                  seq_parallel=run.seq_parallel and shp.kind != "decode")

    if shp.kind == "train":
        fn = S.make_train_step(cfg, run, rules)
        state_sds = jax.eval_shape(
            partial(S.train_state_init, cfg=cfg, dtype=jnp.bfloat16),
            jax.random.PRNGKey(0))
        batch_sds = S.train_batch_shapes(cfg, run)
        state_sh = S.resolve_shardings(rules, S.train_state_specs(cfg),
                                       state_sds)
        batch_sh = S.resolve_shardings(rules, S.train_batch_spec(cfg, run),
                                       batch_sds)
        return fn, (state_sds, batch_sds), (state_sh, batch_sh)

    params_sds = jax.eval_shape(
        partial(lm.lm_init, cfg=cfg, dtype=jnp.bfloat16),
        jax.random.PRNGKey(0))
    params_sh = S.resolve_shardings(rules, lm.lm_specs(cfg), params_sds)
    cache_sds = S.cache_shapes(cfg, run)
    cache_sh = S.resolve_shardings(rules, lm.cache_specs(cfg), cache_sds)

    if shp.kind == "prefill":
        fn = S.make_prefill_step(cfg, run, rules)
        batch_sds = S.serve_batch_shapes(cfg, run, decode=False)
        batch_sh = S.resolve_shardings(
            rules, S.serve_batch_spec(cfg, decode=False), batch_sds)
        return fn, (params_sds, batch_sds, cache_sds), \
            (params_sh, batch_sh, cache_sh)

    # decode
    fn = S.make_decode_step(cfg, run, rules)
    tok_sds = jax.ShapeDtypeStruct((shp.global_batch, 1), jnp.int32)
    tok_sh = rules.sharding(("batch", None), tok_sds.shape)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params_sds, tok_sds, cache_sds, pos_sds), \
        (params_sh, tok_sh, cache_sh, None)


def run_cell(arch: str, shape: str, mesh_kind: str, *, save: bool = True,
             run_overrides=None, tag: str = "") -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
           "devices": mesh.size, "status": "ok", "tag": tag}
    try:
        fn, args_sds, in_sh = build_cell(arch, shape, mesh,
                                         run_overrides=run_overrides)
        with mesh:
            jf = jax.jit(fn, in_shardings=in_sh, donate_argnums=(0,))
            lowered = jf.lower(*args_sds)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
            ca = compiled.cost_analysis() or {}
            ma = compiled.memory_analysis()
            hlo = compiled.as_text()
        rec["cost_analysis"] = {
            k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or "bytes" in k or "utilization" in k.lower())}
        rec["flops"] = float(ca.get("flops", 0.0))
        rec["bytes_accessed"] = float(ca.get("bytes accessed", 0.0))
        if ma is not None:
            rec["memory_analysis"] = {
                a: int(getattr(ma, a))
                for a in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, a)}
        rec["collectives"] = collective_bytes(hlo)   # raw (body-once) counts
        # structural analysis: while trip-count-corrected flops/bytes
        rec["analysis"] = hlo_analysis.analyze(hlo)
        rec["hlo_ops"] = {
            op: hlo.count(f" {op}(") + hlo.count(f" {op}-start(")
            for op in ("fusion", "while", "dot", "convolution")}
        rec["lower_s"] = round(t_lower - t0, 2)
        rec["compile_s"] = round(t_compile - t_lower, 2)
        cfg = get_config(arch)
        rec["model_params"] = cfg.param_count()
        rec["active_params"] = cfg.active_param_count()
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    if save:
        ART_DIR.mkdir(parents=True, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = ART_DIR / f"{arch}_{shape}_{mesh_kind}{suffix}.json"
        path.write_text(json.dumps(rec, indent=1))
        rec["artifact"] = str(path)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        todo = [(a.name, s.name) for a, s, skip in cells() if skip is None]
    else:
        assert args.arch and args.shape
        todo = [(args.arch, args.shape)]

    for arch, shape in todo:
        for mk in meshes:
            suffix = f"_{args.tag}" if args.tag else ""
            path = ART_DIR / f"{arch}_{shape}_{mk}{suffix}.json"
            if args.skip_existing and path.exists() and \
                    json.loads(path.read_text()).get("status") == "ok":
                print(f"[skip] {arch} x {shape} x {mk}")
                continue
            rec = run_cell(arch, shape, mk, tag=args.tag)
            if rec["status"] == "ok":
                an = rec["analysis"]
                print(f"[ok]   {arch} x {shape} x {mk}: "
                      f"dot_flops={an['dot_flops']:.3e}/dev "
                      f"coll={an['collective_bytes']:.3e}B/dev "
                      f"compile={rec['compile_s']}s", flush=True)
                ma = rec.get("memory_analysis")
                if ma:
                    print("       memory_analysis:", ma, flush=True)
            else:
                print(f"[FAIL] {arch} x {shape} x {mk}: {rec['error']}")


if __name__ == "__main__":
    main()
