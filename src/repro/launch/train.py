"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs on whatever devices exist (CPU host devices / TPU mesh).  For the
production 256/512-chip topology, the same step functions are exercised by
``repro.launch.dryrun`` (this launcher is the runnable end-to-end driver:
data -> SN dedup -> train loop with checkpointing)."""
from __future__ import annotations

import argparse
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, smoke_variant
from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.data.corpus import TokenBatcher, dedup_corpus, synth_corpus
from repro.launch.mesh import make_host_mesh
from repro.models import lm
from repro.sharding.rules import Rules
from repro.train import optim, steps
from repro.train.checkpoint import Checkpointer
from repro.train.loop import LoopConfig, train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi4-mini-3.8b",
                    choices=sorted(ARCHS))
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "100m", "full"],
                    help="smoke: tiny; 100m: ~100M-param variant; full: "
                         "the assigned config (needs a real cluster)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--dedup", action="store_true",
                    help="run the SN dedup stage on the corpus first")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    base = get_config(args.arch)
    if args.preset == "smoke":
        cfg = smoke_variant(base)
    elif args.preset == "100m":
        cfg = hundred_m_variant(base)
    else:
        cfg = base

    mesh = make_host_mesh(model=args.model_axis)
    rules = Rules(mesh, fsdp=True)
    shape = ShapeConfig("cli", args.seq_len, args.batch, "train")
    run = RunConfig(model=cfg, shape=shape, remat="block", microbatch=0)
    oc = optim.OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                         total_steps=args.steps)

    # -- data: synthetic corpus (+ the paper's dedup stage) ------------------
    docs = synth_corpus(0, n_docs=4096, doc_len=args.seq_len,
                        vocab=cfg.vocab_size, dup_frac=0.25)
    if args.dedup:
        res = dedup_corpus(docs, r=4, window=10)
        print(f"[dedup] pairs={res.n_pairs} dropped={res.n_dropped} "
              f"gini={res.gini:.2f} overflow={res.overflow}")
        docs = docs[res.keep]
    batcher = TokenBatcher(docs, seq_len=args.seq_len,
                           global_batch=args.batch)

    train_step = steps.make_train_step(cfg, run, rules, oc)
    state = steps.train_state_init(jax.random.PRNGKey(0), cfg, jnp.bfloat16)
    state_sh = steps.resolve_shardings(
        rules, steps.train_state_specs(cfg), state)
    state = jax.tree.map(jax.device_put, state, state_sh)
    jit_step = jax.jit(train_step, donate_argnums=(0,))

    ckpt = Checkpointer(args.ckpt_dir, async_save=True)
    if not args.resume:
        # fresh run: clear stale manifest
        for p in list(ckpt.dir.glob("step_*.npz")) + \
                list(ckpt.dir.glob("manifest.json")):
            p.unlink()
    lc = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every)
    with mesh:
        state, stats = train_loop(jit_step, state, batcher, ckpt, lc,
                                  shardings=state_sh)
    print(f"[done] steps={stats.steps} final_loss={stats.losses[-1]:.4f} "
          f"first_loss={stats.losses[0]:.4f} restores={stats.restores}")
    return stats


def hundred_m_variant(base: ModelConfig) -> ModelConfig:
    """~100M-param member of the same family (example end-to-end driver)."""
    period = len(base.pattern)
    n_layers = max(period, (12 // period) * period)
    kwargs = dict(
        n_layers=n_layers, d_model=768,
        n_heads=12, n_kv_heads=min(base.n_kv_heads, 4),
        head_dim=64, d_ff=2048 if base.d_ff else 0,
        vocab_size=32_768)
    if base.moe is not None:
        kwargs["moe"] = replace(base.moe, n_experts=8, top_k=2,
                                expert_d_ff=512)
    if base.rglru_dim:
        kwargs["rglru_dim"] = 768
    if base.window_size:
        kwargs["window_size"] = 128
    return replace(base, **kwargs)


if __name__ == "__main__":
    main()
