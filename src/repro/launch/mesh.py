"""Production mesh construction.

A FUNCTION (not module-level constant) so importing this module never touches
jax device state.  Single pod: 16x16 = 256 chips (data x model).  Multi-pod:
2 x 16 x 16 = 512 chips with a leading "pod" axis (data parallelism across
pods over DCN/ICI-over-optical; the dry-run proves the pod axis shards).
"""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: older releases reject the
    ``axis_types`` kwarg; newer ones default it to Auto — so never pass it."""
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_host_mesh(model: int = 1):
    """Mesh over whatever devices exist (CPU tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return make_mesh_compat((n // model, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis (per chip).
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW_PER_LINK = 50e9            # B/s per link (~)
ICI_LINKS = 4                     # 2D torus: 4 links/chip (v5e)
