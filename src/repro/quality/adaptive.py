"""Adaptive window sizing — duplicate-density-driven per-entity windows.

Papadakis et al. (arXiv:1905.06167) name density-adaptive windows as the
standard recall lever over fixed-w Sorted Neighborhood: where the key
profile shows a dense block (many entities sharing one blocking key —
likely duplicate clusters plus their collisions), the window should grow to
cover the whole block; in sparse regions it should stay small so the
reduction ratio survives.

The realization here is a PURE FUNCTION of the global ``KeyProfile``:

    weff(entity) = clip(count(entity.key), window, window_max)

Per-entity (not per-shard) effective windows make every existing invariant
hold for free: weff rides the payload as a traced ``_weff`` field, so it
follows entities through shuffles, halos, boundary groups, and stream
chunking, while the band program itself compiles ONCE at ``window_max``
(the executable-cache key never sees the profile).  The pair (i, i+d)
exists iff d < weff[i+d] — the LATER element owns the comparison, the same
ownership rule as the balance cost model, so a block of c <= window_max
co-keyed entities is covered completely: its k-th member owns intra-block
distances 1..k-1 < c <= weff.

Streamed == monolithic (invariant 9) also follows: the merged streaming
profile holds exactly the full corpus's per-key counts, so every chunk
computes the same weff the monolithic resolve does.
"""
from __future__ import annotations

import numpy as np

from repro.balance.profile import KeyProfile


def weff_for_keys(keys, profile: KeyProfile, window: int,
                  window_max: int) -> np.ndarray:
    """Per-entity effective windows: ``clip(block_count(key), window,
    window_max)`` for each entry of ``keys``, int32.

    Keys absent from the profile (possible only for padding slots — the
    profile is built from the same key set) fall back to ``window``."""
    keys = np.asarray(keys, np.int64)
    weff = np.full(keys.shape, window, np.int64)
    if profile.n_blocks:
        idx = np.searchsorted(profile.uniq, keys)
        idx = np.minimum(idx, profile.n_blocks - 1)
        found = profile.uniq[idx] == keys
        weff[found] = np.clip(profile.counts[idx][found], window, window_max)
    return weff.astype(np.int32)
