"""Ground-truth blocking quality metrics (the blocking-survey quartet).

Papadakis et al. (arXiv:1905.06167) evaluate every blocking method on the
same four numbers, computed against a GOLD duplicate pair set (not against
the method's own oracle, which is all this repo measured before §14):

  pairs_completeness  |blocked ∩ gold| / |gold|      (recall of blocking)
  pairs_quality       |blocked ∩ gold| / |blocked|   (precision of blocking)
  reduction_ratio     1 − |blocked| / total_comparisons
  f_measure           harmonic mean of PC and PQ

All set algebra runs on packed uint64 pair arrays (``(lo << 32) | hi``,
the repo-wide representation) — one ``np.intersect1d`` instead of Python
pair loops, so evaluating a million-pair result is a few array ops.

``repro.api.results`` is imported lazily inside functions: ``repro.api``'s
package init pulls the facade, which must stay importable without this
module (and vice versa).
"""
from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np


@dataclass(frozen=True)
class QualityMetrics:
    """Blocking quality against a gold duplicate pair set.

    Attached to results as ``ERMetrics.quality`` by ``attach``; the raw
    counts ride along so Pareto plots and CI gates can recompute any
    derived number without re-running the resolve."""
    pairs_completeness: float
    pairs_quality: float
    reduction_ratio: float
    f_measure: float
    gold_pairs: int
    blocked_pairs: int
    true_positives: int
    total_comparisons: int


def _as_packed(pairs) -> np.ndarray:
    """Anything pair-shaped -> deduplicated packed uint64 array.

    Accepts a resolve result (ERResult / MultiPassResult / StreamResult —
    anything with ``.pairs`` or ``.blocking.pairs``), a set/frozenset of
    (lo, hi) tuples, or an already-packed uint64 array."""
    from repro.api import results as RES

    if hasattr(pairs, "blocking"):
        pairs = pairs.blocking.pairs
    elif hasattr(pairs, "pairs"):
        pairs = pairs.pairs
    if isinstance(pairs, np.ndarray):
        return np.unique(np.asarray(pairs, RES.PACKED_DTYPE))
    return RES.pack_pair_set(pairs)


def _gold_packed(truth) -> np.ndarray:
    """A TruthCorpus (``gold_packed``/``gold``) or raw pair collection ->
    packed gold array."""
    if hasattr(truth, "gold_packed"):
        return np.asarray(truth.gold_packed)
    if hasattr(truth, "gold"):
        truth = truth.gold
    return _as_packed(truth)


def evaluate(result, truth, total_comparisons: int = None) -> QualityMetrics:
    """Score a resolve result's BLOCKED pair set against ground truth.

    ``truth`` is a ``repro.data.truth.TruthCorpus`` (or any gold pair
    collection); ``total_comparisons`` defaults to the corpus's full
    comparison space n·(n−1)/2 (required when ``truth`` is a bare pair
    set — reduction ratio is undefined without it)."""
    blocked = _as_packed(result)
    gold = _gold_packed(truth)
    if total_comparisons is None:
        n = getattr(truth, "n", None)
        if n is None:
            raise ValueError(
                "total_comparisons is required when truth carries no "
                "entity count (pass a TruthCorpus or give it explicitly)")
        total_comparisons = n * (n - 1) // 2
    tp = int(np.intersect1d(blocked, gold, assume_unique=True).size)
    nb, ng = int(blocked.size), int(gold.size)
    pc = 1.0 if ng == 0 else tp / ng
    pq = 1.0 if nb == 0 else tp / nb
    rr = 1.0 if total_comparisons <= 0 else 1.0 - nb / total_comparisons
    f = 0.0 if pc + pq == 0 else 2.0 * pc * pq / (pc + pq)
    return QualityMetrics(pairs_completeness=pc, pairs_quality=pq,
                          reduction_ratio=rr, f_measure=f,
                          gold_pairs=ng, blocked_pairs=nb,
                          true_positives=tp,
                          total_comparisons=int(total_comparisons))


def attach(result, truth, total_comparisons: int = None):
    """Evaluate and surface the quality metrics on ``result.metrics
    .quality``, returning the updated (frozen-dataclass-replaced) result.

    When the run carried no oracle metrics (``compute_metrics=False``) an
    ``ERMetrics`` is synthesized from the ground-truth numbers: reduction
    ratio against the same comparison space, pairs completeness AGAINST
    GOLD (clearly different from the oracle-PC a compute_metrics run
    reports — gold is the point of this subsystem)."""
    from repro.api import results as RES

    q = evaluate(result, truth, total_comparisons)
    if result.metrics is None:
        metrics = RES.ERMetrics(
            reduction_ratio=q.reduction_ratio,
            pairs_completeness=q.pairs_completeness,
            oracle_pairs=q.gold_pairs,
            total_comparisons=q.total_comparisons,
            quality=q)
    else:
        metrics = replace(result.metrics, quality=q)
    return replace(result, metrics=metrics)
