"""repro.quality — ground-truth match-quality measurement (DESIGN.md §14).

Everything the repo measured before this subsystem was bit-parity against
its OWN oracle; nothing asked whether the emitted pairs find the true
duplicates.  This package closes that gap:

  * ``QualityMetrics`` / ``evaluate`` — pairs-completeness, pairs-quality,
    reduction ratio, and F-measure of any resolve result against a gold
    pair set (packed-uint64 set algebra, no Python pair loops);
  * ``attach`` — surface those metrics on ``ERMetrics.quality`` of an
    ERResult / MultiPassResult / StreamResult;
  * ``weff_for_keys`` — the adaptive-window map: per-entity effective
    windows from a ``KeyProfile``'s block densities (the device band and
    the host oracle both consume it).

The labeled corpus generator lives in ``repro.data.truth``
(``labeled_corpus``); the pruning lever in ``core.window
.prune_low_evidence``.  Together they draw the pairs-completeness vs
reduction-ratio Pareto of ``benchmarks/run.py --only recall``.
"""
from repro.quality.adaptive import weff_for_keys
from repro.quality.metrics import QualityMetrics, attach, evaluate

__all__ = ["QualityMetrics", "attach", "evaluate", "weff_for_keys"]
