"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Config registry: ``get_config(name)`` and per-arch modules."""
from repro.configs.base import (ModelConfig, MoEConfig, RunConfig,
                                ShapeConfig, SHAPES, smoke_variant)
from repro.configs.archs import ARCHS, LONG_CONTEXT_OK


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells with skip annotations."""
    out = []
    for arch in ARCHS.values():
        for shape in SHAPES.values():
            skip = None
            if shape.name == "long_500k" and arch.name not in LONG_CONTEXT_OK:
                skip = "pure full-attention arch: long_500k skipped (DESIGN.md §4)"
            if skip is None or include_skipped:
                out.append((arch, shape, skip))
    return out
