"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Arch config module (assignment deliverable f): selectable via --arch."""
from repro.configs.archs import QWEN15_110B as CONFIG
from repro.configs.base import smoke_variant

SMOKE = smoke_variant(CONFIG)
