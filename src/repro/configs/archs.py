"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

The 10 assigned architectures (exact configs from the assignment table).

Each is exposed as a module-level ``ModelConfig`` and via the registry in
``repro.configs``.  Sources: see DESIGN.md §4 and the assignment brackets.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, MoEConfig

# -- MoE -----------------------------------------------------------------------

MIXTRAL_8X22B = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=32768, head_dim=128,
    pattern=("attn_local",), window_size=4096,          # SWA per assignment
    mlp="moe",
    moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=16384, partition="tp"),
)

QWEN3_MOE_235B = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab_size=151936, head_dim=64,
    pattern=("attn_global",),
    mlp="moe",
    moe=MoEConfig(n_experts=128, top_k=8, expert_d_ff=1536, partition="ep"),
    rope_theta=1_000_000.0,
)

# -- dense ----------------------------------------------------------------------

PHI4_MINI = ModelConfig(
    name="phi4-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=200_064, head_dim=128,
    pattern=("attn_global",), mlp="swiglu",
)

QWEN15_110B = ModelConfig(
    name="qwen1.5-110b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=49152, vocab_size=152_064, head_dim=128,
    pattern=("attn_global",), mlp="swiglu", qkv_bias=True,
)

GEMMA2_9B = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8,
    d_ff=14336, vocab_size=256_000, head_dim=256,
    pattern=("attn_local", "attn_global"), window_size=4096,
    mlp="geglu", attn_logit_softcap=50.0, final_logit_softcap=30.0,
    post_block_norm=True, tie_embeddings=True, embed_scale=True,
)

STABLELM_12B = ModelConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=13824, vocab_size=100_352, head_dim=160,
    pattern=("attn_global",), mlp="swiglu", norm="layernorm",
    parallel_block=True,
)

# -- ssm ------------------------------------------------------------------------

# xLSTM[7:1]: 7 mLSTM blocks per sLSTM block (paper's flagship ratio).
XLSTM_350M = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=256,
    pattern=("mlstm",) * 7 + ("slstm",), mlp="swiglu", rope=False,
)

# -- vlm / audio (backbone only; stub frontends) -----------------------------------

LLAVA_NEXT_34B = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000, head_dim=128,
    pattern=("attn_global",), mlp="swiglu", frontend="patch",
)

MUSICGEN_MEDIUM = ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
    d_ff=6144, vocab_size=2048, head_dim=64,
    pattern=("attn_global",), mlp="gelu", norm="layernorm",
    frontend="codec",
)

# -- hybrid ----------------------------------------------------------------------

# Griffin 1:2 attn:recurrent.  38 layers isn't divisible by a (rec,rec,attn)
# period, so the scan group is one period of 19 = 6x(rec,rec,attn) + rec,
# giving 26 recurrent : 12 local-attn over 2 groups (ratio 2.17:1).
_RG_PERIOD = (("rglru", "rglru", "attn_local") * 6 + ("rglru",))

RECURRENTGEMMA_9B = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab_size=256_000, head_dim=256,
    pattern=_RG_PERIOD, window_size=2048,
    mlp="geglu", tie_embeddings=True, embed_scale=True,
    rglru_dim=4096,
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        MIXTRAL_8X22B, QWEN3_MOE_235B, PHI4_MINI, QWEN15_110B, GEMMA2_9B,
        STABLELM_12B, XLSTM_350M, LLAVA_NEXT_34B, MUSICGEN_MEDIUM,
        RECURRENTGEMMA_9B,
    ]
}

# archs with sub-quadratic (or recurrent) sequence mixing: run long_500k.
LONG_CONTEXT_OK = {
    "mixtral-8x22b",        # SWA everywhere
    "gemma2-9b",            # half local; global layers use seq-sharded KV
    "xlstm-350m",           # recurrent state, O(1) decode
    "recurrentgemma-9b",    # RG-LRU + local attn
}
