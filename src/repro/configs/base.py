"""QUARANTINED (ISSUE 5): LM-training scaffolding retained from the seed repo;
NOT part of the Sorted Neighborhood reproduction — see docs/paper-map.md for
what the reproduction actually uses.

Config system for the repro framework.

Every architecture is described by a ``ModelConfig`` (dataclass, hashable) and
every run (arch x input-shape x mesh) by a ``RunConfig``.  Configs are plain
data: model code consumes them, the launcher resolves them by name via
``repro.configs.registry``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Tuple

# ---------------------------------------------------------------------------
# Block kinds: each layer-group (scan unit) is a tuple of block kinds that is
# applied sequentially.  Uniform transformers use a period-1 pattern.
# ---------------------------------------------------------------------------
BlockKind = Literal[
    "attn_global",      # full (causal) attention
    "attn_local",       # sliding-window attention
    "mlstm",            # xLSTM matrix-memory block (parallelizable)
    "slstm",            # xLSTM scalar-memory block (scan)
    "rglru",            # RG-LRU gated linear recurrence (recurrentgemma)
]

MLPKind = Literal["swiglu", "geglu", "gelu", "moe"]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    expert_d_ff: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # "ep": experts sharded over model axis (needs n_experts % model == 0)
    # "tp": expert d_ff sharded over model axis (few, fat experts: mixtral)
    partition: Literal["ep", "tp"] = "ep"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    # layer pattern: tuple of block kinds; layers = groups * len(pattern)
    pattern: Tuple[BlockKind, ...] = ("attn_global",)
    mlp: MLPKind = "swiglu"
    moe: Optional[MoEConfig] = None
    # attention details
    window_size: int = 0                   # sliding window for attn_local / SWA
    attn_logit_softcap: float = 0.0        # gemma2
    final_logit_softcap: float = 0.0       # gemma2
    qkv_bias: bool = False                 # qwen1.5
    rope_theta: float = 10_000.0
    rope: bool = True
    parallel_block: bool = False           # stablelm/gptj style attn+mlp in parallel
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    norm_eps: float = 1e-6
    post_block_norm: bool = False          # gemma2 uses pre+post norms
    tie_embeddings: bool = False
    embed_scale: bool = False              # gemma-style sqrt(d_model) embed scaling
    # modality frontend stub: if set, forward() accepts precomputed embeddings
    # (B, S, frontend_dim) in place of token ids for the first `frontend_len`
    # positions.  Backbone-only per assignment.
    frontend: Optional[str] = None         # "patch" (vlm) | "codec" (audio)
    # xLSTM specifics
    slstm_every: int = 0                   # 1 sLSTM block per `slstm_every` layers
    # RG-LRU specifics
    rglru_dim: int = 0                     # recurrence width (defaults d_model)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern period {len(self.pattern)}")

    @property
    def n_groups(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, h, kh, hd = self.d_model, self.n_heads, self.n_kv_heads, self.head_dim
        n_attn_like = 0
        n_rec = 0
        for kind in self.pattern:
            if kind in ("attn_global", "attn_local"):
                n_attn_like += 1
            else:
                n_rec += 1
        per_period = len(self.pattern)
        groups = self.n_groups
        attn_layers = n_attn_like * groups
        rec_layers = n_rec * groups
        attn_p = attn_layers * (d * h * hd + 2 * d * kh * hd + h * hd * d)
        if self.pattern.count("mlstm") or self.pattern.count("slstm"):
            # xlstm: qkv + gates + out per recurrent layer, roughly 4*d*d
            rec_p = rec_layers * 4 * d * d
        elif self.pattern.count("rglru"):
            rdim = self.rglru_dim or d
            rec_p = rec_layers * (2 * d * rdim + rdim * d + 3 * rdim)
        else:
            rec_p = 0
        if self.moe is not None:
            e = self.moe
            ff_p = self.n_layers * (
                e.n_experts * 3 * d * e.expert_d_ff
                + e.n_shared_experts * 3 * d * e.expert_d_ff
                + d * e.n_experts)
        else:
            mult = 3 if self.mlp in ("swiglu", "geglu") else 2
            ff_p = self.n_layers * mult * d * self.d_ff
        embed_p = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return attn_p + rec_p + ff_p + embed_p

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        e = self.moe
        total = self.param_count()
        all_ff = self.n_layers * e.n_experts * 3 * self.d_model * e.expert_d_ff
        active_ff = self.n_layers * (e.top_k + e.n_shared_experts) * 3 * \
            self.d_model * e.expert_d_ff
        return total - all_ff + active_ff


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    # distribution knobs
    fsdp: bool = True                  # shard params over the data axis
    remat: Literal["none", "block", "full"] = "block"
    scan_layers: bool = True
    # decode: shard kv cache sequence over model axis when kv heads don't shard
    seq_shard_kv: bool = True
    # Megatron-style sequence-parallel residual stream (train/prefill)
    seq_parallel: bool = False
    microbatch: int = 0                # 0 = no gradient accumulation
    param_dtype: str = "bfloat16"
    # perf-iteration knobs (see EXPERIMENTS.md §Perf)
    attn_chunk_q: int = 512
    attn_chunk_kv: int = 1024


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    period = len(cfg.pattern)
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2), expert_d_ff=64)
    return dataclasses.replace(
        cfg,
        n_layers=2 * period,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        moe=moe,
        window_size=min(cfg.window_size, 8) if cfg.window_size else 0,
        rglru_dim=64 if cfg.rglru_dim else 0,
    )
