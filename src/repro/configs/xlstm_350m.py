"""Arch config module (assignment deliverable f): selectable via --arch."""
from repro.configs.archs import XLSTM_350M as CONFIG
from repro.configs.base import smoke_variant

SMOKE = smoke_variant(CONFIG)
