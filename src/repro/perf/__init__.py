"""``repro.perf`` — performance tooling: HLO analysis + the executable
cache behind the steady-state hot path (see ``repro.perf.cache``)."""
from repro.perf.cache import (CacheStats, ExecutableCache, executable_cache,
                              tree_fingerprint)

__all__ = ["CacheStats", "ExecutableCache", "executable_cache",
           "tree_fingerprint"]
