"""Executable cache — compile each shard program once, dispatch forever.

The steady-state throughput problem (ISSUE 4): every ``resolve()`` used to
re-trace its program from scratch — ``VmapRunner`` ran the batching
interpreter op-by-op, ``ShardMapRunner`` additionally paid an ``eval_shape``
pass per call — so repeated runs over same-shaped inputs (the serving
workload the ROADMAP north-star describes) paid compile-class overheads on
every call.  This module gives the runners a process-wide cache mapping

    (runner kind, cfg static fingerprint, cap_link, input shapes/dtypes)
        -> one jitted executable

so the second same-shaped call is a single XLA dispatch.  Three rules keep
it honest:

  * **Keys are exact.**  Anything that changes the traced program — config
    statics (``ERConfig.static_fingerprint()``), planner capacity, input
    tree structure, shapes, dtypes — is in the key.  Boundary *values* are
    traced arguments, so replanning boundaries never retraces.
  * **Traces are counted, not assumed.**  The cached callable wraps the
    program in a trace counter before ``jax.jit``; ``CacheStats.traces``
    increments only when XLA actually (re)traces, which is what the
    zero-retrace tests assert (a key bug would show up as a trace, never
    as silent recompilation).
  * **Donation only for buffers we own.**  Callers donate argument 0 (the
    stacked shard input, rebuilt per call) on backends that support buffer
    donation; user-held entity arrays are never donated.

``facade.resolve`` snapshots ``CacheStats`` around each run and reports the
delta as ``ERResult.perf`` (hits / misses / traces / entries).
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Callable, Tuple

import jax

# Executables retained before least-recently-used eviction: enough for many
# concurrent (variant x engine x shape) working sets, small enough that a
# serving process resolving arbitrarily-shaped batches doesn't accrete
# compiled programs without bound (each entry holds a lowered executable).
DEFAULT_MAX_ENTRIES = 256


@dataclass
class CacheStats:
    """Counters for the executable cache (process-wide, monotone).

    ``misses`` counts cache builds; ``traces`` counts actual jit traces of
    cached programs — equal in a healthy cache (every executable traced
    exactly once), diverging only if a keying bug lets one cached entry see
    two shapes.  ``evictions`` counts LRU drops (an evicted key rebuilds on
    next use; a high rate means the working set exceeds ``max_entries``)."""
    hits: int = 0
    misses: int = 0
    traces: int = 0
    evictions: int = 0

    def snapshot(self) -> Tuple[int, int, int]:
        """Current (hits, misses, traces) — pair with ``delta`` to meter
        one region of work (the counters are process-wide and monotone)."""
        return (self.hits, self.misses, self.traces)

    def delta(self, since: Tuple[int, int, int]) -> Tuple[int, int, int]:
        """(hits, misses, traces) accrued since a ``snapshot()`` — how
        ``facade.resolve`` builds per-call ``PerfStats`` and how
        ``repro.stream`` attributes cache behavior to individual chunks
        (a steady-state chunk shows hits > 0, misses == traces == 0)."""
        h, m, t = since
        return (self.hits - h, self.misses - m, self.traces - t)


def tree_fingerprint(tree) -> Tuple:
    """Hashable (structure, shapes, dtypes) key of an argument pytree —
    the part of a cache key that makes same-key imply same-trace.  Works on
    concrete arrays and abstract tracers alike (only ``.shape``/``.dtype``
    are read), so cached calls stay usable under an outer ``jax.jit``."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (treedef, tuple((tuple(l.shape), str(l.dtype)) for l in leaves))


def supports_donation() -> bool:
    """Buffer donation is a no-op (with a warning) on CPU; only donate
    where XLA can actually reuse the buffer."""
    return jax.default_backend() not in ("cpu",)


class ExecutableCache:
    """Maps hashable program keys to jitted executables (see module doc),
    bounded by LRU eviction so long-lived serving processes don't retain
    one compiled program per distinct shape forever."""

    def __init__(self, max_entries: int = DEFAULT_MAX_ENTRIES):
        self._fns: "OrderedDict[Any, Callable]" = OrderedDict()
        self.max_entries = max_entries
        self.stats = CacheStats()

    def __len__(self) -> int:
        return len(self._fns)

    def clear(self) -> None:
        """Drop all executables (stats keep counting — they are monotone
        telemetry, not per-entry state)."""
        self._fns.clear()

    def get_or_build(self, key, build: Callable[[], Callable], *,
                     donate_argnums: Tuple[int, ...] = ()) -> Callable:
        """Return the jitted executable for ``key``, building (and jitting,
        with a trace counter) via ``build()`` on a miss."""
        fn = self._fns.get(key)
        if fn is not None:
            self.stats.hits += 1
            self._fns.move_to_end(key)       # LRU freshness
            return fn
        self.stats.misses += 1
        program = build()

        def traced(*args):
            self.stats.traces += 1
            return program(*args)

        donate = donate_argnums if supports_donation() else ()
        fn = jax.jit(traced, donate_argnums=donate)
        self._fns[key] = fn
        while len(self._fns) > self.max_entries:
            self._fns.popitem(last=False)    # least recently used
            self.stats.evictions += 1
        return fn


_GLOBAL_CACHE = ExecutableCache()


def executable_cache() -> ExecutableCache:
    """The process-wide cache every runner routes through."""
    return _GLOBAL_CACHE
