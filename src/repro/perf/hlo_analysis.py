"""Structural analyzer for post-SPMD HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, but a scanned
transformer executes it n_groups (and microbatch) times — so FLOPs, HBM and
collective bytes must be re-derived by walking the call graph with loop
trip-count multipliers.  This module parses ``compiled.as_text()`` (per-device
module), builds the computation call graph, extracts while trip counts from
their condition computations, and accumulates:

  * dot FLOPs        2 * prod(result_dims) * prod(lhs_contracting_dims)
  * dot bytes        lhs + rhs + result buffer bytes (HBM-traffic proxy)
  * collective bytes per kind (all-reduce counted 2x for ring cost)

All values are per-device (the SPMD module is single-program).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}
_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_OPCODE = re.compile(r"^((?:\([^=]*\)|[\w\[\],\{\} ]+?))\s*([\w\-]+)\(")
_CALL_ATTR = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")


def _shape_dims(tok: re.Match) -> Tuple[int, List[int]]:
    dt, dims = tok.group(1), tok.group(2)
    if dt not in _DTYPE_BYTES:
        return 0, []
    ds = [int(d) for d in dims.split(",") if d]
    n = 1
    for d in ds:
        n *= d
    return n * _DTYPE_BYTES[dt], ds


def _type_bytes(type_str: str, *, largest_only: bool = False) -> int:
    vals = []
    for tok in _SHAPE_TOKEN.finditer(type_str):
        b, _ = _shape_dims(tok)
        vals.append(b)
    if not vals:
        return 0
    return max(vals) if largest_only else sum(vals)


@dataclass
class Instr:
    name: str
    opcode: str
    type_str: str
    rhs: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    defs: Dict[str, str] = field(default_factory=dict)   # name -> type str
    is_entry: bool = False


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = re.sub(r"/\*.*?\*/", "", raw).rstrip()
        s = line.strip()
        if not s:
            continue
        m = _COMP_HEADER.match(s)
        if m and s.endswith("{"):
            cur = Computation(name=m.group(2), is_entry=bool(m.group(1)))
            comps[cur.name] = cur
            # parameters from header
            for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\)|[\w\[\],]+))",
                                  m.group(3)):
                cur.defs[pm.group(1)] = pm.group(2)
            continue
        if s == "}" or cur is None:
            continue
        im = _INSTR.match(s)
        if not im:
            continue
        name, rhs = im.group(1), im.group(2)
        om = _OPCODE.match(rhs)
        if not om:
            continue
        type_str, opcode = om.group(1), om.group(2)
        cur.defs[name] = type_str
        cur.instrs.append(Instr(name, opcode, type_str, rhs))
    return comps


def _while_trip_count(comps: Dict[str, Computation], cond_name: str) -> int:
    """Max s32 constant in the condition computation ~= scan bound."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant" and "s32" in ins.type_str:
            m = re.search(r"constant\((-?\d+)\)", ins.rhs)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _multipliers(comps: Dict[str, Computation]) -> Dict[str, float]:
    entry = next((c for c in comps.values() if c.is_entry), None)
    mult: Dict[str, float] = {}
    if entry is None:
        return {c: 1.0 for c in comps}
    stack = [(entry.name, 1.0)]
    while stack:
        name, m = stack.pop()
        if mult.get(name, 0.0) >= m and name in mult:
            continue
        mult[name] = max(mult.get(name, 0.0), m)
        comp = comps[name]
        for ins in comp.instrs:
            if ins.opcode == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.rhs)
                bm = re.search(r"body=%?([\w\.\-]+)", ins.rhs)
                trip = _while_trip_count(comps, cm.group(1)) if cm else 1
                if bm and bm.group(1) in comps:
                    stack.append((bm.group(1), m * trip))
                if cm and cm.group(1) in comps:
                    stack.append((cm.group(1), m * (trip + 1)))
            else:
                bm = _BRANCHES.search(ins.rhs)
                if bm:
                    for b in bm.group(1).split(","):
                        b = b.strip().lstrip("%")
                        if b in comps:
                            stack.append((b, m))
                for cm in _CALL_ATTR.finditer(ins.rhs):
                    if "while" not in ins.opcode and cm.group(1) in comps:
                        stack.append((cm.group(1), m))
    for c in comps:
        mult.setdefault(c, 1.0)
    return mult


def _operand_names(rhs: str) -> List[str]:
    inner = rhs[rhs.find("(") + 1:]
    depth = 1
    out = []
    buf = []
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    arg_str = "".join(buf)
    for m in re.finditer(r"%([\w\.\-]+)", arg_str):
        out.append(m.group(1))
    if not out:
        # operands may be given without % in newer dumps: name, name
        for tok in arg_str.split(","):
            tok = tok.strip().split(" ")[-1]
            if tok:
                out.append(tok)
    return out


def _dot_flops(comp: Computation, ins: Instr) -> float:
    res_bytes_dims = list(_SHAPE_TOKEN.finditer(ins.type_str))
    if not res_bytes_dims:
        return 0.0
    _, res_dims = _shape_dims(res_bytes_dims[0])
    n_res = 1
    for d in res_dims:
        n_res *= d
    lhs_contract = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rhs)
    ops = _operand_names(ins.rhs)
    contract = 1
    if lhs_contract and ops:
        lhs_type = comp.defs.get(ops[0], "")
        tm = _SHAPE_TOKEN.search(lhs_type)
        if tm:
            _, lhs_dims = _shape_dims(tm)
            for idx in lhs_contract.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    contract *= lhs_dims[int(idx)]
    return 2.0 * n_res * contract


def _dot_bytes(comp: Computation, ins: Instr) -> float:
    total = _type_bytes(ins.type_str)
    for op in _operand_names(ins.rhs)[:2]:
        total += _type_bytes(comp.defs.get(op, ""))
    return float(total)


def analyze(text: str) -> dict:
    comps = parse_module(text)
    mult = _multipliers(comps)
    flops = 0.0
    dot_bytes = 0.0
    coll = {k: {"bytes": 0.0, "count": 0.0} for k in COLLECTIVE_OPS}
    whiles = []
    for comp in comps.values():
        m = mult[comp.name]
        for ins in comp.instrs:
            op = ins.opcode
            if op == "dot":
                flops += m * _dot_flops(comp, ins)
                dot_bytes += m * _dot_bytes(comp, ins)
            elif op == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", ins.rhs)
                whiles.append({
                    "name": ins.name,
                    "trip": _while_trip_count(comps, cm.group(1)) if cm else 1,
                    "mult": m})
            else:
                base = op[:-6] if op.endswith("-start") else op
                if base in COLLECTIVE_OPS and not op.endswith("-done"):
                    b = _type_bytes(ins.type_str, largest_only=True)
                    factor = 2.0 if base == "all-reduce" else 1.0
                    coll[base]["bytes"] += m * b * factor
                    coll[base]["count"] += m
                    # TPU-adjusted width: XLA:CPU promotes bf16 dots to f32
                    # and psums ride the f32 dot output (verified on phi4 —
                    # EXPERIMENTS.md §Perf); all model state is bf16, so f32
                    # collectives are counted at native-bf16 width too.
                    adj = 0.5 if "f32[" in ins.type_str else 1.0
                    coll[base]["bytes_bf16adj"] = coll[base].get(
                        "bytes_bf16adj", 0.0) + m * b * factor * adj
    total_coll = sum(v["bytes"] for v in coll.values())
    total_adj = sum(v.get("bytes_bf16adj", 0.0) for v in coll.values())
    return {
        "dot_flops": flops,
        "dot_bytes": dot_bytes,
        "collectives": coll,
        "collective_bytes": total_coll,
        "collective_bytes_bf16adj": total_adj,
        "whiles": whiles,
        "n_computations": len(comps),
    }


def analyze_compiled(compiled) -> dict:
    return analyze(compiled.as_text())
