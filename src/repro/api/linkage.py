"""Dual-source (R x S) record linkage — the first new scenario the variant
registry enables (the multi-source direction of Kirsten et al., "Data
Partitioning for Parallel Entity Matching").

Entities are tagged with an int32 ``src`` payload (0 = left source R,
1 = right source S).  The tag rides the SRP shuffle / halo exchange like any
other payload field, and the band masks are restricted to pairs whose
endpoints carry DIFFERENT tags — blocking and matching then only ever emit
cross-source correspondences, while the sort/window structure (and all three
variants' boundary handling) is unchanged.
"""
from __future__ import annotations

from typing import Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import entities as E
from repro.core import sn

Pair = Tuple[int, int]


def cross_source_band(src: jax.Array, w: int) -> jax.Array:
    """(w-1, M) mask: row d-1 true where src[i] != src[i+d] (same band layout
    as window.band_scores).  Delegates to ``window.cross_source_rows`` — the
    single implementation both band engines use."""
    from repro.core.window import cross_source_rows
    return cross_source_rows(src, w)


def tag_sources(lhs: dict, rhs: dict) -> Tuple[dict, int]:
    """Concat two entity sets with source tags and disjoint eids.

    Returns (combined_entities, offset): rhs eids are shifted by ``offset``
    so the merged id space is unique; ``untag_pairs`` maps pairs back to
    (lhs_eid, rhs_eid).  Both inputs must share the same payload schema."""
    lhs_eid = np.asarray(lhs["eid"])
    offset = int(lhs_eid.max()) + 1 if lhs_eid.size else 0

    def with_src(ents, tag, shift):
        n = ents["key"].shape[0]
        payload = dict(ents["payload"])
        payload["src"] = jnp.full((n,), tag, jnp.int32)
        return E.make_entities(ents["key"],
                               jnp.asarray(ents["eid"], jnp.int32) + shift,
                               payload=payload, valid=ents["valid"])

    combined = E.concat(with_src(lhs, 0, 0), with_src(rhs, 1, offset))
    return combined, offset


def untag_pairs(pairs, offset: int) -> Set[Pair]:
    """Map cross-source pairs from the merged eid space back to
    (lhs_eid, rhs_eid) tuples."""
    out: Set[Pair] = set()
    for a, b in pairs:
        if a >= offset:
            a, b = b, a
        out.add((a, b - offset))
    return out


def filter_cross_source(pairs, eids: np.ndarray, src: np.ndarray):
    """Keep only pairs whose endpoints carry different source tags."""
    by_eid = dict(zip(eids.tolist(), src.tolist()))
    return {(a, b) for a, b in pairs if by_eid[a] != by_eid[b]}


def filter_cross_source_packed(packed: np.ndarray, eids: np.ndarray,
                               src: np.ndarray) -> np.ndarray:
    """Vectorized ``filter_cross_source`` over a packed uint64 pair array
    (eid -> src lookup via searchsorted; no Python dict / tuple objects)."""
    from repro.api import results as RES
    if packed.size == 0:
        return packed
    order = np.argsort(eids)
    sorted_eids, sorted_src = eids[order], src[order]
    lo, hi = RES.unpack_pairs(packed)
    s_lo = sorted_src[np.searchsorted(sorted_eids, lo)]
    s_hi = sorted_src[np.searchsorted(sorted_eids, hi)]
    return packed[s_lo != s_hi]


def sequential_link_pairs(keys: np.ndarray, eids: np.ndarray,
                          src: np.ndarray, w: int) -> Set[Pair]:
    """Host oracle: sequential SN window pairs restricted to cross-source
    endpoints (merged eid space)."""
    return filter_cross_source(sn.sequential_sn_pairs(keys, eids, w),
                               eids, src)
