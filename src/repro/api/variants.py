"""Variant registry — the SN strategies behind ``repro.api.resolve``.

Each variant owns three hooks:

  * ``shard_program(ents, bounds, r, axis, cfg, cap_link=None)``  the
    per-shard collective program (runs under vmap-with-axis-name or
    shard_map); returns a dict of per-shard outputs with at least
    ``overflow``, ``load`` and one or more band parts (``main``, optionally
    ``boundary``).  ``cap_link`` is the planner-provided shuffle capacity
    (repro.balance ShardPlan); None derives it from ``cfg.cap_factor``.
  * ``collect(out)``  turn the stacked runner output into host pair sets
    (blocked + matched), deduplicating across parts
  * ``sequential_pairs(keys, eids, bounds, w, part=None)``  the HOST oracle
    with this variant's semantics (SRP: per-partition windows — boundary
    pairs are missed by design; RepSN/JobSN: the complete sequential SN
    pair set).  ``part`` carries per-entity shard ids from a rank-granular
    ShardPlan; the sequential runner always passes it.

New variants register with ``@register_variant("name")`` — no dispatch code
anywhere else changes (this replaces the old if/elif in pipeline.sn_shard).
"""
from __future__ import annotations

from typing import Dict, Set, Tuple, Type

import jax
import numpy as np

from repro.core import jobsn as J
from repro.core import repsn as R
from repro.core import sn
from repro.core import srp as S
from repro.core import window as W
from repro.api import results as RES

_REGISTRY: Dict[str, Type["VariantBase"]] = {}


def register_variant(name: str):
    """Class decorator: ``@register_variant("repsn")``."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def get_variant(name: str) -> "VariantBase":
    """Instantiate the registered variant named ``name`` (raises
    ``ValueError`` listing the registry when unknown)."""
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise ValueError(f"unknown SN variant {name!r}; registered: "
                         f"{available_variants()}") from None


def available_variants() -> Tuple[str, ...]:
    """Sorted names of every registered SN variant."""
    return tuple(sorted(_REGISTRY))


class VariantBase:
    """Shared SRP front-end + band evaluation; subclasses add the variant's
    boundary-handling step."""

    name = "?"
    parts: Tuple[str, ...] = ("main",)
    halo_slices = False        # True: slices w-1 boundary slots per shard
    boundary_complete = True   # sequential_pairs == full SN oracle

    # -- device side ---------------------------------------------------------

    def shard_program(self, ents: dict, bounds: jax.Array, r: int,
                      axis: str, cfg, cap_link: int = None) -> dict:
        """The per-shard collective program (runs under vmap-with-axis-name
        or shard_map): SRP shuffle + this variant's ``_windows`` step.
        Returns the per-shard output dict (``overflow``, ``load``, one band
        part per ``self.parts``).  ``cap_link`` is the planner-provided
        shuffle capacity; None derives it from ``cfg.cap_factor``."""
        # capacity precedence: planner-provided cap_link (exact, from the
        # ShardPlan) > cfg.cap_factor > full capacity (never overflows)
        cap0 = ents["key"].shape[0]
        if cap_link is None:
            cap_link = cap0 if cfg.cap_factor <= 0 else \
                max(1, int(np.ceil(cap0 * cfg.cap_factor / r)))
        if self.halo_slices and cfg.window - 1 > r * cap_link:
            raise ValueError(
                f"variant {self.name!r} slices w-1 boundary slots per "
                f"shard, but window={cfg.window} exceeds the per-shard "
                f"buffer of {r * cap_link} slots; reduce window or "
                f"num_shards, raise cap_factor, or use runner='sequential'")
        sorted_ents, overflow = S.srp_shard(ents, bounds, r, axis, cap_link)
        out = {"overflow": overflow, "load": S.local_load(sorted_ents, axis)}
        out.update(self._windows(sorted_ents, r, axis, cfg))
        return out

    def _windows(self, sorted_ents: dict, r: int, axis: str, cfg) -> dict:
        raise NotImplementedError

    def _band(self, e: dict, halo_len: int, mode: str, cfg) -> dict:
        """Evaluate this part's window band with the configured BandEngine
        (scan oracle or the Pallas cascade — see core/window.py); the engine
        owns masking (incl. the linkage cross-source rule), matching, and
        the cascade's candidate/overflow accounting.

        With ``cfg.emit == "pairs"`` the boolean bands never leave the
        device: each is compacted into a packed flat-index buffer
        (``window.emit_band_indices`` — capacity ``cfg.pair_cap``, overflow
        counted) and the part carries only those buffers plus the (M,) eid
        vector for host translation, instead of O(w*M) bands + full payload
        slices."""
        engine = W.get_band_engine(getattr(cfg, "band_engine", "scan"))
        out = engine.band(e, cfg, halo_len=halo_len, mode=mode)
        if getattr(cfg, "emit", "band") == "pairs":
            m = e["valid"].shape[0]
            full = (cfg.window - 1) * m
            pair_cap = cfg.pair_cap or 0   # None (unresolved auto) -> full
            cap = min(pair_cap, full) if pair_cap > 0 else full
            bound = engine.match_bound(e, cfg)     # match band is sparser:
            caps = {"mask": cap,                   # engines with a provable
                    "match": cap if bound is None  # bound (pallas cand_cap)
                    else min(cap, bound)}          # shrink its buffer
            for field in ("mask", "match"):
                emitted = W.emit_band_indices(out.pop(field), caps[field])
                out.update({f"{field}_idx": emitted["idx"],
                            f"{field}_n": emitted["n"],
                            f"{field}_overflow": emitted["overflow"]})
            out["eid"] = e["eid"]
        else:
            out["ents"] = e
        out["halo_len"] = halo_len
        return out

    # -- host side -----------------------------------------------------------

    def collect(self, out: dict) -> RES.CollectedPairs:
        """Stacked runner output -> deduplicated PACKED pair arrays (uint64
        ``(lo << 32) | hi``).  Parts are unioned via np.unique, so a pair
        emitted by several parts/shards counts once; frozensets appear only
        at the RunnerOutcome boundary.  Device-emitted parts (emit="pairs")
        and band parts are consumed transparently
        (``results.packed_pairs_from_part``)."""
        blocked = [RES.packed_pairs_from_part(out[p], "mask")
                   for p in self.parts if p in out]
        matched = [RES.packed_pairs_from_part(out[p], "match")
                   for p in self.parts if p in out]
        dedup = lambda parts: np.unique(np.concatenate(parts)) if parts \
            else np.empty((0,), RES.PACKED_DTYPE)
        return RES.CollectedPairs(blocked=dedup(blocked),
                                  matched=dedup(matched))

    def sequential_pairs(self, keys: np.ndarray, eids: np.ndarray,
                         bounds: np.ndarray, w: int,
                         part: np.ndarray = None,
                         weff: np.ndarray = None) -> Set[Tuple[int, int]]:
        """Host oracle with this variant's semantics (boundary-complete
        variants return the full sequential SN pair set).  ``part``: per-
        entity shard ids from a rank-granular ShardPlan — overrides the
        key->shard map for variants whose pair set depends on the
        partitioning (SRP).  ``weff``: per-entity effective windows
        (adaptive policy) — the later sorted element's weff bounds each
        pair's distance, overriding the constant ``w``."""
        if weff is not None:
            return sn.adaptive_sn_pairs(keys, eids, weff)
        return sn.sequential_sn_pairs(keys, eids, w)


@register_variant("srp")
class SrpVariant(VariantBase):
    """Plain Sorted Reduce Partitions (paper §4.1): window within each
    partition only; misses (r-1)*w*(w-1)/2 boundary pairs by design."""

    boundary_complete = False

    def _windows(self, sorted_ents, r, axis, cfg):
        return {"main": self._band(sorted_ents, 0, "all", cfg)}

    def sequential_pairs(self, keys, eids, bounds, w, part=None, weff=None):
        """SRP's host oracle: SN pairs WITHIN each partition only (``part``
        per-entity ids win over the ``bounds`` key map) — boundary pairs
        are missed by design, exactly like the device program."""
        if part is None:
            part = np.searchsorted(np.asarray(bounds), keys, side="left")
        pairs: Set[Tuple[int, int]] = set()
        for p in np.unique(part):
            sel = part == p
            if weff is not None:
                pairs |= sn.adaptive_sn_pairs(keys[sel], eids[sel],
                                              np.asarray(weff)[sel])
            else:
                pairs |= sn.sequential_sn_pairs(keys[sel], eids[sel], w)
        return pairs


@register_variant("repsn")
class RepSNVariant(VariantBase):
    """SN with replication (paper §4.3): halo-prepend the predecessor's last
    w-1 entities, then window with mode="native"."""

    halo_slices = True

    def _windows(self, sorted_ents, r, axis, cfg):
        combined, hl = R.repsn_combine(sorted_ents, cfg.window, r, axis,
                                       hops=cfg.hops)
        return {"main": self._band(combined, hl, "native", cfg)}


@register_variant("jobsn")
class JobSNVariant(VariantBase):
    """SN with an additional phase (paper §4.2): plain SRP window plus a
    boundary-group pass restricted to cross-boundary pairs."""

    parts = ("main", "boundary")
    halo_slices = True

    def _windows(self, sorted_ents, r, axis, cfg):
        group, hl = J.boundary_group(sorted_ents, cfg.window, r, axis)
        return {"main": self._band(sorted_ents, 0, "all", cfg),
                "boundary": self._band(group, hl, "cross", cfg)}
