"""Typed result objects + host-side pair extraction.

Replaces the raw nested dicts the old pipeline returned: results carry the
pair sets, per-shard load, overflow accounting, and (optionally) blocking
quality metrics computed against the sequential oracle.

Internally pairs travel as PACKED uint64 arrays — ``(lo << 32) | hi`` with
``lo < hi`` eids — deduplicated by ``np.unique``.  Collection is then one
batched nonzero + pack + unique (linear, vectorized) instead of building
millions of Python tuples; frozensets of (lo, hi) tuples appear only at the
public ``RunnerOutcome``/``BlockingResult`` boundary.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet, NamedTuple, Optional, Set, Tuple

import numpy as np

from repro.resilience.retry import ResilienceStats

Pair = Tuple[int, int]

PACKED_DTYPE = np.uint64


def pack_pairs(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Elementwise (a, b) eid pairs -> canonical packed uint64
    ``(min << 32) | max``.  Eids must be non-negative and < 2^32."""
    a = np.asarray(a, PACKED_DTYPE)
    b = np.asarray(b, PACKED_DTYPE)
    lo = np.minimum(a, b)
    hi = np.maximum(a, b)
    return (lo << PACKED_DTYPE(32)) | hi


def unpack_pairs(packed: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Packed uint64 -> (lo, hi) int64 arrays."""
    packed = np.asarray(packed, PACKED_DTYPE)
    lo = (packed >> PACKED_DTYPE(32)).astype(np.int64)
    hi = (packed & PACKED_DTYPE(0xFFFFFFFF)).astype(np.int64)
    return lo, hi


def pack_pair_set(pairs: Set[Pair]) -> np.ndarray:
    """Host pair set -> sorted deduplicated packed array."""
    if not pairs:
        return np.empty((0,), PACKED_DTYPE)
    flat = np.fromiter((c for p in pairs for c in p), np.int64,
                       2 * len(pairs)).reshape(-1, 2)
    return np.unique(pack_pairs(flat[:, 0], flat[:, 1]))


def packed_to_frozenset(packed: np.ndarray) -> FrozenSet[Pair]:
    """Packed array -> public frozenset of (lo, hi) tuples (the one place
    Python pair objects are materialized)."""
    lo, hi = unpack_pairs(packed)
    return frozenset(zip(lo.tolist(), hi.tolist()))


class CollectedPairs(NamedTuple):
    """Deduplicated packed uint64 pair arrays (see ``pack_pairs``)."""
    blocked: np.ndarray
    matched: np.ndarray


@dataclass(frozen=True)
class BalanceMetrics:
    """Planned vs realized per-shard load under a ``repro.balance``
    ShardPlan (the skew telemetry of ISSUE 3: wall-clock is the MAX of
    per-shard matcher work, so the imbalance ratio max/mean is the direct
    parallel-efficiency loss).

    planned_*            what the partition planner promised (profile-based)
    realized_*           what the run delivered (post-shuffle valid counts;
                         comparisons re-derived through the window cost
                         model from the realized contiguous rank layout)
    imbalance_*          max/mean of per-shard comparison counts (1.0 =
                         perfectly level)
    straggler_shard      shard id with the largest realized comparison load
    halo_entities        total entities replicated across boundaries
    cap_link             planned per-(mapper, dest) shuffle capacity
                         (None: capacity derived from cfg.cap_factor)
    """
    partitioner: str
    planned_load: Tuple[int, ...]
    realized_load: Tuple[int, ...]
    planned_comparisons: Tuple[int, ...]
    realized_comparisons: Tuple[int, ...]
    imbalance_planned: float
    imbalance_realized: float
    straggler_shard: int
    halo_entities: int
    cap_link: Optional[int] = None


@dataclass(frozen=True)
class PerfStats:
    """Execution-cache telemetry for one ``resolve``/``link`` call (the
    steady-state contract of ISSUE 4: after warmup, every call should be
    ``cache_hits > 0, cache_misses == traces == 0``).

    cache_hits      executables reused from the repro.perf cache
    cache_misses    executables built (== programs lowered) by this call
    traces          jit traces actually performed (a healthy cache has
                    traces == cache_misses; more means a keying bug let one
                    executable see two shapes)
    cache_entries   total executables resident after the call
    """
    cache_hits: int
    cache_misses: int
    traces: int
    cache_entries: int

    @property
    def steady_state(self) -> bool:
        """True when the call ran entirely from cached executables — at
        least one hit and no build/trace.  A bypassed cache (jit_cache=
        False, legacy shims) reports all-zero counters and is NOT steady
        state: it re-traced every call."""
        return self.cache_hits > 0 and self.traces == 0 and \
            self.cache_misses == 0


@dataclass(frozen=True)
class ERMetrics:
    """Blocking quality vs the sequential-SN oracle (the standard blocking
    metrics; the paper reports |B| and completeness of the variants).

    reduction_ratio     1 - |blocked| / |all comparable pairs|
    pairs_completeness  |blocked ∩ oracle| / |oracle|
    balance             planned-vs-realized shard load (profile-backed runs)
    resilience          overflow-recovery telemetry (retries / escalations /
                        final caps — DESIGN.md §11)
    """
    reduction_ratio: float
    pairs_completeness: float
    oracle_pairs: int
    total_comparisons: int
    balance: Optional[BalanceMetrics] = None
    resilience: Optional[ResilienceStats] = None
    quality: Optional[object] = None  # ground-truth QualityMetrics
    #                                   (repro.quality.evaluate attaches
    #                                   PC/PQ/RR/F vs a labeled corpus's
    #                                   gold pair set — None unless a truth
    #                                   set was supplied)


@dataclass(frozen=True)
class BlockingResult:
    """Outcome of the blocking stage (candidate generation)."""
    pairs: FrozenSet[Pair]          # blocked (candidate) pairs, (lo, hi) eids
    load: Tuple[int, ...]           # per-shard valid counts (skew telemetry)
    overflow: int                   # entities dropped by capacity limits
    variant: str
    runner: str
    window: int
    num_shards: int
    cand_count: Tuple[int, ...] = ()  # per-shard gate survivors (pallas)
    cand_overflow: int = 0          # cascade survivors dropped by cand_cap
    matcher_evals: int = 0          # full-cascade evaluations actually run
    pair_overflow: int = 0          # emitted pair-index slots dropped by
    #                                 pair_cap (emit="pairs"; can lose
    #                                 blocked pairs AND matches — counted,
    #                                 never silent)
    pruned: int = 0                 # band slots dropped by meta-blocking
    #                                 comparison pruning (prune_policy=
    #                                 "evidence"): deliberate low-evidence
    #                                 filtering, accounted like overflow but
    #                                 never retried

    @property
    def max_load(self) -> int:
        """Largest per-shard valid count — the straggler's load (wall-clock
        scales with this, not the mean)."""
        return max(self.load) if self.load else 0

    @property
    def total_load(self) -> int:
        """Sum of per-shard valid counts (== entities that survived the
        shuffle; compare with the input n to spot capacity overflow)."""
        return sum(self.load)


@dataclass(frozen=True)
class ERResult:
    """Full entity-resolution outcome: blocking + matching (+ metrics).

    ``balance`` is populated whenever the run executed under a profile-
    backed ShardPlan (any ``cfg.partitioner`` default-bounds run); runs on
    explicit raw bounds have no plan to compare against and carry None."""
    blocking: BlockingResult
    matches: FrozenSet[Pair]        # matcher-accepted pairs
    metrics: Optional[ERMetrics] = None
    balance: Optional[BalanceMetrics] = None
    perf: Optional[PerfStats] = None  # executable-cache telemetry for this
    #                                   call (hits / misses / traces)
    resilience: Optional[ResilienceStats] = None  # overflow-recovery
    #                                   telemetry (retries / escalations /
    #                                   final caps — DESIGN.md §11)
    trace: Optional[object] = None  # repro.obs.TraceReport when the run
    #                                 executed under ERConfig.trace=True
    #                                 (spans + metrics + the legacy stats
    #                                 unified — DESIGN.md §12)

    @property
    def pairs(self) -> FrozenSet[Pair]:
        """The blocked (candidate) pair set — sugar for blocking.pairs."""
        return self.blocking.pairs


@dataclass(frozen=True)
class MultiPassResult:
    """Outcome of a multi-pass run (``ERConfig.passes`` non-empty).

    One full ER pipeline execution per ``SortKeySpec``; the top-level
    ``blocking``/``matches`` hold the UNION across passes (the recall
    lever: a pair blocked by any pass is blocked), while ``passes`` keeps
    each pass's complete single-pass ``ERResult`` — per-pass loads,
    overflow, balance, and perf stay individually auditable.  The union
    ``blocking`` aggregates accounting additively (overflow / cand_overflow
    / pair_overflow / matcher_evals are summed; ``load`` is left empty —
    per-pass shard loads live on ``passes[i].blocking.load``).  ``metrics``
    (when requested) compares the union pair set against the union of the
    per-pass sequential oracles."""
    passes: Tuple[ERResult, ...]
    pass_names: Tuple[str, ...]
    blocking: BlockingResult
    matches: FrozenSet[Pair]
    metrics: Optional[ERMetrics] = None
    resilience: Optional[ResilienceStats] = None  # summed across passes
    trace: Optional[object] = None  # repro.obs.TraceReport spanning every
    #                                 pass (ERConfig.trace=True)

    @property
    def pairs(self) -> FrozenSet[Pair]:
        """The union blocked pair set — sugar for blocking.pairs."""
        return self.blocking.pairs

    def pass_result(self, name: str) -> ERResult:
        """The single-pass ERResult for the pass named ``name``."""
        try:
            return self.passes[self.pass_names.index(name)]
        except ValueError:
            raise KeyError(f"no pass named {name!r}; passes: "
                           f"{self.pass_names}") from None


# -- pair extraction (band mask -> host pairs) --------------------------------------

def packed_pairs_from_idx(part: dict, field: str = "match") -> np.ndarray:
    """Device-emitted packed indices -> deduplicated packed pair array.

    ``part``: stacked per-shard output with ``eid`` (r, M) plus the emitted
    buffers ``<field>_idx`` (r, cap) int32 flat band indices ``(d-1)*M+i``
    and ``<field>_n`` (r,) valid counts (window.emit_band_indices).  Eid
    translation is vectorized: one mask + two fancy gathers + ``np.unique``
    over ~cap slots instead of an O(r*w*M) band scan."""
    eid = np.asarray(part["eid"] if "eid" in part
                     else part["ents"]["eid"])            # (r, M)
    idx = np.asarray(part[field + "_idx"])                # (r, cap)
    cnt = np.asarray(part[field + "_n"]).reshape(-1)      # (r,)
    m = eid.shape[1]
    keep = np.arange(idx.shape[1])[None, :] < cnt[:, None]
    ss, pp = np.nonzero(keep)
    if ss.size == 0:
        return np.empty((0,), PACKED_DTYPE)
    flat = idx[ss, pp].astype(np.int64)
    d = flat // m + 1
    i = flat % m
    a = eid[ss, i]
    b = eid[ss, i + d]              # in-bounds: band masks force i + d < M
    return np.unique(pack_pairs(a, b))


def packed_pairs_from_part(part: dict, field: str = "match") -> np.ndarray:
    """Collect a part through whichever representation it carries:
    device-emitted index buffers (emit="pairs") or boolean bands."""
    if field + "_idx" in part:
        return packed_pairs_from_idx(part, field)
    return packed_pairs_from_band(part, field)


def packed_pairs_from_band(part: dict, field: str = "match") -> np.ndarray:
    """Vectorized band -> deduplicated packed pair array (the hot host path).

    ``part``: stacked per-shard output dict with ``ents`` (eid: (r, M)) and a
    boolean band ``field`` of shape (r, w-1, M); band[s, d-1, i] pairs slot i
    with slot i+d of shard s.  One batched nonzero + pack + ``np.unique`` —
    no Python pair objects anywhere on the path."""
    eid = np.asarray(part["ents"]["eid"])                 # (r, M)
    band = np.asarray(part[field])                        # (r, w-1, M)
    ss, ds, iis = np.nonzero(band)
    if ss.size == 0:
        return np.empty((0,), PACKED_DTYPE)
    a = eid[ss, iis]
    b = eid[ss, iis + ds + 1]       # in-bounds: masks force i + d < M
    return np.unique(pack_pairs(a, b))


def pairs_from_band(part: dict, field: str = "match") -> Set[Pair]:
    """Band -> Python pair set.  Kept as the public/reference surface (and
    the benchmark baseline); the collection hot path is
    ``packed_pairs_from_band``."""
    eid = np.asarray(part["ents"]["eid"])                 # (r, M)
    band = np.asarray(part[field])                        # (r, w-1, M)
    ss, ds, iis = np.nonzero(band)
    if ss.size == 0:
        return set()
    a = eid[ss, iis]
    b = eid[ss, iis + ds + 1]       # in-bounds: masks force i + d < M
    lo = np.minimum(a, b)
    hi = np.maximum(a, b)
    return set(zip(lo.tolist(), hi.tolist()))


def compute_metrics(blocked: FrozenSet[Pair], oracle: Set[Pair],
                    total_comparisons: int) -> ERMetrics:
    """Standard blocking-quality metrics of ``blocked`` against the
    sequential-SN ``oracle`` pair set: reduction ratio = 1 − |blocked| /
    ``total_comparisons`` (the full comparison space) and pairs
    completeness = |blocked ∩ oracle| / |oracle| (1.0 when no oracle pair
    was lost; degenerate inputs score 1.0 by convention)."""
    n_oracle = len(oracle)
    pc = 1.0 if n_oracle == 0 else len(blocked & oracle) / n_oracle
    rr = 1.0 if total_comparisons <= 0 else \
        1.0 - len(blocked) / total_comparisons
    return ERMetrics(reduction_ratio=rr, pairs_completeness=pc,
                     oracle_pairs=n_oracle,
                     total_comparisons=total_comparisons)
