"""Typed result objects + host-side pair extraction.

Replaces the raw nested dicts the old pipeline returned: results carry the
pair sets, per-shard load, overflow accounting, and (optionally) blocking
quality metrics computed against the sequential oracle.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet, NamedTuple, Optional, Set, Tuple

import numpy as np

Pair = Tuple[int, int]


class CollectedPairs(NamedTuple):
    blocked: FrozenSet[Pair]
    matched: FrozenSet[Pair]


@dataclass(frozen=True)
class ERMetrics:
    """Blocking quality vs the sequential-SN oracle (the standard blocking
    metrics; the paper reports |B| and completeness of the variants).

    reduction_ratio     1 - |blocked| / |all comparable pairs|
    pairs_completeness  |blocked ∩ oracle| / |oracle|
    """
    reduction_ratio: float
    pairs_completeness: float
    oracle_pairs: int
    total_comparisons: int


@dataclass(frozen=True)
class BlockingResult:
    """Outcome of the blocking stage (candidate generation)."""
    pairs: FrozenSet[Pair]          # blocked (candidate) pairs, (lo, hi) eids
    load: Tuple[int, ...]           # per-shard valid counts (skew telemetry)
    overflow: int                   # entities dropped by capacity limits
    variant: str
    runner: str
    window: int
    num_shards: int

    @property
    def max_load(self) -> int:
        return max(self.load) if self.load else 0

    @property
    def total_load(self) -> int:
        return sum(self.load)


@dataclass(frozen=True)
class ERResult:
    """Full entity-resolution outcome: blocking + matching (+ metrics)."""
    blocking: BlockingResult
    matches: FrozenSet[Pair]        # matcher-accepted pairs
    metrics: Optional[ERMetrics] = None

    @property
    def pairs(self) -> FrozenSet[Pair]:
        return self.blocking.pairs


# -- pair extraction (band mask -> host pair set) --------------------------------

def pairs_from_band(part: dict, field: str = "match") -> Set[Pair]:
    """Vectorized band -> pair-set conversion.

    ``part``: stacked per-shard output dict with ``ents`` (eid: (r, M)) and a
    boolean band ``field`` of shape (r, w-1, M); band[s, d-1, i] pairs slot i
    with slot i+d of shard s.  One batched nonzero + fancy indexing replaces
    the old per-shard Python loops (the host-side bottleneck at large n*r)."""
    eid = np.asarray(part["ents"]["eid"])                 # (r, M)
    band = np.asarray(part[field])                        # (r, w-1, M)
    ss, ds, iis = np.nonzero(band)
    if ss.size == 0:
        return set()
    a = eid[ss, iis]
    b = eid[ss, iis + ds + 1]       # in-bounds: masks force i + d < M
    lo = np.minimum(a, b)
    hi = np.maximum(a, b)
    return set(zip(lo.tolist(), hi.tolist()))


def compute_metrics(blocked: FrozenSet[Pair], oracle: Set[Pair],
                    total_comparisons: int) -> ERMetrics:
    n_oracle = len(oracle)
    pc = 1.0 if n_oracle == 0 else len(blocked & oracle) / n_oracle
    rr = 1.0 if total_comparisons <= 0 else \
        1.0 - len(blocked) / total_comparisons
    return ERMetrics(reduction_ratio=rr, pairs_completeness=pc,
                     oracle_pairs=n_oracle,
                     total_comparisons=total_comparisons)
