"""``resolve`` / ``link`` — the facade tying config, variants, runners and
results together.

    res = api.resolve(ents, api.ERConfig(variant="jobsn", runner="vmap"))
    linked = api.link(ents_r, ents_s, api.ERConfig(window=6))

Shard boundaries come from the ``repro.balance`` planning subsystem:
``cfg.partitioner`` names either a legacy boundary derivation (balanced |
range | sample) or a profile-backed planner (uniform | blocksplit |
pairrange), and ``resolve`` builds the ``ShardPlan`` automatically —
profile -> plan -> execute, with planned-vs-realized load reported on
``ERResult.balance``.  Explicit ``bounds`` (a raw array or a prebuilt
ShardPlan) always win.
"""
from __future__ import annotations

import numpy as np

from repro import balance as B
from repro.api import linkage as LK
from repro.api.config import ERConfig
from repro.api.results import (BalanceMetrics, BlockingResult, ERResult,
                               PerfStats, compute_metrics)
from repro.api.runners import (Runner, SequentialRunner, ShardMapRunner,
                               VmapRunner)
from repro.core import sn
from repro.perf import cache as PC


def make_runner(cfg: ERConfig, *, mesh=None, axis: str = "data") -> Runner:
    """Instantiate the runner named by ``cfg.runner``."""
    if cfg.runner == "sequential":
        return SequentialRunner(num_shards=cfg.num_shards)
    if cfg.runner == "vmap":
        return VmapRunner(num_shards=cfg.num_shards)
    if cfg.runner == "shard_map":
        return ShardMapRunner(mesh=mesh, axis=axis)
    raise ValueError(f"unknown runner {cfg.runner!r}")


def default_bounds(ents: dict, cfg: ERConfig, r: int):
    """Derive partition boundaries per ``cfg.partitioner`` from the data.

    Kept as the key-bounds view of ``balance.plan_shards``; rank-granular
    planners (blocksplit splits, pairrange) carry per-entity routing that a
    bare boundary array cannot express — pass the ShardPlan itself to
    ``resolve(..., bounds=plan)`` to preserve it."""
    return B.plan_shards(ents, cfg, r).bounds


def _total_comparisons(ents: dict, cfg: ERConfig) -> int:
    """Comparison-space size for the reduction ratio: all valid pairs, or
    R x S cross-source pairs in linkage mode."""
    valid = np.asarray(ents["valid"])
    if cfg.linkage and "src" in ents["payload"]:
        src = np.asarray(ents["payload"]["src"])[valid]
        n_r = int((src == 0).sum())
        return n_r * (len(src) - n_r)
    n = int(valid.sum())
    return n * (n - 1) // 2


def _host_oracle(ents: dict, cfg: ERConfig):
    """Sequential-SN oracle pair set (cross-source-filtered in linkage
    mode)."""
    valid = np.asarray(ents["valid"])
    keys = np.asarray(ents["key"])[valid]
    eids = np.asarray(ents["eid"])[valid]
    if cfg.linkage and "src" in ents["payload"]:
        src = np.asarray(ents["payload"]["src"])[valid]
        return LK.sequential_link_pairs(keys, eids, src, cfg.window)
    return sn.sequential_sn_pairs(keys, eids, cfg.window)


def _balance_metrics(plan: B.ShardPlan, out, window: int):
    """Planned vs realized shard load (both sides through the one cost
    model in ``balance.planners``)."""
    if plan.planned_comparisons is None:
        return None
    realized_comp = B.realized_comparisons(out.load, window)
    return BalanceMetrics(
        partitioner=plan.partitioner,
        planned_load=tuple(int(x) for x in plan.planned_load),
        realized_load=tuple(int(x) for x in out.load),
        planned_comparisons=tuple(int(x) for x in plan.planned_comparisons),
        realized_comparisons=tuple(int(x) for x in realized_comp),
        imbalance_planned=plan.imbalance,
        imbalance_realized=B.imbalance_ratio(realized_comp),
        straggler_shard=int(np.argmax(realized_comp)),
        halo_entities=int(np.asarray(plan.halo).sum()),
        cap_link=plan.cap_link)


def resolve(ents: dict, cfg: ERConfig, *, bounds=None, mesh=None,
            axis: str = "data") -> ERResult:
    """Run the configured ER pipeline over one entity set.

    ``bounds``: explicit partition boundaries ((r-1,) int32) or a
    ``repro.balance.ShardPlan``; planned from ``cfg.partitioner`` when
    omitted.  ``mesh``/``axis`` only matter for the shard_map runner
    (default: all local devices on a 1-D mesh)."""
    runner = make_runner(cfg, mesh=mesh, axis=axis)
    n_valid = int(np.asarray(ents["valid"]).sum())
    if bounds is None:
        if 0 < n_valid < runner.shards:
            # planning more shards than entities: every extra shard is
            # guaranteed empty and halo-hop assumptions quietly break
            raise ValueError(
                f"num_shards={runner.shards} exceeds the entity count "
                f"({n_valid} valid entities); lower num_shards (or shrink "
                f"the mesh) so every shard can hold at least one entity")
        plan = B.plan_shards(ents, cfg, runner.shards)
    else:
        plan = B.as_plan(bounds)
        if cfg.runner != "sequential" and plan.num_shards != runner.shards:
            # SRP routes each entity to partition index == shard index; a
            # mismatch would silently drop everything past the last shard.
            raise ValueError(
                f"bounds define {plan.num_shards} partitions but the "
                f"{runner.name} runner has {runner.shards} shards")
        # the sequential runner takes its partition count from the plan, so
        # validate against that (cfg.num_shards is not used there)
        if 0 < n_valid < plan.num_shards:
            raise ValueError(
                f"bounds define {plan.num_shards} partitions but only "
                f"{n_valid} valid entities exist; use fewer partitions")
    cache = PC.executable_cache()
    h0, m0, t0 = cache.stats.snapshot()
    out = runner.resolve(ents, plan, cfg)
    h1, m1, t1 = cache.stats.snapshot()
    perf = PerfStats(cache_hits=h1 - h0, cache_misses=m1 - m0,
                     traces=t1 - t0, cache_entries=len(cache))

    blocking = BlockingResult(pairs=out.blocked, load=out.load,
                              overflow=out.overflow, variant=cfg.variant,
                              runner=runner.name, window=cfg.window,
                              num_shards=out.num_shards,
                              cand_count=out.cand_count,
                              cand_overflow=out.cand_overflow,
                              matcher_evals=out.matcher_evals,
                              pair_overflow=out.pair_overflow)
    balance = _balance_metrics(plan, out, cfg.window)
    metrics = None
    if cfg.compute_metrics:
        from dataclasses import replace

        from repro.api.variants import get_variant
        if cfg.runner == "sequential" and \
                get_variant(cfg.variant).boundary_complete:
            oracle = set(out.blocked)     # already the full SN oracle
        else:
            oracle = _host_oracle(ents, cfg)
        metrics = replace(
            compute_metrics(out.blocked, oracle,
                            _total_comparisons(ents, cfg)),
            balance=balance)
    return ERResult(blocking=blocking, matches=out.matched, metrics=metrics,
                    balance=balance, perf=perf)


def link(lhs: dict, rhs: dict, cfg: ERConfig, *, bounds=None, mesh=None,
         axis: str = "data") -> ERResult:
    """Dual-source linkage R x S: blocked/matched pairs are CROSS-SOURCE
    only, returned as (lhs_eid, rhs_eid) tuples in each source's original id
    space.  Both sources must share the same payload schema."""
    cfg = cfg.with_(linkage=True)
    ents, offset = LK.tag_sources(lhs, rhs)
    res = resolve(ents, cfg, bounds=bounds, mesh=mesh, axis=axis)
    b = res.blocking
    blocking = BlockingResult(
        pairs=frozenset(LK.untag_pairs(b.pairs, offset)), load=b.load,
        overflow=b.overflow, variant=b.variant, runner=b.runner,
        window=b.window, num_shards=b.num_shards, cand_count=b.cand_count,
        cand_overflow=b.cand_overflow, matcher_evals=b.matcher_evals,
        pair_overflow=b.pair_overflow)
    return ERResult(blocking=blocking,
                    matches=frozenset(LK.untag_pairs(res.matches, offset)),
                    metrics=res.metrics, balance=res.balance, perf=res.perf)
