"""``resolve`` / ``link`` — the facade tying config, variants, runners and
results together.

    res = api.resolve(ents, api.ERConfig(variant="jobsn", runner="vmap"))
    linked = api.link(ents_r, ents_s, api.ERConfig(window=6))

Shard boundaries come from the ``repro.balance`` planning subsystem:
``cfg.partitioner`` names either a legacy boundary derivation (balanced |
range | sample) or a profile-backed planner (uniform | blocksplit |
pairrange), and ``resolve`` builds the ``ShardPlan`` automatically —
profile -> plan -> execute, with planned-vs-realized load reported on
``ERResult.balance``.  Explicit ``bounds`` (a raw array or a prebuilt
ShardPlan) always win.
"""
from __future__ import annotations

from dataclasses import replace as _replace

import numpy as np

from repro import balance as B
from repro import obs as OBS
from repro.api import linkage as LK
from repro.api.config import ERConfig
from repro.api.results import (BalanceMetrics, BlockingResult, ERResult,
                               MultiPassResult, PerfStats, compute_metrics)
from repro.api.runners import (Runner, SequentialRunner, ShardMapRunner,
                               VmapRunner)
from repro.core import keys as K
from repro.core import sn
from repro.perf import cache as PC
from repro.resilience import retry as RZ


def make_runner(cfg: ERConfig, *, mesh=None, axis: str = "data") -> Runner:
    """Instantiate the runner named by ``cfg.runner``."""
    if cfg.runner == "sequential":
        return SequentialRunner(num_shards=cfg.num_shards)
    if cfg.runner == "vmap":
        return VmapRunner(num_shards=cfg.num_shards)
    if cfg.runner == "shard_map":
        return ShardMapRunner(mesh=mesh, axis=axis)
    raise ValueError(f"unknown runner {cfg.runner!r}")


def default_bounds(ents: dict, cfg: ERConfig, r: int):
    """Derive partition boundaries per ``cfg.partitioner`` from the data.

    Kept as the key-bounds view of ``balance.plan_shards``; rank-granular
    planners (blocksplit splits, pairrange) carry per-entity routing that a
    bare boundary array cannot express — pass the ShardPlan itself to
    ``resolve(..., bounds=plan)`` to preserve it."""
    return B.plan_shards(ents, cfg, r).bounds


def _total_comparisons(ents: dict, cfg: ERConfig) -> int:
    """Comparison-space size for the reduction ratio: all valid pairs, or
    R x S cross-source pairs in linkage mode."""
    valid = np.asarray(ents["valid"])
    if cfg.linkage and "src" in ents["payload"]:
        src = np.asarray(ents["payload"]["src"])[valid]
        n_r = int((src == 0).sum())
        return n_r * (len(src) - n_r)
    n = int(valid.sum())
    return n * (n - 1) // 2


def _host_oracle(ents: dict, cfg: ERConfig):
    """Sequential-SN oracle pair set (cross-source-filtered in linkage
    mode).  Adaptive-window runs get the adaptive oracle: the attached
    ``_weff`` when the entity set already carries one, else weff recomputed
    from the key profile (the same pure function the device path uses)."""
    valid = np.asarray(ents["valid"])
    keys = np.asarray(ents["key"])[valid]
    eids = np.asarray(ents["eid"])[valid]
    if cfg.linkage and "src" in ents["payload"]:
        src = np.asarray(ents["payload"]["src"])[valid]
        return LK.sequential_link_pairs(keys, eids, src, cfg.window)
    weff = None
    if "_weff" in ents["payload"]:
        weff = np.asarray(ents["payload"]["_weff"])[valid]
    elif cfg.window_policy == "adaptive":
        from repro import quality as Q
        profile = B.profile_keys(keys, window=cfg.window)
        weff = Q.weff_for_keys(keys, profile, cfg.window, cfg.window_max)
    if weff is not None:
        return sn.adaptive_sn_pairs(keys, eids, weff)
    return sn.sequential_sn_pairs(keys, eids, cfg.window)


def _adaptive_rewrite(ents: dict, cfg: ERConfig):
    """Realize ``window_policy="adaptive"`` (DESIGN.md §14): attach the
    per-entity effective windows as a traced ``_weff`` payload field (a
    pure function of the global key profile, so it rides every shuffle /
    halo / chunking) and rewrite ``window`` to ``window_max`` — the ONE
    width the band program compiles at.  ``window_policy``/``window_max``
    stay set (the validation invariant holds at equality), so downstream
    code can still see the run is adaptive."""
    import jax.numpy as jnp

    from repro import quality as Q
    profile = B.profile_keys(ents["key"], window=cfg.window,
                             valid=ents["valid"])
    weff = Q.weff_for_keys(np.asarray(ents["key"]), profile, cfg.window,
                           cfg.window_max)
    ents = dict(ents, payload=dict(ents["payload"],
                                   _weff=jnp.asarray(weff, jnp.int32)))
    return ents, cfg.with_(window=cfg.window_max)


def _balance_metrics(plan: B.ShardPlan, out, window: int):
    """Planned vs realized shard load (both sides through the one cost
    model in ``balance.planners``)."""
    if plan.planned_comparisons is None:
        return None
    realized_comp = B.realized_comparisons(out.load, window)
    return BalanceMetrics(
        partitioner=plan.partitioner,
        planned_load=tuple(int(x) for x in plan.planned_load),
        realized_load=tuple(int(x) for x in out.load),
        planned_comparisons=tuple(int(x) for x in plan.planned_comparisons),
        realized_comparisons=tuple(int(x) for x in realized_comp),
        imbalance_planned=plan.imbalance,
        imbalance_realized=B.imbalance_ratio(realized_comp),
        straggler_shard=int(np.argmax(realized_comp)),
        halo_entities=int(np.asarray(plan.halo).sum()),
        cap_link=plan.cap_link)


def attach_trace(res, tracer):
    """Capture ``tracer`` as a ``TraceReport`` and attach it to ``res``
    (ERResult / MultiPassResult / StreamResult — whichever of the legacy
    stats fields the result carries ride into the unified schema).  Pair/
    match gauges are stamped here so every report answers pairs-per-second
    without consulting the result object."""
    from dataclasses import replace
    m = tracer.metrics
    m.gauge("pairs").set(len(res.blocking.pairs))
    m.gauge("matches").set(len(res.matches))
    stats = [getattr(res, f, None)
             for f in ("balance", "perf", "stream", "resilience")]
    return replace(res, trace=OBS.TraceReport.from_tracer(tracer, stats))


def resolve(ents: dict, cfg: ERConfig, *, bounds=None, mesh=None,
            axis: str = "data"):
    """Run the configured ER pipeline over one entity set.

    ``bounds``: explicit partition boundaries ((r-1,) int32) or a
    ``repro.balance.ShardPlan``; planned from ``cfg.partitioner`` when
    omitted.  ``mesh``/``axis`` only matter for the shard_map runner
    (default: all local devices on a 1-D mesh).

    Returns an ``ERResult`` — or, when ``cfg.passes`` selects multi-pass
    blocking, a ``MultiPassResult`` holding the per-pass ERResults plus the
    union pair sets.  Under ``cfg.trace`` the result additionally carries a
    ``repro.obs.TraceReport`` (``result.trace``) — unless a tracer is
    already active on this thread, in which case the call contributes its
    spans to that outer trace instead (multi-pass passes, stream chunks)."""
    if cfg.trace and OBS.current_tracer() is None:
        tracer = OBS.Tracer()
        with OBS.activate(tracer), OBS.span(
                "resolve", variant=cfg.variant, runner=cfg.runner,
                window=cfg.window):
            res = _resolve(ents, cfg, bounds=bounds, mesh=mesh, axis=axis)
        return attach_trace(res, tracer)
    return _resolve(ents, cfg, bounds=bounds, mesh=mesh, axis=axis)


def _resolve(ents: dict, cfg: ERConfig, *, bounds=None, mesh=None,
             axis: str = "data"):
    """``resolve`` minus trace ownership (the body every caller shares)."""
    if cfg.passes:
        return _resolve_multipass(ents, cfg, bounds=bounds, mesh=mesh,
                                  axis=axis)
    if cfg.window_policy == "adaptive":
        ents, cfg = _adaptive_rewrite(ents, cfg)
    runner = make_runner(cfg, mesh=mesh, axis=axis)
    n_valid = int(np.asarray(ents["valid"]).sum())
    with OBS.span("plan", partitioner=cfg.partitioner, n=n_valid):
        if bounds is None:
            if 0 < n_valid < runner.shards:
                # planning more shards than entities: every extra shard is
                # guaranteed empty and halo-hop assumptions quietly break
                raise ValueError(
                    f"num_shards={runner.shards} exceeds the entity count "
                    f"({n_valid} valid entities); lower num_shards (or "
                    f"shrink the mesh) so every shard can hold at least "
                    f"one entity")
            plan = B.plan_shards(ents, cfg, runner.shards)
        else:
            plan = B.as_plan(bounds)
            if cfg.runner != "sequential" \
                    and plan.num_shards != runner.shards:
                # SRP routes each entity to partition index == shard index;
                # a mismatch would silently drop everything past the last
                # shard.
                raise ValueError(
                    f"bounds define {plan.num_shards} partitions but the "
                    f"{runner.name} runner has {runner.shards} shards")
            # the sequential runner takes its partition count from the
            # plan, so validate against that (cfg.num_shards is unused
            # there)
            if 0 < n_valid < plan.num_shards:
                raise ValueError(
                    f"bounds define {plan.num_shards} partitions but only "
                    f"{n_valid} valid entities exist; use fewer partitions")
        # unset (None) caps resolve from the plan's profiled loads when the
        # partitioner is profile-backed; legacy/raw-bounds plans fall back
        # to the historical unbounded semantics (DESIGN.md §11)
        cfg, auto_caps = RZ.autosize_caps(cfg, plan=plan)
    cache = PC.executable_cache()
    before = cache.stats.snapshot()

    def _attempt(c: ERConfig, attempt: int):
        # retries lift the plan's EXACT cap_link: it was sized for the
        # planned loads the overflow just disproved, and cfg.cap_factor
        # (doubled by the ladder) takes over as the shuffle capacity
        p = plan if attempt == 0 or plan.cap_link is None \
            else _replace(plan, cap_link=None)
        return runner.resolve(ents, p, c)

    with OBS.span("execute", runner=runner.name, shards=runner.shards):
        out, run_cfg, retries, escalations = \
            RZ.run_with_recovery(_attempt, cfg)
    dh, dm, dt = cache.stats.delta(before)
    perf = PerfStats(cache_hits=dh, cache_misses=dm, traces=dt,
                     cache_entries=len(cache))
    resilience = RZ.ResilienceStats(
        policy=cfg.on_overflow, retries=retries, escalations=escalations,
        cand_cap=run_cfg.cand_cap or 0, pair_cap=run_cfg.pair_cap or 0,
        auto_caps=auto_caps)

    blocking = BlockingResult(pairs=out.blocked, load=out.load,
                              overflow=out.overflow, variant=cfg.variant,
                              runner=runner.name, window=cfg.window,
                              num_shards=out.num_shards,
                              cand_count=out.cand_count,
                              cand_overflow=out.cand_overflow,
                              matcher_evals=out.matcher_evals,
                              pair_overflow=out.pair_overflow,
                              pruned=out.pruned)
    balance = _balance_metrics(plan, out, cfg.window)
    metrics = None
    if cfg.compute_metrics:
        from dataclasses import replace

        from repro.api.variants import get_variant
        with OBS.span("metrics"):
            # a pruned run's blocked set is NOT the oracle (pruning is the
            # point); only the unpruned sequential boundary-complete result
            # doubles as its own oracle
            if cfg.runner == "sequential" and \
                    cfg.prune_policy == "off" and \
                    get_variant(cfg.variant).boundary_complete:
                oracle = set(out.blocked)     # already the full SN oracle
            else:
                oracle = _host_oracle(ents, cfg)
            metrics = replace(
                compute_metrics(out.blocked, oracle,
                                _total_comparisons(ents, cfg)),
                balance=balance, resilience=resilience)
    return ERResult(blocking=blocking, matches=out.matched, metrics=metrics,
                    balance=balance, perf=perf, resilience=resilience)


def _rekeyed(ents: dict, spec) -> dict:
    """Entity set with its sort key replaced by ``spec``'s derivation (the
    per-pass view multi-pass blocking resolves; payload/eid/valid shared)."""
    return {"key": K.derive_sort_key(ents, spec), "eid": ents["eid"],
            "valid": ents["valid"], "payload": ents["payload"]}


def union_blocking(results, cfg, runner_name: str) -> BlockingResult:
    """Union BlockingResult across passes: pair union + additive accounting
    (``load`` stays empty — per-pass shard loads live on the pass results).
    ``results`` is any sequence of objects carrying ``.blocking`` — the ONE
    implementation behind both ``MultiPassResult`` (here) and the streaming
    union (``repro.stream``), so a counter added to BlockingResult
    aggregates identically in both."""
    union = frozenset().union(*(r.blocking.pairs for r in results))
    return BlockingResult(
        pairs=union, load=(),
        overflow=sum(r.blocking.overflow for r in results),
        variant=cfg.variant, runner=runner_name, window=cfg.window,
        num_shards=results[0].blocking.num_shards,
        cand_overflow=sum(r.blocking.cand_overflow for r in results),
        matcher_evals=sum(r.blocking.matcher_evals for r in results),
        pair_overflow=sum(r.blocking.pair_overflow for r in results),
        pruned=sum(r.blocking.pruned for r in results))


def _resolve_multipass(ents: dict, cfg: ERConfig, *, bounds, mesh,
                       axis: str) -> MultiPassResult:
    """One full single-pass resolve per SortKeySpec + the pair-set union.

    Explicit ``bounds`` are rejected: boundaries live in ONE key space and
    each pass sorts by a different derived key — per-pass boundaries are
    planned from ``cfg.partitioner`` instead.

    When metrics are requested, each pass's sequential host oracle is
    computed ONCE here (per-pass resolves run metric-less) and serves both
    the pass's own metrics and the union metrics — the O(n·w) host oracle
    is the dominant metrics cost and must not be paid twice per pass."""
    if bounds is not None:
        raise ValueError(
            "explicit bounds cannot be shared across multi-pass sort keys "
            "(each pass sorts by a different derived key); drop bounds and "
            "let cfg.partitioner plan each pass, or run passes manually")
    from dataclasses import replace

    sub = cfg.with_(passes=(), compute_metrics=False)
    results = []
    union_oracle: set = set()
    for spec in cfg.passes:
        with OBS.span("pass", name=spec.name, kind=spec.kind):
            pents = _rekeyed(ents, spec)
            res = resolve(pents, sub, mesh=mesh, axis=axis)
            if cfg.compute_metrics:
                with OBS.span("metrics"):
                    oracle = _host_oracle(pents, sub)
                    union_oracle |= oracle
                    res = replace(res, metrics=replace(
                        compute_metrics(res.blocking.pairs, oracle,
                                        _total_comparisons(ents, cfg)),
                        balance=res.balance))
        results.append(res)
    results = tuple(results)
    matches = frozenset().union(*(r.matches for r in results))
    blocking = union_blocking(results, cfg, results[0].blocking.runner)
    metrics = None
    if cfg.compute_metrics:
        metrics = compute_metrics(blocking.pairs, union_oracle,
                                  _total_comparisons(ents, cfg))
    rz = [r.resilience for r in results if r.resilience is not None]
    resilience = None if not rz else RZ.ResilienceStats(
        policy=rz[0].policy,
        retries=sum(x.retries for x in rz),
        escalations=sum(x.escalations for x in rz),
        cand_cap=max(x.cand_cap for x in rz),
        pair_cap=max(x.pair_cap for x in rz),
        auto_caps=any(x.auto_caps for x in rz))
    return MultiPassResult(passes=results,
                           pass_names=tuple(p.name for p in cfg.passes),
                           blocking=blocking, matches=matches,
                           metrics=metrics, resilience=resilience)


def _untag_blocking(b: BlockingResult, offset: int) -> BlockingResult:
    """Map a BlockingResult's pairs from the merged linkage eid space back
    to (lhs_eid, rhs_eid); every other field is carried through (``replace``
    so future counters survive without touching this code)."""
    from dataclasses import replace
    return replace(b, pairs=frozenset(LK.untag_pairs(b.pairs, offset)))


def link(lhs: dict, rhs: dict, cfg: ERConfig, *, bounds=None, mesh=None,
         axis: str = "data"):
    """Dual-source linkage R x S: blocked/matched pairs are CROSS-SOURCE
    only, returned as (lhs_eid, rhs_eid) tuples in each source's original id
    space.  Both sources must share the same payload schema.

    Returns an ``ERResult`` (or ``MultiPassResult`` under ``cfg.passes``,
    with union and per-pass pairs all mapped back to source id spaces)."""
    cfg = cfg.with_(linkage=True)
    ents, offset = LK.tag_sources(lhs, rhs)
    res = resolve(ents, cfg, bounds=bounds, mesh=mesh, axis=axis)
    if isinstance(res, MultiPassResult):
        passes = tuple(
            ERResult(blocking=_untag_blocking(r.blocking, offset),
                     matches=frozenset(LK.untag_pairs(r.matches, offset)),
                     metrics=r.metrics, balance=r.balance, perf=r.perf,
                     resilience=r.resilience, trace=r.trace)
            for r in res.passes)
        return MultiPassResult(
            passes=passes, pass_names=res.pass_names,
            blocking=_untag_blocking(res.blocking, offset),
            matches=frozenset(LK.untag_pairs(res.matches, offset)),
            metrics=res.metrics, resilience=res.resilience,
            trace=res.trace)
    return ERResult(blocking=_untag_blocking(res.blocking, offset),
                    matches=frozenset(LK.untag_pairs(res.matches, offset)),
                    metrics=res.metrics, balance=res.balance, perf=res.perf,
                    resilience=res.resilience, trace=res.trace)


def serve(cfg: ERConfig, *, initial=None, **kwargs):
    """Start an online incremental ``repro.serve.ResolutionService`` under
    ``cfg`` (single-pass, non-linkage configs only): inserts and deletes
    arrive as micro-batches, and the served pair sets stay bit-identical
    to a from-scratch ``resolve`` over the live corpus at every point.
    ``initial`` seeds the corpus through the same insert path; remaining
    kwargs (``max_batch``, ``max_wait_ms``, ``spool_dir``, ...) are
    forwarded to the service constructor.

    Overload policy (DESIGN.md §13): pass ``admission=AdmissionConfig(...)``
    to pick the queue policy (``block`` | ``reject`` | ``shed_oldest``),
    per-request deadlines, the brownout watermarks, and the stuck-batch
    watchdog.  Under brownout the bit-parity invariant relaxes to
    EVENTUALLY-exact: blocked pairs stay exact, new matches may be
    deferred, and ``repair()`` (run automatically when the queue drains)
    restores full parity.  ``chaos=ChaosPlan(...)`` injects deterministic
    latency/stall/error disturbances at exact batch indices — the overload
    test harness, never set in production."""
    if cfg.window_policy == "adaptive":
        # the incremental profile changes with every insert/delete, so weff
        # would vary over time and served pair sets could never stay
        # bit-identical to a from-scratch resolve
        raise ValueError(
            "window_policy='adaptive' is not servable: per-entity windows "
            "derive from the full-corpus key profile, which is incremental "
            "(time-varying) in the serve path; use a fixed window")
    from repro.serve import ResolutionService
    return ResolutionService(cfg, initial=initial, **kwargs)


def resume(checkpoint_dir: str, *, chunks=None, cfg: ERConfig = None,
           mesh=None, axis: str = "data"):
    """Resume a checkpointed ``stream.resolve_stream(checkpoint_dir=...)``
    run killed mid-flight (DESIGN.md §11): continues at the last committed
    chunk and returns the same ``StreamResult`` — bit-identical pair union
    — an uninterrupted run would have produced.

    The config is rebuilt from the checkpoint manifest; pass ``cfg`` only
    when the original run used a non-default matcher (it is validated
    against the stored fingerprint).  ``chunks`` re-supplies the original
    deterministic chunk iterator and is required only when the run died
    during ingest."""
    from repro.resilience.checkpoint import resume_stream
    return resume_stream(checkpoint_dir, chunks=chunks, cfg=cfg, mesh=mesh,
                         axis=axis)
