"""``resolve`` / ``link`` — the facade tying config, variants, runners and
results together.

    res = api.resolve(ents, api.ERConfig(variant="jobsn", runner="vmap"))
    linked = api.link(ents_r, ents_s, api.ERConfig(window=6))
"""
from __future__ import annotations

import numpy as np

from repro.api import linkage as LK
from repro.api.config import ERConfig
from repro.api.results import (BlockingResult, ERResult, compute_metrics)
from repro.api.runners import (Runner, SequentialRunner, ShardMapRunner,
                               VmapRunner)
from repro.core import partition as P
from repro.core import sn


def make_runner(cfg: ERConfig, *, mesh=None, axis: str = "data") -> Runner:
    """Instantiate the runner named by ``cfg.runner``."""
    if cfg.runner == "sequential":
        return SequentialRunner(num_shards=cfg.num_shards)
    if cfg.runner == "vmap":
        return VmapRunner(num_shards=cfg.num_shards)
    if cfg.runner == "shard_map":
        return ShardMapRunner(mesh=mesh, axis=axis)
    raise ValueError(f"unknown runner {cfg.runner!r}")


def default_bounds(ents: dict, cfg: ERConfig, r: int):
    """Derive partition boundaries per ``cfg.partitioner`` from the data."""
    valid = np.asarray(ents["valid"])
    keys = np.asarray(ents["key"])[valid]
    if keys.size == 0:
        return P.manual_partition(range(1, r)) if r > 1 else \
            P.manual_partition([])
    if cfg.partitioner == "balanced":
        return P.balanced_partition(keys, r)
    if cfg.partitioner == "range":
        return P.range_partition(int(keys.max()) + 1, r)
    if cfg.partitioner == "sample":
        return P.sample_partition(np.sort(keys), r)
    raise ValueError(f"unknown partitioner {cfg.partitioner!r}")


def _total_comparisons(ents: dict, cfg: ERConfig) -> int:
    """Comparison-space size for the reduction ratio: all valid pairs, or
    R x S cross-source pairs in linkage mode."""
    valid = np.asarray(ents["valid"])
    if cfg.linkage and "src" in ents["payload"]:
        src = np.asarray(ents["payload"]["src"])[valid]
        n_r = int((src == 0).sum())
        return n_r * (len(src) - n_r)
    n = int(valid.sum())
    return n * (n - 1) // 2


def _host_oracle(ents: dict, cfg: ERConfig):
    """Sequential-SN oracle pair set (cross-source-filtered in linkage
    mode)."""
    valid = np.asarray(ents["valid"])
    keys = np.asarray(ents["key"])[valid]
    eids = np.asarray(ents["eid"])[valid]
    if cfg.linkage and "src" in ents["payload"]:
        src = np.asarray(ents["payload"]["src"])[valid]
        return LK.sequential_link_pairs(keys, eids, src, cfg.window)
    return sn.sequential_sn_pairs(keys, eids, cfg.window)


def resolve(ents: dict, cfg: ERConfig, *, bounds=None, mesh=None,
            axis: str = "data") -> ERResult:
    """Run the configured ER pipeline over one entity set.

    ``bounds``: explicit partition boundaries ((r-1,) int32); derived from
    ``cfg.partitioner`` when omitted.  ``mesh``/``axis`` only matter for the
    shard_map runner (default: all local devices on a 1-D mesh)."""
    runner = make_runner(cfg, mesh=mesh, axis=axis)
    if bounds is None:
        bounds = default_bounds(ents, cfg, runner.shards)
    elif cfg.runner != "sequential" and \
            int(np.asarray(bounds).shape[0]) + 1 != runner.shards:
        # SRP routes each entity to partition index == shard index; a
        # mismatch would silently drop everything past the last shard.
        raise ValueError(
            f"bounds define {int(np.asarray(bounds).shape[0]) + 1} "
            f"partitions but the {runner.name} runner has {runner.shards} "
            f"shards")
    out = runner.resolve(ents, bounds, cfg)

    blocking = BlockingResult(pairs=out.blocked, load=out.load,
                              overflow=out.overflow, variant=cfg.variant,
                              runner=runner.name, window=cfg.window,
                              num_shards=out.num_shards,
                              cand_count=out.cand_count,
                              cand_overflow=out.cand_overflow,
                              matcher_evals=out.matcher_evals)
    metrics = None
    if cfg.compute_metrics:
        from repro.api.variants import get_variant
        if cfg.runner == "sequential" and \
                get_variant(cfg.variant).boundary_complete:
            oracle = set(out.blocked)     # already the full SN oracle
        else:
            oracle = _host_oracle(ents, cfg)
        metrics = compute_metrics(out.blocked, oracle,
                                  _total_comparisons(ents, cfg))
    return ERResult(blocking=blocking, matches=out.matched, metrics=metrics)


def link(lhs: dict, rhs: dict, cfg: ERConfig, *, bounds=None, mesh=None,
         axis: str = "data") -> ERResult:
    """Dual-source linkage R x S: blocked/matched pairs are CROSS-SOURCE
    only, returned as (lhs_eid, rhs_eid) tuples in each source's original id
    space.  Both sources must share the same payload schema."""
    cfg = cfg.with_(linkage=True)
    ents, offset = LK.tag_sources(lhs, rhs)
    res = resolve(ents, cfg, bounds=bounds, mesh=mesh, axis=axis)
    b = res.blocking
    blocking = BlockingResult(
        pairs=frozenset(LK.untag_pairs(b.pairs, offset)), load=b.load,
        overflow=b.overflow, variant=b.variant, runner=b.runner,
        window=b.window, num_shards=b.num_shards, cand_count=b.cand_count,
        cand_overflow=b.cand_overflow, matcher_evals=b.matcher_evals)
    return ERResult(blocking=blocking,
                    matches=frozenset(LK.untag_pairs(res.matches, offset)),
                    metrics=res.metrics)
