"""ERConfig — the single frozen configuration for an entity-resolution run.

Absorbs the old ``pipeline.SNConfig`` (window / variant / hops / capacity /
matcher) and adds the execution choices that used to live in free-function
signatures: which runner executes the shard program, how many shards, how
boundaries are derived, and whether the run is dual-source linkage.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

from repro.core.match import CascadeMatcher, default_matcher

VARIANTS = ("srp", "repsn", "jobsn")
RUNNERS = ("sequential", "vmap", "shard_map")
PARTITIONERS = ("balanced", "range", "sample")


@dataclass(frozen=True)
class ERConfig:
    """Frozen configuration for ``repro.api.resolve``.

    Blocking / matching (paper §4):
      window       SN window size w (pairs at sorted distance 1..w-1)
      variant      registered variant name: "srp" | "repsn" | "jobsn"
      hops         RepSN halo hops (1 = paper; r-1 = complete for any skew)
      cap_factor   shuffle link capacity = cap0 * cap_factor / r; 0 -> cap0
                   (never overflows)
      matcher      cascade match strategy (paper §5.1 skip optimization)
      return_scores  keep band scores in raw runner output

    Execution:
      runner       "sequential" (host oracle) | "vmap" (single device,
                   named-axis shards) | "shard_map" (real device mesh)
      num_shards   r for sequential/vmap runners (shard_map takes r from
                   its mesh axis)
      partitioner  how default boundaries are derived from the data:
                   "balanced" | "range" | "sample" (explicit ``bounds``
                   passed to resolve() always win)

    Scenario:
      linkage          dual-source R x S mode: only cross-source pairs are
                       blocked/matched (entities carry a "src" payload tag)
      compute_metrics  run the host oracle and attach reduction-ratio /
                       pairs-completeness metrics to the result
    """
    window: int = 10
    variant: str = "repsn"
    hops: int = 1
    cap_factor: float = 0.0
    matcher: CascadeMatcher = field(default_factory=default_matcher)
    return_scores: bool = False

    runner: str = "vmap"
    num_shards: int = 8
    partitioner: str = "balanced"

    linkage: bool = False
    compute_metrics: bool = False

    def __post_init__(self):
        if self.window < 2:
            raise ValueError(f"window must be >= 2, got {self.window}")
        if self.runner not in RUNNERS:
            raise ValueError(f"unknown runner {self.runner!r}; "
                             f"choose from {RUNNERS}")
        if self.partitioner not in PARTITIONERS:
            raise ValueError(f"unknown partitioner {self.partitioner!r}; "
                             f"choose from {PARTITIONERS}")
        if self.num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {self.num_shards}")
        # variant names are validated lazily by the registry (so configs can
        # be built before a plugin variant registers itself)

    def with_(self, **kw) -> "ERConfig":
        """Functional update (dataclasses.replace sugar)."""
        return replace(self, **kw)

    @classmethod
    def from_sn_config(cls, sn_cfg, **kw) -> "ERConfig":
        """Lift an old ``pipeline.SNConfig`` into an ERConfig."""
        return cls(window=sn_cfg.window, variant=sn_cfg.variant,
                   hops=sn_cfg.hops, cap_factor=sn_cfg.cap_factor,
                   matcher=sn_cfg.matcher,
                   return_scores=sn_cfg.return_scores, **kw)
