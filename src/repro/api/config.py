"""ERConfig — the single frozen configuration for an entity-resolution run.

Absorbs the old ``pipeline.SNConfig`` (window / variant / hops / capacity /
matcher) and adds the execution choices that used to live in free-function
signatures: which runner executes the shard program, how many shards, how
boundaries are derived, and whether the run is dual-source linkage.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

from repro.core.match import CascadeMatcher, default_matcher

VARIANTS = ("srp", "repsn", "jobsn")
RUNNERS = ("sequential", "vmap", "shard_map")
# legacy boundary derivations + the repro.balance planner registry
# (uniform | blocksplit | pairrange — profile-backed ShardPlans with
# planned comparison counts, rank-granular splits, and exact capacities)
PARTITIONERS = ("balanced", "range", "sample",
                "uniform", "blocksplit", "pairrange")
BAND_ENGINES = ("scan", "pallas")
EMIT_MODES = ("band", "pairs")
SORT_KEY_KINDS = ("identity", "prefix", "word")
OVERFLOW_POLICIES = ("count", "retry", "raise")
WINDOW_POLICIES = ("fixed", "adaptive")
PRUNE_POLICIES = ("off", "evidence")


@dataclass(frozen=True)
class SortKeySpec:
    """One blocking pass of multi-pass SN: how the sort key is derived.

    Multi-pass Sorted Neighborhood (Papadakis et al., arXiv:1905.06167 —
    the standard recall lever over single-key SN) runs the whole blocking
    workflow once per sort key and unions the pair sets.  A spec names one
    derivation, resolved by ``core.keys.derive_sort_key``:

      kind="identity"  use the entity's own ``key`` field (source="key") or
                       a 1-D integer payload field named by ``source``
      kind="prefix"    pack ``width`` characters of the padded-byte payload
                       field ``source``, starting at ``offset``
                       (``core.keys.prefix_key`` — the paper's "first
                       letters of the title" key family; shifting ``offset``
                       per pass is the classic multi-pass choice)
      kind="word"      column ``index`` of a 2-D integer payload field
                       ``source`` (e.g. one word of the bit-packed trigram
                       signature), masked into the int32 key space

    Derived keys are always non-negative int32 < 2^30 (the entities.py key
    schema).  Specs are frozen/hashable; ``name`` labels the pass in
    ``MultiPassResult``.
    """
    name: str = "key"
    source: str = "key"
    kind: str = "identity"
    offset: int = 0
    width: int = 2
    index: int = 0

    def __post_init__(self):
        if self.kind not in SORT_KEY_KINDS:
            raise ValueError(f"unknown sort-key kind {self.kind!r}; choose "
                             f"from {SORT_KEY_KINDS}")
        if self.kind == "prefix" and not 1 <= self.width <= 5:
            raise ValueError(f"prefix width must be in 1..5 (int32 key "
                             f"space), got {self.width}")
        if self.offset < 0 or self.index < 0:
            raise ValueError("offset/index must be >= 0")
        # parameters that would be silently ignored are rejected — a pass
        # with a mis-applied offset/index quietly derives the WRONG key
        if self.kind != "prefix" and self.offset:
            raise ValueError(f"offset only applies to kind='prefix' "
                             f"(got kind={self.kind!r})")
        if self.kind != "word" and self.index:
            raise ValueError(f"index only applies to kind='word' "
                             f"(got kind={self.kind!r})")


@dataclass(frozen=True)
class ERConfig:
    """Frozen configuration for ``repro.api.resolve``.

    Blocking / matching (paper §4):
      window       SN window size w (pairs at sorted distance 1..w-1)
      variant      registered variant name: "srp" | "repsn" | "jobsn"
      hops         RepSN halo hops (1 = paper; r-1 = complete for any skew)
      cap_factor   shuffle link capacity = cap0 * cap_factor / r; 0 -> cap0
                   (never overflows)
      matcher      cascade match strategy (paper §5.1 skip optimization)
      return_scores  keep band scores in raw runner output

    Band engine (core/window.py — how each shard's window band is evaluated):
      band_engine   "scan" (w-1 shifted full-matcher passes; reference
                    oracle) | "pallas" (fused cheap-band kernel -> cumsum
                    candidate compaction -> expensive matcher on survivors
                    only: the §5.1 cascade with real FLOP savings)
      band_block    Pallas row-block size Bi (band width w-1 must fit:
                    w-1 <= band_block; VMEM grows as band_block^2)
      cand_cap      per-shard survivor capacity of the cascade compaction;
                    0 -> full band (w-1)*M: never overflows, but the
                    expensive stage then scores (and gathers payload for)
                    the whole band — a finite cap is both the FLOP and the
                    memory lever (DESIGN.md §6 sizing rule).  Overflowing
                    candidates are dropped AND counted (cand_overflow in
                    results) — the SRP capacity model applied to matching.
                    None (default) -> auto-sized by ``balance.suggest_caps``
                    from the key profile on the pallas engine (falls back
                    to 0 where no profile-backed plan exists — raw bounds,
                    direct runner calls)
      band_interpret  force the Pallas interpreter on/off; None -> auto
                    (native kernel on TPU; off-TPU the cheap stage runs as
                    a band-shaped jnp evaluation — same math, without the
                    tile kernel's 2*band_block scores per row.  True forces
                    the Pallas interpreter: the kernel-validation path)

    Pair emission (how blocked/matched pairs leave the device):
      emit          "band" (transfer the (w-1, M) boolean bands, extract
                    pairs on host) | "pairs" (compact each band into packed
                    (d-1)*M+i index buffers ON DEVICE via the cumsum
                    machinery; the host consumes small int buffers + per-
                    shard counts — the steady-state transfer path)
      pair_cap      per-shard, per-part capacity of the emitted index
                    buffers; 0 -> (w-1)*M (never overflows).  Overflowing
                    slots are dropped AND counted (pair_overflow in
                    results — blocked pairs CAN be lost here, unlike
                    cand_cap, so size it >= (w-1)*max_load for parity).
                    None (default) -> auto-sized by ``balance.suggest_caps``
                    under emit="pairs" (the profile band bound, which never
                    truncates; falls back to 0 without a profile)

    Overflow recovery (repro.resilience — DESIGN.md §11):
      on_overflow   what a resolve does when a finite capacity actually
                    overflowed (``overflow``/``cand_overflow``/
                    ``pair_overflow`` > 0):
                      "count"  (legacy) keep the truncated result, counters
                               report the drops
                      "retry"  re-execute the affected resolve (or the one
                               overflowing stream chunk) with every
                               overflowed cap doubled, up to ``retry_limit``
                               escalations — doubled caps stay on a
                               power-of-two ladder from the base cap, so
                               retried shapes still bucket into the
                               repro.perf executable cache; a ladder that
                               still overflows raises CapacityOverflowError
                               (never a silent drop)
                      "raise"  raise CapacityOverflowError immediately
      retry_limit   maximum cap-doubling rounds per resolve under
                    on_overflow="retry"

    Execution cache:
      jit_cache     route device runners through the repro.perf executable
                    cache: each (config statics, shapes) combination lowers
                    to one jitted executable, reused across calls (cache
                    hits/misses/traces reported on ERResult.perf).  False
                    keeps the legacy trace-per-call behavior

    Execution:
      runner       "sequential" (host oracle) | "vmap" (single device,
                   named-axis shards) | "shard_map" (real device mesh)
      num_shards   r for sequential/vmap runners (shard_map takes r from
                   its mesh axis)
      partitioner  how shard boundaries are planned from the data:
                   legacy "balanced" | "range" | "sample" (key bounds
                   only), or the repro.balance planners "uniform"
                   (even key-space baseline) | "blocksplit" (greedy
                   comparison-count balance over key blocks, splitting
                   oversized blocks) | "pairrange" (equal SN pair-space
                   ranges) — planner names produce a full ShardPlan with
                   planned per-shard loads, rank-granular routing, and
                   exact padded capacities (explicit ``bounds``/ShardPlans
                   passed to resolve() always win)

    Scenario:
      linkage          dual-source R x S mode: only cross-source pairs are
                       blocked/matched (entities carry a "src" payload tag)
      compute_metrics  run the host oracle and attach reduction-ratio /
                       pairs-completeness metrics to the result
      passes           multi-pass SN (empty = single pass on the entity
                       ``key``): one SortKeySpec per blocking pass.  The
                       whole variant x runner x engine pipeline runs once
                       per derived sort key and ``resolve``/``link`` return
                       a ``MultiPassResult`` whose union pair set is the
                       recall lever of the blocking survey (arXiv:
                       1905.06167); per-pass results keep their own
                       overflow/metrics accounting.  Orchestrated host-side
                       — passes do NOT enter ``static_fingerprint`` (each
                       pass reuses the single-pass executable; only the key
                       VALUES differ)

    Observability (repro.obs — DESIGN.md §12):
      trace            record a span/metrics ``TraceReport`` for the run
                       and attach it as ``result.trace`` (resolve / link /
                       resolve_stream; the serve service keeps a tracer for
                       its lifetime and exposes ``trace_report()``).
                       Host-side only — excluded from
                       ``static_fingerprint``, so traced and untraced runs
                       share executables and pair sets bit-identically
                       (invariant 12); the disabled path costs one
                       thread-local lookup per span site

    Quality levers (repro.quality — DESIGN.md §14):
      window_policy    "fixed" (every entity uses ``window``) | "adaptive"
                       (each entity's effective window grows with the size
                       of its key block: weff = clip(block_count, window,
                       window_max), a pure function of the global
                       ``KeyProfile``.  The band program compiles ONCE at
                       window_max; per-entity weff rides the payload as a
                       traced ``_weff`` field, so the executable cache and
                       stream/resume invariants hold unchanged)
      window_max       adaptive ceiling (>= window; dense key blocks reach
                       it, sparse regions stay at ``window``)
      prune_policy     "off" | "evidence": meta-blocking comparison pruning
                       — drop candidate pairs whose CHEAP cascade evidence
                       falls below ``prune_threshold`` before the expensive
                       matcher ever sees them.  Pruned pairs leave the
                       blocked set (reduction ratio improves) and are
                       counted in ``pruned`` — accounted like overflow, but
                       deliberate: never retried
      prune_threshold  normalized cheap-evidence keep bar in [0, 1); a pair
                       survives iff cheap_score >= threshold * cheap_weight
                       (invariant 14: a gold pair at/above the bar is NEVER
                       pruned, in either band engine)

    Serving admission control (repro.serve — DESIGN.md §13) is NOT
    configured here: ``AdmissionConfig`` is a service-level policy passed
    to ``api.serve(..., admission=...)``.  It changes when requests are
    refused or deferred, never what a correct resolve produces, so none
    of its knobs participate in ``static_fingerprint``.
    """
    window: int = 10
    variant: str = "repsn"
    hops: int = 1
    cap_factor: float = 0.0
    matcher: CascadeMatcher = field(default_factory=default_matcher)
    return_scores: bool = False

    band_engine: str = "scan"
    band_block: int = 256
    cand_cap: Optional[int] = None
    band_interpret: Optional[bool] = None

    emit: str = "band"
    pair_cap: Optional[int] = None
    jit_cache: bool = True

    on_overflow: str = "count"
    retry_limit: int = 3

    runner: str = "vmap"
    num_shards: int = 8
    partitioner: str = "balanced"

    linkage: bool = False
    compute_metrics: bool = False
    passes: Tuple[SortKeySpec, ...] = ()

    trace: bool = False

    window_policy: str = "fixed"
    window_max: int = 0
    prune_policy: str = "off"
    prune_threshold: float = 0.0

    def __post_init__(self):
        if not isinstance(self.passes, tuple) or any(
                not isinstance(p, SortKeySpec) for p in self.passes):
            raise ValueError("passes must be a tuple of SortKeySpec")
        if len({p.name for p in self.passes}) != len(self.passes):
            raise ValueError("pass names must be unique")
        if self.window < 2:
            raise ValueError(f"window must be >= 2, got {self.window}")
        if self.runner not in RUNNERS:
            raise ValueError(f"unknown runner {self.runner!r}; "
                             f"choose from {RUNNERS}")
        if self.partitioner not in PARTITIONERS:
            # planners registered via repro.balance.register_partitioner are
            # first-class citizens of the config surface
            from repro.balance.planners import available_partitioners
            if self.partitioner not in available_partitioners():
                raise ValueError(
                    f"unknown partitioner {self.partitioner!r}; choose from "
                    f"{PARTITIONERS} or a registered planner "
                    f"({available_partitioners()})")
        if self.num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {self.num_shards}")
        if self.band_engine not in BAND_ENGINES:
            raise ValueError(f"unknown band engine {self.band_engine!r}; "
                             f"choose from {BAND_ENGINES}")
        if self.band_block < 1:
            raise ValueError(f"band_block must be >= 1, got {self.band_block}")
        if self.cand_cap is not None and self.cand_cap < 0:
            raise ValueError(f"cand_cap must be >= 0 (0 = unbounded, "
                             f"None = auto), got {self.cand_cap}")
        if self.emit not in EMIT_MODES:
            raise ValueError(f"unknown emit mode {self.emit!r}; choose from "
                             f"{EMIT_MODES}")
        if self.pair_cap is not None and self.pair_cap < 0:
            raise ValueError(f"pair_cap must be >= 0 (0 = full band, never "
                             f"overflows; None = auto), got {self.pair_cap}")
        if self.on_overflow not in OVERFLOW_POLICIES:
            raise ValueError(f"unknown on_overflow policy "
                             f"{self.on_overflow!r}; choose from "
                             f"{OVERFLOW_POLICIES}")
        if self.retry_limit < 0:
            raise ValueError(f"retry_limit must be >= 0, "
                             f"got {self.retry_limit}")
        if self.emit == "pairs" and self.return_scores:
            raise ValueError(
                "emit='pairs' transfers packed pair indices instead of "
                "bands, so per-slot scores are not materialized on host; "
                "use emit='band' with return_scores=True")
        if self.window_policy not in WINDOW_POLICIES:
            raise ValueError(f"unknown window_policy {self.window_policy!r}; "
                             f"choose from {WINDOW_POLICIES}")
        if self.window_policy == "adaptive":
            if self.linkage:
                raise ValueError(
                    "window_policy='adaptive' does not support linkage "
                    "mode (the dual-source oracle has no per-entity "
                    "window form yet); use a fixed window")
            if self.window_max < self.window:
                raise ValueError(
                    f"window_policy='adaptive' needs window_max >= window "
                    f"(the per-entity effective window grows FROM window UP "
                    f"TO window_max), got window_max={self.window_max} < "
                    f"window={self.window}")
            if self.band_engine == "pallas" \
                    and self.window_max - 1 > self.band_block:
                raise ValueError(
                    f"band_engine='pallas' under window_policy='adaptive' "
                    f"compiles the band at window_max={self.window_max}, "
                    f"whose band width ({self.window_max - 1}) must fit one "
                    f"row block, but band_block={self.band_block}")
        elif self.window_max:
            raise ValueError(
                f"window_max only applies to window_policy='adaptive' "
                f"(got window_policy={self.window_policy!r} with "
                f"window_max={self.window_max})")
        if self.prune_policy not in PRUNE_POLICIES:
            raise ValueError(f"unknown prune_policy {self.prune_policy!r}; "
                             f"choose from {PRUNE_POLICIES}")
        if self.prune_policy == "evidence":
            if not 0.0 <= self.prune_threshold < 1.0:
                raise ValueError(
                    f"prune_threshold must be in [0, 1) (a normalized "
                    f"cheap-evidence fraction), got {self.prune_threshold}")
        elif self.prune_threshold:
            raise ValueError(
                f"prune_threshold only applies to prune_policy='evidence' "
                f"(got prune_policy={self.prune_policy!r} with "
                f"prune_threshold={self.prune_threshold})")
        if self.band_engine == "pallas" and self.window - 1 > self.band_block:
            # the band kernels need the whole w-1 band inside one row block
            # (plus its successor); catching this here beats a kernel assert
            raise ValueError(
                f"band_engine='pallas' needs the band width (window-1="
                f"{self.window - 1}) to fit one row block, but band_block="
                f"{self.band_block}; raise band_block (VMEM grows as "
                f"band_block^2), lower window, or use band_engine='scan'")
        # variant names are validated lazily by the registry (so configs can
        # be built before a plugin variant registers itself)

    def with_(self, **kw) -> "ERConfig":
        """Functional update (dataclasses.replace sugar)."""
        return replace(self, **kw)

    def static_fingerprint(self) -> tuple:
        """Stable hashable key of every field that shapes the traced shard
        program — the config half of a ``repro.perf`` executable-cache key.

        Two configs with equal fingerprints lower to the same program for
        same-shaped inputs; fields that only steer host-side planning or
        result assembly (runner, num_shards, partitioner, compute_metrics,
        jit_cache, passes — each blocking pass reruns the same program on
        re-derived key values) are deliberately excluded so e.g. switching
        partitioners reuses the compiled executable (boundaries are traced
        arguments).  ``on_overflow``/``retry_limit`` are host-side recovery
        policy and excluded too: a retry re-executes under a cfg whose
        DOUBLED caps fingerprint to their own (bucketed) entries.
        ``trace`` is likewise excluded — spans only read host clocks
        (invariant 12), so a traced run must HIT the very executables an
        untraced one built.  Auto
        (None) caps are resolved to concrete ints by the facade/stream
        before any runner call, so a fingerprint with a None cap only
        arises from direct raw-runner use (where None means 0)."""
        return ("ERConfig", self.window, self.variant, self.hops,
                self.cap_factor, self.matcher, self.return_scores,
                self.band_engine, self.band_block, self.cand_cap,
                self.band_interpret, self.emit, self.pair_cap, self.linkage,
                self.window_policy, self.window_max,
                self.prune_policy, self.prune_threshold)

    @classmethod
    def from_sn_config(cls, sn_cfg, **kw) -> "ERConfig":
        """Lift an old ``pipeline.SNConfig`` into an ERConfig."""
        return cls(window=sn_cfg.window, variant=sn_cfg.variant,
                   hops=sn_cfg.hops, cap_factor=sn_cfg.cap_factor,
                   matcher=sn_cfg.matcher,
                   return_scores=sn_cfg.return_scores, **kw)
