"""Pluggable runners — who executes the variant's shard program.

  * SequentialRunner  host numpy, wraps the ``sn.py`` oracle with the chosen
                      variant's SEMANTICS (srp: per-partition windows;
                      repsn/jobsn: the complete SN pair set) — the reference
                      every parallel run is checked against
  * VmapRunner        single device, r shards on a vmapped named axis
                      (property tests, skew studies)
  * ShardMapRunner    real devices: shards live on a mesh axis (multi-CPU
                      subprocess / TPU mesh)

All three satisfy the ``Runner`` protocol: ``resolve(ents, bounds, cfg)``
returns a ``RunnerOutcome`` with identical semantics, so callers (and the
facade) never branch on the execution substrate.  The device runners also
expose ``run_raw`` returning the stacked per-shard output dict (band masks,
halos, scores) for benchmarks and invariant tests.

``bounds`` may be a raw (r-1,) boundary array OR a ``repro.balance``
ShardPlan — plans additionally carry rank-granular per-entity routing
(attached as a ``_dest`` payload tag consumed by ``srp.srp_shard``) and the
planned shuffle capacity (used when ``cfg.cap_factor`` doesn't override it),
so every variant x runner x band-engine combination executes planner output
with zero call-site changes.

Steady-state execution (ISSUE 4): with ``cfg.jit_cache`` (the default) the
device runners route through the ``repro.perf`` executable cache — each
(config statics, planner capacity, input shapes) combination is lowered to
ONE jitted executable (boundary VALUES are traced arguments, so replanning
never retraces), with the stacked shard input donated where the backend
supports it.  ``SequentialRunner._match`` jit-caches its chunk scorer the
same way, padding the tail chunk so every chunk reuses one executable.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, FrozenSet, NamedTuple, Protocol, Tuple, \
    runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs as OBS
from repro.api import linkage as LK
from repro.api import results as RES
from repro.api.variants import get_variant
from repro.balance.planners import as_plan
from repro.core import entities as E
from repro.perf import cache as PC

Pair = Tuple[int, int]


def _apply_plan(ents: dict, bounds, r: int, cfg):
    """Normalize (bounds | ShardPlan) for a device runner: returns
    (ents_with_routing, bounds_array, cap_link).  A partition count other
    than the runner's shard count is rejected — entities routed past the
    last shard would be dropped by ``bucketize`` WITHOUT being counted as
    overflow (its accounting only covers dest < r)."""
    plan = as_plan(bounds)
    if plan.num_shards != r:
        raise ValueError(
            f"plan defines {plan.num_shards} partitions but the runner has "
            f"{r} shards")
    if plan.dest is not None:
        ents = dict(ents)
        ents["payload"] = dict(ents["payload"],
                               _dest=jnp.asarray(plan.dest, jnp.int32))
    # explicit cap_factor keeps its historical override (overflow stays an
    # accounted outcome); otherwise the planner's exact capacity applies
    cap_link = plan.cap_link if cfg.cap_factor <= 0 else None
    return ents, jnp.asarray(plan.bounds, jnp.int32), cap_link


def _cache_fingerprint(cfg):
    """Config half of an executable-cache key, or None to bypass the cache
    (cfg.jit_cache=False, or a legacy ``pipeline.SNConfig`` shim object
    without the ERConfig surface)."""
    if not getattr(cfg, "jit_cache", True):
        return None
    fp = getattr(cfg, "static_fingerprint", None)
    return fp() if fp is not None else None


class RunnerOutcome(NamedTuple):
    """What every runner returns: host pair sets + accounting.

    ``cand_count`` is the PER-SHARD cascade-gate survivors kept (pallas
    band engine; zeros for scan) — per-shard like ``load`` so the
    DESIGN.md §6 cand_cap sizing rule (cap ~1.25x the busiest shard) is
    executable from the public result.  ``cand_overflow`` counts survivors
    dropped by ``cfg.cand_cap`` (may lose MATCHES, never blocked pairs);
    ``matcher_evals`` counts full-cascade evaluations ACTUALLY run — one
    per band slot for scan, one per cand_cap buffer slot for pallas (static
    shapes: a finite cand_cap is the §5.1 FLOP lever, reported honestly so
    benchmarks can verify it)."""
    blocked: FrozenSet[Pair]
    matched: FrozenSet[Pair]
    load: Tuple[int, ...]
    overflow: int
    num_shards: int
    cand_count: Tuple[int, ...] = ()
    cand_overflow: int = 0
    matcher_evals: int = 0
    pair_overflow: int = 0      # emitted index-buffer slots dropped by
    #                             cfg.pair_cap (emit="pairs" only; counted,
    #                             never silent — can lose blocked pairs)
    pruned: int = 0             # band slots dropped by meta-blocking
    #                             comparison pruning (prune_policy=
    #                             "evidence"; deliberate, never retried)


class PackedOutcome(NamedTuple):
    """``RunnerOutcome``'s packed-array twin: identical accounting, but the
    pair sets stay as deduplicated PACKED uint64 arrays (``(lo << 32) |
    hi``, see ``results.pack_pairs``).

    This is the collection hot path for callers that aggregate MANY runner
    invocations — ``repro.stream`` unions one of these per chunk with a
    single ``np.unique`` at the end, instead of materializing a Python
    frozenset per chunk.  ``to_outcome()`` converts to the public tuple-set
    form (the one place Python pair objects appear)."""
    blocked: "np.ndarray"
    matched: "np.ndarray"
    load: Tuple[int, ...]
    overflow: int
    num_shards: int
    cand_count: Tuple[int, ...] = ()
    cand_overflow: int = 0
    matcher_evals: int = 0
    pair_overflow: int = 0
    pruned: int = 0

    def to_outcome(self) -> RunnerOutcome:
        """Materialize the public RunnerOutcome (frozensets of (lo, hi))."""
        return RunnerOutcome(
            blocked=RES.packed_to_frozenset(self.blocked),
            matched=RES.packed_to_frozenset(self.matched),
            load=self.load, overflow=self.overflow,
            num_shards=self.num_shards, cand_count=self.cand_count,
            cand_overflow=self.cand_overflow,
            matcher_evals=self.matcher_evals,
            pair_overflow=self.pair_overflow,
            pruned=self.pruned)


@runtime_checkable
class Runner(Protocol):
    """The execution contract every runner satisfies (see module doc)."""

    name: str

    @property
    def shards(self) -> int:
        """Number of shards this runner executes (r)."""
        ...

    def resolve(self, ents: dict, bounds, cfg) -> RunnerOutcome:
        """Run blocking + matching; pair sets as frozensets of (lo, hi)."""
        ...

    def resolve_packed(self, ents: dict, bounds, cfg) -> PackedOutcome:
        """Like ``resolve`` but pair sets stay packed uint64 arrays (the
        aggregation hot path — see ``PackedOutcome``)."""
        ...


def shard_input(ents: dict, r: int) -> dict:
    """Round-robin split into r mapper shards (paper: mappers scan disjoint
    input partitions), padded to equal capacity."""
    n = ents["key"].shape[0]
    cap0 = int(np.ceil(n / r))
    pad = r * cap0 - n
    padded = E.concat(ents, E.empty_like(ents, pad)) if pad else ents
    return jax.tree.map(
        lambda x: x.reshape((r, cap0) + x.shape[1:]), padded)


def _device_outcome_packed(out: dict, cfg, r: int) -> PackedOutcome:
    """Stacked device output -> PackedOutcome (collection + accounting; the
    shared back half of every device runner's resolve/resolve_packed).
    Under an active tracer the whole collection runs inside a ``collect``
    span carrying the device->host transfer bytes and the realized
    per-shard loads — the Afrati/Ullman communication-cost attribution of
    DESIGN.md §12."""
    sp = OBS.span("collect")
    with sp:
        if sp.enabled:
            nbytes = sum(int(getattr(x, "nbytes", 0))
                         for x in jax.tree.leaves(out))
            sp.set(transfer_bytes=nbytes)
            OBS.current_tracer().metrics.counter("transfer_bytes") \
                .inc(nbytes)
        variant = get_variant(cfg.variant)
        col = variant.collect(out)
        load = tuple(int(x) for x in np.asarray(out["load"])[0])
        overflow = int(np.asarray(out["overflow"])[0])
        cand_count = np.zeros(r, np.int64)
        cand_overflow = matcher_evals = pair_overflow = pruned = 0
        for p in variant.parts:
            if p in out:
                cand_count += np.asarray(out[p]["cand_count"], np.int64)
                cand_overflow += \
                    int(np.asarray(out[p]["cand_overflow"]).sum())
                matcher_evals += \
                    int(np.asarray(out[p]["matcher_evals"]).sum())
                if "pruned" in out[p]:  # meta-blocking comparison pruning
                    pruned += int(np.asarray(out[p]["pruned"]).sum())
                if "mask_overflow" in out[p]:  # device-side pair emission
                    pair_overflow += \
                        int(np.asarray(out[p]["mask_overflow"]).sum()) + \
                        int(np.asarray(out[p]["match_overflow"]).sum())
        if sp.enabled:
            sp.set(load=load)
    return PackedOutcome(blocked=col.blocked, matched=col.matched,
                         load=load, overflow=overflow, num_shards=r,
                         cand_count=tuple(int(c) for c in cand_count),
                         cand_overflow=cand_overflow,
                         matcher_evals=matcher_evals,
                         pair_overflow=pair_overflow,
                         pruned=pruned)


@dataclass(frozen=True)
class VmapRunner:
    """r shards on one device via ``jax.vmap(axis_name=...)``."""
    num_shards: int = 8
    name = "vmap"

    @property
    def shards(self) -> int:
        """Number of vmapped shards (== cfg.num_shards)."""
        return self.num_shards

    def run_raw(self, ents: dict, bounds, cfg) -> dict:
        """Execute the variant's shard program and return the STACKED
        per-shard output dict (band masks / emitted index buffers, halos,
        accounting — leading dim r) without host collection; benchmarks and
        invariant tests read this, ``resolve`` consumes it.  Routed through
        the executable cache unless ``cfg.jit_cache`` is off."""
        r = self.num_shards
        variant = get_variant(cfg.variant)
        ents, b, cap_link = _apply_plan(ents, bounds, r, cfg)
        fn = partial(variant.shard_program, r=r, axis="sn", cfg=cfg,
                     cap_link=cap_link)
        stacked = shard_input(ents, r)

        def program(st, bd):
            return jax.vmap(lambda e: fn(e, bounds=bd),
                            axis_name="sn")(st)

        fp = _cache_fingerprint(cfg)
        rows = int(stacked["key"].shape[1])
        sp = OBS.span("shard_program", device=True, runner="vmap",
                      shards=r, rows_per_shard=rows)
        with sp:
            if fp is None:
                out = program(stacked, b)    # legacy trace-per-call path
            else:
                call = PC.executable_cache().get_or_build(
                    ("vmap", r, "sn", fp, cap_link,
                     PC.tree_fingerprint((stacked, b))),
                    lambda: program, donate_argnums=(0,))
                out = call(stacked, b)
            if sp.enabled:
                # async dispatch would end the span before the device ran;
                # blocking only when traced keeps the untraced path
                # identical (invariant 12: no retraces, same pair sets)
                out = jax.block_until_ready(out)
        return out

    def resolve(self, ents: dict, bounds, cfg) -> RunnerOutcome:
        """Run blocking + matching on r vmapped shards; see ``Runner``."""
        return self.resolve_packed(ents, bounds, cfg).to_outcome()

    def resolve_packed(self, ents: dict, bounds, cfg) -> PackedOutcome:
        """``resolve`` with pair sets left as packed uint64 arrays."""
        return _device_outcome_packed(self.run_raw(ents, bounds, cfg), cfg,
                                      self.num_shards)


@dataclass(frozen=True)
class ShardMapRunner:
    """Real devices: shards live on mesh axis ``axis``.  Output arrays carry
    a leading per-shard dim, exactly like VmapRunner."""
    mesh: Any = None                 # jax Mesh; None -> all devices, 1-D
    axis: str = "data"
    name = "shard_map"

    def __post_init__(self):
        if self.mesh is None:
            from repro.launch.mesh import make_mesh_compat
            object.__setattr__(self, "mesh", make_mesh_compat(
                (len(jax.devices()),), (self.axis,)))

    @property
    def shards(self) -> int:
        """Number of shards == devices on the mesh axis."""
        return int(self.mesh.shape[self.axis])

    def run_raw(self, ents: dict, bounds, cfg) -> dict:
        """Execute the variant's shard program under ``shard_map`` and
        return the stacked per-shard output dict (leading dim r, exactly
        like ``VmapRunner.run_raw``); cached/jitted per (mesh, config
        statics, shapes) unless ``cfg.jit_cache`` is off."""
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh, axis = self.mesh, self.axis
        r = int(mesh.shape[axis])
        variant = get_variant(cfg.variant)
        ents, b, cap_link = _apply_plan(ents, bounds, r, cfg)
        stacked = shard_input(ents, r)
        fn = partial(variant.shard_program, r=r, axis=axis, cfg=cfg,
                     cap_link=cap_link)

        def make_program():
            # bounds ride as a replicated traced argument so replanning
            # never rebuilds; the eval_shape pass (out_specs need the output
            # tree; vmap binds the axis name so the collectives trace) runs
            # once per cache entry instead of once per call
            def body(stacked_local, bounds_rep):
                # stacked_local: (1, cap0, ...) — this shard's partition
                local = jax.tree.map(lambda x: x[0], stacked_local)
                out = fn(local, bounds=bounds_rep)
                return jax.tree.map(lambda x: jnp.expand_dims(x, 0), out)

            out_sds = jax.eval_shape(
                lambda st, bd: jax.vmap(lambda l: fn(l, bounds=bd),
                                        axis_name=axis)(st), stacked, b)
            out_specs = jax.tree.map(lambda _: P(axis), out_sds)
            return shard_map(
                body, mesh=mesh,
                in_specs=(jax.tree.map(lambda _: P(axis), stacked), P()),
                out_specs=out_specs, check_rep=False)

        fp = _cache_fingerprint(cfg)
        rows = int(stacked["key"].shape[1])
        sp = OBS.span("shard_program", device=True, runner="shard_map",
                      shards=r, rows_per_shard=rows)
        with sp:
            if fp is None:
                out = make_program()(stacked, b)   # legacy per-call path
            else:
                call = PC.executable_cache().get_or_build(
                    ("shard_map", axis, self.mesh, fp,
                     cap_link, PC.tree_fingerprint((stacked, b))),
                    make_program, donate_argnums=(0,))
                out = call(stacked, b)
            if sp.enabled:
                out = jax.block_until_ready(out)  # see VmapRunner.run_raw
        return out

    def resolve(self, ents: dict, bounds, cfg) -> RunnerOutcome:
        """Run blocking + matching on the mesh shards; see ``Runner``."""
        return self.resolve_packed(ents, bounds, cfg).to_outcome()

    def resolve_packed(self, ents: dict, bounds, cfg) -> PackedOutcome:
        """``resolve`` with pair sets left as packed uint64 arrays."""
        return _device_outcome_packed(self.run_raw(ents, bounds, cfg), cfg,
                                      self.shards)


@dataclass(frozen=True)
class SequentialRunner:
    """Host oracle: variant-faithful sequential blocking + batched matching.
    ``load`` reports per-PARTITION sizes (what each reducer would hold)."""
    num_shards: int = 1
    name = "sequential"
    match_chunk: int = 1 << 16

    @property
    def shards(self) -> int:
        """Default partition count (a ShardPlan passed to resolve wins)."""
        return self.num_shards

    def resolve(self, ents: dict, bounds, cfg) -> RunnerOutcome:
        """Variant-faithful host resolve; see ``Runner``."""
        return self.resolve_packed(ents, bounds, cfg).to_outcome()

    def resolve_packed(self, ents: dict, bounds, cfg) -> PackedOutcome:
        """``resolve`` with pair sets left as packed uint64 arrays (the
        internal representation this runner already uses)."""
        plan = as_plan(bounds)
        bounds = np.asarray(plan.bounds)
        r = plan.num_shards
        valid = np.asarray(ents["valid"])
        keys = np.asarray(ents["key"])[valid]
        eids = np.asarray(ents["eid"])[valid]
        # partition ids under the plan (rank-granular when it carries dest)
        part = plan.assignment(np.asarray(ents["key"]), valid)

        weff_all = ents["payload"].get("_weff")
        weff = None if weff_all is None else np.asarray(weff_all)[valid]

        with OBS.span("block", runner="sequential", shards=r):
            blocked = RES.pack_pair_set(
                get_variant(cfg.variant).sequential_pairs(
                    keys, eids, bounds, cfg.window, part=part, weff=weff))
            if getattr(cfg, "linkage", False) and "src" in ents["payload"]:
                src = np.asarray(ents["payload"]["src"])[valid]
                blocked = LK.filter_cross_source_packed(blocked, eids, src)
        pruned = 0
        if getattr(cfg, "prune_policy", "off") == "evidence":
            blocked, pruned = self._prune(ents, blocked, cfg)
        with OBS.span("match", pairs=int(blocked.size)):
            matched = self._match(ents, blocked, cfg)

        load = tuple(np.bincount(part, minlength=r).astype(int).tolist())
        return PackedOutcome(blocked=blocked, matched=matched,
                             load=load, overflow=0, num_shards=r,
                             matcher_evals=int(blocked.size),
                             pruned=pruned)

    def _prune(self, ents: dict, blocked: np.ndarray, cfg
               ) -> Tuple[np.ndarray, int]:
        """Meta-blocking comparison pruning, sequential-oracle form: score
        each blocked pair's CHEAP cascade evidence with the same jnp ops
        the band engines' ``prune_low_evidence`` uses, keep pairs at/above
        ``prune_threshold`` of the cheap prefix weight.  Identical keep
        decisions to the device engines (same math, same GATE_EPS slack)."""
        from repro.core import window as W
        from repro.core.match import cosine_sim, jaccard_sig

        split = W.split_cascade(cfg.matcher, ents["payload"])
        if split is None:
            raise ValueError(
                "prune_policy='evidence' needs a matcher whose cascade "
                "starts with a kernel-supported cheap stage (cosine/jaccard "
                "on a present payload field); split_cascade found none")
        if blocked.size == 0:
            return blocked, 0
        valid = np.asarray(ents["valid"])
        rows = np.nonzero(valid)[0]
        eids = np.asarray(ents["eid"])[rows]
        order = np.argsort(eids)
        sorted_eids, sorted_rows = eids[order], rows[order]
        plo, phi = RES.unpack_pairs(np.sort(blocked))
        ra = sorted_rows[np.searchsorted(sorted_eids, plo)]
        rb = sorted_rows[np.searchsorted(sorted_eids, phi)]
        cheap = jnp.zeros((ra.shape[0],), jnp.float32)
        if split.feat_field is not None:
            feat = jnp.asarray(ents["payload"][split.feat_field])
            cheap = cheap + split.w_cos * cosine_sim(feat[ra], feat[rb])
        if split.sig_field is not None:
            sig = jnp.asarray(ents["payload"][split.sig_field])
            cheap = cheap + split.w_jac * jaccard_sig(sig[ra], sig[rb])
        bar = cfg.prune_threshold * (split.w_cos + split.w_jac) - W.GATE_EPS
        keep = np.asarray(cheap) >= bar
        kept = np.sort(blocked)[keep]
        return kept, int(blocked.size - kept.size)

    def _match(self, ents: dict, blocked: np.ndarray, cfg) -> np.ndarray:
        """Batch-score blocked pairs (packed uint64 array) with the cascade
        matcher (skip=False: identical accept/reject decisions, exact
        scores).  Returns the matched subset, still packed.

        The chunk scorer is jit-compiled ONCE per (payload schema, chunk
        shape, matcher) through the repro.perf executable cache — payload
        moves to device once per call and chunks gather inside the compiled
        program; the tail chunk is padded to ``match_chunk`` so it reuses
        the same executable instead of compiling a second shape."""
        if blocked.size == 0:
            return blocked
        valid = np.asarray(ents["valid"])
        rows = np.nonzero(valid)[0]
        eids = np.asarray(ents["eid"])[rows]
        order = np.argsort(eids)
        sorted_eids, sorted_rows = eids[order], rows[order]
        blocked = np.sort(blocked)          # == lexicographic (lo, hi) order
        plo, phi = RES.unpack_pairs(blocked)
        ra = sorted_rows[np.searchsorted(sorted_eids, plo)]
        rb = sorted_rows[np.searchsorted(sorted_eids, phi)]
        payload = {k: jnp.asarray(v) for k, v in ents["payload"].items()}

        chunk = self.match_chunk
        matcher = cfg.matcher

        def program(pl, ia, ib):
            pa = {k: v[ia] for k, v in pl.items()}
            pb = {k: v[ib] for k, v in pl.items()}
            score, _ = matcher.combined(pa, pb, skip=False)
            return score >= matcher.threshold

        if getattr(cfg, "jit_cache", True):
            scorer = PC.executable_cache().get_or_build(
                ("seq_match", matcher, chunk,
                 PC.tree_fingerprint(payload)),
                lambda: program)
        else:
            scorer = program

        keep = np.zeros(blocked.shape[0], bool)
        for s in range(0, blocked.shape[0], chunk):
            ia, ib = ra[s:s + chunk], rb[s:s + chunk]
            ln = ia.shape[0]
            if ln < chunk:                  # pad the tail: one executable
                ia = np.concatenate([ia, np.zeros(chunk - ln, ia.dtype)])
                ib = np.concatenate([ib, np.zeros(chunk - ln, ib.dtype)])
            got = np.asarray(scorer(payload, jnp.asarray(ia),
                                    jnp.asarray(ib)))
            keep[s:s + ln] = got[:ln]
        return blocked[keep]
