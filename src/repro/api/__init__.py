"""``repro.api`` — the public entity-resolution facade.

One entry point for the paper's parallel Sorted Neighborhood workflows:

    from repro import api

    res = api.resolve(ents, api.ERConfig(variant="repsn", runner="vmap",
                                         num_shards=8, window=10))
    res.blocking.pairs      # frozenset of blocked (candidate) pairs
    res.matches             # frozenset of matcher-accepted pairs
    res.blocking.load       # per-shard valid counts (skew telemetry)
    res.metrics             # reduction ratio / pairs completeness vs oracle

Pieces (each importable on its own):

  * config.ERConfig        frozen run configuration (variant, runner, window,
                           partitioner, capacity, matcher, linkage mode)
  * variants               registry of SN variants (srp | repsn | jobsn);
                           ``@register_variant`` adds new ones without
                           touching any dispatch code
  * runners                Runner protocol + SequentialRunner / VmapRunner /
                           ShardMapRunner
  * results                typed BlockingResult / ERResult / ERMetrics
  * linkage                dual-source (R x S) record linkage: source tags,
                           cross-source band masks, host oracle
  * repro.balance          skew-aware load balancing: KeyProfile (the
                           analysis job), partition planners (uniform |
                           blocksplit | pairrange) producing ShardPlans
                           with planned loads + exact capacities, reported
                           back as ERResult.balance
  * facade.resolve/link    glue the above together — and, under
                           ``ERConfig.passes`` (multi-pass SN over several
                           derived sort keys), return a ``MultiPassResult``
                           with the union + per-pass outcomes
  * repro.stream           out-of-core streaming twin: ``resolve_stream``
                           consumes an iterator of chunks, externally
                           sorts, and resolves chunk-by-chunk with a w-1
                           seam halo — bit-identical pair sets with device
                           residency bounded by the chunk size
  * repro.serve            online incremental twin: ``api.serve(cfg)``
                           starts a ``ResolutionService`` (persistent
                           sorted index + neighborhood-delta matching
                           behind a micro-batched queue) whose served pair
                           sets stay bit-identical to a from-scratch
                           ``resolve`` of the live corpus under mutation
  * repro.resilience       fault tolerance: checkpointed/resumable streaming
                           (``resolve_stream(checkpoint_dir=...)`` +
                           ``api.resume``), the ``ERConfig.on_overflow``
                           cap-escalation retry ladder, and the
                           deterministic fault-injection harness
  * repro.obs              unified tracing + metrics (DESIGN.md §12):
                           ``ERConfig(trace=True)`` attaches a
                           ``TraceReport`` (spans, counters, histograms,
                           every legacy stats type behind one schema) to
                           any resolve/stream result, exportable as a
                           Chrome/Perfetto ``trace.json``
"""
# repro.obs is a leaf (stdlib/numpy only at import), so the eager import is
# cycle-safe — unlike serve/resilience, which resolve lazily below
from repro.obs import (SCHEMA_VERSION, TraceReport, Tracer, pack_stats,
                       unpack_stats)
from repro.api.config import ERConfig, SortKeySpec
from repro.api.facade import (default_bounds, link, make_runner, resolve,
                              resume, serve)
from repro.api.linkage import sequential_link_pairs, tag_sources
from repro.api.results import (BalanceMetrics, BlockingResult, ERMetrics,
                               ERResult, MultiPassResult, PerfStats,
                               ResilienceStats, pack_pairs,
                               packed_pairs_from_band, packed_pairs_from_idx,
                               packed_pairs_from_part, packed_to_frozenset,
                               pairs_from_band, unpack_pairs)
from repro.api.runners import (PackedOutcome, Runner, RunnerOutcome,
                               SequentialRunner, ShardMapRunner, VmapRunner,
                               shard_input)
from repro.api.variants import (available_variants, get_variant,
                                register_variant)
from repro.balance import (KeyProfile, ShardPlan, available_partitioners,
                           get_partitioner, plan_shards, profile_keys,
                           register_partitioner)
from repro.core.window import (available_band_engines, get_band_engine,
                               register_band_engine)

_SERVE_TYPES = ("ResolutionService", "IncrementalResult", "ServeStats")
_RESILIENCE_TYPES = ("StreamCheckpoint", "FaultPlan", "InjectedFault",
                     "CapacityOverflowError")


def __getattr__(name):
    # the serve/resilience types resolve lazily (PEP 562): both packages
    # import repro.api submodules, so an eager import here would be a cycle
    if name in _SERVE_TYPES:
        import repro.serve as _serve
        return getattr(_serve, name)
    if name in _RESILIENCE_TYPES:
        import repro.resilience as _resilience
        return getattr(_resilience, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "ERConfig", "SortKeySpec",
    "resolve", "link", "serve", "resume", "make_runner", "default_bounds",
    "ResolutionService", "IncrementalResult", "ServeStats",
    "ResilienceStats", "StreamCheckpoint", "FaultPlan", "InjectedFault",
    "CapacityOverflowError",
    "BlockingResult", "ERResult", "ERMetrics", "BalanceMetrics", "PerfStats",
    "MultiPassResult",
    "pairs_from_band",
    "packed_pairs_from_band", "packed_pairs_from_idx",
    "packed_pairs_from_part", "pack_pairs", "unpack_pairs",
    "packed_to_frozenset",
    "Runner", "RunnerOutcome", "PackedOutcome",
    "SequentialRunner", "VmapRunner", "ShardMapRunner", "shard_input",
    "register_variant", "get_variant", "available_variants",
    "register_band_engine", "get_band_engine", "available_band_engines",
    "KeyProfile", "ShardPlan", "profile_keys", "plan_shards",
    "register_partitioner", "get_partitioner", "available_partitioners",
    "tag_sources", "sequential_link_pairs",
    "Tracer", "TraceReport", "pack_stats", "unpack_stats", "SCHEMA_VERSION",
]
