"""Checkpointed streaming — the on-disk resume protocol of DESIGN.md §11.

A checkpoint directory is the durable mirror of one ``resolve_stream`` run:

    MANIFEST.json           versioned manifest (atomic tmp-then-rename):
                            config fingerprint, ingest progress, and one
                            state record per streaming pass
    raw/raw%06d.npz         the ingested chunk store (shared across passes)
    runs-<label>/run%06d.npz  the pass's sorted runs (external-sort output)
    profile-<label>.npz     the pass's merged KeyProfile
    pairs-<label>-%06d.npz  per-chunk packed blocked/matched pair spool
    carry-<label>.npz       the current w-1 seam halo (overwritten per chunk)

Commit protocol (per resolved chunk): write the chunk's pair spool, write
the carry, then write the manifest recording ``completed_chunks = k+1``
plus every accumulated counter.  All three are atomic writes, and the
manifest is LAST — so a crash anywhere leaves either a manifest that does
not know about chunk k (the orphaned spool/carry files are simply
overwritten when the chunk is redone) or a fully committed chunk.  Nothing
is ever partially visible, which is what makes invariant 11 (resumed pair
union == uninterrupted run) hold at every kill point.

Resume (``resume_stream`` / ``api.resume``) re-derives the merged stream
from the durable sorted runs — the external merge is deterministic — skips
``completed_chunks`` chunks, reloads their pair spools, restores the carry
and counters, and continues the loop as if never interrupted.  A run killed
mid-INGEST resumes too, but needs the chunk iterator re-supplied (the
already-committed prefix is skipped; the iterator must be deterministic).

Checkpointed runs do not support ``compute_metrics`` (the host oracle is a
whole-run accumulation the checkpoint deliberately does not persist).
"""
from __future__ import annotations

import json
import os
import re
from typing import Iterable, Optional, Tuple

import numpy as np

from repro import balance as B


def _store():
    # lazy: repro.stream's package __init__ pulls in the resolver, which
    # imports repro.api.results, which imports this package — importing
    # the store eagerly here would close that cycle mid-initialization
    from repro.stream import store as S
    return S

MANIFEST = "MANIFEST.json"
VERSION = 1

# ERConfig fields the manifest serializes verbatim (everything except the
# matcher, which is rebuilt as default_matcher() or re-supplied by the
# caller) — SortKeySpec passes are stored as dicts
_CFG_FIELDS = ("window", "variant", "hops", "cap_factor", "return_scores",
               "band_engine", "band_block", "cand_cap", "band_interpret",
               "emit", "pair_cap", "jit_cache", "on_overflow", "retry_limit",
               "runner", "num_shards", "partitioner", "linkage",
               "window_policy", "window_max", "prune_policy",
               "prune_threshold")
_PASS_FIELDS = ("name", "source", "kind", "offset", "width", "index")

_COUNTERS = ("chunks", "carry_total", "degenerate", "steady", "hits",
             "misses", "traces", "overflow", "cand_overflow",
             "matcher_evals", "pair_overflow", "pruned", "retries",
             "escalations", "device_bytes")


def _slug(label: str) -> str:
    """Filesystem-safe pass label (pass names are user strings)."""
    return re.sub(r"[^A-Za-z0-9_.-]", "_", label) or "pass"


def _fresh_pass_state() -> dict:
    state = {c: 0 for c in _COUNTERS}
    state.update(sorted=False, n_runs=0, completed_chunks=0, rank_offset=0,
                 carry_rows=0, done=False, load_max=[], cand_max=[])
    return state


class StreamCheckpoint:
    """Handle on one checkpoint directory (see module doc).

    ``open`` creates a fresh manifest or attaches to an existing one whose
    fingerprint matches the supplied config (so re-running the same
    ``resolve_stream(checkpoint_dir=...)`` command after a kill IS a
    resume); ``load`` attaches without a config (``api.resume``) and
    rebuilds it from the manifest."""

    def __init__(self, path: str, manifest: dict):
        self.path = path
        self.manifest = manifest

    # -- construction --------------------------------------------------------

    @classmethod
    def open(cls, path: str, cfg, chunk_size: Optional[int]
             ) -> "StreamCheckpoint":
        """Create a fresh checkpoint at ``path``, or attach to an existing
        one — re-running the original call IS the resume path.  Attaching
        validates ``cfg`` (fingerprint + host setup) and ``chunk_size``
        against the manifest: both shape the committed chunk grid, so
        drift across a resume is rejected loudly."""
        os.makedirs(path, exist_ok=True)
        mpath = os.path.join(path, MANIFEST)
        if os.path.exists(mpath):
            ckpt = cls.load(path)
            ckpt._check_config(cfg)
            if ckpt.manifest["chunk_size"] != chunk_size:
                raise ValueError(
                    f"checkpoint {path!r} was created with chunk_size="
                    f"{ckpt.manifest['chunk_size']} but this run requests "
                    f"{chunk_size}; the chunk grid defines every commit "
                    f"point, so it cannot change across a resume")
            return ckpt
        manifest = {
            "version": VERSION,
            "fingerprint": repr(cfg.static_fingerprint()),
            "host": {"runner": cfg.runner, "num_shards": cfg.num_shards,
                     "partitioner": cfg.partitioner},
            "default_matcher": cls._is_default_matcher(cfg),
            "config": cls._config_blob(cfg),
            "chunk_size": chunk_size,
            "phase": "ingest",
            "ingest": {"chunks": 0, "max_len": 0, "total": 0, "nbytes": 0},
            "passes": {},
        }
        ckpt = cls(path, manifest)
        ckpt.save()
        return ckpt

    @classmethod
    def load(cls, path: str) -> "StreamCheckpoint":
        """Attach to an existing checkpoint directory (manifest version
        checked); raises FileNotFoundError if ``path`` holds none."""
        mpath = os.path.join(path, MANIFEST)
        if not os.path.exists(mpath):
            raise FileNotFoundError(
                f"no checkpoint manifest at {mpath!r}; was this run started "
                f"with resolve_stream(checkpoint_dir=...)?")
        with open(mpath) as f:
            manifest = json.load(f)
        if manifest.get("version") != VERSION:
            raise ValueError(
                f"checkpoint {path!r} has manifest version "
                f"{manifest.get('version')!r}; this build reads version "
                f"{VERSION} — finish it with the build that wrote it")
        return cls(path, manifest)

    def save(self) -> None:
        """Atomically rewrite the manifest — the ONE commit point: state
        not reachable from the manifest does not exist after a crash."""
        _store().atomic_write_json(os.path.join(self.path, MANIFEST),
                                   self.manifest)

    # -- config round-trip ---------------------------------------------------

    @staticmethod
    def _is_default_matcher(cfg) -> bool:
        from repro.core.match import default_matcher
        return cfg.matcher == default_matcher()

    @staticmethod
    def _config_blob(cfg) -> dict:
        blob = {f: getattr(cfg, f) for f in _CFG_FIELDS}
        blob["passes"] = [{f: getattr(p, f) for f in _PASS_FIELDS}
                          for p in cfg.passes]
        return blob

    def _check_config(self, cfg) -> None:
        fp = repr(cfg.static_fingerprint())
        if fp != self.manifest["fingerprint"]:
            raise ValueError(
                f"config does not match checkpoint {self.path!r}: "
                f"fingerprint {fp} vs stored {self.manifest['fingerprint']} "
                f"— a resumed run must use the original configuration")
        host = {"runner": cfg.runner, "num_shards": cfg.num_shards,
                "partitioner": cfg.partitioner}
        if host != self.manifest["host"]:
            raise ValueError(
                f"execution setup does not match checkpoint {self.path!r}: "
                f"{host} vs stored {self.manifest['host']} (shard count and "
                f"partitioner shape the pair sets — they cannot change "
                f"across a resume)")

    def resolve_config(self, cfg=None):
        """The checkpoint's ERConfig: validate ``cfg`` against the stored
        fingerprint, or rebuild from the manifest (default matcher only —
        a custom matcher cannot be serialized and must be re-supplied)."""
        if cfg is not None:
            self._check_config(cfg)
            return cfg
        if not self.manifest["default_matcher"]:
            raise ValueError(
                f"checkpoint {self.path!r} was created with a non-default "
                f"matcher, which the manifest cannot serialize; call "
                f"resume(checkpoint_dir, cfg=<original config>)")
        from repro.api.config import ERConfig, SortKeySpec
        blob = dict(self.manifest["config"])
        passes = tuple(SortKeySpec(**p) for p in blob.pop("passes"))
        cfg = ERConfig(passes=passes, **blob)
        self._check_config(cfg)
        return cfg

    # -- ingest phase --------------------------------------------------------

    @property
    def phase(self) -> str:
        """Lifecycle phase: ``"ingest"`` → ``"resolve"`` → ``"done"``."""
        return self.manifest["phase"]

    @property
    def ingest(self) -> dict:
        """Committed ingest totals (chunks / max_len / total / nbytes)."""
        return self.manifest["ingest"]

    def raw_store(self):
        """The durable raw chunk store, re-attached to exactly the
        committed chunk count (un-committed debris swept)."""
        raw_dir = os.path.join(self.path, "raw")
        if not os.path.isdir(raw_dir):
            return _store().ChunkStore(raw_dir, prefix="raw")
        return _store().ChunkStore.attach(raw_dir, "raw",
                                 count=self.ingest["chunks"])

    def commit_raw(self, max_len: int, total: int, nbytes: int) -> None:
        """Commit one durably-appended raw chunk (running totals)."""
        self.manifest["ingest"] = {
            "chunks": self.ingest["chunks"] + 1, "max_len": max_len,
            "total": total, "nbytes": nbytes}
        self.save()

    def ingest_done(self) -> None:
        """Advance ingest → resolve (idempotent on a resumed run)."""
        if self.manifest["phase"] == "ingest":
            self.manifest["phase"] = "resolve"
            self.save()

    def mark_done(self) -> None:
        """Commit run completion; a resume of a done checkpoint replays
        the (deterministic) merge and returns the identical result."""
        self.manifest["phase"] = "done"
        self.save()

    # -- per-pass state ------------------------------------------------------

    def pass_state(self, label: str) -> dict:
        """The pass's live manifest state dict (created on first touch):
        sort status, completed_chunks, carry/rank bookkeeping, and every
        streaming counter — mutate it, then ``save()`` to commit."""
        states = self.manifest["passes"]
        if label not in states:
            states[label] = _fresh_pass_state()
        return states[label]

    def runs_store(self, label: str):
        """(runs store, sorted_already): attach the pass's committed sorted
        runs, or hand back a swept store for a (re)run of the sort phase —
        a crash mid-sort simply redoes it."""
        runs_dir = os.path.join(self.path, f"runs-{_slug(label)}")
        state = self.pass_state(label)
        if state["sorted"]:
            return _store().ChunkStore.attach(runs_dir, "run",
                                     count=state["n_runs"]), True
        if os.path.isdir(runs_dir):          # sweep a half-written sort
            _store().ChunkStore.attach(runs_dir, "run", count=0)
        return _store().ChunkStore(runs_dir, prefix="run"), False

    def commit_sorted(self, label: str, runs,
                      profile: B.KeyProfile) -> None:
        """Commit the pass's sort phase: profile to disk, then manifest."""
        _store().atomic_savez(
            self._profile_path(label),
            n=np.int64(profile.n), window=np.int64(profile.window),
            uniq=profile.uniq, counts=profile.counts,
            cum_entities=profile.cum_entities,
            block_comparisons=profile.block_comparisons,
            cum_comparisons=profile.cum_comparisons)
        state = self.pass_state(label)
        state["sorted"] = True
        state["n_runs"] = len(runs)
        self.save()

    def load_profile(self, label: str) -> B.KeyProfile:
        """Reload the pass's committed ``KeyProfile`` (the exact merged
        profile — SRP replanning on resume is bit-identical)."""
        with np.load(self._profile_path(label), allow_pickle=False) as z:
            return B.KeyProfile(
                n=int(z["n"]), window=int(z["window"]), uniq=z["uniq"],
                counts=z["counts"], cum_entities=z["cum_entities"],
                block_comparisons=z["block_comparisons"],
                cum_comparisons=z["cum_comparisons"])

    # -- per-chunk commits ---------------------------------------------------

    def spool_chunk(self, label: str, chunk: int, blocked: np.ndarray,
                    matched: np.ndarray) -> None:
        """Write chunk ``chunk``'s packed pair arrays (atomic; NOT yet
        committed — the manifest still points at the previous chunk)."""
        _store().atomic_savez(self._pairs_path(label, chunk),
                     blocked=blocked, matched=matched)

    def commit_chunk(self, label: str, carry: Optional[dict],
                     **state_updates) -> None:
        """Commit one completed chunk: persist the seam halo, then write
        the manifest with ``completed_chunks`` advanced and every
        accumulator updated.  The manifest write is the commit point."""
        state = self.pass_state(label)
        if carry is not None:
            pfx = _store()._PAYLOAD_PREFIX
            _store().atomic_savez(
                os.path.join(self.path, f"carry-{_slug(label)}.npz"),
                key=carry["key"], eid=carry["eid"], valid=carry["valid"],
                **{pfx + k: v
                   for k, v in carry["payload"].items()})
            state["carry_rows"] = int(carry["key"].shape[0])
        state["completed_chunks"] += 1
        state.update(state_updates)
        self.save()

    def load_pairs(self, label: str,
                   chunk: int) -> Tuple[np.ndarray, np.ndarray]:
        """(blocked, matched) packed uint64 pair arrays of one committed
        chunk — the restore path re-unions them on resume."""
        with np.load(self._pairs_path(label, chunk),
                     allow_pickle=False) as z:
            return z["blocked"], z["matched"]

    def load_carry(self, label: str) -> Optional[dict]:
        """The persisted w−1 seam-halo carry of the last committed chunk
        (host entity dict), or None when nothing carries over."""
        state = self.pass_state(label)
        if state["carry_rows"] == 0 or state["completed_chunks"] == 0:
            return None
        pfx = _store()._PAYLOAD_PREFIX
        path = os.path.join(self.path, f"carry-{_slug(label)}.npz")
        with np.load(path, allow_pickle=False) as z:
            return {
                "key": z["key"], "eid": z["eid"], "valid": z["valid"],
                "payload": {k[len(pfx):]: z[k]
                            for k in z.files
                            if k.startswith(pfx)},
            }

    def mark_pass_done(self, label: str) -> None:
        """Commit the pass as fully resolved (all chunks committed)."""
        state = self.pass_state(label)
        state["done"] = True
        self.save()

    def _profile_path(self, label: str) -> str:
        return os.path.join(self.path, f"profile-{_slug(label)}.npz")

    def _pairs_path(self, label: str, chunk: int) -> str:
        return os.path.join(self.path,
                            f"pairs-{_slug(label)}-{chunk:06d}.npz")


def resume_stream(checkpoint_dir: str, *, chunks: Optional[Iterable] = None,
                  cfg=None, mesh=None, axis: str = "data"):
    """Resume a checkpointed ``resolve_stream`` run (== ``api.resume``).

    Loads the manifest, validates/rebuilds the config (``cfg`` is only
    required when the original run used a non-default matcher), and
    continues at the last committed chunk.  ``chunks`` must re-supply the
    original (deterministic) chunk iterator ONLY when the run died during
    ingest — after ingest the corpus is durable in the checkpoint and the
    iterator is not consulted.  Returns the same ``StreamResult`` an
    uninterrupted run would have returned, with a bit-identical pair
    union (invariant 11)."""
    ckpt = StreamCheckpoint.load(checkpoint_dir)
    cfg = ckpt.resolve_config(cfg)
    from repro.stream import resolver
    return resolver._resolve_checkpointed(chunks, cfg, ckpt, mesh=mesh,
                                          axis=axis, fault=None)
