"""``repro.resilience`` — fault tolerance for long-running resolution.

The paper's premise is cloud MapReduce, where worker failure is routine and
the framework re-executes lost tasks transparently (§2).  This package is
that guarantee for the repro: a killed run loses at most one chunk of work,
and a capacity overflow is RECOVERED instead of silently counted.

Three legs (DESIGN.md §11):

  * checkpoint    ``StreamCheckpoint`` — the versioned on-disk manifest
                  behind ``resolve_stream(checkpoint_dir=...)``: ingested
                  chunks, sorted runs, the merged ``KeyProfile``, the w-1
                  seam halo, and a per-chunk packed-pair spool, all written
                  crash-atomically after every completed chunk.
                  ``resume_stream`` (== ``api.resume``) picks a killed run
                  up at the last committed chunk; the resumed pair union is
                  bit-identical to an uninterrupted run (invariant 11).
  * retry         the ``ERConfig.on_overflow`` escalation ladder: a resolve
                  (or single stream chunk) whose finite caps overflowed is
                  re-executed with every overflowed cap doubled, up to
                  ``retry_limit`` rounds — power-of-two caps keep retried
                  shapes inside the ``repro.perf`` executable cache.
                  ``autosize_caps`` fills unset (None) caps from
                  ``balance.suggest_caps`` on the key profile.
  * faults        the deterministic ``FaultPlan`` injection harness the
                  kill/resume parity tests drive: crash-after-chunk-k,
                  crash-between-spool-and-commit, and a flaky chunk
                  iterator that dies mid-ingest.  ``ChaosPlan`` extends
                  the harness to the serving layer: latency spikes,
                  worker stalls, and matcher errors at exact micro-batch
                  indices (the overload property tests — DESIGN.md §13).

Serve-side durability (``SortedIndex.snapshot``/``restore``,
``ResolutionService.snapshot``/``restore``) lives in ``repro.serve`` and is
documented there.
"""
from repro.resilience.checkpoint import StreamCheckpoint, resume_stream
from repro.resilience.faults import (ChaosEvent, ChaosPlan, FaultPlan,
                                     InjectedFault, flaky_chunks,
                                     micro_caps)
from repro.resilience.retry import (CapacityOverflowError, ResilienceStats,
                                    autosize_caps, run_with_recovery)

__all__ = [
    "StreamCheckpoint", "resume_stream",
    "FaultPlan", "InjectedFault", "flaky_chunks", "micro_caps",
    "ChaosEvent", "ChaosPlan",
    "CapacityOverflowError", "ResilienceStats", "autosize_caps",
    "run_with_recovery",
]
