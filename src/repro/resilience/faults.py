"""Deterministic fault injection — the harness behind kill/resume tests.

Real preemption is nondeterministic; the parity tests need the opposite: a
crash at an EXACT point in the pipeline, repeatable for every chunk index.
``FaultPlan`` injects those crashes from inside ``resolve_stream``'s chunk
loop, and ``flaky_chunks`` wraps an ingest iterator so it dies mid-ingest —
together they cover every durability seam the checkpoint protocol has:

  * ``crash_after_chunk=k``    raise AFTER chunk k's checkpoint committed
                               (clean kill: resume continues at chunk k+1)
  * ``crash_before_commit=k``  raise after chunk k's pair spool was written
                               but BEFORE the manifest committed it (torn
                               kill: resume must redo chunk k, atomically
                               overwriting the orphaned spool file)
  * ``flaky_chunks(it, fail_after=j)``  the ingest iterator raises after
                               yielding j chunks (resume re-supplies the
                               iterator and skips the j committed chunks)

Overflow-forcing micro-caps are just configuration — build them with
``micro_caps``.  Injected crashes raise ``InjectedFault`` so tests can
catch exactly the planned failure and nothing else.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Iterator, Optional


class InjectedFault(RuntimeError):
    """A crash raised by a FaultPlan / flaky iterator (never by real code
    paths) — tests catch this exact type so an unplanned error still
    fails them loudly."""


@dataclass(frozen=True)
class FaultPlan:
    """Deterministic crash points for one streaming run (see module doc).

    Chunk indices are 0-based within the streaming pass named ``label``
    (None = any pass — single-pass runs have exactly one, labelled "key").
    A plan is consulted, never mutated: the resumed run simply passes no
    plan (or a different one) instead."""
    crash_after_chunk: Optional[int] = None
    crash_before_commit: Optional[int] = None
    label: Optional[str] = None

    def _matches(self, label: str) -> bool:
        return self.label is None or self.label == label

    def before_commit(self, label: str, chunk: int) -> None:
        """Called between a chunk's pair-spool write and its manifest
        commit — the torn-write injection point."""
        if self._matches(label) and self.crash_before_commit == chunk:
            raise InjectedFault(
                f"injected crash before committing chunk {chunk} "
                f"(pass {label!r}): spool written, manifest not updated")

    def after_commit(self, label: str, chunk: int) -> None:
        """Called after a chunk's checkpoint fully committed — the clean
        kill injection point."""
        if self._matches(label) and self.crash_after_chunk == chunk:
            raise InjectedFault(
                f"injected crash after committing chunk {chunk} "
                f"(pass {label!r})")


def flaky_chunks(chunks: Iterable[dict], fail_after: int) -> Iterator[dict]:
    """Wrap an ingest iterator to raise ``InjectedFault`` after yielding
    ``fail_after`` chunks — the mid-ingest kill.  The resumed run gets a
    FRESH (deterministic) iterator; the checkpoint skips the chunks it
    already committed."""
    for i, c in enumerate(chunks):
        if i == fail_after:
            raise InjectedFault(
                f"injected mid-ingest failure after {fail_after} chunks")
        yield c


def micro_caps(cfg, *, cand_cap: int = 2, pair_cap: int = 2):
    """An overflow-forcing config: absurdly small finite caps that make
    every realistic chunk overflow — the fixture the zero-dropped-pairs
    retry tests (and BENCH_resilience's retry column) run under."""
    return cfg.with_(cand_cap=cand_cap, pair_cap=pair_cap)
