"""Deterministic fault injection — the harness behind kill/resume tests.

Real preemption is nondeterministic; the parity tests need the opposite: a
crash at an EXACT point in the pipeline, repeatable for every chunk index.
``FaultPlan`` injects those crashes from inside ``resolve_stream``'s chunk
loop, and ``flaky_chunks`` wraps an ingest iterator so it dies mid-ingest —
together they cover every durability seam the checkpoint protocol has:

  * ``crash_after_chunk=k``    raise AFTER chunk k's checkpoint committed
                               (clean kill: resume continues at chunk k+1)
  * ``crash_before_commit=k``  raise after chunk k's pair spool was written
                               but BEFORE the manifest committed it (torn
                               kill: resume must redo chunk k, atomically
                               overwriting the orphaned spool file)
  * ``flaky_chunks(it, fail_after=j)``  the ingest iterator raises after
                               yielding j chunks (resume re-supplies the
                               iterator and skips the j committed chunks)

The SERVING layer generalizes the same idea past checkpoint labels:
``ChaosPlan`` injects latency spikes, worker stalls, and matcher errors
at exact micro-batch indices inside ``ResolutionService``'s batch-apply
path.  The service consults the plan BEFORE any state mutation, so an
injected error fails only the batch that hit it — the chaos property
tests sweep injection schedules against every ``queue_policy`` and
assert no future ever hangs or silently disappears (DESIGN.md §13).

Overflow-forcing micro-caps are just configuration — build them with
``micro_caps``.  Injected crashes raise ``InjectedFault`` so tests can
catch exactly the planned failure and nothing else.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Iterable, Iterator, Optional, Tuple

CHAOS_KINDS = ("latency", "stall", "error")


class InjectedFault(RuntimeError):
    """A crash raised by a FaultPlan / flaky iterator (never by real code
    paths) — tests catch this exact type so an unplanned error still
    fails them loudly."""


@dataclass(frozen=True)
class FaultPlan:
    """Deterministic crash points for one streaming run (see module doc).

    Chunk indices are 0-based within the streaming pass named ``label``
    (None = any pass — single-pass runs have exactly one, labelled "key").
    A plan is consulted, never mutated: the resumed run simply passes no
    plan (or a different one) instead."""
    crash_after_chunk: Optional[int] = None
    crash_before_commit: Optional[int] = None
    label: Optional[str] = None

    def _matches(self, label: str) -> bool:
        return self.label is None or self.label == label

    def before_commit(self, label: str, chunk: int) -> None:
        """Called between a chunk's pair-spool write and its manifest
        commit — the torn-write injection point."""
        if self._matches(label) and self.crash_before_commit == chunk:
            raise InjectedFault(
                f"injected crash before committing chunk {chunk} "
                f"(pass {label!r}): spool written, manifest not updated")

    def after_commit(self, label: str, chunk: int) -> None:
        """Called after a chunk's checkpoint fully committed — the clean
        kill injection point."""
        if self._matches(label) and self.crash_after_chunk == chunk:
            raise InjectedFault(
                f"injected crash after committing chunk {chunk} "
                f"(pass {label!r})")


@dataclass(frozen=True)
class ChaosEvent:
    """One injected disturbance at an exact serving micro-batch index.

    ``kind="latency"``  sleep ``seconds`` before the batch's delta call —
                        a straggler batch (inflates p95, drives the
                        brownout watermark) that still completes normally;
    ``kind="stall"``    same sleep, but sized to outlive the service's
                        ``batch_timeout_s`` — the watchdog fixture (a
                        stall without a watchdog is just a big latency);
    ``kind="error"``    raise ``InjectedFault`` — a matcher/delta error.
                        The service consults the plan before mutating any
                        state, so the error is request-level: the batch's
                        futures fail, the service keeps serving.
    """
    batch: int
    kind: str
    seconds: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in CHAOS_KINDS:
            raise ValueError(f"kind={self.kind!r} not in {CHAOS_KINDS}")
        if self.batch < 0 or self.seconds < 0:
            raise ValueError("batch and seconds must be >= 0")


@dataclass(frozen=True)
class ChaosPlan:
    """Deterministic disturbance schedule for one ``ResolutionService``
    (the serving analogue of ``FaultPlan``).  Batch indices are 0-based
    over the batches the service applies, in order — the same counter
    ``ServeStats.batches`` reports.  A plan is consulted, never mutated;
    ``on_batch`` is the single hook the service calls at the top of its
    batch-apply path."""
    events: Tuple[ChaosEvent, ...] = ()

    def on_batch(self, index: int) -> None:
        """Apply every event scheduled at ``index``: sleeps first (a
        stalled worker that THEN errors is the worst case), then at most
        one raise."""
        hit = [ev for ev in self.events if ev.batch == index]
        for ev in hit:
            if ev.kind in ("latency", "stall"):
                time.sleep(ev.seconds)
        for ev in hit:
            if ev.kind == "error":
                raise InjectedFault(
                    f"injected matcher error at serving batch {index}")


def flaky_chunks(chunks: Iterable[dict], fail_after: int) -> Iterator[dict]:
    """Wrap an ingest iterator to raise ``InjectedFault`` after yielding
    ``fail_after`` chunks — the mid-ingest kill.  The resumed run gets a
    FRESH (deterministic) iterator; the checkpoint skips the chunks it
    already committed."""
    for i, c in enumerate(chunks):
        if i == fail_after:
            raise InjectedFault(
                f"injected mid-ingest failure after {fail_after} chunks")
        yield c


def micro_caps(cfg, *, cand_cap: int = 2, pair_cap: int = 2):
    """An overflow-forcing config: absurdly small finite caps that make
    every realistic chunk overflow — the fixture the zero-dropped-pairs
    retry tests (and BENCH_resilience's retry column) run under."""
    return cfg.with_(cand_cap=cand_cap, pair_cap=pair_cap)
