"""Overflow recovery — the ``ERConfig.on_overflow`` escalation ladder.

The capacity knobs (``cand_cap``/``pair_cap``/``cap_factor``) buy static
shapes at the price of truncation: an overflowed buffer historically just
COUNTED its drops (``overflow``/``cand_overflow``/``pair_overflow``).  The
ladder turns that into MapReduce-style task re-execution: the affected
resolve (or the one overflowing stream chunk) reruns with every overflowed
finite cap doubled, up to ``cfg.retry_limit`` rounds.

Doubling is deliberate: caps stay on the power-of-two ladder above the base
cap, so across many chunks the retried executions collapse onto a handful
of ``static_fingerprint`` values and keep hitting the ``repro.perf``
executable cache (a per-overflow "exact" resize would trace a fresh
program per chunk).  A ladder that still overflows after ``retry_limit``
rounds raises ``CapacityOverflowError`` — under ``on_overflow="retry"`` a
result NEVER silently drops pairs.

``autosize_caps`` closes the loop on sizing: unset (None) caps are derived
from ``balance.suggest_caps`` on the key profile — the band bound that
provably cannot overflow under the planned loads — so the ladder is a
safety net for profile drift, not the primary sizing mechanism.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import numpy as np

from repro import balance as B
from repro import obs as OBS


class CapacityOverflowError(RuntimeError):
    """A finite capacity truncated the result and the policy forbids
    keeping it (``on_overflow="raise"``, or ``"retry"`` after the ladder
    was exhausted).  Carries the offending counters for diagnostics."""

    def __init__(self, msg: str, *, overflow: int = 0, cand_overflow: int = 0,
                 pair_overflow: int = 0, retries: int = 0):
        super().__init__(msg)
        self.overflow = overflow
        self.cand_overflow = cand_overflow
        self.pair_overflow = pair_overflow
        self.retries = retries


class ResilienceStats(NamedTuple):
    """Overflow-recovery telemetry of one resolve / streaming pass.

    retries       device re-executions the ladder performed
    escalations   individual cap doublings applied (>= retries: one retry
                  may double several overflowed caps at once)
    cand_cap /    the caps the FINAL (kept) execution ran under, post
    pair_cap      auto-sizing and escalation (0 = unbounded)
    auto_caps     True when unset caps were derived from the key profile
                  (``balance.suggest_caps``) rather than given explicitly
    """
    policy: str
    retries: int
    escalations: int
    cand_cap: int
    pair_cap: int
    auto_caps: bool


def _overflowed(out) -> bool:
    """Did any finite capacity truncate this outcome?"""
    return (int(out.overflow) > 0 or int(out.cand_overflow) > 0
            or int(out.pair_overflow) > 0)


def _escalated(cfg, out) -> Tuple[object, int]:
    """One ladder rung: double every finite cap whose buffer overflowed.
    Returns (new cfg, doublings applied).  Link-capacity overflow with
    ``cap_factor == 0`` counts as one escalation with no cfg change — the
    caller's ``call(cfg, attempt)`` closure lifts the plan's exact
    ``cap_link`` on retries (attempt > 0), which is the actual recovery."""
    kw = {}
    doublings = 0
    if int(out.cand_overflow) > 0 and (cfg.cand_cap or 0) > 0:
        kw["cand_cap"] = 2 * cfg.cand_cap
        doublings += 1
    if int(out.pair_overflow) > 0 and (cfg.pair_cap or 0) > 0:
        kw["pair_cap"] = 2 * cfg.pair_cap
        doublings += 1
    if int(out.overflow) > 0:
        if cfg.cap_factor > 0:
            kw["cap_factor"] = 2.0 * cfg.cap_factor
        doublings += 1
    return (cfg.with_(**kw) if kw else cfg), doublings


def run_with_recovery(call: Callable, cfg):
    """Execute ``call(cfg, attempt)`` under the ``cfg.on_overflow`` policy.

    ``call`` runs the resolve and returns any outcome carrying the three
    overflow counters (``RunnerOutcome``/``PackedOutcome``); ``attempt`` is
    0 for the first execution and increments per retry (callers use it to
    lift plan-exact ``cap_link`` capacities the cfg cannot express).

    Returns ``(outcome, cfg_used, retries, escalations)`` where ``cfg_used``
    is the (possibly escalated) config of the kept execution.  Raises
    ``CapacityOverflowError`` under policy "raise" (immediately) or "retry"
    (after ``cfg.retry_limit`` fruitless rounds).

    Under an active tracer every ladder rung runs inside an ``attempt``
    child span (attempt index, the caps it ran under, whether it
    overflowed), and retries/overflow events land on the tracer's
    counters — the DESIGN.md §12 view of the recovery ladder."""

    def _call(c, attempt: int):
        sp = OBS.span("attempt", attempt=attempt,
                      cand_cap=getattr(c, "cand_cap", 0) or 0,
                      pair_cap=getattr(c, "pair_cap", 0) or 0)
        with sp:
            o = call(c, attempt)
            if sp.enabled:
                over = _overflowed(o)
                sp.set(overflowed=over)
                m = OBS.current_tracer().metrics
                if over:
                    m.counter("overflow_events").inc()
                if attempt > 0:
                    m.counter("retries").inc()
        return o

    out = _call(cfg, 0)
    if cfg.on_overflow == "count" or not _overflowed(out):
        return out, cfg, 0, 0
    if cfg.on_overflow == "raise":
        raise CapacityOverflowError(
            f"capacity overflow under on_overflow='raise': "
            f"overflow={int(out.overflow)} "
            f"cand_overflow={int(out.cand_overflow)} "
            f"pair_overflow={int(out.pair_overflow)}; raise the caps or "
            f"use on_overflow='retry'",
            overflow=int(out.overflow), cand_overflow=int(out.cand_overflow),
            pair_overflow=int(out.pair_overflow))
    retries = escalations = 0
    while _overflowed(out) and retries < cfg.retry_limit:
        nxt, doublings = _escalated(cfg, out)
        if doublings == 0:
            break          # nothing left to escalate: fail loudly below
        cfg = nxt
        retries += 1
        escalations += doublings
        out = _call(cfg, retries)
    if _overflowed(out):
        raise CapacityOverflowError(
            f"capacity overflow survived {retries} retry escalation(s) "
            f"(retry_limit={cfg.retry_limit}): "
            f"overflow={int(out.overflow)} "
            f"cand_overflow={int(out.cand_overflow)} "
            f"pair_overflow={int(out.pair_overflow)}; raise retry_limit or "
            f"the base caps",
            overflow=int(out.overflow), cand_overflow=int(out.cand_overflow),
            pair_overflow=int(out.pair_overflow), retries=retries)
    return out, cfg, retries, escalations


def autosize_caps(cfg, *, plan=None, profile: Optional[B.KeyProfile] = None,
                  r: Optional[int] = None, floor_load: int = 0):
    """Resolve unset (None) caps to concrete ints before any runner call.

    When a profile-backed plan (``planned_load``) or a merged ``KeyProfile``
    is available, unset caps become ``balance.suggest_caps``'s band bound —
    the (w-1)*max_load + slack capacity that cannot overflow under the
    planned loads.  Without one (legacy partitioners, raw bounds), unset
    caps fall back to 0 = the legacy unbounded/full-band semantics, so
    nothing changes for runs that never had a profile.  Only caps the
    config actually consumes are sized (``cand_cap`` on the pallas engine,
    ``pair_cap`` under emit="pairs") — everything else resolves to 0 and
    keeps its pre-auto executable-cache fingerprint.

    ``floor_load`` raises the sizing load to at least that many rows — the
    stream passes its combined [halo | chunk] width, because a degenerate
    (collapsed) chunk lands whole on a single shard regardless of the
    planned per-shard loads.

    Returns ``(cfg with int caps, auto: bool)``."""
    need_cand = cfg.cand_cap is None and cfg.band_engine == "pallas"
    need_pair = cfg.pair_cap is None and cfg.emit == "pairs"
    fill = {}
    auto = False
    if need_cand or need_pair:
        max_load = None
        if plan is not None and getattr(plan, "planned_load", None) \
                is not None:
            max_load = int(np.max(np.asarray(plan.planned_load))) \
                + cfg.window - 1
        elif profile is not None and profile.n > 0:
            max_load = B.suggest_caps(profile, cfg, r).max_load
        if max_load is not None:
            caps = B.suggest_caps(profile, cfg, r,
                                  max_load=max(max_load, floor_load))
            auto = True
            if need_cand:
                fill["cand_cap"] = caps.cand_cap
            if need_pair:
                fill["pair_cap"] = caps.pair_cap
    if cfg.cand_cap is None and "cand_cap" not in fill:
        fill["cand_cap"] = 0
    if cfg.pair_cap is None and "pair_cap" not in fill:
        fill["pair_cap"] = 0
    return (cfg.with_(**fill) if fill else cfg), auto
